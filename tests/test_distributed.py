"""Multi-device semantics (8 fake CPU devices via a subprocess, so the main
pytest process keeps its single-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout=600, env_overrides: dict | None = None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.update(env_overrides or {})
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=timeout,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_hierarchical_collectives_match_flat():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.shard_compat import SM_CHECK_KW, shard_map
        from repro.distributed.collectives import (
            hierarchical_all_reduce, hierarchical_all_to_all)
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        sm = lambda f, i, o: shard_map(f, mesh=mesh, in_specs=i, out_specs=o, **SM_CHECK_KW)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 37)), jnp.float32)
        h = sm(lambda v: hierarchical_all_reduce(v, "data", "pod"), P(("pod","data")), P(("pod","data")))(x)
        f = sm(lambda v: jax.lax.psum(v, ("pod","data")), P(("pod","data")), P(("pod","data")))(x)
        assert float(jnp.abs(h - f).max()) < 1e-5
        y = jnp.asarray(np.random.default_rng(1).normal(size=(64, 5)), jnp.float32)
        ha = sm(lambda v: hierarchical_all_to_all(v, "data", "pod"), P(("pod","data")), P(("pod","data")))(y)
        fa = sm(lambda v: jax.lax.all_to_all(v.reshape(8,1,5), ("pod","data"), 0, 0).reshape(8,5),
                P(("pod","data")), P(("pod","data")))(y)
        assert float(jnp.abs(ha - fa).max()) == 0.0
        print("OK")
    """)


def test_ef_compression_unbiased_over_time():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core.shard_compat import SM_CHECK_KW, shard_map
        from repro.distributed.collectives import ef_all_reduce
        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        g = jnp.asarray(np.random.default_rng(2).normal(size=(8, 16)), jnp.float32)
        step = shard_map(lambda gg, ee: ef_all_reduce(gg, ee, "pod"), mesh=mesh,
            in_specs=(P(("pod","data")), P(("pod","data"))),
            out_specs=(P(("pod","data")), P(("pod","data"))), **SM_CHECK_KW)
        true = shard_map(lambda gg: jax.lax.pmean(gg, "pod"), mesh=mesh,
            in_specs=P(("pod","data")), out_specs=P(("pod","data")), **SM_CHECK_KW)(g)
        err = jnp.zeros_like(g); acc = jnp.zeros_like(g)
        for _ in range(20):
            red, err = step(g, err); acc += red
        one_shot = float(jnp.abs(step(g, jnp.zeros_like(g))[0] - true).max())
        avged = float(jnp.abs(acc / 20 - true).max())
        assert avged < one_shot / 5, (avged, one_shot)  # error feedback integrates away
        print("OK")
    """)


def test_moe_sharded_matches_reference_both_modes():
    _run("""
        import jax, jax.numpy as jnp
        from repro.models.moe import init_moe, moe_reference, moe_block_sharded
        from repro.configs.base import ModelConfig
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        cfg = ModelConfig(d_model=32, n_experts=8, top_k=2, moe_d_ff=16, capacity_factor=8.0)
        params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        for shape in ((4, 8, 32), (8, 1, 32), (3, 1, 32)):
            x = jax.random.normal(jax.random.PRNGKey(shape[0]), shape, jnp.float32)
            y_ref, aux_r = moe_reference(params, x.reshape(-1, 32), cfg)
            y_sh, aux_s = jax.jit(lambda p, xx: moe_block_sharded(p, xx, cfg, mesh))(params, x)
            err = float(jnp.abs(y_ref.reshape(shape) - y_sh).max())
            assert err < 1e-5, (shape, err)
            assert bool((aux_r["load"] == aux_s["load"]).all()), shape
        print("OK")
    """)


def test_sharded_event_engine_matches_local():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.tags import NetworkSpec, compile_network
        from repro.core.event_engine import EventEngine
        from repro.core.neuron import NeuronState
        rng = np.random.default_rng(0)
        spec = NetworkSpec(n_neurons=64, cluster_size=8, k_tags=64, max_cam_words=32, max_sram_entries=16)
        seen = set()
        for _ in range(80):
            s, d = int(rng.integers(64)), int(rng.integers(64))
            if (s, d) in seen: continue
            seen.add((s, d)); spec.connect(s, d, int(rng.integers(4)))
        tables = compile_network(spec)
        eng = EventEngine(tables)
        mesh = jax.make_mesh((4,), ("data",))
        sharded = eng.make_sharded_step(mesh, "data")
        carry = eng.init_state()
        state, prev = carry
        inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
        for _ in range(10):
            (state_l, prev_l), spikes_l = eng.step((state, prev), inp)
            state_s, spikes_s = sharded(eng.tables, state, prev, inp, jnp.zeros((64,)))
            assert float(jnp.abs(spikes_l - spikes_s).max()) < 1e-6
            assert float(jnp.abs(state_l.v - state_s.v).max()) < 1e-6
            state, prev = state_l, spikes_l
        print("OK")
    """)


def test_sharded_event_engine_batched_2d_mesh():
    """Batched make_sharded_step on a 2-D (batch x cluster) mesh matches the
    local batched engine step: streams shard over `data`, clusters over
    `model`, stage-1 reduce-scatter runs per-stream."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.tags import NetworkSpec, compile_network
        from repro.core.event_engine import EventEngine
        rng = np.random.default_rng(0)
        spec = NetworkSpec(n_neurons=64, cluster_size=8, k_tags=64, max_cam_words=32, max_sram_entries=16)
        seen = set()
        for _ in range(80):
            s, d = int(rng.integers(64)), int(rng.integers(64))
            if (s, d) in seen: continue
            seen.add((s, d)); spec.connect(s, d, int(rng.integers(4)))
        tables = compile_network(spec)
        eng = EventEngine(tables)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sharded = eng.make_sharded_step(mesh, "model", batch_axis="data")
        b = 4
        state, prev = eng.init_state(batch=b)
        inp = jnp.zeros((b, tables.n_clusters, tables.k_tags))
        for stream in range(b):  # heterogeneous stimuli per stream
            inp = inp.at[stream, stream % tables.n_clusters, :4].set(4.0)
        i_ext = jnp.zeros((b, 64))
        for _ in range(10):
            (state_l, prev_l), spikes_l = eng.step((state, prev), inp)
            state_s, spikes_s = sharded(eng.tables, state, prev, inp, i_ext)
            assert float(jnp.abs(spikes_l - spikes_s).max()) < 1e-6
            assert float(jnp.abs(state_l.v - state_s.v).max()) < 1e-6
            state, prev = state_l, spikes_l
        print("OK")
    """)


def test_fabric_sharded_step_matches_local_multidevice():
    """Tiles -> devices (DESIGN.md §11): the fabric-mode sharded step on a
    4-device cluster axis matches the local fabric engine bit-for-bit —
    time-wheel arrivals (ring sharded over clusters, cursor replicated),
    link-FIFO drops, and the psum-reduced stats."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.routing import ChipConstants, Fabric
        from repro.core.tags import NetworkSpec, compile_network
        from repro.core.event_engine import EventEngine
        dt = 1e-3
        const = ChipConstants(latency_across_chip_s=2 * dt)
        fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2, constants=const)
        rng = np.random.default_rng(0)
        spec = NetworkSpec(n_neurons=64, cluster_size=8, k_tags=64,
                           max_cam_words=32, max_sram_entries=16)
        seen = set()
        for _ in range(90):
            s, d = int(rng.integers(64)), int(rng.integers(64))
            if (s, d) in seen: continue
            seen.add((s, d)); spec.connect(s, d, int(rng.integers(4)))
        tables = compile_network(spec, fabric=fab)
        eng = EventEngine(tables, fabric=fab,
                          fabric_options={"dt": dt, "link_capacity": 2})
        mesh = jax.make_mesh((4,), ("model",))  # 1 tile per device
        sharded = eng.make_sharded_step(mesh, "model")
        state, prev, ring, cur = eng.init_state()
        prev = prev.at[jnp.arange(0, 64, 2)].set(1.0)
        inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
        saw_drop = saw_arrival = False
        for _ in range(8):
            (st_l, sp_l, ring_l, cur_l), (_, stats_l) = eng.step(
                (state, prev, ring, cur), inp)
            st_s, sp_s, ring_s, cur_s, stats_s = sharded(
                eng.tables, state, prev, ring, cur, inp, jnp.zeros((64,)))
            assert float(jnp.abs(sp_l - sp_s).max()) < 1e-6
            assert float(jnp.abs(ring_l - ring_s).max()) < 1e-6
            assert int(cur_l) == int(cur_s)
            assert float(jnp.abs(st_l.v - st_s.v).max()) < 1e-6
            for f in ("dropped", "link_dropped", "delivered", "hops"):
                assert int(getattr(stats_l, f)) == int(getattr(stats_s, f)), f
            assert abs(float(stats_l.energy_j) - float(stats_s.energy_j)) < 1e-12
            saw_drop |= int(stats_l.link_dropped) > 0
            saw_arrival |= float(ring_l.sum()) > 0
            state, prev, ring, cur = st_l, sp_l, ring_l, cur_l
        assert saw_drop and saw_arrival  # the interesting paths actually ran
        print("OK")
    """)


def test_dryrun_cell_on_test_mesh():
    """run_cell end-to-end on a (2,2,2) mesh with a smoke config — proves the
    lower+compile+analysis pipeline independent of the 512-device sweep.

    Pinned to x64-off: under JAX_ENABLE_X64=1 the LM cell's scan-over-periods
    trips an s64/s32 index-dtype mismatch inside XLA's SPMD partitioner
    (jaxlib-level; unrelated to what this test covers), so the CI x64 variant
    would fail here spuriously."""
    _run(env_overrides={"JAX_ENABLE_X64": "0"}, body="""
        from repro.configs import get_config, Shape
        from repro.launch import dryrun as dr
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2,2,2), ("pod","data","model"))
        cfg = get_config("deepseek-v3-671b", smoke=True)
        r = dr.run_cell("deepseek-v3-671b", Shape("train_4k", 32, 8, "train"),
                        multi_pod=True, save=False, mesh=mesh, cfg=cfg)
        assert r["roofline"]["compute_s"] > 0
        assert r["collective_bytes_per_device"]["total"] > 0
        assert r["memory"]["temp_size_in_bytes"] > 0
        print("OK")
    """, timeout=900)


def test_elastic_remesh_restore():
    """Checkpoint written under one mesh restores onto a different mesh."""
    _run("""
        import jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.launch.mesh import make_mesh
        mesh_a = make_mesh((2, 4), ("data", "model"))
        mesh_b = make_mesh((4, 2), ("data", "model"))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d)
            ck.save(1, {"w": xa}, blocking=True)
            out = ck.restore(1, {"w": x},
                             shardings={"w": NamedSharding(mesh_b, P("data", "model"))})
            assert out["w"].sharding.mesh.shape["data"] == 4
            assert float(jnp.abs(out["w"] - x).max()) == 0.0
        print("OK")
    """)
