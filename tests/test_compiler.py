"""Routing compiler v2 (DESIGN.md §13): conformance, placement, diagnostics.

The contract under test:

  * **Differential conformance** — for any NetworkSpec that v1 compiles, v2
    (conflict-graph tag reuse) emits tables with the *bit-exact* dense
    connectivity (multiset of (src, dst, syn) rows, multiplicity included)
    and never spends more tags, SRAM entries, or CAM words than v1.
    Property-based over hypothesis-generated random specs, plus fixed-seed
    differential runs through the reference / fused / fabric engine
    backends asserting spike-by-spike parity against each other and the
    dense oracle.
  * **Tag reuse unlocks capacity** — the benchmark's two-groups-per-source
    topology overflows v1's K but compiles under v2 with the same K.
  * **Traffic-aware placement** — on the Table-IV geometry (4x4 mesh of
    4-core tiles) the optimizer cuts *measured* mean mesh hops vs the
    hierarchical-linear default by >= 1.3x, and the device-slab-constrained
    mode produces placements the sharded fabric step accepts.
  * **Diagnostics** — tag/SRAM/CAM overflow errors name the offending
    cluster/neuron and the binding constraint; CompileReport matches a
    hand-counted 2-cluster example.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.core import memory_model as mm
from repro.core.compiler import (
    CompileResult,
    build_report,
    compile_network_v2,
    optimize_placement,
    placement_cost,
    traffic_matrix,
)
from repro.core.event_engine import EventEngine
from repro.core.routing import ChipConstants, Fabric, tile_hop_matrix
from repro.core.tags import NetworkSpec, SynapseType, compile_network


def _random_spec(seed, n=64, cluster=16, k=96, edges=40, groups=12):
    """Random mix of point connections and (shared / per-source) groups with
    repeated source sets — the structures tag reuse must stay exact on."""
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(
        n_neurons=n, cluster_size=cluster, k_tags=k,
        max_cam_words=64, max_sram_entries=16,
    )
    for _ in range(edges):
        spec.connect(int(rng.integers(n)), int(rng.integers(n)), int(rng.integers(4)))
    # a few source populations, each reused by 1-3 groups (identical source
    # sets are exactly what the conflict-graph pass merges)
    pops = [
        tuple(int(s) for s in rng.choice(n, size=int(rng.integers(1, 5)), replace=False))
        for _ in range(4)
    ]
    for _ in range(groups):
        srcs = pops[int(rng.integers(len(pops)))]
        tgts = [
            (int(rng.integers(n)), int(rng.integers(4)))
            for _ in range(int(rng.integers(1, 4)))
        ]
        spec.connect_group(
            srcs, tgts,
            shared_tag=bool(rng.integers(2)),
            copies=int(rng.integers(1, 3)),
        )
    return spec


def _resources(tables):
    src_tag = np.asarray(tables.src_tag)
    src_dest = np.asarray(tables.src_dest)
    src, ent = np.nonzero(src_tag >= 0)
    tags = len({(int(src_dest[i, e]), int(src_tag[i, e])) for i, e in zip(src, ent)})
    return (
        tags,
        int((src_tag >= 0).sum()),
        int((np.asarray(tables.cam_tag) >= 0).sum()),
    )


def _assert_v2_conforms(spec):
    t1 = compile_network(spec, allocator="greedy")
    t2 = compile_network(spec, allocator="reuse")
    # bit-exact connectivity, multiplicity included (rows come sorted)
    np.testing.assert_array_equal(t1.dense_equivalent(), t2.dense_equivalent())
    tags1, sram1, cam1 = _resources(t1)
    tags2, sram2, cam2 = _resources(t2)
    assert tags2 <= tags1, "v2 spent more tags than v1"
    assert sram2 <= sram1, "v2 spent more SRAM entries than v1"
    assert cam2 <= cam1, "v2 spent more CAM words than v1"
    return t1, t2


@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_property_v2_bit_exact_and_never_more_memory(seed):
    _assert_v2_conforms(_random_spec(seed))


def test_fixed_seeds_v2_bit_exact_and_never_more_memory():
    """Deterministic slice of the property above (runs without hypothesis)."""
    saved = False
    for seed in (0, 1, 2, 3, 7, 11):
        t1, t2 = _assert_v2_conforms(_random_spec(seed))
        saved |= _resources(t2)[0] < _resources(t1)[0]
    assert saved, "no seed exercised actual tag reuse — generator regressed"


def _step_all_backends(tables, fabric=None):
    """One engine step per backend from an all-sources-spiking carry."""
    const = ChipConstants(latency_across_chip_s=0.0)  # fabric: zero-warp parity
    outs = {}
    for name, kwargs in (
        ("reference", {}),
        ("fused", {"backend": "fused"}),
        ("fabric", {"fabric": Fabric(grid_x=2, grid_y=2, cores_per_tile=1,
                                     constants=const)}),
    ):
        eng = EventEngine(tables, **kwargs)
        carry = eng.init_state()
        carry = (carry[0], jnp.ones_like(carry[1]), *carry[2:])
        inp = jnp.zeros((eng.n_clusters, eng.k_tags))
        _, out = eng.step(carry, inp)
        outs[name] = np.asarray(out[0] if isinstance(out, tuple) else out)
    return outs


def test_differential_delivery_parity_across_backends():
    """v1 and v2 tables drive bit-identical spikes through the reference,
    fused, and fabric backends, all matching the dense oracle."""
    from repro.core.event_engine import (
        dense_reference_step,
        dense_weights_from_tables,
    )
    from repro.core.neuron import NeuronParams

    spec = _random_spec(5, n=16, cluster=4, k=64, edges=24, groups=8)
    t1 = compile_network(spec, allocator="greedy")
    t2 = compile_network(spec, allocator="reuse")
    outs1 = _step_all_backends(t1)
    outs2 = _step_all_backends(t2)
    for name in outs1:
        np.testing.assert_array_equal(outs1[name], outs2[name], err_msg=name)
        np.testing.assert_array_equal(outs1["reference"], outs1[name], err_msg=name)
        np.testing.assert_array_equal(outs2["reference"], outs2[name], err_msg=name)
    # dense oracle on the v2 tables agrees with the routed path
    dense_w = jnp.asarray(dense_weights_from_tables(t2))
    eng = EventEngine(t2)
    state, _ = eng.init_state()
    spikes = jnp.ones((t2.n_neurons,))
    _, dense_spikes = dense_reference_step(
        dense_w, spikes, state, NeuronParams()
    )
    np.testing.assert_array_equal(outs2["reference"], np.asarray(dense_spikes))


# ---------------------------------------------------------------------------
# tag reuse unlocks capacity (the acceptance topology)
# ---------------------------------------------------------------------------
def _two_groups_per_source_spec(nc=4, cl=8, k=8):
    """Shrunk benchmark topology (routing_throughput ``_compiler_net``):
    every source fires two connect-groups into one destination cluster, so
    v1 needs 2 tags/source = 2*cl per cluster while v2 needs cl."""
    rng = np.random.default_rng(17)
    perm = rng.permutation(nc)
    spec = NetworkSpec(n_neurons=nc * cl, cluster_size=cl, k_tags=k)
    want = []
    for s in range(spec.n_neurons):
        dst_cl = int(perm[s // cl])
        for syn in (0, 1):
            dsts = dst_cl * cl + rng.choice(cl, size=2, replace=False)
            spec.connect_one_to_many(s, [int(d) for d in dsts], syn)
            want += [(s, int(d), syn) for d in dsts]
    return spec, sorted(want)


def test_v1_tag_overflow_topology_compiles_under_v2_same_k():
    spec, want = _two_groups_per_source_spec(nc=4, cl=8, k=8)
    with pytest.raises(ValueError, match="tag overflow"):
        compile_network(spec)  # v1: needs 16 tags/cluster, K=8
    tables = compile_network(spec, allocator="reuse")  # v2: 8 tags fit K=8
    assert tables.k_tags == spec.k_tags  # unchanged K
    got = [tuple(int(x) for x in row) for row in tables.dense_equivalent()]
    assert got == want
    tags_used, _, _ = _resources(tables)
    assert tags_used == 4 * 8  # one tag per source, every cluster full


# ---------------------------------------------------------------------------
# traffic-aware placement
# ---------------------------------------------------------------------------
def _shuffle_net(fabric, cl=4, k=64, seed=17):
    """Permutation traffic on the fabric's geometry: cluster c fans into
    cluster perm(c) — structured communication the linear default scatters
    across the mesh."""
    rng = np.random.default_rng(seed)
    nc = fabric.n_cores
    perm = rng.permutation(nc)
    spec = NetworkSpec(n_neurons=nc * cl, cluster_size=cl, k_tags=k)
    for s in range(spec.n_neurons):
        dst_cl = int(perm[s // cl])
        dsts = dst_cl * cl + rng.choice(cl, size=min(4, cl), replace=False)
        spec.connect_one_to_many(s, [int(d) for d in dsts], int(rng.integers(4)))
    return spec


def _measured_mean_hops(tables, fabric):
    eng = EventEngine(tables, fabric=fabric)
    state, spikes, *delay = eng.init_state()
    carry = (state, jnp.ones_like(spikes), *delay)
    _, (_, stats) = eng.step(
        carry, jnp.zeros((tables.n_clusters, tables.k_tags))
    )
    return float(np.asarray(stats.hops)) / float(np.asarray(stats.delivered))


def test_optimized_placement_cuts_measured_hops_1p3x_table4_geometry():
    """Acceptance: >= 1.3x fewer measured mean mesh hops than
    default_tile_of_cluster on the Table-IV geometry (4x4 mesh, 4-core
    tiles), through the executable fabric's own hop accounting."""
    fab = Fabric(grid_x=4, grid_y=4, cores_per_tile=4)
    spec = _shuffle_net(fab)
    tables_def = compile_network(spec, fabric=fab)  # hierarchical linear
    res = compile_network_v2(spec, fabric=fab, seed=0)
    hops_def = _measured_mean_hops(tables_def, fab)
    hops_opt = _measured_mean_hops(res.tables, fab)
    assert hops_def / hops_opt >= 1.3, (hops_def, hops_opt)
    # the report's traffic-weighted prediction matches the measurement
    # (uniform rates = one event per SRAM entry, exactly what the step did)
    assert res.report.mean_hops == pytest.approx(hops_opt, rel=1e-6)
    rep_def = build_report(spec, tables_def, fabric=fab)
    assert rep_def.mean_hops == pytest.approx(hops_def, rel=1e-6)


def test_optimize_placement_respects_capacity_and_determinism():
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=4)
    spec = _shuffle_net(fab, cl=2, k=32, seed=3)
    tables = compile_network(spec)
    t = traffic_matrix(tables)
    p1, info1 = optimize_placement(t, fab, seed=42)
    p2, _ = optimize_placement(t, fab, seed=42)
    np.testing.assert_array_equal(p1, p2)  # deterministic per seed
    assert np.bincount(p1, minlength=fab.n_tiles).max() <= fab.cores_per_tile
    assert info1["cost_final"] <= info1["cost_init"]
    assert info1["cost_final"] == pytest.approx(
        placement_cost(t, tile_hop_matrix(fab).astype(float), p1)
    )


def test_device_slab_placement_runs_sharded_fabric():
    """device_slabs-constrained placements satisfy the sharded fabric step's
    no-split-tiles invariant end-to-end (and unconstrained ones need not)."""
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    spec = _shuffle_net(fab, cl=8, k=64, seed=9)
    res = compile_network_v2(spec, fabric=fab, seed=1, device_slabs=2)
    eng = EventEngine(res.tables, fabric=fab)
    mesh = jax.make_mesh((1,), ("model",))
    # the 2-slab invariant holds, so forcing the 2-device view must not raise
    step = eng._make_sharded_fabric_step(mesh, "model", None, 2, None)
    sharded_1dev = eng.make_sharded_step(mesh, axis="model")
    state, prev, ring, cur = eng.init_state()
    prev = prev.at[jnp.arange(0, res.tables.n_neurons, 3)].set(1.0)
    inp = jnp.zeros((res.tables.n_clusters, res.tables.k_tags))
    (st_l, sp_l, ring_l, cur_l), (_, stats_l) = eng.step(
        (state, prev, ring, cur), inp
    )
    st_s, sp_s, ring_s, cur_s, stats_s = sharded_1dev(
        eng.tables, state, prev, ring, cur, inp,
        jnp.zeros((res.tables.n_neurons,)),
    )
    np.testing.assert_allclose(np.asarray(sp_l), np.asarray(sp_s), atol=1e-6)
    np.testing.assert_allclose(np.asarray(ring_l), np.asarray(ring_s), atol=1e-6)
    assert int(cur_l) == int(cur_s)
    assert int(stats_l.delivered) == int(stats_s.delivered)
    assert step is not None


def test_engine_accepts_compile_result_directly():
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    spec = _shuffle_net(fab, cl=2, k=32, seed=4)
    res = compile_network_v2(spec, fabric=fab)
    assert isinstance(res, CompileResult)
    eng = EventEngine(res, fabric=fab)  # CompileResult unwraps to its tables
    assert eng.n_neurons == res.tables.n_neurons
    np.testing.assert_array_equal(
        eng.fabric_model.tile_of_cluster, res.tables.tile_of_cluster
    )


# ---------------------------------------------------------------------------
# diagnostics + report
# ---------------------------------------------------------------------------
def test_tag_overflow_diagnostics_name_cluster_and_constraint():
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=2, max_cam_words=8)
    for s in range(3):
        spec.connect(s, 16)
    with pytest.raises(ValueError, match=r"tag overflow in cluster 2.*K=2"):
        compile_network(spec)
    # v2's overflow names the distinct-source-set pressure
    spec2 = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=2, max_cam_words=8)
    for s in range(3):
        spec2.connect(s, 16 + s)
    with pytest.raises(ValueError, match=r"cluster 2.*distinct source sets"):
        compile_network(spec2, allocator="reuse")
    with pytest.raises(ValueError, match="unknown allocator"):
        compile_network(spec2, allocator="v3")


def test_sram_overflow_diagnostics_name_source_and_constraint():
    spec = NetworkSpec(
        n_neurons=32, cluster_size=8, k_tags=32, max_cam_words=8,
        max_sram_entries=2,
    )
    for dst in (0, 8, 16):  # three destination clusters > 2 SRAM entries
        spec.connect(1, dst)
    with pytest.raises(
        ValueError, match=r"source 1 \(cluster 0\).*F/M=2.*max_sram_entries"
    ):
        compile_network(spec)


def test_cam_overflow_diagnostics_name_neuron_and_constraint():
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=32, max_cam_words=2)
    for s in range(3):
        spec.connect(s, 17)
    with pytest.raises(
        ValueError, match=r"neuron 17 \(cluster 2\).*CAM capacity 2.*max_cam_words"
    ):
        compile_network(spec)


def test_compile_report_matches_hand_counted_two_cluster_example():
    """2-cluster network small enough to count on paper (see inline math)."""
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8, max_cam_words=8,
                       max_sram_entries=4)
    # two shared groups with the SAME source set {0,1} -> v2 shares one tag
    spec.connect_group([0, 1], [(4, SynapseType.FAST_EXC),
                                (5, SynapseType.SLOW_EXC)])
    spec.connect_group([0, 1], [(6, SynapseType.SUB_INH)])
    spec.connect(2, 3)
    res = compile_network_v2(spec)  # no fabric: report only
    rep = res.report
    np.testing.assert_array_equal(rep.tags_used, [1, 1])  # v2: one tag each
    np.testing.assert_array_equal(rep.tags_v1, [1, 2])  # v1: 2 units in cl 1
    np.testing.assert_array_equal(rep.sram_fill, [1, 1, 1, 0, 0, 0, 0, 0])
    np.testing.assert_array_equal(rep.cam_fill, [0, 0, 0, 1, 1, 1, 1, 0])
    # 3 SRAM entries x (log2 8 + log2 2) = 12; 4 CAM words x (log2 8 + 2) = 20
    assert rep.sram_bits == 12 and rep.cam_bits == 20
    assert rep.measured_bits_per_neuron == pytest.approx(32 / 8)
    # empirical eq.(2): 7 connections (2 sources x audience 3, 1 x 1) ->
    # F = 7/8, M = 7/3 mean audience per entry
    assert rep.eq2_bits_per_neuron == pytest.approx(
        mm.mem_total_bits(n=8, f=7 / 8, c=4, m=7 / 3, k=8)
    )
    assert rep.mean_hops is None  # no fabric, no placement
    assert "tags/cluster" in rep.summary()


def test_poker_cnn_compiles_through_v2_with_report():
    """The Table-V CNN through the v2 allocator: bit-exact vs greedy, and
    the report sees the reuse (Hebbian fc_select repeats pool sources)."""
    from repro.core.cnn import compile_poker_cnn

    cc1 = compile_poker_cnn()
    rng = np.random.default_rng(0)
    fc = np.stack([rng.choice(256, size=64, replace=False) for _ in range(4)])
    cc2 = compile_poker_cnn(fc_select=fc, allocator="reuse", with_report=True)
    np.testing.assert_array_equal(
        compile_poker_cnn(fc_select=fc).tables.dense_equivalent(),
        cc2.tables.dense_equivalent(),
    )
    rep = cc2.report
    assert rep is not None
    assert int(rep.tags_used.sum()) <= int(rep.tags_v1.sum())
    # random fc_select overlaps between classes -> real sharing
    assert int(rep.tags_used.sum()) < int(rep.tags_v1.sum())
    assert cc1.report is None  # report is opt-in
