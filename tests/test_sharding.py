"""Sharding resolver + q8 codec unit/property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd
from repro.train.optimizer import _q8_decode, _q8_encode


class _FakeMesh:
    """Duck-typed mesh: resolver only touches .shape."""

    def __init__(self, shape: dict):
        self.shape = shape


MESH = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_heads_shard_when_divisible():
    spec = shd.resolve(("embed", "heads", "head_dim"), (4608, 32, 128), MESH)
    assert spec == P(None, "model", None)


def test_small_attention_replicates_not_row_parallel():
    """gemma3-1b: 4 heads, tiny weight -> fully replicated (B1 policy)."""
    spec = shd.resolve(("embed", "heads", "head_dim"), (1152, 4, 256), MESH)
    assert spec == P(None, None, None)


def test_large_non_divisible_heads_fall_back_to_embed():
    """yi-34b: 56 heads, 51M elements -> row-parallel on embed."""
    spec = shd.resolve(("embed", "heads", "head_dim"), (7168, 56, 128), MESH)
    assert spec == P("model", None, None)


def test_experts_prefer_widest_mesh():
    spec = shd.resolve((None, "experts", "embed", "mlp"), (58, 256, 7168, 2048), MESH)
    assert spec[1] == ("data", "model")  # EP256 in-pod
    spec64 = shd.resolve((None, "experts", "embed", "mlp"), (27, 64, 2048, 1408), MESH)
    assert spec64[1] == "model"  # 64 experts -> EP16


def test_vocab_in_never_shards_vocab():
    spec = shd.resolve(("vocab_in", "embed"), (129280, 7168), MESH)
    assert spec == P(None, "model")
    out = shd.resolve(("vocab", "embed"), (129280, 7168), MESH)
    assert out == P("model", None)


def test_batch_pspec_degrades_gracefully():
    assert shd.batch_pspec(256, MESH) == P(("pod", "data"))
    assert shd.batch_pspec(16, MESH) == P(("data",))  # 16 % 32 != 0
    assert shd.batch_pspec(1, MESH) == P(None)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 8))
    y = shd.constrain(x, ("batch", "model"))
    assert y is x


# ---------------------------------------------------------------------------
@given(
    shape=st.sampled_from([(7,), (3, 5), (2, 4, 300), (1, 257), (256,), (2, 512)]),
    seed=st.integers(0, 100),
)
@settings(max_examples=40, deadline=None)
def test_q8_roundtrip_error_bound(shape, seed):
    """Blockwise int8: |x - dec(enc(x))| <= scale/2 = max|block|/254."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=shape) * 10.0, jnp.float32)
    enc = _q8_encode(x)
    dec = _q8_decode(enc, x.shape)
    assert dec.shape == x.shape
    err = np.abs(np.asarray(dec - x))
    bound = float(jnp.abs(x).max()) / 127.0 * 0.51 + 1e-6
    assert err.max() <= bound


def test_q8_preserves_leading_dims():
    x = jnp.ones((58, 16, 32, 300), jnp.bfloat16)
    enc = _q8_encode(x)
    assert enc["q"].shape[:3] == (58, 16, 32)  # leading dims intact
    assert enc["q"].shape[-1] <= 256
