"""Analytical fabric model vs the paper's Tables II-IV."""

import numpy as np
import pytest

from repro.core.routing import (
    ChipConstants,
    Fabric,
    avg_distance_hierarchical,
    avg_distance_mesh,
)


def test_hops_same_core_vs_cross_chip():
    f = Fabric(grid_x=3, grid_y=3)
    same = f.hops(0, 0)
    assert same["r3"] == 0 and same["r2"] == 0
    # core 0 (tile 0,0) -> core of tile (2,1): XY distance 3
    far = f.hops(0, (2 + 1 * 3) * 4)
    assert far["r3"] == 3 and far["r2"] == 2


def test_latency_matches_table2_constants():
    f = Fabric()
    c = f.constants
    # local broadcast only
    assert f.latency_s(0, 0) == pytest.approx(c.broadcast_time_s)
    # one mesh hop adds the measured 15.4 ns across-chip latency
    lat1 = f.latency_s(0, 4)  # adjacent tile
    assert lat1 > c.broadcast_time_s
    assert lat1 - f.latency_s(0, 1) == pytest.approx(c.latency_across_chip_s, rel=0.3)
    # classification-relevant: any 3x3-board route stays < 200 ns
    worst = max(f.latency_s(0, d * 4) for d in range(f.n_tiles))
    assert worst < 200e-9


def test_energy_table3():
    f = Fabric()
    e_same = f.energy_j(0, 0, vdd=1.3)
    e_far = f.energy_j(0, 4 * 4, vdd=1.3)
    assert e_far > e_same
    # 1.3V total for a local event: spike+encode+broadcast+pulse ~ 3 nJ
    assert e_same == pytest.approx(260e-12 + 507e-12 + 2.2e-9 + 26e-12, rel=1e-6)
    # per-hop energy matches Table IV (17 pJ @ 1.3 V)
    assert f.energy_j(0, 16, 1.3) - f.energy_j(0, 4, 1.3) == pytest.approx(
        f.constants.energy_per_hop_j * (f.hops(0, 16)["r3"] - f.hops(0, 4)["r3"]), rel=1e-6
    )


def test_avg_distance_hierarchy_halves_mesh():
    """Table IV: hierarchical sqrt(N)/3 vs flat mesh 2*sqrt(N)/3."""
    for n in (64, 256, 1024, 4096):
        mesh = avg_distance_mesh(n)
        hier = avg_distance_hierarchical(n, cluster=4)
        assert hier < mesh
        assert hier / mesh == pytest.approx(0.5, abs=0.12)
    # absolute scaling ~ 2*sqrt(N)/3 for the flat mesh
    assert avg_distance_mesh(1024) == pytest.approx(2 * np.sqrt(1024) / 3, rel=0.05)


def test_fan_in_throughput_paper_figures():
    """§V: 27 ns broadcast -> ~7200 fan-in @ 20 Hz, ~1400 @ 100 Hz."""
    f = Fabric()
    assert f.max_fan_in(20.0) == pytest.approx(7234, rel=0.05)
    assert f.max_fan_in(100.0) == pytest.approx(1447, rel=0.05)


def test_traffic_utilization_bounds():
    f = Fabric(grid_x=2, grid_y=1)
    rates = np.full(f.n_cores, 256 * 20.0)  # every neuron at 20 Hz
    dsts = [[(c + 1) % f.n_cores] for c in range(f.n_cores)]
    t = f.traffic(rates, dsts)
    assert t["broadcast_utilization"] < 1.0  # within the 38 Mev/s bound
    assert t["r3_utilization"] < 1.0


def test_tile_of_core_rejects_out_of_range():
    """Regression: core 36 on a 3x3x4 fabric used to alias core 0 via %."""
    f = Fabric(grid_x=3, grid_y=3, cores_per_tile=4)
    assert f.tile_of_core(35) == (2, 2)
    with pytest.raises(ValueError, match="out of range"):
        f.tile_of_core(36)
    with pytest.raises(ValueError, match="out of range"):
        f.tile_of_core(-1)
    with pytest.raises(ValueError, match="out of range"):
        f.hops(0, f.n_cores)  # hops/latency/energy inherit the check
    with pytest.raises(ValueError, match="out of range"):
        f.tile_xy(f.n_tiles)


def test_traffic_validates_input_lengths():
    f = Fabric(grid_x=2, grid_y=1)
    rates = np.full(f.n_cores, 20.0)
    dsts = [[0] for _ in range(f.n_cores)]
    with pytest.raises(ValueError, match="rates_hz"):
        f.traffic(rates[:-1], dsts)
    with pytest.raises(ValueError, match="dst_cores"):
        f.traffic(rates, dsts[:-1])
    with pytest.raises(ValueError, match="out of range"):
        f.traffic(rates, [[f.n_cores]] + dsts[1:])
