"""Compiled-network artifacts (DESIGN.md §16): save/load round-trip,
geometry retargeting, and feasibility reporting.

The artifact is the unit of loading for multi-model serving, so the
round-trip must be *bytes*-exact (tables, report arrays, entry-table
reconstruction), and ``retarget`` to any feasible geometry must preserve
the network's dense-equivalent connectivity bit-exactly — pad neurons are
unconnected, re-allocation may move tags, but the spikes a network can
produce are geometry-invariant.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.compiler import (
    CompiledArtifact,
    Geometry,
    InfeasibleGeometryError,
    artifact_from_tables,
    compile_network_v2,
    retarget,
)
from repro.core.tags import NetworkSpec, compile_network


def _random_spec(seed, n=64, cluster=16, k=96, edges=40, groups=8):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(
        n_neurons=n, cluster_size=cluster, k_tags=k,
        max_cam_words=64, max_sram_entries=16,
    )
    for _ in range(edges):
        spec.connect(int(rng.integers(n)), int(rng.integers(n)), int(rng.integers(4)))
    for _ in range(groups):
        srcs = [int(s) for s in rng.choice(n, size=int(rng.integers(1, 4)), replace=False)]
        tgts = [(int(rng.integers(n)), int(rng.integers(4)))
                for _ in range(int(rng.integers(1, 4)))]
        spec.connect_group(srcs, tgts, shared_tag=bool(rng.integers(2)))
    return spec


def _entries_equal(a, b):
    for f in ("src", "dstk", "delay", "cross", "link_start", "hops",
              "latency_s", "energy_j", "valid", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)), err_msg=f
        )


def test_save_load_round_trip_bytes_identical(tmp_path):
    spec = _random_spec(3)
    geo = Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                   k_tags=96)
    art = retarget(spec, geo, anneal_steps=50)
    path = art.save(str(tmp_path / "art"))
    back = CompiledArtifact.load(path)

    for f in ("src_tag", "src_dest", "cam_tag", "cam_syn", "tile_of_cluster"):
        np.testing.assert_array_equal(
            np.asarray(getattr(art.tables, f)),
            np.asarray(getattr(back.tables, f)),
            err_msg=f,
        )
    assert back.geometry == art.geometry
    assert back.fingerprint() == art.fingerprint()
    assert back.feasibility.binding == art.feasibility.binding
    assert back.feasibility.asdict() == art.feasibility.asdict()
    # the compile report rides along, array-exact
    assert (back.report is None) == (art.report is None)
    if art.report is not None:
        np.testing.assert_array_equal(back.report.tags_used, art.report.tags_used)
        np.testing.assert_array_equal(back.report.cam_fill, art.report.cam_fill)
        assert back.report.eq2_bits_per_neuron == art.report.eq2_bits_per_neuron
    # the fabric entry table is reconstructed, not stored — and identical
    _entries_equal(art.entry_table(), back.entry_table())


def test_load_rejects_tampered_artifact(tmp_path):
    spec = _random_spec(4)
    geo = Geometry(grid_x=2, grid_y=1, cores_per_tile=2, neurons_per_core=16,
                   k_tags=96)
    path = retarget(spec, geo, optimize=False).save(str(tmp_path / "art"))
    # flip one CAM word on disk: the recorded fingerprint must catch it
    import json
    import os
    with np.load(os.path.join(path, "tables.npz")) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays["cam_tag"].flat[0] += 1
    np.savez(os.path.join(path, "tables.npz"), **arrays)
    with pytest.raises(ValueError, match="corrupt"):
        CompiledArtifact.load(path)
    # sanity: the json alone still parses
    with open(os.path.join(path, "artifact.json")) as f:
        assert json.load(f)["format"] == 1


@pytest.mark.parametrize(
    "geo, binding",
    [
        # 64 neurons at 16/core need 4 cores; 1 tile x 2 cores can't host
        (Geometry(grid_x=1, grid_y=1, cores_per_tile=2, neurons_per_core=16,
                  k_tags=96), "cores"),
        (Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                  k_tags=96, max_cam_words=1), "cam"),
        (Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                  k_tags=96, max_sram_entries=1), "sram"),
        (Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                  k_tags=2), "tags"),
    ],
)
def test_retarget_names_binding_constraint(geo, binding):
    spec = _random_spec(5)
    with pytest.raises(InfeasibleGeometryError) as ei:
        retarget(spec, geo)
    assert ei.value.report.binding == binding
    assert not ei.value.report.feasible


def test_feasibility_report_on_feasible_geometry():
    spec = _random_spec(6)
    geo = Geometry(grid_x=2, grid_y=2, cores_per_tile=2, neurons_per_core=16,
                   k_tags=96)
    art = retarget(spec, geo, optimize=False)
    fz = art.feasibility
    assert fz.feasible
    assert set(fz.utilization) == {"tags", "cam", "sram", "cores", "link"}
    assert fz.binding in fz.utilization
    assert all(fz.utilization[k] <= 1.0 for k in ("tags", "cam", "sram", "cores"))
    # placement was stamped into the tables (self-contained artifact)
    assert art.tables.tile_of_cluster is not None
    assert art.tables.tile_of_cluster.shape == (art.tables.n_clusters,)


def test_artifact_from_tables_keeps_postprocessed_tables():
    """Placement-only retarget: tables bound as-is (the spliced-CAM path)."""
    spec = _random_spec(7)
    tables = compile_network(spec)
    geo = Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                   k_tags=96)
    art = artifact_from_tables(tables, geo, optimize=False)
    for f in ("src_tag", "src_dest", "cam_tag", "cam_syn"):
        np.testing.assert_array_equal(
            np.asarray(getattr(art.tables, f)), np.asarray(getattr(tables, f))
        )
    # wrong cluster size cannot be fixed by placement alone
    with pytest.raises(InfeasibleGeometryError) as ei:
        artifact_from_tables(tables, Geometry(neurons_per_core=32))
    assert ei.value.report.binding == "cores"


def test_fingerprint_tracks_geometry_and_content():
    spec = _random_spec(8)
    g1 = Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                  k_tags=96)
    g2 = Geometry(grid_x=4, grid_y=1, cores_per_tile=1, neurons_per_core=16,
                  k_tags=96)
    a1 = retarget(spec, g1, optimize=False)
    a2 = retarget(spec, g2, optimize=False)
    assert a1.fingerprint() != a2.fingerprint()
    # deterministic: same inputs, same fingerprint
    assert a1.fingerprint() == retarget(spec, g1, optimize=False).fingerprint()


@given(seed=st.integers(0, 10_000), npc=st.sampled_from([8, 16, 32]))
@settings(max_examples=20, deadline=None)
def test_retarget_preserves_dense_equivalent(seed, npc):
    """Property: retargeting to any feasible geometry preserves the
    network's dense-equivalent connectivity multiset bit-exactly — tags,
    clustering and placement all move, spikes cannot."""
    # n=56 at spec cluster 8 is valid (7 clusters) yet not a multiple of the
    # 16/32-neuron target cores — retarget must pad up to whole cores
    spec = _random_spec(seed, n=56, cluster=8, edges=30, groups=6)
    baseline = compile_network(spec).dense_equivalent()
    geo = Geometry(grid_x=2, grid_y=2, cores_per_tile=2, neurons_per_core=npc,
                   k_tags=128)
    art = retarget(spec, geo, optimize=False)
    assert art.tables.cluster_size == npc
    assert art.tables.n_neurons % npc == 0
    np.testing.assert_array_equal(art.tables.dense_equivalent(), baseline)


def test_retarget_preserves_dense_equivalent_seeded():
    """Deterministic companion to the hypothesis property above, so the
    invariant is exercised even without the ``test`` extra installed."""
    for seed, npc in [(0, 8), (1, 16), (2, 32), (3, 16)]:
        spec = _random_spec(seed, n=56, cluster=8, edges=30, groups=6)
        baseline = compile_network(spec).dense_equivalent()
        geo = Geometry(grid_x=2, grid_y=2, cores_per_tile=2,
                       neurons_per_core=npc, k_tags=128)
        art = retarget(spec, geo, optimize=False)
        np.testing.assert_array_equal(art.tables.dense_equivalent(), baseline)


def test_retarget_from_compile_result_keeps_optimized_placement():
    """A CompileResult's annealed placement survives when it fits the target
    fabric; the artifact is feasible and reports link utilization."""
    spec = _random_spec(9)
    res = compile_network_v2(spec, fabric=Geometry(
        grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16, k_tags=96
    ).fabric(), anneal_steps=50)
    geo = Geometry(grid_x=2, grid_y=2, cores_per_tile=1, neurons_per_core=16,
                   k_tags=96)
    art = artifact_from_tables(res, geo)
    np.testing.assert_array_equal(
        art.tables.tile_of_cluster, res.tables.tile_of_cluster
    )
    assert "link" in art.feasibility.utilization
