"""Network compiler: routing tables must reproduce requested connectivity."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.core.tags import NetworkSpec, SynapseType, compile_network


def _random_spec(seed, n=64, cluster=16, k=64, edges=80):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(
        n_neurons=n, cluster_size=cluster, k_tags=k, max_cam_words=32, max_sram_entries=16
    )
    want = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        syn = int(rng.integers(4))
        if (s, d) in {(a, b) for a, b, _ in want}:
            continue
        want.add((s, d, syn))
        spec.connect(s, d, syn)
    return spec, want


@given(seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_compiled_tables_reproduce_connectivity(seed):
    spec, want = _random_spec(seed)
    tables = compile_network(spec)
    got = {(int(s), int(d), int(t)) for s, d, t in tables.dense_equivalent()}
    assert got == want


def test_shared_tag_group_semantics():
    """A shared-tag population: every source reaches every target; tag count
    is 1 per destination cluster (weight sharing keeps K constant)."""
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=8, max_cam_words=8)
    srcs = [0, 1, 2, 3]
    tgts = [(16, SynapseType.FAST_EXC), (17, SynapseType.FAST_EXC)]
    spec.connect_group(srcs, tgts, shared_tag=True)
    tables = compile_network(spec)
    got = {(int(s), int(d)) for s, d, _ in tables.dense_equivalent()}
    assert got == {(s, d) for s in srcs for d in (16, 17)}
    # one tag allocated in cluster 2, one CAM word per target
    assert (tables.cam_tag[16] >= 0).sum() == 1
    assert (tables.src_tag[0] >= 0).sum() == 1


def test_empty_source_group_allocates_no_tags():
    """Regression: connect_group with no sources used to burn one tag per
    destination cluster (shared branch) — tags nothing sends and no CAM word
    subscribes to. K=1 leaves no headroom for leaks."""
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=1, max_cam_words=8)
    spec.connect_group([], [(16, SynapseType.FAST_EXC), (24, SynapseType.FAST_EXC)])
    spec.connect(0, 16)  # must still get cluster 2's single tag
    tables = compile_network(spec)
    got = {(int(s), int(d)) for s, d, _ in tables.dense_equivalent()}
    assert got == {(0, 16)}
    # the empty group left no trace in either memory
    assert (tables.cam_tag[24] >= 0).sum() == 0
    assert tables.sram_bits() == (tables.src_tag >= 0).sum() * (1 + 2)


def test_tag_overflow_raises():
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=2, max_cam_words=8)
    spec.connect(0, 16)
    spec.connect(1, 17)
    with pytest.raises(ValueError, match="tag overflow"):
        spec.connect(2, 18)
        compile_network(spec)


def test_cam_overflow_raises():
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=8, max_cam_words=2)
    for s in range(3):
        spec.connect(s, 16)
    with pytest.raises(ValueError, match="CAM capacity"):
        compile_network(spec)


def test_v1_cam_layout_is_target_outer_tag_inner():
    """Regression: the unit-based materialization must keep the pre-refactor
    v1 table layout — a multi-source non-shared group writes each target's
    CAM words for ALL the group's tags contiguously (target-outer,
    tag-inner), not one unit (tag) at a time. Anything serializing or
    diffing compiled tables across versions depends on this."""
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=8, max_cam_words=16)
    spec.connect_group(
        [0, 1, 2], [(16, SynapseType.FAST_EXC), (17, SynapseType.SLOW_EXC)],
        shared_tag=False, copies=2,
    )
    tables = compile_network(spec)
    # sources 0,1,2 get tags 0,1,2 in cluster 2; each target's row holds
    # tag 0 x2, tag 1 x2, tag 2 x2 — contiguous per tag, all tags in order
    np.testing.assert_array_equal(
        tables.cam_tag[16, :6], [0, 0, 1, 1, 2, 2]
    )
    np.testing.assert_array_equal(
        tables.cam_tag[17, :6], [0, 0, 1, 1, 2, 2]
    )
    assert (tables.cam_syn[16, :6] == SynapseType.FAST_EXC).all()
    assert (tables.cam_syn[17, :6] == SynapseType.SLOW_EXC).all()


def test_memory_accounting_counts_occupied_entries():
    spec = NetworkSpec(n_neurons=32, cluster_size=8, k_tags=8, max_cam_words=8)
    spec.connect(0, 16)
    tables = compile_network(spec)
    # 1 SRAM entry: log2(8) tag + log2(4 clusters) = 3 + 2 bits
    assert tables.sram_bits() == 5
    # 1 CAM word: log2(8) tag + 2 synapse-type bits
    assert tables.cam_bits() == 5
