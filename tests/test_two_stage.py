"""Two-stage dispatch == dense connectivity (the paper's core claim)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.core.event_engine import (
    EventEngine,
    dense_reference_step,
    dense_weights_from_tables,
)
from repro.core.neuron import NeuronParams, init_state
from repro.core.tags import NetworkSpec, compile_network
from repro.core.two_stage import stage1_route, stage2_cam_match, two_stage_deliver


def _tables(seed, n=48, cluster=16, k=48, edges=60):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=24, max_sram_entries=16)
    seen = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        spec.connect(s, d, int(rng.integers(4)))
    return compile_network(spec)


@given(seed=st.integers(0, 500), spike_p=st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_two_stage_equals_dense(seed, spike_p):
    tables = _tables(seed)
    rng = np.random.default_rng(seed + 7)
    spikes = (rng.random(tables.n_neurons) < spike_p).astype(np.float32)
    drive = two_stage_deliver(
        jnp.asarray(spikes),
        jnp.asarray(tables.src_tag),
        jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag),
        jnp.asarray(tables.cam_syn),
        tables.cluster_size,
        tables.k_tags,
    )
    dense = dense_weights_from_tables(tables)
    ref = jnp.einsum("dst,s->dt", jnp.asarray(dense), jnp.asarray(spikes))
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-6)


def test_stage1_drops_invalid_entries():
    src_tag = jnp.asarray([[0, -1], [1, 2]], jnp.int32)
    src_dest = jnp.asarray([[1, -1], [0, 1]], jnp.int32)
    a = stage1_route(jnp.asarray([1.0, 2.0]), src_tag, src_dest, n_clusters=2, k_tags=4)
    expect = np.zeros((2, 4), np.float32)
    expect[1, 0] = 1.0  # neuron 0, entry 0
    expect[0, 1] = 2.0  # neuron 1, entry 0
    expect[1, 2] = 2.0  # neuron 1, entry 1
    np.testing.assert_allclose(np.asarray(a), expect)


def test_engine_dynamics_match_dense_reference():
    """Full engine step == dense-delivery reference step over several steps."""
    tables = _tables(3)
    dense = jnp.asarray(dense_weights_from_tables(tables))
    params = NeuronParams()
    eng = EventEngine(tables, params)
    carry = eng.init_state()
    state_ref = init_state(tables.n_neurons, params)
    spikes_ref = jnp.zeros((tables.n_neurons,))
    ext = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
    ext_drive = stage2_cam_match(
        ext, jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn), tables.cluster_size
    )
    for _ in range(30):
        carry, spikes = eng.step(carry, ext)
        state_ref, spikes_ref = dense_reference_step(
            dense, spikes_ref, state_ref, params, external_drive=ext_drive
        )
        np.testing.assert_allclose(np.asarray(spikes), np.asarray(spikes_ref), atol=1e-6)
    assert not bool(jnp.isnan(carry[0].v).any())


def test_engine_run_scan_no_nan():
    tables = _tables(11)
    eng = EventEngine(tables)
    carry = eng.init_state()
    inp = jnp.zeros((50, tables.n_clusters, tables.k_tags)).at[:, :, :4].set(2.0)
    carry, out = eng.run(carry, inp)
    assert out.shape == (50, tables.n_neurons)
    assert not bool(jnp.isnan(out).any())


def test_engine_run_time_varying_i_ext_matches_per_step():
    """Regression: a [T, ...] external current must be scanned per step, not
    broadcast whole every step (which silently mis-applied all T currents at
    once)."""
    tables = _tables(13)
    eng = EventEngine(tables)
    t = 20
    rng = np.random.default_rng(0)
    i_ext = jnp.asarray(
        rng.uniform(0, 3e3, size=(t, tables.n_neurons)), jnp.float32
    )
    inp = jnp.zeros((t, tables.n_clusters, tables.k_tags)).at[:, :, :4].set(2.0)
    _, out_run = eng.run(eng.init_state(), inp, i_ext)
    carry = eng.init_state()
    per_step = []
    for step in range(t):
        carry, spikes = eng.step(carry, inp[step], i_ext[step])
        per_step.append(np.asarray(spikes))
    np.testing.assert_array_equal(np.asarray(out_run), np.stack(per_step))
    assert np.asarray(out_run).sum() > 0  # the current did drive spikes
    # constant (non-time-varying) i_ext still broadcasts as before
    _, out_const = eng.run(eng.init_state(), inp, i_ext[0])
    carry = eng.init_state()
    for step in range(t):
        carry, spikes = eng.step(carry, inp[step], i_ext[0])
    np.testing.assert_array_equal(np.asarray(out_const[-1]), np.asarray(spikes))
    # a batched per-stream constant [B, N] with B == T must NOT be misread
    # as a time series (it has the spike state's rank, not rank + 1)
    b = t
    i_const = jnp.asarray(
        rng.uniform(0, 3e3, size=(b, tables.n_neurons)), jnp.float32
    )
    inp_b = jnp.broadcast_to(
        inp[:, None], (t, b, tables.n_clusters, tables.k_tags)
    )
    _, out_b = eng.run(eng.init_state(batch=b), inp_b, i_const)
    carry = eng.init_state(batch=b)
    for step in range(t):
        carry, spikes_b = eng.step(carry, inp_b[step], i_const)
    np.testing.assert_array_equal(np.asarray(out_b[-1]), np.asarray(spikes_b))


def test_inhibition_reduces_firing():
    """Subtractive-inhibition events must not increase firing (paper §IV-A)."""
    spec = NetworkSpec(n_neurons=16, cluster_size=16, k_tags=16, max_cam_words=8)
    tables = compile_network(spec)
    # neuron 0: excitatory input tag 0; neuron 1: same + inhibitory tag 1
    cam_tag = tables.cam_tag.copy()
    cam_syn = tables.cam_syn.copy()
    cam_tag[0, 0], cam_syn[0, 0] = 0, 0
    cam_tag[1, 0], cam_syn[1, 0] = 0, 0
    cam_tag[1, 1], cam_syn[1, 1] = 1, 2  # subtractive inh
    import dataclasses

    tables = dataclasses.replace(tables, cam_tag=cam_tag, cam_syn=cam_syn)
    eng = EventEngine(tables)
    carry = eng.init_state()
    inp = jnp.zeros((400, 1, 16)).at[:, :, 0].set(3.0).at[:, :, 1].set(3.0)
    _, out = eng.run(carry, inp)
    assert float(out[:, 1].sum()) <= float(out[:, 0].sum())
    assert float(out[:, 0].sum()) > 0  # excitation drives spiking
