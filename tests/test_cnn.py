"""Spiking-CNN compiler (paper §V, Table V): structure + event flow."""

import numpy as np
import jax.numpy as jnp

from repro.core.cnn import CnnConfig, compile_poker_cnn, edge_kernels
from repro.core.event_engine import EventEngine
from repro.core.neuron import NeuronParams


def test_table5_structure():
    cc = compile_poker_cnn()
    t = cc.tables
    assert t.n_neurons == 1536  # 1024 conv + 256 pool + 256 out
    assert t.n_clusters == 6  # 6 cores
    assert cc.conv == (0, 1024)
    assert cc.pool == (1024, 1280)
    assert cc.out == (1280, 1536)


def test_cam_budget_respected():
    """Every conv neuron's receptive field fits the chip's 64 CAM words."""
    cc = compile_poker_cnn()
    words = (cc.tables.cam_tag >= 0).sum(axis=1)
    assert int(words.max()) <= 64
    # conv neurons use pixel-id tags; the ternary 8x8 kernels have 48
    # non-zero taps, so interior neurons hold 48 of their 64 CAM words
    conv_words = words[: cc.conv[1]]
    assert int(conv_words.max()) == 48


def test_edge_kernels_ternary():
    ks = edge_kernels(8)
    assert ks.shape == (4, 8, 8)
    assert set(np.unique(ks)).issubset({-1.0, 0.0, 1.0})
    # vertical kernel responds to vertical edges: transpose = horizontal
    assert (ks[1] == ks[0].T).all()


def test_input_events_reach_conv_layer():
    cc = compile_poker_cnn()
    # a centered vertical bar of events
    ys, xs = np.meshgrid(np.arange(8, 24), np.arange(15, 17), indexing="ij")
    events = np.stack([ys.ravel(), xs.ravel()], 1)
    act = cc.input_activity(events)
    assert act.sum() == len(events) * cc.cfg.n_kernels  # one tag per feature cluster
    eng = EventEngine(cc.tables, NeuronParams(refrac=1e-3))
    carry = eng.init_state()
    inp = jnp.broadcast_to(jnp.asarray(act), (40, *act.shape))
    _, spikes = eng.run(carry, inp)
    conv_spikes = np.asarray(spikes)[:, : cc.conv[1]]
    assert conv_spikes.sum() > 0, "conv layer must respond to input events"
    # vertical-edge map (feature 0) should out-respond horizontal map (1)
    per_map = conv_spikes.sum(0).reshape(4, -1).sum(1)
    assert per_map[0] > per_map[1]
