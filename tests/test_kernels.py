"""Pallas kernel validation: interpret-mode sweep vs pure-jnp oracles."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.cam_match.cam_match import cam_match_pallas
from repro.kernels.cam_match.ref import cam_match_ref
from repro.kernels.rwkv6.ref import rwkv6_chunk_ref
from repro.kernels.rwkv6.rwkv6 import rwkv6_chunk_pallas


@pytest.mark.parametrize(
    "ncl,c,s,k,block_c",
    [
        (4, 16, 8, 32, 8),
        (2, 256, 64, 1024, 16),  # the chip's core geometry
        (3, 32, 16, 128, 16),
        (1, 64, 4, 64, 64),
        (5, 8, 8, 16, 4),
    ],
)
def test_cam_match_shapes(ncl, c, s, k, block_c):
    rng = np.random.default_rng(ncl * 1000 + c)
    n = ncl * c
    act = jnp.asarray(rng.random((ncl, k)), jnp.float32)
    tag = jnp.asarray(rng.integers(-1, k, (n, s)), jnp.int32)
    syn = jnp.asarray(rng.integers(0, 4, (n, s)), jnp.int32)
    out_k = cam_match_pallas(act, tag, syn, c, block_c=block_c)
    out_r = cam_match_ref(act, tag, syn, c)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cam_match_dtypes(dtype):
    rng = np.random.default_rng(0)
    act = jnp.asarray(rng.random((2, 64)), dtype)
    tag = jnp.asarray(rng.integers(-1, 64, (32, 8)), jnp.int32)
    syn = jnp.asarray(rng.integers(0, 4, (32, 8)), jnp.int32)
    out_k = cam_match_pallas(act, tag, syn, 16, block_c=8)
    out_r = cam_match_ref(act, tag, syn, 16)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32), rtol=2e-2, atol=2e-2
    )


def test_cam_match_empty_cam_rows():
    """All-empty CAMs produce zero drive."""
    act = jnp.ones((2, 16), jnp.float32)
    tag = jnp.full((8, 4), -1, jnp.int32)
    syn = jnp.zeros((8, 4), jnp.int32)
    out = cam_match_pallas(act, tag, syn, 4, block_c=4)
    assert float(jnp.abs(out).max()) == 0.0


@pytest.mark.parametrize(
    "b,t,h,p",
    [(2, 8, 3, 16), (1, 64, 2, 64), (2, 16, 4, 32), (1, 32, 1, 8)],
)
def test_rwkv6_chunk_shapes(b, t, h, p):
    rng = np.random.default_rng(b * 100 + t)
    r = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32) * 0.5
    k = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32) * 0.5
    v = jnp.asarray(rng.normal(size=(b, t, h, p)), jnp.float32) * 0.5
    lw = -jnp.asarray(rng.uniform(0.01, 1.0, size=(b, t, h, p)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, p)), jnp.float32) * 0.1
    s0 = jnp.asarray(rng.normal(size=(b, h, p, p)), jnp.float32) * 0.2
    y_k, s_k = rwkv6_chunk_pallas(r, k, v, lw, u, s0)
    y_r, s_r = rwkv6_chunk_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-4, atol=1e-5)


def test_rwkv6_chunk_state_threading():
    """Two chunks via the kernel == one double-length reference chunk."""
    rng = np.random.default_rng(5)
    b, t, h, p = 1, 8, 2, 16
    mk = lambda: jnp.asarray(rng.normal(size=(b, 2 * t, h, p)), jnp.float32) * 0.5
    r, k, v = mk(), mk(), mk()
    lw = -jnp.asarray(rng.uniform(0.01, 1.0, size=(b, 2 * t, h, p)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, p)), jnp.float32) * 0.1
    s0 = jnp.zeros((b, h, p, p), jnp.float32)
    y1, s1 = rwkv6_chunk_pallas(r[:, :t], k[:, :t], v[:, :t], lw[:, :t], u, s0)
    y2, s2 = rwkv6_chunk_pallas(r[:, t:], k[:, t:], v[:, t:], lw[:, t:], u, s1)
    y_ref, s_ref = rwkv6_chunk_ref(r, k, v, lw, u, s0)
    np.testing.assert_allclose(
        np.concatenate([y1, y2], 1), np.asarray(y_ref), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_ref), rtol=1e-4, atol=1e-5)
