"""Sharded session fleet (serve/sharded.py, DESIGN.md §17).

In-process tests run every shard on a ``(1, 1)`` mesh — the
:class:`ShardedEventEngine` code path is identical with or without real
devices, so admission, migration and elastic-restart semantics are covered
at full speed. Multi-device placement (disjoint device sets per shard,
cluster-axis sharding under ``device_slab_placement``, cross-mesh
migration) runs in subprocesses with fake CPU devices, same pattern as
tests/test_distributed.py.
"""

import os
import subprocess
import sys
import tempfile
import textwrap

import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.cnn import compile_poker_cnn
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
from repro.serve.aer import (
    AerServeConfig,
    AerSessionPool,
    CheckpointMismatchError,
    DvsSession,
    build_poker_engine,
)
from repro.serve.health import FleetWatchdog
from repro.serve.sharded import (
    AdmissionError,
    ShardConfig,
    ShardedSessionPool,
)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=timeout,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.fixture(scope="module")
def cc():
    return compile_poker_cnn()


def _session(i, symbol, tenant=None):
    return DvsSession(
        i,
        DvsStreamSource(
            DvsStreamConfig(symbol=symbol, events_per_step=16, seed=9),
            session_id=i,
        ),
        label=symbol,
        tenant=tenant,
    )


def _drain(fleet, res=None):
    res = {} if res is None else res
    while fleet.busy:
        fleet.step()
        for r in fleet.evict_finished():
            res[r.session_id] = r
    return res


def _fleet(cc, n_shards=2, pool_size=2, queue_depth=2, backend="reference",
           max_steps=25):
    return ShardedSessionPool(
        cc,
        AerServeConfig(pool_size=pool_size, max_steps=max_steps),
        ShardConfig(n_shards=n_shards, queue_depth=queue_depth,
                    backend=backend),
    )


# ---------------------------------------------------------------------------
# layer 1+2: fleet stepping and admission control
# ---------------------------------------------------------------------------
def test_admission_balances_by_traffic_score(cc):
    fleet = _fleet(cc, n_shards=2)
    picks = [fleet.submit(_session(i, i % 4)) for i in range(4)]
    # least-loaded routing alternates on an empty symmetric fleet
    assert sorted(picks) == [0, 0, 1, 1]
    occ = fleet.occupancy()
    assert occ[0][1] + occ[1][1] == 4  # all queued until the first backfill
    fleet.step()
    occ = fleet.occupancy()
    assert occ[0] == (2, 0) and occ[1] == (2, 0)


def test_admission_bounded_queue_raises_typed_error(cc):
    fleet = _fleet(cc, n_shards=2, pool_size=2, queue_depth=2)
    # capacity before any step: per shard 2 slot-bound + 2 overflow
    for i in range(8):
        fleet.submit(_session(i, i % 4))
    with pytest.raises(AdmissionError, match="queue_depth"):
        fleet.submit(_session(99, 0))
    # serving drains the backlog; everything completes
    res = _drain(fleet)
    assert set(res) == set(range(8))


def test_admission_rejects_unknown_model(cc):
    fleet = _fleet(cc, n_shards=2)
    sess = _session(0, 0)
    sess.model = "nope"
    with pytest.raises(KeyError, match="not resident"):
        fleet.submit(sess)


def test_fleet_serve_matches_solo_pool_bit_exact(cc):
    fleet = _fleet(cc, n_shards=2, pool_size=2)
    res = {r.session_id: r
           for r in fleet.serve([_session(i, i % 4) for i in range(8)])}
    solo = AerSessionPool(
        cc, build_poker_engine(cc.tables),
        AerServeConfig(pool_size=2, max_steps=25),
    )
    ref = {r.session_id: r
           for r in solo.serve([_session(i, i % 4) for i in range(8)])}
    assert set(res) == set(ref) == set(range(8))
    for sid in ref:
        assert np.array_equal(res[sid].counts, ref[sid].counts), sid
        assert res[sid].prediction == ref[sid].prediction
        assert res[sid].latency_steps == ref[sid].latency_steps


def test_fleet_stats_sums_shards(cc):
    fleet = _fleet(cc, n_shards=2, backend="fabric")
    assert fleet.fleet_stats() is None  # nothing stepped yet
    for i in range(4):
        fleet.submit(_session(i, i % 4))
    for _ in range(6):
        fleet.step()
    stats = fleet.fleet_stats()
    assert stats is not None and int(stats.delivered) > 0
    per_shard = sum(
        int(np.asarray(fleet.pools[i].last_stats.delivered).sum())
        for i in fleet.live_shards()
    )
    assert int(stats.delivered) == per_shard


def test_fleet_watchdog_scans_every_shard(cc):
    fleet = _fleet(cc, n_shards=2, backend="fabric")
    wd = FleetWatchdog()
    for i in range(4):
        fleet.submit(_session(i, i % 4))
    for _ in range(4):
        fleet.step()
        events = wd.observe(fleet)
        assert all(shard in (0, 1) for shard, _ in events)
    assert set(wd._per_shard) == {0, 1}
    assert wd.link_drop_rate() >= 0.0


# ---------------------------------------------------------------------------
# layer 3: live migration and drain
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["reference", "fabric"])
def test_migration_mid_flight_is_invariant(cc, backend):
    """A tenant migrated between shards mid-serve finishes with results
    byte-equal to the undisturbed run — neuron state, queued spikes and the
    phase-normalized in-flight fabric slab all survive the move."""

    def run(migrate):
        fleet = _fleet(cc, n_shards=2, backend=backend)
        fleet.submit(_session(10, 2))
        fleet.submit(_session(11, 1))
        for _ in range(4):
            fleet.step()
        if migrate:
            shard, _ = fleet.locate(10)
            fleet.migrate(10, 1 - shard)
            assert fleet.locate(10)[0] == 1 - shard
        return _drain(fleet)

    ref, moved = run(False), run(True)
    for sid in (10, 11):
        assert np.array_equal(ref[sid].counts, moved[sid].counts), sid
        assert ref[sid].prediction == moved[sid].prediction
        assert ref[sid].latency_steps == moved[sid].latency_steps


def test_migrate_validates_destination(cc):
    fleet = _fleet(cc, n_shards=2)
    fleet.submit(_session(0, 0))
    fleet.step()
    with pytest.raises(KeyError, match="not resident"):
        fleet.locate(77)
    fleet.kill_shard(1)
    with pytest.raises(ValueError, match="not live"):
        fleet.migrate(0, 1)


def test_drain_shard_moves_everything(cc):
    fleet = _fleet(cc, n_shards=2, pool_size=4)
    for i in range(4):
        fleet.submit(_session(i, i % 4))
    for _ in range(3):
        fleet.step()
    moved = fleet.drain_shard(0)
    assert moved == 2
    assert fleet.occupancy()[0] == (0, 0)
    res = _drain(fleet)
    assert set(res) == set(range(4))


def test_drain_shard_raises_when_no_room(cc):
    fleet = _fleet(cc, n_shards=2, pool_size=2)
    for i in range(4):
        fleet.submit(_session(i, i % 4))
    fleet.step()  # both shards full
    with pytest.raises(AdmissionError, match="cannot drain"):
        fleet.drain_shard(0)


# ---------------------------------------------------------------------------
# layer 4: fleet checkpoint, elastic restore, kill + recover
# ---------------------------------------------------------------------------
def _baseline(cc, backend, n_shards=4, pool_size=4):
    fleet = _fleet(cc, n_shards=n_shards, pool_size=pool_size,
                   queue_depth=4, backend=backend)
    for i in range(8):
        fleet.submit(_session(i, i % 4))
    for _ in range(5):
        fleet.step()
    return _drain(fleet, {r.session_id: r for r in fleet.evict_finished()})


@pytest.mark.parametrize("backend", ["reference", "fabric"])
def test_restore_onto_fewer_shards_bit_exact(cc, backend):
    """Save a 4-shard fleet mid-serve, restore at 2 shards: surviving shards
    restore in place, lost shards' sessions redistribute into free slots;
    every session finishes byte-equal to the undisturbed 4-shard run."""
    ref = _baseline(cc, backend)
    fleet = _fleet(cc, n_shards=4, pool_size=4, queue_depth=4,
                   backend=backend)
    for i in range(8):
        fleet.submit(_session(i, i % 4))
    for _ in range(5):
        fleet.step()
    cfg = AerServeConfig(pool_size=4, max_steps=25)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        fleet.checkpoint(ck, blocking=True)
        small = ShardedSessionPool.restore(
            cc, cfg,
            ShardConfig(n_shards=2, queue_depth=4, backend=backend), ck,
        )
    assert small.n_steps == fleet.n_steps
    assert sum(o for o, _ in small.occupancy().values()) == 8
    res = _drain(small)
    assert set(res) == set(ref)
    for sid in ref:
        assert np.array_equal(res[sid].counts, ref[sid].counts), sid
        assert res[sid].prediction == ref[sid].prediction


def test_restore_impossible_raises_typed_mismatch(cc):
    fleet = _fleet(cc, n_shards=4, pool_size=4, queue_depth=4)
    for i in range(8):
        fleet.submit(_session(i, i % 4))
    for _ in range(3):
        fleet.step()
    cfg = AerServeConfig(pool_size=4, max_steps=25)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        fleet.checkpoint(ck, blocking=True)
        # 1 shard x 4 slots cannot hold 8 mid-flight sessions
        with pytest.raises(CheckpointMismatchError, match="redistribute"):
            ShardedSessionPool.restore(
                cc, cfg, ShardConfig(n_shards=1, queue_depth=0), ck,
            )
        # wrong per-shard pool geometry is also typed
        with pytest.raises(CheckpointMismatchError, match="pool_size"):
            ShardedSessionPool.restore(
                cc, AerServeConfig(pool_size=2, max_steps=25),
                ShardConfig(n_shards=4, queue_depth=4), ck,
            )


@pytest.mark.parametrize("backend", ["reference", "fabric"])
def test_kill_shard_recover_from_checkpoint_bit_exact(cc, backend):
    """Kill a shard mid-serve; its sessions roll back to the checkpoint and
    splice into survivors (whose current state keeps serving untouched).
    Deterministic replay makes every result — including the recovered
    tenants' — byte-equal to the run where nothing died. Covers both the
    queued and fabric-ring carry layouts."""
    ref = _baseline(cc, backend)
    fleet = _fleet(cc, n_shards=4, pool_size=4, queue_depth=4,
                   backend=backend)
    for i in range(8):
        fleet.submit(_session(i, i % 4))
    for _ in range(3):
        fleet.step()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        fleet.checkpoint(ck, blocking=True)
        for _ in range(2):
            fleet.step()
        victim = 2
        held = [s.session_id for s in fleet.pools[victim].slots
                if s is not None]
        assert held  # the scenario is real: the dead shard held tenants
        fleet.kill_shard(victim)
        with pytest.raises(ValueError, match="already dead"):
            fleet.kill_shard(victim)
        assert fleet.recover_shard(ck, victim) == len(held)
    res = _drain(fleet, {r.session_id: r for r in fleet.evict_finished()})
    assert set(res) == set(ref)
    for sid in ref:
        assert np.array_equal(res[sid].counts, ref[sid].counts), sid
        assert res[sid].prediction == ref[sid].prediction
        assert res[sid].latency_steps == ref[sid].latency_steps


def test_recover_shard_guards(cc):
    fleet = _fleet(cc, n_shards=2)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        with pytest.raises(ValueError, match="is live"):
            fleet.recover_shard(ck, 0)
        fleet.kill_shard(0)
        with pytest.raises(FileNotFoundError):
            fleet.recover_shard(ck, 0)


# ---------------------------------------------------------------------------
# multi-device placement (subprocess: fake CPU devices)
# ---------------------------------------------------------------------------
def test_fleet_disjoint_devices_matches_single_device():
    """2 shards x (1 batch x 2 cluster) disjoint device meshes, fabric-ring
    backend under device_slab_placement: fleet results match the
    single-device fleet bit-for-bit."""
    _run("""
        import numpy as np
        from repro.core.cnn import compile_poker_cnn
        from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
        from repro.serve.aer import AerServeConfig, DvsSession
        from repro.serve.sharded import (ShardConfig, ShardedSessionPool,
                                         retile_for_slabs)
        # both fleets on the SAME slab-compliant placement (retiling is
        # idempotent) so only the mesh differs between the two runs
        cc = retile_for_slabs(compile_poker_cnn(), 2)
        def sess(i, symbol):
            return DvsSession(i, DvsStreamSource(
                DvsStreamConfig(symbol=symbol, events_per_step=16, seed=9),
                session_id=i), label=symbol)
        def serve(cluster_devices):
            fleet = ShardedSessionPool(
                cc, AerServeConfig(pool_size=2, max_steps=25),
                ShardConfig(n_shards=2, queue_depth=4, backend="fabric",
                            cluster_devices=cluster_devices))
            return {r.session_id: r
                    for r in fleet.serve([sess(i, i % 4) for i in range(6)])}
        multi = serve(2)   # 2 shards x 2 devices, disjoint
        single = serve(1)
        assert set(multi) == set(single) == set(range(6))
        for sid in single:
            assert np.array_equal(multi[sid].counts, single[sid].counts), sid
            assert multi[sid].latency_steps == single[sid].latency_steps
        print("OK")
    """)


def test_cross_mesh_migration_bit_exact():
    """The cross-host move: a tenant starts on a single-device shard and
    migrates mid-flight onto a shard whose clusters span 2 devices (same
    retiled tables, different mesh). It finishes byte-equal to the solo
    local-engine run — migration is a placement move, never a value move."""
    _run("""
        import numpy as np
        from repro.core.cnn import compile_poker_cnn
        from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
        from repro.serve.aer import (AerServeConfig, AerSessionPool,
                                     DvsSession, build_poker_engine)
        from repro.serve.sharded import (ShardConfig, ShardedSessionPool,
                                         build_poker_shard_engine,
                                         retile_for_slabs)
        import jax
        cc = retile_for_slabs(compile_poker_cnn(), 2)
        def sess(i, symbol):
            return DvsSession(i, DvsStreamSource(
                DvsStreamConfig(symbol=symbol, events_per_step=16, seed=9),
                session_id=i), label=symbol)
        devs = jax.devices()
        def factory(shard_id, devices):
            if shard_id == 0:  # single-device shard
                return build_poker_shard_engine(
                    cc.tables, "fabric", cluster_devices=1,
                    batch_devices=1, devices=devs[:1])
            return build_poker_shard_engine(  # 2-device cluster shard
                cc.tables, "fabric", cluster_devices=2,
                batch_devices=1, devices=devs[1:3])
        fleet = ShardedSessionPool(
            cc, AerServeConfig(pool_size=2, max_steps=25),
            ShardConfig(n_shards=2, queue_depth=4, backend="fabric"),
            engine_factory=factory)
        fleet.submit(sess(10, 2))
        fleet.step()  # backfill: the session becomes resident
        if fleet.locate(10)[0] != 0:
            fleet.migrate(10, 0)
        for _ in range(3):
            fleet.step()
        assert fleet.locate(10)[0] == 0
        fleet.migrate(10, 1)  # 1-device mesh -> 2-device mesh, mid-flight
        assert fleet.locate(10)[0] == 1
        res = {}
        while fleet.busy:
            fleet.step()
            for r in fleet.evict_finished():
                res[r.session_id] = r
        solo = AerSessionPool(
            cc, build_poker_engine(cc.tables),
            AerServeConfig(pool_size=2, max_steps=25))
        ref = {r.session_id: r for r in solo.serve([sess(10, 2)])}
        assert np.array_equal(res[10].counts, ref[10].counts)
        assert res[10].prediction == ref[10].prediction
        assert res[10].latency_steps == ref[10].latency_steps
        print("OK")
    """)


def test_elastic_restore_across_mesh_shapes():
    """Fleet checkpointed with shards on (1 x 2) cluster meshes restores
    onto (2 x 2) meshes — surviving a mesh-shape change, bit-exact (carry
    values are global; elasticity is placement-only). The cluster extent is
    kept so both fleets run the same device-slab placement."""
    _run("""
        import numpy as np, tempfile
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.core.cnn import compile_poker_cnn
        from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
        from repro.serve.aer import AerServeConfig, DvsSession
        from repro.serve.sharded import ShardConfig, ShardedSessionPool
        cc = compile_poker_cnn()
        def sess(i, symbol):
            return DvsSession(i, DvsStreamSource(
                DvsStreamConfig(symbol=symbol, events_per_step=16, seed=9),
                session_id=i), label=symbol)
        cfg = AerServeConfig(pool_size=2, max_steps=25)
        def drain(fleet, res):
            while fleet.busy:
                fleet.step()
                for r in fleet.evict_finished():
                    res[r.session_id] = r
            return res
        base = ShardedSessionPool(cc, cfg, ShardConfig(
            n_shards=2, queue_depth=4, backend="fabric", cluster_devices=2))
        for i in range(4):
            base.submit(sess(i, i % 4))
        for _ in range(5):
            base.step()
        ref = drain(base, {r.session_id: r for r in base.evict_finished()})
        f = ShardedSessionPool(cc, cfg, ShardConfig(
            n_shards=2, queue_depth=4, backend="fabric", cluster_devices=2))
        for i in range(4):
            f.submit(sess(i, i % 4))
        for _ in range(5):
            f.step()
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            f.checkpoint(ck, blocking=True)
            g = ShardedSessionPool.restore(
                cc, cfg,
                ShardConfig(n_shards=2, queue_depth=4, backend="fabric",
                            cluster_devices=2, batch_devices=2), ck)
        res = drain(g, {})
        assert set(res) == set(ref) == set(range(4))
        for sid in ref:
            assert np.array_equal(res[sid].counts, ref[sid].counts), sid
            assert res[sid].latency_steps == ref[sid].latency_steps
        print("OK")
    """)
