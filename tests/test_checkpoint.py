"""Checkpointer failure paths: async write errors and crash atomicity.

test_train.py covers the happy path (roundtrip, retention, async
completion); this file covers the two §15 robustness guarantees:

  * an **async** writer failure must not vanish with its worker thread —
    it is re-raised on the next ``wait()``/``save()``, and the failed
    attempt leaves no visible ``step_<n>/`` dir and no ``.tmp`` debris;
  * a crash **mid-write** (after leaves, before the atomic rename) leaves
    only a ``.tmp`` dir, which ``steps()``/``latest_step()`` ignore, so a
    restart resumes from the previous complete step.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint.checkpointer as ckpt_mod
from repro.checkpoint.checkpointer import Checkpointer


def _tree(v):
    return {"a": jnp.full((3,), float(v)), "b": jnp.arange(4) * v}


def test_async_write_failure_surfaces_on_wait(tmp_path, monkeypatch):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(1), blocking=True)

    def _boom(*a, **k):
        raise OSError("disk full")

    monkeypatch.setattr(ckpt_mod.np, "save", _boom)
    ck.save(2, _tree(2))  # async: the failure lands on the worker thread
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        ck.wait()
    # the failed attempt is invisible: no step dir, no .tmp debris
    assert ck.steps() == [1]
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    # the error does not wedge the checkpointer: wait() is clean again...
    ck.wait()
    monkeypatch.undo()
    # ...and the next save works and is restorable
    ck.save(3, _tree(3))
    ck.wait()
    assert ck.latest_step() == 3
    restored = ck.restore(3, _tree(0))
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.full((3,), 3.0))


def test_async_write_failure_surfaces_on_next_save(tmp_path, monkeypatch):
    """save() joins the outstanding write first, so a failed async write
    also surfaces on the *next* save call — it can never be lost."""
    ck = Checkpointer(str(tmp_path))
    monkeypatch.setattr(ckpt_mod.np, "save", lambda *a, **k: (_ for _ in ()).throw(OSError("boom")))
    ck.save(1, _tree(1))
    with pytest.raises(RuntimeError, match="async checkpoint write failed"):
        ck.save(2, _tree(2))  # joins the failed write before snapshotting
    monkeypatch.undo()
    ck.save(2, _tree(2), blocking=True)
    assert ck.steps() == [2]


def test_crash_mid_write_leaves_only_tmp_and_resumes(tmp_path, monkeypatch):
    """Kill the writer between the leaf files and the atomic rename: only
    step_<n>.tmp exists, the step index never sees it, and a fresh
    Checkpointer over the same dir restores the previous complete step."""
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(5), blocking=True)

    def _crash(src, dst):
        raise KeyboardInterrupt("simulated crash at the rename boundary")

    monkeypatch.setattr(ckpt_mod.os, "rename", _crash)
    with pytest.raises(KeyboardInterrupt):
        ck.save(6, _tree(6), blocking=True)
    monkeypatch.undo()
    # the half-written snapshot is present on disk but never visible as a step
    assert (tmp_path / "step_6.tmp").is_dir()
    assert not (tmp_path / "step_6").exists()
    survivor = Checkpointer(str(tmp_path))  # "restart"
    assert survivor.steps() == [5]
    assert survivor.latest_step() == 5
    restored = survivor.restore(5, _tree(0))
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.full((3,), 5.0))
    # a later successful save of the same step clears the stale .tmp
    survivor.save(6, _tree(6), blocking=True)
    assert survivor.steps() == [5, 6]
    assert not (tmp_path / "step_6.tmp").exists()
