"""Batched, backend-pluggable event dispatch (core/dispatch.py).

Covers the acceptance criteria of the batched-dispatch refactor and the
event-sparse delivery layer:
  * batched step/run == independent single runs (B=3 vs 3x B=1)
  * every registered backend (reference / pallas / sharded / fused) matches
    the dense oracle for B in {1, 4} at activity levels {1%, 10%, 100%},
    dense and event-queued (queue below capacity)
  * the batched Pallas kernels match the batched jnp reference
  * registry ergonomics (unknown names, instance pass-through)
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.dispatch import (
    DispatchBackend,
    FusedBackend,
    PallasBackend,
    available_backends,
    get_backend,
)
from repro.core.event_engine import EventEngine, dense_weights_from_tables
from repro.core.tags import NetworkSpec, compile_network
from repro.core.two_stage import stage1_route, stage2_cam_match, two_stage_deliver
from repro.kernels.cam_match.cam_match import cam_match_pallas
from repro.kernels.cam_match.ref import cam_match_ref


ALL_BACKENDS = ["reference", "pallas", "sharded", "fused"]


def _bk(name):
    """'pallas'/'fused' with the platform default would fall back to the jnp
    reference on CPU; force interpret mode so CI exercises the real kernels."""
    if name == "pallas":
        return PallasBackend(interpret=True)
    if name == "fused":
        return FusedBackend(interpret=True)
    return name


def _tables(seed, n=48, cluster=16, k=48, edges=60):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=24, max_sram_entries=16)
    seen = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        spec.connect(s, d, int(rng.integers(4)))
    return compile_network(spec)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_lists_all_builtin_backends():
    assert {"reference", "pallas", "sharded", "fused"} <= set(available_backends())


def test_unknown_backend_raises_with_choices():
    with pytest.raises(ValueError, match="unknown dispatch backend"):
        get_backend("no-such-backend")


def test_instance_passes_through_and_options_construct():
    inst = PallasBackend(block_c=8)
    assert get_backend(inst) is inst
    assert get_backend("pallas", block_c=8) == inst
    assert isinstance(get_backend(None), DispatchBackend)  # default
    with pytest.raises(ValueError, match="passed as an instance"):
        get_backend(inst, block_c=4)  # options + instance = caller confusion


# ---------------------------------------------------------------------------
# batched primitives == per-element single calls
# ---------------------------------------------------------------------------
def test_batched_stage1_equals_stacked_single():
    tables = _tables(0)
    rng = np.random.default_rng(1)
    spikes = jnp.asarray(rng.random((5, tables.n_neurons)), jnp.float32)
    src_tag, src_dest = jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest)
    batched = stage1_route(spikes, src_tag, src_dest, tables.n_clusters, tables.k_tags)
    singles = jnp.stack([
        stage1_route(spikes[i], src_tag, src_dest, tables.n_clusters, tables.k_tags)
        for i in range(5)
    ])
    np.testing.assert_allclose(np.asarray(batched), np.asarray(singles), rtol=1e-6)


def test_batched_stage2_equals_stacked_single():
    tables = _tables(2)
    rng = np.random.default_rng(3)
    act = jnp.asarray(rng.random((4, tables.n_clusters, tables.k_tags)), jnp.float32)
    cam_tag, cam_syn = jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn)
    batched = stage2_cam_match(act, cam_tag, cam_syn, tables.cluster_size)
    singles = jnp.stack([
        stage2_cam_match(act[i], cam_tag, cam_syn, tables.cluster_size) for i in range(4)
    ])
    np.testing.assert_allclose(np.asarray(batched), np.asarray(singles), rtol=1e-6)


# ---------------------------------------------------------------------------
# backend parity vs the dense oracle, B in {1, 4}
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("b", [1, 4])
def test_backend_matches_dense_oracle(backend, b):
    tables = _tables(7)
    dense = jnp.asarray(dense_weights_from_tables(tables))
    rng = np.random.default_rng(b * 100 + 9)
    spikes = jnp.asarray(rng.random((b, tables.n_neurons)) < 0.3, jnp.float32)
    drive = two_stage_deliver(
        spikes,
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags, backend=_bk(backend),
    )
    ref = jnp.einsum("dst,bs->bdt", dense, spikes)
    assert drive.shape == (b, tables.n_neurons, 4)
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("b", [1, 4])
@pytest.mark.parametrize("activity", [0.01, 0.1, 1.0])
def test_backend_event_queued_matches_dense_oracle(backend, b, activity):
    """Event-sparse delivery == dense oracle at every sparsity level, for
    every backend, while the AER queue is below capacity (DESIGN.md §10)."""
    tables = _tables(31)
    dense = jnp.asarray(dense_weights_from_tables(tables))
    rng = np.random.default_rng(int(activity * 100) + b)
    spikes = jnp.asarray(rng.random((b, tables.n_neurons)) < activity, jnp.float32)
    drive, stats = two_stage_deliver(
        spikes,
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags, backend=_bk(backend),
        queue_capacity=tables.n_neurons, with_stats=True,
    )
    ref = jnp.einsum("dst,bs->bdt", dense, spikes)
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-5, atol=1e-6)
    assert stats.dropped.shape == (b,)
    assert int(np.asarray(stats.dropped).max()) == 0  # below capacity: lossless


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_multidim_batch_shape(backend):
    """The [..., N] contract holds for >1 leading batch dims on every backend."""
    tables = _tables(23)
    rng = np.random.default_rng(24)
    spikes = jnp.asarray(rng.random((2, 3, tables.n_neurons)) < 0.3, jnp.float32)
    drive = two_stage_deliver(
        spikes,
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags, backend=_bk(backend),
    )
    dense = jnp.asarray(dense_weights_from_tables(tables))
    ref = jnp.einsum("dst,bcs->bcdt", dense, spikes)
    assert drive.shape == (2, 3, tables.n_neurons, 4)
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_backend_unbatched_shape_preserved(backend):
    """B-less inputs keep the original [N, 4] contract on every backend."""
    tables = _tables(5)
    rng = np.random.default_rng(6)
    spikes = jnp.asarray(rng.random(tables.n_neurons) < 0.3, jnp.float32)
    drive = two_stage_deliver(
        spikes,
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags, backend=_bk(backend),
    )
    dense = jnp.asarray(dense_weights_from_tables(tables))
    ref = jnp.einsum("dst,s->dt", dense, spikes)
    assert drive.shape == (tables.n_neurons, 4)
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# batched Pallas kernel vs batched reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b", [1, 4])
def test_cam_match_pallas_batched_matches_ref(b):
    rng = np.random.default_rng(b)
    ncl, c, s, k = 3, 16, 8, 32
    n = ncl * c
    act = jnp.asarray(rng.random((b, ncl, k)), jnp.float32)
    tag = jnp.asarray(rng.integers(-1, k, (n, s)), jnp.int32)
    syn = jnp.asarray(rng.integers(0, 4, (n, s)), jnp.int32)
    out_k = cam_match_pallas(act, tag, syn, c, block_c=8)
    out_r = cam_match_ref(act, tag, syn, c)
    assert out_k.shape == (b, n, 4)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused Pallas kernel vs the jnp event-sparse reference
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b", [1, 3])
def test_fused_deliver_pallas_matches_ref(b):
    from repro.core.two_stage import compact_events
    from repro.kernels.fused_deliver import fused_deliver, fused_deliver_ref

    rng = np.random.default_rng(b + 40)
    ncl, c, s, k, e = 3, 16, 8, 32, 4
    n = ncl * c
    src_tag = jnp.asarray(rng.integers(-1, k, (n, e)), jnp.int32)
    src_dest = jnp.asarray(rng.integers(0, ncl, (n, e)), jnp.int32)
    cam_tag = jnp.asarray(rng.integers(-1, k, (n, s)), jnp.int32)
    cam_syn = jnp.asarray(rng.integers(0, 4, (n, s)), jnp.int32)
    spikes = jnp.asarray(rng.random((b, n)) < 0.4, jnp.float32)
    ext = jnp.asarray(rng.random((b, ncl, k)), jnp.float32)
    queue = compact_events(spikes, 24)
    out_k = fused_deliver(
        queue, src_tag, src_dest, cam_tag, cam_syn, c, k,
        external_activity=ext, block_c=8, interpret=True,
    )
    out_r = fused_deliver_ref(
        queue, src_tag, src_dest, cam_tag, cam_syn, c, k, external_activity=ext
    )
    assert out_k.shape == (b, n, 4)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: batched carry == independent single runs
# ---------------------------------------------------------------------------
def test_engine_batched_step_equals_independent_runs():
    tables = _tables(11)
    eng = EventEngine(tables)
    b = 3
    rng = np.random.default_rng(12)
    # distinct stimulus per stream so the batch is genuinely heterogeneous
    inp_b = jnp.asarray(rng.random((b, tables.n_clusters, tables.k_tags)) * 4.0,
                        jnp.float32)
    carry_b = eng.init_state(batch=b)
    singles = [eng.init_state() for _ in range(b)]
    for _ in range(20):
        carry_b, spikes_b = eng.step(carry_b, inp_b)
        for i in range(b):
            singles[i], s_i = eng.step(singles[i], inp_b[i])
            np.testing.assert_allclose(
                np.asarray(spikes_b[i]), np.asarray(s_i), atol=1e-6
            )
    for i in range(b):
        np.testing.assert_allclose(
            np.asarray(carry_b[0].v[i]), np.asarray(singles[i][0].v), atol=1e-6
        )


def test_engine_batched_run_scan_shapes_and_no_nan():
    tables = _tables(13)
    eng = EventEngine(tables)
    b, t = 4, 30
    inp = jnp.zeros((t, b, tables.n_clusters, tables.k_tags)).at[:, :, :, :4].set(2.0)
    carry, out = eng.run(eng.init_state(batch=b), inp)
    assert out.shape == (t, b, tables.n_neurons)
    assert carry[0].v.shape == (b, tables.n_neurons)
    assert not bool(jnp.isnan(out).any())


@pytest.mark.parametrize("backend", ["pallas", "sharded", "fused"])
def test_engine_backends_agree_with_reference_batched(backend):
    tables = _tables(17)
    b = 2
    inp = jnp.zeros((b, tables.n_clusters, tables.k_tags)).at[:, :, 0].set(4.0)
    eng_ref = EventEngine(tables, backend="reference")
    eng_alt = EventEngine(tables, backend=_bk(backend))
    carry_r, carry_a = eng_ref.init_state(batch=b), eng_alt.init_state(batch=b)
    for _ in range(10):
        carry_r, s_r = eng_ref.step(carry_r, inp)
        carry_a, s_a = eng_alt.step(carry_a, inp)
        np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_r), atol=1e-5)


def test_dense_reference_step_batched():
    from repro.core.event_engine import dense_reference_step
    from repro.core.neuron import NeuronParams, init_state

    tables = _tables(19)
    dense = jnp.asarray(dense_weights_from_tables(tables))
    params = NeuronParams()
    b = 3
    rng = np.random.default_rng(20)
    spikes = jnp.asarray(rng.random((b, tables.n_neurons)) < 0.4, jnp.float32)
    state_b = init_state(tables.n_neurons, params, batch=b)
    new_b, out_b = dense_reference_step(dense, spikes, state_b, params)
    for i in range(b):
        state_i = init_state(tables.n_neurons, params)
        new_i, out_i = dense_reference_step(dense, spikes[i], state_i, params)
        np.testing.assert_allclose(np.asarray(out_b[i]), np.asarray(out_i), atol=1e-6)
        np.testing.assert_allclose(np.asarray(new_b.v[i]), np.asarray(new_i.v), atol=1e-6)
