"""Dispatch autotuner (DESIGN.md §18).

``backend="auto"`` measures the dense/queued/fused crossover at the
engine's (activity, batch) operating point and builds the winner. The
load-bearing claims:

  * injected measurements make the decision a pure function — same inputs,
    same :class:`AutotuneDecision`, no timers involved;
  * ties break in candidate order (a stable decision under equal timings);
  * the decision actually changes the built step: a ``dense`` winner
    bypasses AER queue compaction (zero reported drops), a ``queued``
    winner is bit-identical to an explicit ``backend="reference"`` engine
    under the same queue capacity;
  * the decision is part of the serving identity: pools tuned to different
    winners have different fingerprints (checkpoint restore refuses a
    differently-tuned engine);
  * misuse is a typed error, not silent fallback.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnn import compile_poker_cnn
from repro.core.dispatch import (
    AutotuneDecision,
    autotune_backend,
    autotune_candidates,
)
from repro.core.event_engine import EventEngine
from repro.core.tags import NetworkSpec, compile_network
from repro.serve.aer import AerServeConfig, AerSessionPool


@functools.lru_cache(maxsize=1)
def _tables():
    rng = np.random.default_rng(0)
    n = 64
    spec = NetworkSpec(n_neurons=n, cluster_size=8, k_tags=64)
    for _ in range(150):
        spec.connect(int(rng.integers(n)), int(rng.integers(n)),
                     int(rng.integers(4)))
    return compile_network(spec)


def _decide(measure, **kw):
    t = _tables()
    return autotune_backend(
        t.src_tag, t.src_dest, t.cam_tag, t.cam_syn,
        t.cluster_size, t.k_tags, measure=measure, **kw,
    )


# ---------------------------------------------------------------------------
# decision mechanics
# ---------------------------------------------------------------------------
def test_fully_injected_decision_is_deterministic():
    measure = {"dense": 3.0, "queued": 1.5, "fused": 2.0}
    a = _decide(measure, activity=0.25, batch=4)
    b = _decide(measure, activity=0.25, batch=4)
    assert a == b  # no timing ran: the decision is a pure function
    assert a.winner == "queued" and a.backend == "reference" and not a.dense
    assert a.measurements == (("dense", 3.0), ("queued", 1.5), ("fused", 2.0))
    assert a.token() == "autotune:queued:act0.25:B4"


def test_tie_breaks_in_candidate_order():
    flat = {"dense": 1.0, "queued": 1.0, "fused": 1.0}
    assert _decide(flat).winner == "dense"
    swapped = _decide(flat, candidates=("fused", "queued", "dense"))
    assert swapped.winner == "fused"


def test_noise_band_resolves_dead_heats_to_the_earlier_candidate():
    """A dead heat (queued under a lossless queue degenerates to dense)
    times within jitter of the fastest; the tol band must resolve it to
    the earlier candidate instead of flipping on the argmin."""
    near = {"dense": 1.04, "queued": 1.0, "fused": 3.0}
    assert _decide(near).winner == "dense"  # within the default 5% band
    assert _decide(near, tol=0.0).winner == "queued"  # strict argmin
    clear = {"dense": 2.0, "queued": 1.0, "fused": 3.0}
    assert _decide(clear).winner == "queued"  # real wins are untouched


def test_lossless_queue_aliases_queued_to_dense_structurally():
    """Under a lossless queue the queued path IS dense (the §10 shortcut):
    the tuner must record dense's timing for it instead of racing two
    timings of the same program, so queued can never win the dead heat no
    matter what the clock does."""
    for cap in (None, _tables().n_neurons):
        d = _decide({"fused": 9e9}, queue_capacity=cap)
        m = dict(d.measurements)
        assert m["queued"] == m["dense"]  # one timing, copied — not re-raced
        assert d.winner == "dense"
    # a genuinely compacting capacity still times queued independently
    d = _decide({"fused": 9e9}, queue_capacity=4, activity=1.0)
    m = dict(d.measurements)
    assert m["queued"] != m["dense"]


def test_dense_winner_bypasses_compaction():
    d = _decide({"dense": 1.0, "queued": 2.0, "fused": 3.0})
    assert d.winner == "dense" and d.backend == "reference" and d.dense


def test_fabric_ring_requires_injected_measurement():
    with pytest.raises(ValueError, match="injected"):
        _decide(None, candidates=("fabric_ring",))
    d = _decide({"fabric_ring": 0.5, "dense": 9.0},
                candidates=("dense", "fabric_ring"))
    assert d.winner == "fabric_ring" and d.backend == "fabric"
    assert "fabric_ring" in autotune_candidates()


def test_unknown_candidate_rejected():
    with pytest.raises(ValueError, match="unknown autotune candidate"):
        _decide(None, candidates=("dense", "sparse"))


# ---------------------------------------------------------------------------
# the decision is honored by the built engine
# ---------------------------------------------------------------------------
def _run(engine, steps=4, batch=2, seed=1):
    """Kick every neuron at step 0 only: later dynamics are driven purely by
    delivered events, so dropped events must show up in the final state."""
    t = _tables()
    rng = np.random.default_rng(seed)
    ev = jnp.asarray(
        rng.random((steps, batch, t.n_clusters, t.k_tags)) < 0.2, jnp.float32
    ) * 4.0
    i_ext = jnp.zeros((steps, batch, t.n_neurons)).at[0].set(4e3)
    carry, (spikes, stats) = engine.run(
        engine.init_state(batch=batch), ev, i_ext)
    return carry, np.asarray(spikes), jax.tree.map(np.asarray, stats)


def test_queued_decision_bit_identical_to_reference_engine():
    t = _tables()
    d = _decide({"dense": 9.0, "queued": 1.0, "fused": 5.0},
                queue_capacity=4)
    auto = EventEngine(t, backend="auto", queue_capacity=4,
                       autotune={"decision": d})
    assert auto.autotune_decision == d
    ref = EventEngine(t, backend="reference", queue_capacity=4)
    ca, sp_a, st_a = _run(auto)
    cr, sp_r, st_r = _run(ref)
    np.testing.assert_array_equal(sp_a, sp_r)
    jax.tree.map(np.testing.assert_array_equal, st_a, st_r)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        ca, cr,
    )


def test_dense_and_queued_decisions_build_different_steps():
    """Under a 4-deep AER queue the dense winner sees every event (zero
    reported drops) while the queued winner compacts and drops; the lost
    events change delivery, so the spike rasters diverge once the first
    post-drop step's spikes feed back (step >= 2)."""
    t = _tables()
    mk = lambda m: EventEngine(
        t, backend="auto", queue_capacity=4,
        autotune={"decision": _decide(m, queue_capacity=4)},
    )
    dense = mk({"dense": 1.0, "queued": 9.0, "fused": 9.0})
    queued = mk({"dense": 9.0, "queued": 1.0, "fused": 9.0})
    assert dense._autotune_dense and not queued._autotune_dense
    cd, sp_d, st_d = _run(dense, steps=6)
    cq, sp_q, st_q = _run(queued, steps=6)
    assert int(np.asarray(st_d.dropped).sum()) == 0  # bypassed compaction
    assert int(np.asarray(st_q.dropped).sum()) > 0  # 4-deep queue overflowed
    # the dropped events were delivery the dense path integrated: the final
    # membrane state must differ even if neither raster re-crosses threshold
    assert not np.array_equal(np.asarray(cd[0].v), np.asarray(cq[0].v))


# ---------------------------------------------------------------------------
# serving identity + typed misuse
# ---------------------------------------------------------------------------
def test_pool_fingerprint_carries_the_decision():
    cc = compile_poker_cnn()
    cfg = AerServeConfig(pool_size=2, max_steps=12)

    def pool(measure):
        d = autotune_backend(
            cc.tables.src_tag, cc.tables.src_dest, cc.tables.cam_tag,
            cc.tables.cam_syn, cc.tables.cluster_size, cc.tables.k_tags,
            measure=measure,
        )
        return AerSessionPool.from_models(
            {"m": cc}, cfg, backend="auto", autotune={"decision": d})

    p_dense = pool({"dense": 1.0, "queued": 2.0, "fused": 3.0})
    p_queued = pool({"dense": 2.0, "queued": 1.0, "fused": 3.0})
    p_dense2 = pool({"dense": 1.0, "queued": 5.0, "fused": 9.0})
    assert p_dense.fingerprint() != p_queued.fingerprint()
    assert p_dense.fingerprint() == p_dense2.fingerprint()  # decision, not µs
    untuned = AerSessionPool.from_models({"m": cc}, cfg, backend="reference")
    assert untuned.fingerprint() != p_queued.fingerprint()


def test_autotune_misuse_is_typed():
    t = _tables()
    d = _decide({"dense": 1.0, "queued": 2.0, "fused": 3.0})
    with pytest.raises(ValueError, match="backend='auto'"):
        EventEngine(t, backend="reference", autotune={"decision": d})
    with pytest.raises(ValueError, match="explicit backend"):
        from repro.core.routing import Fabric

        EventEngine(t, backend="auto", fabric=Fabric(grid_x=2, grid_y=1))
    with pytest.raises(ValueError, match="exclusive"):
        EventEngine(t, backend="auto",
                    autotune={"decision": d, "activity": 0.5})
