"""Layer-level equivalences: attention paths, MLA, Mamba2, RWKV6, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import attention as at
from repro.models.mla import init_mla, init_mla_cache, mla_layer
from repro.models.moe import init_moe, moe_local, moe_reference
from repro.models.rwkv import init_rwkv6, init_rwkv6_state, rwkv6_layer
from repro.models.ssm import init_mamba2, init_mamba2_state, mamba2_layer


@pytest.mark.parametrize("window", [None, 17])
@pytest.mark.parametrize("softcap", [None, 20.0])
def test_chunked_attention_equals_dense(window, softcap):
    rng = jax.random.PRNGKey(0)
    B, S, H, KV, D = 2, 130, 8, 2, 16
    q = jax.random.normal(rng, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, S, KV, D))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    d = at.attend_dense(q, k, v, pos, pos, window=window, scale=0.25, softcap=softcap)
    c = at.attend_chunked(
        q, k, v, pos, pos, window=window, scale=0.25, softcap=softcap, block_q=32, block_k=32
    )
    np.testing.assert_allclose(np.asarray(d), np.asarray(c), atol=2e-6)


@pytest.mark.parametrize("window", [None, 5])
def test_attention_prefill_decode_equals_full(window):
    cfg = ModelConfig(d_model=64, n_heads=8, n_kv_heads=2, head_dim=16, qk_norm=True)
    params = at.init_attention(jax.random.PRNGKey(3), cfg, jnp.float32)
    B, S = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, 64))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = at.attention_layer(params, x, pos, cfg, window=window)
    cache = at.init_kv_cache(B, S, 2, 16, window, jnp.float32)
    out, cache = at.attention_layer(params, x[:, :8], pos[:, :8], cfg, window=window, cache=cache)
    outs = [out]
    for t in range(8, S):
        o, cache = at.attention_layer(
            params, x[:, t : t + 1], pos[:, t : t + 1], cfg, window=window, cache=cache
        )
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=2e-5
    )


def test_mla_absorbed_decode_equals_naive():
    cfg = ModelConfig(
        d_model=64, n_heads=4, q_lora_rank=24, kv_lora_rank=16,
        qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
    )
    params = init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 64))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = mla_layer(params, x, pos, cfg)
    cache = init_mla_cache(B, S, cfg, jnp.float32)
    out, cache = mla_layer(params, x[:, :6], pos[:, :6], cfg, cache)
    outs = [out]
    for t in range(6, S):
        o, cache = mla_layer(params, x[:, t : t + 1], pos[:, t : t + 1], cfg, cache)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(full), atol=2e-5
    )


def test_mamba2_chunked_equals_sequential_and_decode():
    cfg = ModelConfig(d_model=32, ssm_state=8, ssm_expand=2, ssm_heads=4, ssm_chunk=16)
    params = init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 50
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    y_seq, _ = mamba2_layer(params, u, cfg, sequential=True)
    y_chk, _ = mamba2_layer(params, u, cfg)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk), atol=2e-5)
    st = init_mamba2_state(B, cfg)
    y_p, st = mamba2_layer(params, u[:, :30], cfg, state=st)
    outs = [y_p]
    for t in range(30, S):
        o, st = mamba2_layer(params, u[:, t : t + 1], cfg, state=st)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(y_seq), atol=2e-5
    )


def test_rwkv6_chunked_equals_sequential_and_decode():
    cfg = ModelConfig(d_model=32, n_heads=4, ssm_chunk=8, rwkv_lora_w=8, rwkv_lora_mix=4)
    params = init_rwkv6(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, S = 2, 36
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, 32)) * 0.5
    y_seq, _ = rwkv6_layer(params, x, cfg, sequential=True)
    y_chk, _ = rwkv6_layer(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_chk), atol=2e-5)
    st = init_rwkv6_state(B, cfg)
    y_p, st = rwkv6_layer(params, x[:, :20], cfg, state=st)
    outs = [y_p]
    for t in range(20, S):
        o, st = rwkv6_layer(params, x[:, t : t + 1], cfg, state=st)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(y_seq), atol=2e-5
    )


@pytest.mark.parametrize("aux_free", [True, False])
def test_moe_local_equals_reference(aux_free):
    cfg = ModelConfig(
        d_model=32, n_experts=8, top_k=2, moe_d_ff=16, capacity_factor=8.0,
        router_aux_free=aux_free,
    )
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 32))
    y_ref, aux_r = moe_reference(params, x, cfg)
    y_loc, aux_l = moe_local(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_loc), atol=2e-6)
    assert bool((aux_r["load"] == aux_l["load"]).all())


def test_moe_capacity_drops_tokens_gracefully():
    """With capacity 1 most assignments drop; output stays finite and the
    kept assignments still route correctly."""
    cfg = ModelConfig(d_model=16, n_experts=4, top_k=2, moe_d_ff=8, capacity_factor=1.0)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y, _ = moe_local(params, x, cfg, capacity=1)
    assert not bool(jnp.isnan(y).any())


def test_head_padding_is_exact():
    """Zero-weight padded heads (TP-divisibility trick) leave outputs exact."""
    import dataclasses

    cfg = ModelConfig(d_model=64, n_heads=6, n_kv_heads=2, head_dim=16)
    cfg_p = dataclasses.replace(cfg, n_heads=8)
    params = at.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    # GQA-aware padding: zero heads go at the END OF EACH KV GROUP
    # (group size 3 -> 4), otherwise heads change kv-group membership.
    idx = jnp.asarray([g * 4 + i for g in range(2) for i in range(3)])
    padded = {
        "wq": jnp.zeros((64, 8, 16)).at[:, idx].set(params["wq"]),
        "wk": params["wk"],
        "wv": params["wv"],
        "wo": jnp.zeros((8, 16, 64)).at[idx].set(params["wo"]),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64))
    pos = jnp.broadcast_to(jnp.arange(10)[None], (2, 10))
    y0, _ = at.attention_layer(params, x, pos, cfg, window=None)
    y1, _ = at.attention_layer(padded, x, pos, cfg_p, window=None)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-6)
