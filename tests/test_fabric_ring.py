"""Ring fast path (DESIGN.md §14): time-wheel delivery vs the roll oracle.

The property suite locks the tentpole equivalence: the static-entry-table /
prefix-count / time-wheel pipeline of kernels/fabric_deliver must be
bit-identical to the per-step roll pipeline (``compact_events`` →
``stage1_route_events_fabric`` → ``advance_inflight``) in everything
integer-valued — arrival steps, drive patterns, queue drops, link drops,
delivered/hops counts — across random geometries, delays and capacities,
including cursor wraparound (T > max_delay). Float latency/energy sums may
associate differently (same addends) and are compared allclose.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dispatch import FabricBackend
from repro.core.event_engine import EventEngine
from repro.core.routing import ChipConstants, Fabric
from repro.core.two_stage import (
    _accumulate_into,
    compact_events,
    stage1_route,
    stage1_route_events,
)
from repro.kernels.fabric_deliver.ref import fabric_deliver_ring_ref

from tests._hypothesis_compat import given, settings, st

DT = 1e-3


def _random_tables(rng, n, n_clusters, k, e=3, s=4):
    src_tag = rng.integers(-1, k, (n, e)).astype(np.int32)
    src_dest = rng.integers(0, n_clusters, (n, e)).astype(np.int32)
    cam_tag = rng.integers(-1, k, (n, s)).astype(np.int32)
    cam_syn = rng.integers(0, 4, (n, s)).astype(np.int32)
    return src_tag, src_dest, cam_tag, cam_syn


def _assert_stats_equal(a, b, msg, float_rtol=1e-5):
    for f in ("dropped", "link_dropped", "delivered", "hops"):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{msg}: {f}",
        )
    for f in ("latency_s", "energy_j"):
        np.testing.assert_allclose(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            rtol=float_rtol, err_msg=f"{msg}: {f}",
        )


# ---------------------------------------------------------------------------
# the tentpole property: ring == roll, bit-exact on integers, over whole runs
# ---------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    grid=st.sampled_from([(1, 2), (2, 2), (3, 2)]),
    cores_per_tile=st.integers(1, 2),
    cluster_size=st.integers(2, 5),
    k_tags=st.sampled_from([4, 8, 16]),
    link_capacity=st.sampled_from([None, 1, 2, 4]),
    queue_frac=st.sampled_from([0.25, 0.6, 1.0]),
    latency_mult=st.sampled_from([0.5, 1.0, 2.0]),
    batch=st.sampled_from([None, 2]),
)
def test_ring_matches_roll_property(
    seed, grid, cores_per_tile, cluster_size, k_tags, link_capacity,
    queue_frac, latency_mult, batch,
):
    """Random geometry/delay/capacity: the ring fast path, the ring ref and
    the roll oracle agree step-for-step over T > max_delay steps (cursor
    wraps), on drives and every integer stat; floats allclose."""
    gx, gy = grid
    fab = Fabric(
        grid_x=gx, grid_y=gy, cores_per_tile=cores_per_tile,
        constants=ChipConstants(latency_across_chip_s=latency_mult * DT),
    )
    nc = fab.n_cores
    n = nc * cluster_size
    rng = np.random.default_rng(seed)
    src_tag, src_dest, cam_tag, cam_syn = _random_tables(rng, n, nc, k_tags)
    qcap = max(1, int(queue_frac * n))

    be = FabricBackend(fabric=fab, dt=DT, link_capacity=link_capacity)
    model, arrs = be.model_for(nc)
    entries = be.build_entries(src_tag, src_dest, cluster_size, k_tags)
    t_steps = model.max_delay + 3  # > max_delay + 1: the cursor wraps

    inflight = be.init_inflight(nc, k_tags, batch=batch)
    ring_f, cur_f = be.init_ring(nc, k_tags, batch=batch)
    ring_r, cur_r = be.init_ring(nc, k_tags, batch=batch)
    lead = () if batch is None else (batch,)
    for t in range(t_steps):
        spikes = jnp.asarray(
            (rng.random((*lead, n)) < 0.4) * rng.random((*lead, n)), jnp.float32
        )
        d_roll, inflight, s_roll = be.deliver_fabric(
            spikes, src_tag, src_dest, cam_tag, cam_syn, cluster_size, k_tags,
            inflight=inflight, queue_capacity=qcap,
        )
        d_fast, ring_f, cur_f, s_fast = be.deliver_fabric_ring(
            spikes, entries, cam_tag, cam_syn, cluster_size, k_tags,
            ring_f, cur_f, queue_capacity=qcap,
        )
        d_ref, ring_r, cur_r, s_ref = fabric_deliver_ring_ref(
            spikes, jnp.asarray(src_tag), jnp.asarray(src_dest),
            jnp.asarray(cam_tag), jnp.asarray(cam_syn), cluster_size, k_tags,
            ring_r, cur_r, cluster_tile=arrs["cluster_tile"],
            delay_steps=arrs["delay_steps"], n_tiles=model.n_tiles,
            max_delay=model.max_delay, link_capacity=model.link_capacity,
            queue_capacity=qcap, mesh_hops=arrs["mesh_hops"],
            latency_s=arrs["latency_s"], energy_j=arrs["energy_j"],
        )
        np.testing.assert_allclose(
            np.asarray(d_roll), np.asarray(d_ref), rtol=1e-6, atol=1e-6,
            err_msg=f"step {t}: roll vs ref drive",
        )
        np.testing.assert_allclose(
            np.asarray(d_roll), np.asarray(d_fast), rtol=1e-5, atol=1e-5,
            err_msg=f"step {t}: roll vs fast-path drive",
        )
        _assert_stats_equal(s_roll, s_ref, f"step {t}: roll vs ref")
        _assert_stats_equal(s_roll, s_fast, f"step {t}: roll vs fast")
    # after T steps the wheel has wrapped; cursors agree and the carried
    # mass (events still in transit) matches the roll's in-flight tail
    assert int(cur_f) == t_steps % (model.max_delay + 1) == int(cur_r)
    np.testing.assert_allclose(
        np.asarray(ring_f).sum(), np.asarray(inflight).sum(), rtol=1e-5
    )


# ---------------------------------------------------------------------------
# engine-level parity + carry contract
# ---------------------------------------------------------------------------
def _engine_tables(rng, n=48, cluster=6, k=12):
    from repro.core.tags import RoutingTables

    nc = n // cluster
    src_tag, src_dest, cam_tag, cam_syn = _random_tables(rng, n, nc, k)
    return RoutingTables(
        src_tag=src_tag, src_dest=src_dest, cam_tag=cam_tag, cam_syn=cam_syn,
        cluster_size=cluster, k_tags=k,
    )


def _engines(tables, **extra):
    from repro.core.neuron import NeuronParams

    params = NeuronParams(dt=DT)
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2,
                 constants=ChipConstants(latency_across_chip_s=2 * DT))
    ring = EventEngine(tables, params, fabric=fab, queue_capacity=20,
                       fabric_options={"dt": DT, **extra})
    roll = EventEngine(tables, params, fabric=fab, queue_capacity=20,
                       fabric_options={"dt": DT, "ring": False, **extra})
    return ring, roll


def test_engine_ring_run_matches_roll():
    """Whole-scan engine parity: spikes and stats identical ring vs roll,
    over enough steps for several cursor revolutions."""
    rng = np.random.default_rng(2)
    tables = _engine_tables(rng)
    e_ring, e_roll = _engines(tables)
    assert e_ring.fabric_ring and not e_roll.fabric_ring
    assert e_ring.fabric_model.max_delay >= 2  # delays actually in play
    b, t = 3, 11
    inp = jnp.asarray(
        (rng.random((t, b, tables.n_clusters, tables.k_tags)) < 0.05) * 4.0,
        jnp.float32,
    )
    c_ring, (spk_ring, st_ring) = e_ring.run(e_ring.init_state(batch=b), inp)
    c_roll, (spk_roll, st_roll) = e_roll.run(e_roll.init_state(batch=b), inp)
    np.testing.assert_array_equal(np.asarray(spk_ring), np.asarray(spk_roll))
    _assert_stats_equal(st_ring, st_roll, "scan stats")
    assert len(c_ring) == 4 and len(c_roll) == 3
    assert c_ring[2].shape == (b, e_ring.fabric_model.max_delay + 1,
                               tables.n_clusters, tables.k_tags)
    assert int(c_ring[3]) == t % (e_ring.fabric_model.max_delay + 1)


def test_engine_ring_sharded_step_matches_local():
    """The ring-mode sharded fabric step (1x1 mesh; multi-device parity in
    test_distributed.py) matches the local ring step including the carried
    wheel and the replicated cursor."""
    rng = np.random.default_rng(3)
    tables = _engine_tables(rng)
    eng, _ = _engines(tables)
    mesh = jax.make_mesh((1,), ("model",))
    sharded = eng.make_sharded_step(mesh, axis="model")
    state, prev, ring, cur = eng.init_state()
    prev = prev.at[jnp.arange(0, tables.n_neurons, 3)].set(1.0)
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
    zeros = jnp.zeros((tables.n_neurons,))
    for t in range(5):
        (st_l, sp_l, ring_l, cur_l), (_, stats_l) = eng.step(
            (state, prev, ring, cur), inp
        )
        st_s, sp_s, ring_s, cur_s, stats_s = sharded(
            eng.tables, state, prev, ring, cur, inp, zeros
        )
        np.testing.assert_allclose(np.asarray(sp_l), np.asarray(sp_s), atol=1e-6)
        np.testing.assert_allclose(np.asarray(ring_l), np.asarray(ring_s), atol=1e-6)
        assert int(cur_l) == int(cur_s)
        _assert_stats_equal(stats_l, stats_s, f"step {t}")
        state, prev, ring, cur = st_l, sp_l, ring_l, cur_l


def test_reset_slots_ring_leak_free_at_any_phase():
    """Evicting a tenant mid-revolution (cursor != 0, events in transit at
    several depths) must zero that slot's entire wheel: with zero input the
    evicted slot stays silent for good, while the surviving tenant's
    in-transit events still arrive."""
    rng = np.random.default_rng(4)
    tables = _engine_tables(rng)
    eng, _ = _engines(tables)
    d1 = eng.fabric_model.max_delay + 1
    assert d1 >= 3
    b = 2
    carry = eng.init_state(batch=b)
    inp_hot = jnp.asarray(
        (rng.random((b, tables.n_clusters, tables.k_tags)) < 0.3) * 6.0,
        jnp.float32,
    )
    zero_inp = jnp.zeros_like(inp_hot)
    # drive both tenants until the cursor sits mid-phase with transit traffic
    for _ in range(d1 + 1):
        carry, _ = eng.step(carry, inp_hot)
    assert int(carry[3]) != 0  # genuinely mid-revolution
    assert float(jnp.abs(carry[2][0]).sum()) > 0  # slot 0 has events in transit
    carry = eng.reset_slots(carry, np.asarray([True, False]))
    assert float(jnp.abs(carry[2][0]).sum()) == 0.0
    survivor_delivered = 0
    for _ in range(2 * d1):
        carry, (spikes, stats) = eng.step(carry, zero_inp)
        assert float(jnp.abs(spikes[0]).sum()) == 0.0  # evicted slot silent
        assert int(stats.delivered[0]) == 0
        survivor_delivered += int(stats.delivered[1])
    assert survivor_delivered > 0  # the unmasked tenant kept its traffic


def test_ring_kernel_interpret_matches_jnp():
    """The fabric_deliver Pallas kernel (interpret mode) and the jnp fast
    path produce identical drives and rings over several wrapped steps."""
    rng = np.random.default_rng(5)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2,
                 constants=ChipConstants(latency_across_chip_s=2 * DT))
    nc, cs, k = fab.n_cores, 4, 8
    n = nc * cs
    src_tag, src_dest, cam_tag, cam_syn = _random_tables(rng, n, nc, k)
    be_j = FabricBackend(fabric=fab, dt=DT, link_capacity=2)
    be_k = FabricBackend(fabric=fab, dt=DT, link_capacity=2, interpret=True)
    entries = be_j.build_entries(src_tag, src_dest, cs, k)
    model, _ = be_j.model_for(nc)
    b = 2
    ring_j, cur_j = be_j.init_ring(nc, k, batch=b)
    ring_k, cur_k = be_k.init_ring(nc, k, batch=b)
    for t in range(2 * (model.max_delay + 1) + 1):
        spikes = jnp.asarray((rng.random((b, n)) < 0.5), jnp.float32)
        ext = jnp.asarray(rng.random((b, nc, k)) < 0.1, jnp.float32)
        d_j, ring_j, cur_j, s_j = be_j.deliver_fabric_ring(
            spikes, entries, cam_tag, cam_syn, cs, k, ring_j, cur_j,
            external_activity=ext, queue_capacity=n // 2,
        )
        d_k, ring_k, cur_k, s_k = be_k.deliver_fabric_ring(
            spikes, entries, cam_tag, cam_syn, cs, k, ring_k, cur_k,
            external_activity=ext, queue_capacity=n // 2,
        )
        np.testing.assert_allclose(
            np.asarray(d_j), np.asarray(d_k), atol=1e-5, err_msg=f"step {t}"
        )
        np.testing.assert_allclose(
            np.asarray(ring_j), np.asarray(ring_k), atol=1e-5, err_msg=f"step {t}"
        )
        _assert_stats_equal(s_j, s_k, f"step {t}")


# ---------------------------------------------------------------------------
# building blocks: scatter helper + dense stage-1 shortcut
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("path", ["flat32", "flat64", "2d"])
def test_accumulate_into_forced_paths_agree(path):
    """All overflow-guard paths of the in-place ring scatter add the same
    mass to the same cells — including out-of-range drops."""
    if path == "flat64" and not jax.config.jax_enable_x64:
        pytest.skip("flat64 path needs JAX_ENABLE_X64")
    rng = np.random.default_rng(6)
    b, size, m = 3, 40, 25
    buf = jnp.asarray(rng.random((b, size)), jnp.float32)
    flat = jnp.asarray(rng.integers(0, size, (b, m)), jnp.int32)
    w = jnp.asarray(rng.random((b, m)), jnp.float32)
    want = np.asarray(buf).copy()
    for i in range(b):
        for j in range(m):
            want[i, int(flat[i, j])] += float(w[i, j])
    got = _accumulate_into(buf, flat, w, _force_path=path)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)
    # batch-shared 1-D indices broadcast across the batch
    flat1 = flat[0]
    got1 = _accumulate_into(buf, flat1, w, _force_path=path)
    want1 = np.asarray(buf).copy()
    for i in range(b):
        for j in range(m):
            want1[i, int(flat1[j])] += float(w[i, j])
    np.testing.assert_allclose(np.asarray(got1), want1, rtol=1e-5)


def test_dense_stage1_shortcut_matches_lossless_queue():
    """queue_capacity >= N: the dense scatter shortcut is bit-identical to
    compacting through a lossless queue (the satellite-2 regression — the
    queued path at 100% activity paid compaction for nothing)."""
    rng = np.random.default_rng(7)
    n, nc, k = 48, 8, 16
    src_tag, src_dest, _, _ = _random_tables(rng, n, nc, k)
    spikes = jnp.asarray(
        (rng.random((4, n)) < 0.9) * rng.random((4, n)), jnp.float32
    )
    a_dense = stage1_route(spikes, src_tag, src_dest, nc, k)
    q = compact_events(spikes, n)
    a_queue = stage1_route_events(q, src_tag, src_dest, nc, k)
    np.testing.assert_array_equal(np.asarray(a_dense), np.asarray(a_queue))
    assert int(np.asarray(q.dropped).sum()) == 0
    # the backend hook takes the shortcut for cap >= N and stays bit-identical
    from repro.core.dispatch import _stage1_activity

    a_hook, dropped = _stage1_activity(spikes, src_tag, src_dest, nc, k, n)
    np.testing.assert_array_equal(np.asarray(a_hook), np.asarray(a_dense))
    assert int(np.asarray(dropped).sum()) == 0
