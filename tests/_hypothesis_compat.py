"""Optional-hypothesis shim: property tests skip cleanly without the extra.

``hypothesis`` lives in the ``test`` extra (pyproject.toml). When it isn't
installed, ``@given``-decorated tests must still *collect* — previously four
whole modules failed at import, taking their plain unit tests down with
them. Importing ``given``/``settings``/``st`` from here instead of from
``hypothesis`` degrades each property test to an individually-skipped test
while the rest of the module runs normally.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """st.<anything>(...) placeholder; values are never drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(_f):
            @pytest.mark.skip(reason="hypothesis not installed (pip install .[test])")
            def _skipped():
                pass  # pragma: no cover

            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda f: f
