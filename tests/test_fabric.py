"""Executable fabric delivery (DESIGN.md §11): latency, bandwidth, stats.

The contract under test:
  * the delivery model's per-cluster-pair matrices agree with the scalar
    ``Fabric`` methods (Table II-IV figures) under the linear placement;
  * fabric mode is bit-parity with the zero-latency engine when all traffic
    is intra-tile, and when link capacity is infinite and mesh latency zero;
  * a hand-computable 2-tile case: cross-tile events arrive exactly
    ``ceil(hops * latency_across_chip_s / dt)`` steps late, the link FIFO
    keeps the lowest-source-id event and counts the drop;
  * per-step hop/latency/energy accumulators cross-check against
    ``Fabric.latency_s`` / ``Fabric.energy_j`` summed over routed entries;
  * measured mean mesh hops under uniform traffic reproduce Table IV's ~2x
    hierarchical-vs-flat-mesh average-distance advantage *empirically*;
  * the sharded fabric step (tiles -> devices) matches the local step.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.dispatch import FabricBackend, get_backend
from repro.core.event_engine import EventEngine
from repro.core.routing import ChipConstants, Fabric, build_delivery_model
from repro.core.tags import NetworkSpec, compile_network

DT = 1e-3


def _random_net(rng, n=64, cluster=8, k=64, edges=120, fabric=None, tiles=None):
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=32, max_sram_entries=16)
    seen = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        spec.connect(s, d, int(rng.integers(4)))
    return compile_network(spec, fabric=fabric, tile_of_cluster=tiles)


def _entry_pairs(tables):
    """(src_cluster, dst_cluster) of every occupied SRAM entry."""
    src, ent = np.nonzero(np.asarray(tables.src_tag) >= 0)
    return src // tables.cluster_size, np.asarray(tables.src_dest)[src, ent]


# ---------------------------------------------------------------------------
# delivery model vs the scalar Fabric methods
# ---------------------------------------------------------------------------
def test_delivery_model_matches_fabric_methods():
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2)
    m = build_delivery_model(fab, fab.n_cores, DT)
    for i in range(fab.n_cores):
        for j in range(fab.n_cores):
            h = fab.hops(i, j)
            assert int(m.mesh_hops[i, j]) == h["r3"]
            assert m.latency_s[i, j] == pytest.approx(fab.latency_s(i, j), rel=1e-6)
            assert m.energy_j[i, j] == pytest.approx(fab.energy_j(i, j), rel=1e-6)
            want_delay = int(np.ceil(h["r3"] * fab.constants.latency_across_chip_s / DT - 1e-9))
            assert int(m.delay_steps[i, j]) == max(0, want_delay)
    # diagonal is the same-core case: no R2/R3, broadcast latency only
    assert m.latency_s[0, 0] == pytest.approx(fab.constants.broadcast_time_s)
    assert m.max_delay == int(m.delay_steps.max())


def test_delivery_model_rejects_bad_placements():
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    with pytest.raises(ValueError, match="do not fit"):
        build_delivery_model(fab, fab.n_cores + 1, DT)
    with pytest.raises(ValueError, match="tile ids"):
        build_delivery_model(fab, 2, DT, tile_of_cluster=np.asarray([0, 5]))
    with pytest.raises(ValueError, match="clusters on one tile"):
        build_delivery_model(fab, 3, DT, tile_of_cluster=np.asarray([0, 0, 0]))
    with pytest.raises(ValueError, match="shape"):
        build_delivery_model(fab, 2, DT, tile_of_cluster=np.asarray([0]))


def test_compile_network_carries_placement():
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    spec = NetworkSpec(n_neurons=16, cluster_size=4, k_tags=8)
    spec.connect(0, 12)
    tables = compile_network(spec, fabric=fab)
    np.testing.assert_array_equal(tables.tile_of_cluster, [0, 0, 1, 1])
    custom = compile_network(spec, fabric=fab, tile_of_cluster=[1, 0, 1, 0])
    np.testing.assert_array_equal(custom.tile_of_cluster, [1, 0, 1, 0])
    with pytest.raises(ValueError, match="requires a fabric"):
        compile_network(spec, tile_of_cluster=[0, 0, 1, 1])


# ---------------------------------------------------------------------------
# parity with the zero-latency engine
# ---------------------------------------------------------------------------
def test_fabric_parity_all_intra_tile():
    """All clusters on one tile: R1/R2 only, bit-parity with the plain engine."""
    fab = Fabric(grid_x=1, grid_y=1, cores_per_tile=8)
    rng = np.random.default_rng(0)
    tables = _random_net(rng, fabric=fab)
    eng0 = EventEngine(tables, queue_capacity=tables.n_neurons)
    engf = EventEngine(tables, fabric=fab, fabric_options={"dt": DT})
    assert engf.fabric_model.max_delay == 0
    inp = jnp.zeros((2, tables.n_clusters, tables.k_tags)).at[:, :, :4].set(2.0)
    ev = jnp.broadcast_to(inp, (10, *inp.shape))
    i_ext = jnp.full((2, tables.n_neurons), 5e3)  # keep sources spiking
    _, (s0, _) = eng0.run(eng0.init_state(batch=2), ev, i_ext)
    _, (sf, stats) = engf.run(engf.init_state(batch=2), ev, i_ext)
    assert np.asarray(s0).sum() > 0
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(sf))
    assert int(np.asarray(stats.delivered).sum()) > 0
    assert int(np.asarray(stats.link_dropped).sum()) == 0
    assert int(np.asarray(stats.hops).sum()) == 0


def test_fabric_parity_zero_latency_infinite_links():
    """Cross-tile traffic with zero mesh latency and ample link capacity is
    indistinguishable from the zero-latency engine."""
    const = ChipConstants(latency_across_chip_s=0.0)
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2, constants=const)
    rng = np.random.default_rng(1)
    tables = _random_net(rng, fabric=fab)
    eng0 = EventEngine(tables, queue_capacity=tables.n_neurons)
    engf = EventEngine(tables, fabric=fab, fabric_options={"dt": DT})
    assert engf.fabric_model.max_delay == 0
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, :4].set(2.0)
    ev = jnp.broadcast_to(inp, (10, *inp.shape))
    i_ext = jnp.full((tables.n_neurons,), 5e3)  # keep sources spiking
    _, (s0, _) = eng0.run(eng0.init_state(), ev, i_ext)
    _, (sf, stats) = engf.run(engf.init_state(), ev, i_ext)
    assert np.asarray(s0).sum() > 0
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(sf))
    assert int(np.asarray(stats.link_dropped).sum()) == 0
    assert int(np.asarray(stats.hops).sum()) > 0  # traffic did cross tiles


# ---------------------------------------------------------------------------
# hand-computable 2-tile case: arrival step + drop count
# ---------------------------------------------------------------------------
def _two_tile_backend(delay_steps=3, link_capacity=1):
    const = ChipConstants(latency_across_chip_s=delay_steps * DT)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8)
    spec.connect(0, 4)  # cross-tile, lowest source id -> wins the link
    spec.connect(1, 5)  # cross-tile, contends for the same (0 -> 1) link
    spec.connect(2, 3)  # intra-tile control
    tables = compile_network(spec, fabric=fab)
    backend = FabricBackend(fabric=fab, tile_of_cluster=tables.tile_of_cluster,
                            dt=DT, link_capacity=link_capacity)
    return tables, backend


def test_two_tile_exact_arrival_step_and_drop():
    tables, backend = _two_tile_backend(delay_steps=3, link_capacity=1)
    model, _ = backend.model_for(tables.n_clusters)
    assert model.max_delay == 3 and model.link_capacity == 1
    args = (
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )
    spikes0 = jnp.zeros((8,)).at[jnp.asarray([0, 1, 2])].set(1.0)
    inflight = backend.init_inflight(tables.n_clusters, tables.k_tags)
    drives = []
    for t in range(6):
        spikes = spikes0 if t == 0 else jnp.zeros((8,))
        drive, inflight, stats = backend.deliver_fabric(spikes, *args, inflight=inflight)
        if t == 0:
            # 3 routed entries: intra kept, one cross kept, one cross dropped
            assert int(stats.delivered) == 2
            assert int(stats.link_dropped) == 1
            assert int(stats.hops) == 1
        else:
            assert int(stats.link_dropped) == 0
        drives.append(np.asarray(drive))
    drives = np.stack(drives)  # [T, N, 4]
    # intra-tile edge 2 -> 3 lands immediately (call 0)
    assert drives[0, 3].sum() == 1.0
    # cross-tile edge 0 -> 4 arrives exactly 3 calls later, nowhere else
    assert (drives[:, 4].sum(-1) != 0).nonzero()[0].tolist() == [3]
    # the dropped 1 -> 5 event never arrives
    assert drives[:, 5].sum() == 0.0


def test_two_tile_engine_run_arrival_vs_zero_latency():
    """End-to-end through EventEngine.run: the destination neuron's response
    in fabric mode is the zero-latency response shifted by the hop delay."""
    delay = 2
    const = ChipConstants(latency_across_chip_s=delay * DT)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8, max_cam_words=64)
    # heavy synaptic weight (64 CAM copies) so one cross-tile event makes the
    # destination neuron spike a few steps after arrival
    spec.connect_group([0], [(4, 0)], shared_tag=False, copies=64)
    tables = compile_network(spec, fabric=fab)
    eng0 = EventEngine(tables, queue_capacity=8)
    engf = EventEngine(tables, fabric=fab, fabric_options={"dt": DT})
    # kick neuron 0 once via a strong external current at t=0 only
    T = 12
    i_ext = np.zeros((T, 8), np.float32)
    i_ext[0, 0] = 1e4
    ev = jnp.zeros((T, tables.n_clusters, tables.k_tags))
    _, (s0, _) = eng0.run(eng0.init_state(), ev, jnp.asarray(i_ext))
    _, (sf, _) = engf.run(engf.init_state(), ev, jnp.asarray(i_ext))
    s0, sf = np.asarray(s0), np.asarray(sf)
    t0 = np.nonzero(s0[:, 4])[0]
    tf = np.nonzero(sf[:, 4])[0]
    assert t0.size and tf.size, "destination neuron never spiked"
    assert tf[0] - t0[0] == delay
    np.testing.assert_array_equal(s0[:, 0], sf[:, 0])  # source side unaffected


# ---------------------------------------------------------------------------
# stats accumulators vs the analytical model
# ---------------------------------------------------------------------------
def test_stats_cross_check_against_fabric_methods():
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=1)
    rng = np.random.default_rng(2)
    tables = _random_net(rng, n=16, cluster=4, k=32, edges=40, fabric=fab)
    backend = get_backend("fabric", fabric=fab,
                         tile_of_cluster=tables.tile_of_cluster, dt=DT)
    spikes = jnp.ones((tables.n_neurons,))  # every SRAM entry routes once
    drive, stats = backend.deliver(
        spikes, jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags, with_stats=True,
    )
    src_cl, dst_cl = _entry_pairs(tables)
    assert int(stats.delivered) == len(src_cl)
    assert int(stats.dropped) == 0 and int(stats.link_dropped) == 0
    # cores_per_tile=1 + linear placement: cluster c IS fabric core c
    want_hops = sum(fab.hops(int(s), int(d))["r3"] for s, d in zip(src_cl, dst_cl))
    want_lat = sum(fab.latency_s(int(s), int(d)) for s, d in zip(src_cl, dst_cl))
    want_en = sum(fab.energy_j(int(s), int(d)) for s, d in zip(src_cl, dst_cl))
    assert int(stats.hops) == want_hops
    assert float(stats.latency_s) == pytest.approx(want_lat, rel=1e-5)
    assert float(stats.energy_j) == pytest.approx(want_en, rel=1e-5)
    # zero-warp statistical mode: drive equals the reference path's
    ref = get_backend("reference").deliver(
        spikes, jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-6)


# ---------------------------------------------------------------------------
# Table IV, empirically: hierarchy halves the mean mesh distance
# ---------------------------------------------------------------------------
def _mean_hops_for_placement(tables, fabric, batch=None):
    """One engine step with every neuron spiking: mean mesh hops/event."""
    eng = EventEngine(tables, fabric=fabric, fabric_options={"dt": DT})
    carry = eng.init_state(batch=batch)
    lead = () if batch is None else (batch,)
    spikes = jnp.ones((*lead, tables.n_neurons))
    carry = (carry[0], spikes, *carry[2:])
    inp = jnp.zeros((*lead, tables.n_clusters, tables.k_tags))
    _, (_, stats) = eng.step(carry, inp)
    return float(np.asarray(stats.hops).sum()) / float(np.asarray(stats.delivered).sum())


def _mesh_mean_manhattan(side: int) -> float:
    """Exact mean Manhattan distance between uniform node pairs on a side^2
    mesh: 2 * (side^2 - 1) / (3 * side) -> 2*sqrt(N)/3 at scale."""
    return 2.0 * (side * side - 1) / (3.0 * side)


@pytest.mark.parametrize("grid", [2, 4])
def test_table4_hierarchy_vs_flat_mesh_empirical(grid):
    """Uniform random traffic, measured through the executable fabric:
    hierarchical placement (4 cores/tile on a grid x grid mesh) needs ~half
    the mesh hops of a flat mesh (1 core/tile on a 2grid x 2grid mesh) —
    Table IV's sqrt(N)/3 vs 2 sqrt(N)/3 (exact finite-size expectation:
    2.5x at 2x2, 2.1x at 4x4, -> 2x at scale)."""
    n_cores = 4 * grid * grid
    hier = Fabric(grid_x=grid, grid_y=grid, cores_per_tile=4)
    flat = Fabric(grid_x=2 * grid, grid_y=2 * grid, cores_per_tile=1)
    rng = np.random.default_rng(3)
    tables_h = _random_net(rng, n=n_cores * 4, cluster=4, k=64,
                           edges=12 * n_cores, fabric=hier)
    rng = np.random.default_rng(3)  # same connectivity, different placement
    tables_f = _random_net(rng, n=n_cores * 4, cluster=4, k=64,
                           edges=12 * n_cores, fabric=flat)
    mean_h = _mean_hops_for_placement(tables_h, hier)
    mean_f = _mean_hops_for_placement(tables_f, flat)
    assert mean_h < mean_f
    want = _mesh_mean_manhattan(2 * grid) / _mesh_mean_manhattan(grid)
    assert want >= 2.0  # the paper's ~2x advantage, finite-size included
    assert mean_f / mean_h == pytest.approx(want, rel=0.15)


# ---------------------------------------------------------------------------
# engine integration: batching, scan stacking, link-drop reporting
# ---------------------------------------------------------------------------
def test_fabric_engine_batched_run_stacks_stats():
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    rng = np.random.default_rng(4)
    tables = _random_net(rng, n=32, cluster=8, k=64, edges=60, fabric=fab)
    eng = EventEngine(tables, fabric=fab, fabric_options={"dt": DT},
                      queue_capacity=16)
    b, T = 3, 7
    inp = jnp.zeros((b, tables.n_clusters, tables.k_tags)).at[:, :, :6].set(3.0)
    ev = jnp.broadcast_to(inp, (T, *inp.shape))
    carry, (spikes, stats) = eng.run(eng.init_state(batch=b), ev)
    assert spikes.shape == (T, b, 32)
    for field in ("dropped", "link_dropped", "delivered", "hops"):
        assert getattr(stats, field).shape == (T, b), field
    assert stats.latency_s.shape == (T, b)
    # ring-mode carry: (state, spikes, ring, cursor) — the wheel keeps its
    # shape across the scan and the cursor advances T steps around it
    fresh = eng.init_state(batch=b)
    assert len(carry) == 4 and carry[2].shape == fresh[2].shape
    assert int(carry[3]) == T % (eng.fabric_model.max_delay + 1)


def test_fabric_model_inherits_engine_dt():
    """Regression: delays/link capacity must be derived at the dt the neurons
    integrate with, not the backend default (1e-3) — a 1e-4 engine saw
    cross-tile events arrive 10x too early."""
    from repro.core.neuron import NeuronParams

    const = ChipConstants(latency_across_chip_s=3e-4)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8)
    spec.connect(0, 4)
    tables = compile_network(spec, fabric=fab)
    eng = EventEngine(tables, params=NeuronParams(dt=1e-4), fabric=fab)
    assert eng.fabric_model.max_delay == 3  # ceil(1 hop * 3e-4 / 1e-4)
    # an explicit fabric_options dt matching params.dt is fine
    eng2 = EventEngine(tables, params=NeuronParams(dt=3e-4), fabric=fab,
                       fabric_options={"dt": 3e-4})
    assert eng2.fabric_model.max_delay == 1
    # any dt disagreeing with the engine's integration step raises —
    # whether smuggled via fabric_options or a prebuilt backend
    with pytest.raises(ValueError, match="dt"):
        EventEngine(tables, params=NeuronParams(dt=1e-4), fabric=fab,
                    fabric_options={"dt": 1e-3})
    with pytest.raises(ValueError, match="dt"):
        EventEngine(tables, params=NeuronParams(dt=1e-4),
                    fabric=FabricBackend(fabric=fab))  # backend default 1e-3
    with pytest.raises(ValueError, match="placement"):
        EventEngine(tables, fabric=FabricBackend(
            fabric=fab, tile_of_cluster=np.asarray([1, 0], np.int32)))
    # matching dt + placement passes
    ok = FabricBackend(fabric=fab, dt=1e-3,
                       tile_of_cluster=tables.tile_of_cluster)
    assert EventEngine(tables, fabric=ok).fabric_model.max_delay == 1


def test_fabric_engine_link_overflow_reported():
    """A 2x2-tile fabric with capacity-1 links under all-to-all traffic must
    drop and report cross-tile events."""
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=1)
    rng = np.random.default_rng(5)
    tables = _random_net(rng, n=16, cluster=4, k=64, edges=60, fabric=fab)
    eng = EventEngine(tables, fabric=fab,
                      fabric_options={"dt": DT, "link_capacity": 1})
    carry = eng.init_state()
    carry = (carry[0], jnp.ones((16,)), *carry[2:])
    _, (_, stats) = eng.step(carry, jnp.zeros((tables.n_clusters, tables.k_tags)))
    src_cl, dst_cl = _entry_pairs(tables)
    cross = np.asarray([
        fab.hops(int(s), int(d))["r3"] > 0 for s, d in zip(src_cl, dst_cl)
    ])
    # per directed tile pair, one event passes; the rest drop
    pair_ids = {
        (int(s), int(d)) for s, d, c in zip(src_cl, dst_cl, cross) if c
    }
    links = {(fab.tile_index(int(s)), fab.tile_index(int(d))) for s, d in pair_ids}
    want_dropped = int(cross.sum()) - len(links)
    assert int(stats.link_dropped) == want_dropped
    assert int(stats.delivered) == len(src_cl) - want_dropped


def test_fabric_sharded_step_matches_local():
    """1x1 mesh smoke of the tiles->devices step (multi-device parity lives
    in test_distributed.py): state, spikes, inflight, and stats agree.
    Pinned to the roll carry (``ring=False``) — the ring-mode sharded step
    has its own parity coverage in test_fabric_ring.py."""
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    rng = np.random.default_rng(6)
    tables = _random_net(rng, n=32, cluster=8, k=64, edges=60, fabric=fab)
    eng = EventEngine(tables, fabric=fab,
                      fabric_options={"dt": DT, "ring": False})
    mesh = jax.make_mesh((1,), ("model",))
    sharded = eng.make_sharded_step(mesh, axis="model")
    state, prev, inflight = eng.init_state()
    prev = prev.at[jnp.arange(0, 32, 3)].set(1.0)
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
    for _ in range(4):
        (st_l, sp_l, inf_l), (_, stats_l) = eng.step((state, prev, inflight), inp)
        st_s, sp_s, inf_s, stats_s = sharded(
            eng.tables, state, prev, inflight, inp, jnp.zeros((32,))
        )
        np.testing.assert_allclose(np.asarray(sp_l), np.asarray(sp_s), atol=1e-6)
        np.testing.assert_allclose(np.asarray(inf_l), np.asarray(inf_s), atol=1e-6)
        np.testing.assert_allclose(np.asarray(st_l.v), np.asarray(st_s.v), atol=1e-6)
        for f in ("dropped", "link_dropped", "delivered", "hops"):
            assert int(getattr(stats_l, f)) == int(getattr(stats_s, f)), f
        state, prev, inflight = st_l, sp_l, inf_l



# ---------------------------------------------------------------------------
# determinism regression: event order must not matter
# ---------------------------------------------------------------------------
def _edges_for_determinism(rng, n=32, edges=70):
    seen, out = set(), []
    while len(out) < edges:
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        out.append((s, d, int(rng.integers(4))))
    return out


def _spec_from_edges(edges, n=32, cluster=8, k=128):
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=32, max_sram_entries=16)
    for s, d, syn in edges:
        spec.connect(s, d, syn)
    return spec


def test_fabric_determinism_under_event_order_permutation():
    """Permuting the pre-step event order — the order connections were
    declared in, which permutes each source's SRAM-entry order and the tag
    numbering — leaves fabric-mode arrivals (the spike trajectory), link-drop
    counts, and the integer DeliveryStats bit-identical: arbitration is
    lowest-source-id-first by contract, never declaration order. (latency/
    energy are float sums of the same per-event multiset; summation order
    may differ, so they are compared to tolerance.)"""
    const = ChipConstants(latency_across_chip_s=2 * DT)
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=1, constants=const)
    rng = np.random.default_rng(8)
    edges = _edges_for_determinism(rng)
    shuffled = list(edges)
    np.random.default_rng(99).shuffle(shuffled)
    assert shuffled != edges
    T = 10
    i_ext = np.zeros((T, 32), np.float32)
    i_ext[0, ::2] = 1e4  # kick half the sources at t=0
    runs = []
    for e in (edges, shuffled):
        tables = compile_network(_spec_from_edges(e), fabric=fab)
        eng = EventEngine(tables, fabric=fab, fabric_options={"dt": DT},
                          queue_capacity=32)
        ev = jnp.zeros((T, tables.n_clusters, tables.k_tags))
        _, (spikes, stats) = eng.run(eng.init_state(), ev, jnp.asarray(i_ext))
        runs.append((np.asarray(spikes), stats))
    (s0, st0), (s1, st1) = runs
    assert s0.sum() > 0 and int(np.asarray(st0.delivered).sum()) > 0
    np.testing.assert_array_equal(s0, s1)  # arrivals: bit-identical
    for f in ("dropped", "link_dropped", "delivered", "hops"):
        np.testing.assert_array_equal(
            np.asarray(getattr(st0, f)), np.asarray(getattr(st1, f)), err_msg=f
        )
    for f in ("latency_s", "energy_j"):
        np.testing.assert_allclose(
            np.asarray(getattr(st0, f)), np.asarray(getattr(st1, f)),
            rtol=1e-5, err_msg=f,
        )


def test_fabric_determinism_batch_slot_permutation():
    """Permuting which batch slot carries which stream permutes every output
    and every per-stream stat exactly — no cross-slot leakage, bit-identical
    including the float accumulators (per-slot sums are untouched)."""
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1)
    rng = np.random.default_rng(12)
    tables = _random_net(rng, n=8, cluster=4, k=32, edges=14, fabric=fab)
    eng = EventEngine(tables, fabric=fab, fabric_options={"dt": DT},
                      queue_capacity=8)
    b = 4
    perm = np.asarray([2, 0, 3, 1])
    spikes = (np.random.default_rng(1).random((b, 8)) < 0.5).astype(np.float32)
    state, _, *delay = eng.init_state(batch=b)
    inp = jnp.zeros((b, tables.n_clusters, tables.k_tags))
    _, (out, stats) = eng.step((state, jnp.asarray(spikes), *delay), inp)
    _, (out_p, stats_p) = eng.step(
        (state, jnp.asarray(spikes[perm]), *delay), inp
    )
    np.testing.assert_array_equal(np.asarray(out)[perm], np.asarray(out_p))
    for f in ("dropped", "link_dropped", "delivered", "hops",
              "latency_s", "energy_j"):
        np.testing.assert_array_equal(
            np.asarray(getattr(stats, f))[perm],
            np.asarray(getattr(stats_p, f)), err_msg=f,
        )


def test_link_arbitration_keeps_lowest_source_ids_first():
    """Four sources on one tile contend for the same capacity-1 link; the
    survivor must be the lowest source id regardless of declaration order —
    the arbitration contract the determinism tests above rely on."""
    const = ChipConstants(latency_across_chip_s=DT)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    for order in (range(4), reversed(range(4))):
        spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8)
        for s in order:
            spec.connect(s, 4 + s)  # all cross the single 0 -> 1 link
        tables = compile_network(spec, fabric=fab)
        backend = FabricBackend(fabric=fab, tile_of_cluster=tables.tile_of_cluster,
                                dt=DT, link_capacity=1)
        inflight = backend.init_inflight(tables.n_clusters, tables.k_tags)
        spikes = jnp.zeros((8,)).at[jnp.arange(4)].set(1.0)
        args = (jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
                jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
                tables.cluster_size, tables.k_tags)
        drive, inflight, stats = backend.deliver_fabric(
            spikes, *args, inflight=inflight
        )
        assert int(stats.link_dropped) == 3 and int(stats.delivered) == 1
        drive, inflight, stats = backend.deliver_fabric(
            jnp.zeros((8,)), *args, inflight=inflight
        )
        got = np.nonzero(np.asarray(drive).sum(-1))[0].tolist()
        assert got == [4], f"survivor was not source 0's event (order {list(order)})"


def test_fabric_sharded_step_rejects_split_tiles():
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=2)
    spec = NetworkSpec(n_neurons=16, cluster_size=4, k_tags=8)
    spec.connect(0, 12)
    # interleaved placement: both devices would host half of each tile
    tables = compile_network(spec, fabric=fab, tile_of_cluster=[0, 1, 0, 1])
    eng = EventEngine(tables, fabric=fab, fabric_options={"dt": DT})
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="split across devices"):
        # 1 device cannot split a tile; force the check with a fake 2-slab view
        eng._make_sharded_fabric_step(mesh, "model", None, 2, None)
