"""Shared pytest config.

JAX compilation caches accumulate across the suite (10 architectures x
train/serve graphs) and can OOM a 35 GB host in one process; clear them
between modules.
"""

import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
