"""End-to-end behaviour tests for the whole system."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import cells, get_config
from repro.models.model import build_model
from repro.serve.engine import Engine, ServeConfig


def test_cell_enumeration_covers_assignment():
    """40 (arch x shape) cells; long_500k runs only for sub-quadratic archs."""
    cs = cells()
    assert len(cs) == 40
    runnable = [(a, s.name) for a, s, ok, _ in cs if ok]
    skipped = [(a, s.name, why) for a, s, ok, why in cs if not ok]
    assert ("zamba2-2.7b", "long_500k") in runnable
    assert ("rwkv6-3b", "long_500k") in runnable
    assert all(s == "long_500k" for _, s, _ in skipped)
    assert len(skipped) == 8
    assert all(why for _, _, why in skipped)


def test_serve_engine_generates_greedy_deterministic():
    cfg = get_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_len=48, temperature=0.0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab, dtype=jnp.int32)
    out1 = eng.generate(prompts, 6)
    out2 = eng.generate(prompts, 6)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_serve_engine_rejects_kv_cache_overrun():
    """prompt + max_new past max_len used to wrap the ring-buffer KV cache
    and clobber the oldest entries without error."""
    import pytest

    cfg = get_config("gemma3-1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_len=16))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab, dtype=jnp.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.generate(prompts, 5)
    assert eng.generate(prompts, 4).shape == (1, 4)  # exactly filling is fine
    assert eng.generate(prompts, 0).shape == (1, 0)  # 0 new tokens, not 1


def test_serve_matches_teacher_forced_forward():
    """Greedy generation replayed through the full forward gives the same
    argmax at every step (serving path == training path semantics)."""
    cfg = get_config("glm4-9b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_len=32))
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab, dtype=jnp.int32)
    gen = eng.generate(prompts, 5)
    full = jnp.concatenate([prompts, gen], axis=1)
    pos = jnp.broadcast_to(jnp.arange(full.shape[1])[None], full.shape)
    h, _, _ = model.forward(params, full, pos, None, None)
    logits = model._unembed(params, h)
    for t in range(5):
        pred = jnp.argmax(logits[:, 5 + t], -1)
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(gen[:, t]))
