"""Multi-model fabric serving (DESIGN.md §16).

Locks the three load-bearing claims of multi-tenant multi-model residency:

  * **Slab conformance** — the ring fast path's entry table built
    slab-by-slab (``build_fabric_entries_slabs``) is bit-identical to the
    one built from the concatenated tables, so per-model compilation and
    combined execution describe the same machine.
  * **Serving isolation** — a session served from an N-model pool is
    bit-identical (queued mode) to the same session served solo, through
    admits, hot model loads under live sessions, and checkpoint restore;
    and the whole mixed pool runs on ONE compiled step (model id is data).
  * **Typed refusal** — a checkpoint restored into a retargeted or
    re-provisioned pool raises :class:`CheckpointMismatchError` before any
    carry state is spliced; mis-sized slot masks and mismatched SlotCarry
    leaves raise instead of broadcasting.
"""

import functools

import numpy as np
import pytest

from repro.core.cnn import compile_poker_cnn
from repro.core.compiler import Geometry, artifact_from_tables
from repro.core.event_engine import EventEngine, ModelRegistry, reset_slots
from repro.core.neuron import NeuronParams
from repro.core.routing import build_delivery_model, default_tile_of_cluster
from repro.core.tags import NetworkSpec, compile_network, concat_tables
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
from repro.kernels.fabric_deliver.ops import (
    build_fabric_entries,
    build_fabric_entries_slabs,
)
from repro.serve.aer import (
    AerServeConfig,
    AerSessionPool,
    CheckpointMismatchError,
    DvsSession,
    build_poker_engine,
)


@functools.lru_cache(maxsize=1)
def _poker_cc():
    return compile_poker_cnn()


def _session(i, symbol, model=None, seed=9):
    return DvsSession(
        session_id=i,
        source=DvsStreamSource(
            DvsStreamConfig(symbol=symbol, events_per_step=16, seed=seed),
            session_id=i,
        ),
        label=symbol,
        model=model,
    )


def _cfg(pool_size=2, **kw):
    kw.setdefault("max_steps", 12)
    return AerServeConfig(pool_size=pool_size, **kw)


def _random_tables(seed, n=32, cluster=8, k=24, edges=48):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k)
    for _ in range(edges):
        spec.connect(int(rng.integers(n)), int(rng.integers(n)), int(rng.integers(4)))
    return compile_network(spec)


# ---------------------------------------------------------------------------
# Slab conformance: per-model entry construction == concatenated construction
# ---------------------------------------------------------------------------
def test_entry_table_slabs_bit_identical_to_concat():
    parts = [_random_tables(0), _random_tables(1, n=48, k=40), _random_tables(2)]
    combined, slabs = concat_tables(parts)
    assert [s.neuron_lo for s in slabs] == [0, 32, 80]
    assert combined.k_tags == 40  # padded to the widest resident model

    fab = Geometry(grid_x=2, grid_y=2, cores_per_tile=4, neurons_per_core=8).fabric()
    placement = default_tile_of_cluster(combined.n_clusters, fab)
    model = build_delivery_model(fab, combined.n_clusters, 1e-3,
                                 tile_of_cluster=placement)
    direct = build_fabric_entries(
        combined.src_tag, combined.src_dest, combined.cluster_size,
        combined.k_tags, model,
    )
    slabbed = build_fabric_entries_slabs(
        [(t.src_tag, t.src_dest) for t in parts],
        combined.cluster_size, combined.k_tags, model,
    )
    for f in ("src", "dstk", "delay", "cross", "link_start", "hops",
              "latency_s", "energy_j", "valid", "alive"):
        np.testing.assert_array_equal(
            np.asarray(getattr(direct, f)), np.asarray(getattr(slabbed, f)),
            err_msg=f,
        )


def test_concat_tables_dense_equivalents_stack():
    """Each slab's dense connectivity is the solo table's, offset intact."""
    parts = [_random_tables(3), _random_tables(4)]
    combined, slabs = concat_tables(parts)
    got = np.asarray(combined.dense_equivalent())
    rows = []
    for t, s in zip(parts, slabs):
        solo = np.asarray(t.dense_equivalent())
        if solo.size:
            solo = solo + np.array([[s.neuron_lo, s.neuron_lo, 0]])
        rows.append(solo)
    want = np.concatenate([r for r in rows if r.size], axis=0)
    got_sorted = got[np.lexsort(got.T[::-1])]
    want_sorted = want[np.lexsort(want.T[::-1])]
    np.testing.assert_array_equal(got_sorted, want_sorted)


def test_registry_rejects_mixed_cluster_size_and_duplicates():
    reg = ModelRegistry({"a": _random_tables(0)})
    with pytest.raises(ValueError, match="already resident"):
        reg.load("a", _random_tables(1))
    with pytest.raises(ValueError, match="cluster_size"):
        reg.load("b", _random_tables(1, cluster=16, k=64))
    reg.load("b", _random_tables(1))
    assert reg.names == ["a", "b"]
    reg.unload("a")
    assert reg.names == ["b"]
    combined, slabs = reg.combined()
    assert combined.n_neurons == 32 and slabs["b"].neuron_lo == 0


# ---------------------------------------------------------------------------
# Serving isolation
# ---------------------------------------------------------------------------
def test_two_model_pool_bit_identical_to_solo_queued():
    cc = _poker_cc()
    solo = AerSessionPool(cc, build_poker_engine(cc.tables), _cfg())
    r_solo = {r.session_id: r
              for r in solo.serve([_session(0, 1), _session(1, 2)])}

    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg())
    r_multi = {r.session_id: r
               for r in pool.serve([_session(0, 1, "a"), _session(1, 2, "b")])}

    for sid in r_solo:
        np.testing.assert_array_equal(r_solo[sid].counts, r_multi[sid].counts)
        assert r_solo[sid].latency_steps == r_multi[sid].latency_steps
        assert r_solo[sid].prediction == r_multi[sid].prediction


def test_two_model_pool_compiles_once():
    """Tier-1 gate: a mixed 2-model pool is ONE compiled step — admitting
    sessions on either model never recompiles (model id is data)."""
    cc = _poker_cc()
    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg())
    pool.serve([_session(0, 1, "a"), _session(1, 2, "b"),
                _session(2, 3, "b"), _session(3, 0, "a")])
    assert pool.engine._jit_step._cache_size() == 1


def test_fabric_multimodel_prediction_parity():
    cc = _poker_cc()
    solo = AerSessionPool(cc, build_poker_engine(cc.tables, backend="fabric"),
                          _cfg())
    r_solo = {r.session_id: r
              for r in solo.serve([_session(0, 1), _session(1, 2)])}
    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg(),
                                      backend="fabric")
    r_multi = {r.session_id: r
               for r in pool.serve([_session(0, 1, "a"), _session(1, 2, "b")])}
    for sid in r_solo:
        assert r_solo[sid].prediction == r_multi[sid].prediction


def test_admit_requires_model_name_when_ambiguous():
    cc = _poker_cc()
    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg())
    with pytest.raises(ValueError, match="must name its model"):
        pool.admit(_session(0, 1))
    with pytest.raises(KeyError, match="not resident"):
        pool.admit(_session(0, 1, "zebra"))
    # single-model pools keep the old contract: no name needed
    solo = AerSessionPool.from_models({"a": cc}, _cfg())
    solo.admit(_session(0, 1))
    assert solo.slots[0].model == "a"


@pytest.mark.parametrize("backend", ["reference", "fabric"])
def test_hot_load_under_live_sessions(backend):
    """load_model on a live pool: in-flight sessions finish with counts
    identical to an undisturbed run (queued mode is bit-exact; fabric
    migration re-buckets delays on the grown mesh placement)."""
    cc = _poker_cc()
    pool = AerSessionPool.from_models({"a": cc}, _cfg(), backend=backend)
    pool.admit(_session(0, 1, "a"))
    pool.admit(_session(1, 2, "a"))
    for _ in range(4):
        pool.step()
    pool.load_model("b", cc)  # live: slots migrate across the slab re-layout
    assert list(pool.models) == ["a", "b"]
    results = []
    while pool.occupied:
        pool.step()
        done = pool.finished_slots()
        if done:
            results.extend(pool.evict_many(done))
    assert len(results) == 2 and all(r.error is None for r in results)

    if backend == "reference":
        undisturbed = AerSessionPool.from_models({"a": cc}, _cfg())
        r_ref = {r.session_id: r
                 for r in undisturbed.serve([_session(0, 1, "a"),
                                             _session(1, 2, "a")])}
        for r in results:
            np.testing.assert_array_equal(r.counts, r_ref[r.session_id].counts)

    # the hot-swap ladder's last rung: drain, then unload the old model
    pool.unload_model("a")
    assert list(pool.models) == ["b"]
    pool.serve([_session(9, 3, "b")])  # the survivor still serves


def test_unload_refuses_live_sessions_and_last_model():
    cc = _poker_cc()
    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg())
    pool.admit(_session(0, 1, "a"))
    with pytest.raises(RuntimeError, match="live sessions"):
        pool.unload_model("a")
    pool.evict(0)
    pool.unload_model("a")
    with pytest.raises(ValueError, match="last resident"):
        pool.unload_model("b")
    with pytest.raises(KeyError, match="not resident"):
        pool.unload_model("a")


def test_hot_swap_pool_wraps_fixed_engine_refuses():
    cc = _poker_cc()
    pool = AerSessionPool(cc, build_poker_engine(cc.tables), _cfg())
    with pytest.raises(RuntimeError, match="from_models"):
        pool.load_model("b", cc)


# ---------------------------------------------------------------------------
# Checkpoint fingerprinting (satellite: restore must raise, not corrupt)
# ---------------------------------------------------------------------------
def test_restore_into_retargeted_engine_raises(tmp_path):
    cc = _poker_cc()
    pool = AerSessionPool(cc, build_poker_engine(cc.tables), _cfg())
    pool.admit(_session(0, 1))
    pool.step()
    ck = Checkpointer(str(tmp_path))
    pool.checkpoint(ck, blocking=True)

    art = artifact_from_tables(
        cc.tables,
        Geometry(grid_x=2, grid_y=2, cores_per_tile=2, neurons_per_core=256),
        optimize=False,
    )
    retargeted = build_poker_engine(art.tables, backend="fabric")
    with pytest.raises(CheckpointMismatchError):
        AerSessionPool.restore(cc, retargeted, _cfg(), ck)

    # the matching engine still restores bit-exactly, models intact
    back = AerSessionPool.restore(cc, build_poker_engine(cc.tables), _cfg(), ck)
    assert back.n_steps == 1 and back.slots[0].model == "default"
    np.testing.assert_array_equal(back.slots[0].counts, pool.slots[0].counts)


def test_restore_model_set_mismatch_raises(tmp_path):
    cc = _poker_cc()
    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg())
    pool.admit(_session(0, 1, "a"))
    pool.step()
    ck = Checkpointer(str(tmp_path))
    pool.checkpoint(ck, blocking=True)
    with pytest.raises(CheckpointMismatchError):
        AerSessionPool.restore(cc, build_poker_engine(cc.tables), _cfg(), ck)


def test_multimodel_checkpoint_roundtrip_bit_exact(tmp_path):
    cc = _poker_cc()
    pool = AerSessionPool.from_models({"a": cc, "b": cc}, _cfg(),
                                      donate_carry=False)
    pool.admit(_session(0, 1, "a"))
    pool.admit(_session(1, 2, "b"))
    for _ in range(3):
        pool.step()
    ck = Checkpointer(str(tmp_path))
    pool.checkpoint(ck, blocking=True)

    engine = AerSessionPool._engine_for(
        {"a": cc, "b": cc},
        {"backend": "reference", "donate_carry": False, "faults": None},
    )
    back = AerSessionPool.restore(cc, engine, _cfg(), ck,
                                  models={"a": cc, "b": cc})
    assert [s.model for s in back.slots if s is not None] == ["a", "b"]
    for _ in range(3):
        pool.step()
        back.step()
    for i in range(2):
        np.testing.assert_array_equal(pool.slots[i].counts,
                                      back.slots[i].counts)


# ---------------------------------------------------------------------------
# Slot-surgery validation (satellite: raise, never broadcast)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _tiny_engine():
    return EventEngine(_random_tables(7, n=16, cluster=8, k=16, edges=12),
                       NeuronParams(), queue_capacity=16)


def test_reset_slots_rejects_mismatched_mask():
    eng = _tiny_engine()
    carry = eng.init_state(batch=4)
    with pytest.raises(ValueError, match="mask"):
        eng.reset_slots(carry, np.zeros(3, dtype=bool))  # length mismatch
    with pytest.raises(ValueError, match="mask"):
        eng.reset_slots(carry, np.zeros((2, 2), dtype=bool))  # rank mismatch
    # the functional core refuses too (custom serving loops use it directly)
    import jax.numpy as jnp
    fresh = eng.init_state(batch=4)
    with pytest.raises(ValueError, match="mask"):
        reset_slots(carry, jnp.zeros(5, dtype=bool), fresh)
    # and the well-formed mask still works
    out = eng.reset_slots(carry, np.array([True, False, False, True]))
    assert np.asarray(out[1]).shape == np.asarray(carry[1]).shape


def test_splice_slots_rejects_mismatched_state_leaf():
    eng = _tiny_engine()
    carry = eng.init_state(batch=4)
    sc = eng.extract_slots(carry, [0, 1])
    import dataclasses as dc
    import jax
    bad = dc.replace(
        sc,
        state=jax.tree_util.tree_map(lambda x: x[:, :-1], sc.state),
    )
    with pytest.raises(ValueError, match="leaf"):
        eng.splice_slots(carry, [0, 1], bad)
    # wrong slot count in the carry vs the index list
    with pytest.raises(ValueError, match="SlotCarry holds"):
        eng.splice_slots(carry, [0, 1, 2], sc)
    # out-of-range and duplicate slot ids keep raising
    with pytest.raises(ValueError, match="out of range"):
        eng.extract_slots(carry, [0, 99])
    with pytest.raises(ValueError, match="unique"):
        eng.extract_slots(carry, [1, 1])
