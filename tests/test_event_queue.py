"""AER event-queue compaction and overflow semantics (DESIGN.md §10).

The contract under test:
  * below capacity the queued path is lossless — bit-parity with the dense
    delivery path and the dense [N, N, 4] oracle;
  * above capacity the overflow is deterministic: the first ``capacity``
    active sources (lowest ids — the arbiter scan order) win the bus, the
    drop counter equals ``n_active - capacity``, and the delivered drive is
    exactly the oracle applied to the kept subset (no NaNs/garbage);
  * the property holds across random sparsity levels (hypothesis, skipped
    cleanly when the extra isn't installed);
  * EventEngine threads capacity + drop stats through step/run and the
    stats stack over the scan's time axis.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from tests._hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.core.dispatch import available_backends, get_backend
from repro.core.event_engine import EventEngine, dense_weights_from_tables
from repro.core.tags import NetworkSpec, compile_network
from repro.core.two_stage import (
    _accumulate_activity,
    compact_events,
    stage1_route,
    stage1_route_events,
    two_stage_deliver,
)


def _tables(seed, n=48, cluster=16, k=48, edges=70):
    rng = np.random.default_rng(seed)
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=24, max_sram_entries=16)
    seen = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        spec.connect(s, d, int(rng.integers(4)))
    return compile_network(spec)


def _deliver_args(tables):
    return (
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )


# ---------------------------------------------------------------------------
# compaction primitive
# ---------------------------------------------------------------------------
def test_compact_picks_lowest_ids_in_order():
    spikes = jnp.zeros((12,)).at[jnp.asarray([1, 4, 7, 9])].set(
        jnp.asarray([0.5, 2.0, 1.5, 3.0])
    )
    q = compact_events(spikes, 8)
    np.testing.assert_array_equal(np.asarray(q.src)[:4], [1, 4, 7, 9])
    np.testing.assert_array_equal(np.asarray(q.src)[4:], [-1] * 4)
    np.testing.assert_allclose(np.asarray(q.weight)[:4], [0.5, 2.0, 1.5, 3.0])
    np.testing.assert_allclose(np.asarray(q.weight)[4:], 0.0)
    assert int(q.dropped) == 0


def test_compact_overflow_drops_highest_ids_deterministically():
    spikes = jnp.zeros((16,)).at[jnp.asarray([2, 3, 5, 11, 13, 14])].set(1.0)
    q = compact_events(spikes, 4)
    np.testing.assert_array_equal(np.asarray(q.src), [2, 3, 5, 11])
    assert int(q.dropped) == 2
    # deterministic: identical input -> identical queue
    q2 = compact_events(spikes, 4)
    np.testing.assert_array_equal(np.asarray(q.src), np.asarray(q2.src))


def test_compact_batched_counts_per_stream():
    rng = np.random.default_rng(5)
    spikes = jnp.asarray(rng.random((3, 40)) < 0.5, jnp.float32)
    q = compact_events(spikes, 8)
    n_active = np.asarray((spikes != 0).sum(-1))
    np.testing.assert_array_equal(
        np.asarray(q.dropped), np.maximum(n_active - 8, 0)
    )
    assert q.src.shape == (3, 8)


def test_compact_rejects_nonpositive_capacity():
    with pytest.raises(ValueError, match="capacity"):
        compact_events(jnp.zeros((8,)), 0)


# ---------------------------------------------------------------------------
# parity below capacity; deterministic drops above
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b", [None, 4])
def test_below_capacity_queued_equals_dense_path(b):
    tables = _tables(0)
    rng = np.random.default_rng(1)
    shape = (tables.n_neurons,) if b is None else (b, tables.n_neurons)
    spikes = jnp.asarray(rng.random(shape) < 0.25, jnp.float32)
    args = _deliver_args(tables)
    dense_drive = two_stage_deliver(spikes, *args)
    queued_drive, stats = two_stage_deliver(
        spikes, *args, queue_capacity=tables.n_neurons, with_stats=True
    )
    np.testing.assert_allclose(
        np.asarray(queued_drive), np.asarray(dense_drive), rtol=1e-6
    )
    assert int(np.asarray(stats.dropped).max()) == 0


def test_overflow_drive_equals_oracle_of_kept_subset():
    tables = _tables(2)
    dense = jnp.asarray(dense_weights_from_tables(tables))
    rng = np.random.default_rng(3)
    spikes = jnp.asarray(rng.random((2, tables.n_neurons)) < 0.6, jnp.float32)
    cap = 8
    drive, stats = two_stage_deliver(
        spikes, *_deliver_args(tables), queue_capacity=cap, with_stats=True
    )
    # the kept subset is the first `cap` active sources of each stream
    kept = np.zeros_like(np.asarray(spikes))
    for i, row in enumerate(np.asarray(spikes)):
        active = np.flatnonzero(row)
        kept[i, active[:cap]] = row[active[:cap]]
        assert int(stats.dropped[i]) == max(0, len(active) - cap)
    ref = jnp.einsum("dst,bs->bdt", dense, jnp.asarray(kept))
    np.testing.assert_allclose(np.asarray(drive), np.asarray(ref), rtol=1e-5, atol=1e-6)
    assert np.isfinite(np.asarray(drive)).all()


def test_overflow_stats_consistent_across_backends():
    """Every backend reports the same total drop count for the same input."""
    tables = _tables(4)
    rng = np.random.default_rng(6)
    spikes = jnp.asarray(rng.random((2, tables.n_neurons)) < 0.7, jnp.float32)
    args = _deliver_args(tables)
    counts = {}
    for name in available_backends():
        _, stats = two_stage_deliver(
            spikes, *args, backend=name, queue_capacity=16, with_stats=True
        )
        counts[name] = np.asarray(stats.dropped)
        assert (counts[name] >= 0).all()
    # reference defines the contract; single-device sharded and fused agree
    for name, c in counts.items():
        np.testing.assert_array_equal(c, counts["reference"], err_msg=name)


if HAS_HYPOTHESIS:
    _sparsity = st.floats(min_value=0.0, max_value=1.0)
    _caps = st.integers(min_value=1, max_value=64)

    @settings(max_examples=25, deadline=None)
    @given(sparsity=_sparsity, cap=_caps, seed=st.integers(0, 2**16))
    def test_property_queue_semantics_random_sparsity(sparsity, cap, seed):
        tables = _tables(7)
        rng = np.random.default_rng(seed)
        spikes = jnp.asarray(
            rng.random(tables.n_neurons) < sparsity, jnp.float32
        )
        drive, stats = two_stage_deliver(
            spikes, *_deliver_args(tables), queue_capacity=cap, with_stats=True
        )
        n_active = int(np.asarray((spikes != 0).sum()))
        assert int(stats.dropped) == max(0, n_active - cap)
        assert np.isfinite(np.asarray(drive)).all()
        if n_active <= cap:  # lossless regime: parity with the dense path
            dense_drive = two_stage_deliver(spikes, *_deliver_args(tables))
            np.testing.assert_allclose(
                np.asarray(drive), np.asarray(dense_drive), rtol=1e-6
            )
else:  # keep the suite honest about what was skipped
    @given()
    def test_property_queue_semantics_random_sparsity():
        pass  # pragma: no cover


# ---------------------------------------------------------------------------
# stage-1 primitives: queued scatter == dense scatter of the kept subset
# ---------------------------------------------------------------------------
def test_stage1_route_events_matches_dense_on_kept():
    tables = _tables(8)
    rng = np.random.default_rng(9)
    spikes = jnp.asarray(rng.random((3, tables.n_neurons)) < 0.5, jnp.float32)
    q = compact_events(spikes, 12)
    kept = jnp.zeros_like(spikes)
    bidx = jnp.arange(3)[:, None]
    kept = kept.at[bidx, jnp.clip(q.src, 0)].add(q.weight)
    a_q = stage1_route_events(
        q, jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        tables.n_clusters, tables.k_tags,
    )
    a_d = stage1_route(
        kept, jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        tables.n_clusters, tables.k_tags,
    )
    np.testing.assert_allclose(np.asarray(a_q), np.asarray(a_d), rtol=1e-6)


def test_accumulate_activity_paths_agree():
    """The int32-overflow fallbacks (int64 offsets / 2-D scatter) compute the
    same activity as the flat int32 fast path."""
    rng = np.random.default_rng(10)
    size = 17
    flat = jnp.asarray(rng.integers(0, size + 1, (6, 30)), jnp.int32)  # incl. sentinel
    w = jnp.asarray(rng.random((6, 30)), jnp.float32)
    base = np.asarray(_accumulate_activity(flat, w, size, _force_path="flat32"))
    np.testing.assert_allclose(
        np.asarray(_accumulate_activity(flat, w, size, _force_path="2d")), base,
        rtol=1e-6,
    )


# ---------------------------------------------------------------------------
# engine threading: capacity + stats through step/run
# ---------------------------------------------------------------------------
def test_engine_queue_step_and_run_emit_stats():
    tables = _tables(11)
    eng = EventEngine(tables, queue_capacity=8)
    b, t = 3, 12
    inp = jnp.zeros((t, b, tables.n_clusters, tables.k_tags)).at[:, :, :, :4].set(3.0)
    carry = eng.init_state(batch=b)
    carry, (spikes, stats) = eng.run(carry, inp)
    assert spikes.shape == (t, b, tables.n_neurons)
    assert stats.dropped.shape == (t, b)
    assert not bool(jnp.isnan(spikes).any())
    assert int(np.asarray(stats.dropped).min()) >= 0


def test_engine_lossless_queue_matches_dense_engine():
    tables = _tables(12)
    eng_dense = EventEngine(tables)
    eng_queue = EventEngine(tables, queue_capacity=tables.n_neurons)
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
    c_d, c_q = eng_dense.init_state(), eng_queue.init_state()
    for _ in range(15):
        c_d, s_d = eng_dense.step(c_d, inp)
        c_q, (s_q, stats) = eng_queue.step(c_q, inp)
        np.testing.assert_allclose(np.asarray(s_q), np.asarray(s_d), atol=1e-6)
        assert int(stats.dropped) == 0


def test_engine_overflowing_queue_stays_finite_and_counts():
    tables = _tables(13)
    eng = EventEngine(tables, queue_capacity=2)
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, :8].set(6.0)
    carry = eng.init_state()
    saw_drop = False
    for _ in range(25):
        carry, (spikes, stats) = eng.step(carry, inp)
        assert np.isfinite(np.asarray(spikes)).all()
        saw_drop |= int(stats.dropped) > 0
    assert saw_drop  # the stimulus drives far more than 2 neurons active


def test_engine_rejects_bad_capacity():
    with pytest.raises(ValueError, match="queue_capacity"):
        EventEngine(_tables(14), queue_capacity=0)


def test_engine_donate_carry_threads_correctly():
    """donate_carry=True matches the default engine when the carry is
    properly threaded (donation is a no-op on CPU; the flag path and the
    thread-the-carry contract are what's under test)."""
    tables = _tables(16)
    eng = EventEngine(tables, queue_capacity=16, donate_carry=True)
    eng_ref = EventEngine(tables, queue_capacity=16)
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
    c_d, c_r = eng.init_state(), eng_ref.init_state()
    for _ in range(10):
        c_d, (s_d, _) = eng.step(c_d, inp)
        c_r, (s_r, _) = eng_ref.step(c_r, inp)
        np.testing.assert_allclose(np.asarray(s_d), np.asarray(s_r), atol=1e-6)


def test_legacy_backend_signature_still_works():
    """Backends registered before event-sparse delivery (no queue_capacity /
    syn_onehot / with_stats keywords) must keep working through both
    two_stage_deliver and EventEngine; asking them for a queue raises."""
    from repro.core.dispatch import DispatchBackend, register_backend
    from repro.core.two_stage import stage1_route, stage2_cam_match

    @register_backend("_test_legacy")
    class LegacyBackend(DispatchBackend):
        # the pre-§10 deliver signature, verbatim
        def deliver(self, spikes, src_tag, src_dest, cam_tag, cam_syn,
                    cluster_size, k_tags, external_activity=None):
            a = stage1_route(spikes, src_tag, src_dest,
                             spikes.shape[-1] // cluster_size, k_tags)
            if external_activity is not None:
                a = a + external_activity
            return stage2_cam_match(a, cam_tag, cam_syn, cluster_size)

    try:
        tables = _tables(17)
        rng = np.random.default_rng(18)
        spikes = jnp.asarray(rng.random((2, tables.n_neurons)) < 0.3, jnp.float32)
        args = _deliver_args(tables)
        ref = two_stage_deliver(spikes, *args)
        # plain delivery passes no new kwargs through
        out = two_stage_deliver(spikes, *args, backend="_test_legacy")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
        # with_stats is synthesized (zero drops), syn_onehot dropped silently
        out, stats = two_stage_deliver(
            spikes, *args, backend="_test_legacy", with_stats=True,
            syn_onehot=jnp.zeros((tables.n_neurons, tables.cam_tag.shape[1], 4)),
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(stats.dropped), 0)
        # the engine always requests stats internally — still fine
        eng = EventEngine(tables, backend="_test_legacy")
        carry, spikes_out = eng.step(eng.init_state(batch=2),
                                     jnp.zeros((2, tables.n_clusters, tables.k_tags)))
        assert spikes_out.shape == (2, tables.n_neurons)
        # a queue is a semantic request a legacy backend cannot honor
        with pytest.raises(ValueError, match="does not support queue_capacity"):
            two_stage_deliver(spikes, *args, backend="_test_legacy",
                              queue_capacity=8)
    finally:
        from repro.core import dispatch as _dispatch

        _dispatch._REGISTRY.pop("_test_legacy", None)


def test_engine_sharded_backend_queue_single_device():
    """The sharded backend's per-core FIFO path on the default 1x1 mesh."""
    tables = _tables(15)
    eng = EventEngine(tables, backend="sharded", queue_capacity=tables.n_neurons)
    eng_ref = EventEngine(tables, queue_capacity=tables.n_neurons)
    b = 2
    inp = jnp.zeros((b, tables.n_clusters, tables.k_tags)).at[:, :, 1].set(4.0)
    c_s, c_r = eng.init_state(batch=b), eng_ref.init_state(batch=b)
    for _ in range(10):
        c_s, (s_s, st_s) = eng.step(c_s, inp)
        c_r, (s_r, st_r) = eng_ref.step(c_r, inp)
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_r), atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(st_s.dropped), np.asarray(st_r.dropped)
        )
