"""Paper §II + Appendix A: memory-optimized routing theory."""

import math

import pytest
from _hypothesis_compat import given, settings, st  # degrades to skip without hypothesis

from repro.core import memory_model as mm


def test_paper_headline_numbers():
    """§II: N=2^20, F=2^13, C=256 -> conventional 160k bits/neuron vs ~1.2k
    per side at the optimum (the paper quotes the per-side figure)."""
    conv = mm.conventional_bits(2**20, 2**13)
    assert conv == pytest.approx(163840.0)
    opt_total = mm.mem_at_optimal_m(2**20, 2**13, 256)
    per_side = opt_total / 2.0  # MEM_S == MEM_T at M*
    assert per_side < 1200.0
    assert conv / opt_total > 70.0  # >70x reduction even counting both sides


def test_paper_design_point_m_star():
    """Appendix A: C=256, alpha=1, F=5040, N=1e10 -> M* ~ 144, F/M ~ 35."""
    m = mm.optimal_m(1e10, 5040, 256)
    assert m == pytest.approx(144.67, abs=0.5)
    assert 5040 / m == pytest.approx(34.8, abs=0.5)


def test_constraint_c_lower_bound():
    """Appendix A: F=5000, N=1e10 -> clusters need C >= ~152."""
    c = mm.constraint_c_lower_bound(1e10, 5000)
    assert 130 <= c <= 175
    assert mm.feasible(1e10, 5000, 256)


@given(
    n=st.integers(2**12, 2**24),
    f=st.integers(64, 2**13),
    c=st.sampled_from([64, 128, 256, 512, 1024]),
)
@settings(max_examples=60, deadline=None)
def test_m_star_minimizes_memory(n, f, c):
    """Property: eq.(5)'s M* is the argmin of eq.(3) over M."""
    m_star = mm.optimal_m(n, f, c)
    best = mm.mem_total_bits_alpha(n, f, c, m_star)
    for mult in (0.5, 0.8, 1.25, 2.0):
        m = max(1.0, m_star * mult)
        assert mm.mem_total_bits_alpha(n, f, c, m) >= best - 1e-6


@given(
    n=st.integers(2**12, 2**22),
    f=st.integers(64, 2**12),
    c=st.sampled_from([128, 256, 512]),
    alpha=st.sampled_from([0.5, 1.0, 2.0, 4.0]),
)
@settings(max_examples=60, deadline=None)
def test_eq6_matches_eq3_at_optimum(n, f, c, alpha):
    """Closed form (eq.6 generalized) equals eq.(3) evaluated at M*."""
    m_star = mm.optimal_m(n, f, c, alpha)
    assert mm.mem_at_optimal_m(n, f, c, alpha) == pytest.approx(
        mm.mem_total_bits_alpha(n, f, c, m_star, alpha), rel=1e-9
    )


@given(n=st.integers(2**14, 2**24), f=st.integers(256, 2**13))
@settings(max_examples=40, deadline=None)
def test_optimized_beats_conventional(n, f):
    """For biologically-plausible fan-outs the scheme always wins (C=256)."""
    if not mm.feasible(n, f, 256):
        return
    assert mm.mem_at_optimal_m(n, f, 256) < mm.conventional_bits(n, f)


@given(
    n=st.integers(2**12, 2**24),
    f=st.integers(64, 2**13),
    c=st.sampled_from([64, 128, 256, 512, 1024]),
    alpha=st.sampled_from([0.5, 1.0, 2.0, 4.0]),
)
@settings(max_examples=60, deadline=None)
def test_optimal_m_integer_is_argmin_over_feasible_range(n, f, c, alpha):
    """Property: ``optimal_m_integer`` is the argmin of eq.(3) over integer
    M in [1, min(F, C)] — checked against brute force."""
    m_int = mm.optimal_m_integer(n, f, c, alpha)
    hi = min(f, c)
    assert 1 <= m_int <= hi
    best = mm.mem_total_bits_alpha(n, f, c, m_int, alpha)
    # brute force on a log-spaced cover plus the exact neighborhood
    candidates = set(range(max(1, m_int - 3), min(hi, m_int + 3) + 1))
    m = 1
    while m <= hi:
        candidates.add(m)
        m *= 2
    candidates.add(hi)
    for cand in candidates:
        assert mm.mem_total_bits_alpha(n, f, c, cand, alpha) >= best - 1e-9


def test_optimal_m_integer_brute_force_small_ranges():
    """Deterministic slice of the property above (runs without hypothesis):
    exhaustive argmin over the whole feasible range."""
    for n, f, c, alpha in [
        (2**14, 100, 64, 1.0),
        (2**20, 2**13, 256, 1.0),
        (2**16, 500, 128, 2.0),
        (2**12, 64, 1024, 0.5),
    ]:
        m_int = mm.optimal_m_integer(n, f, c, alpha)
        hi = min(f, c)
        costs = [mm.mem_total_bits_alpha(n, f, c, m, alpha) for m in range(1, hi + 1)]
        assert mm.mem_total_bits_alpha(n, f, c, m_int, alpha) == pytest.approx(
            min(costs)
        )


def test_fig13_crossover_pinned_at_prototype_point():
    """Fig. 13 pinned row at the prototype design point (N=1024, F=4096,
    C=256, K=256): at M=1 the two-stage scheme degenerates to point-to-point
    and costs *more* than conventional routing (tags buy nothing), at the
    prototype's M=64 it is ~35x cheaper — the crossover the figure plots."""
    p = mm.paper_prototype_params()
    conv = mm.conventional_bits(p.n, p.f)
    assert conv == pytest.approx(40960.0)  # F * log2 N = 4096 * 10
    # M = 1: (F)(log2 K + log2 N/C) + (K/C) log2 K = 4096*10 + 8 bits
    assert mm.mem_total_bits(p.n, p.f, p.c, 1, p.k) == pytest.approx(40968.0)
    assert mm.mem_total_bits(p.n, p.f, p.c, 1, p.k) > conv  # left of crossover
    # prototype M = 64: 64*(8+2) + 64*8 = 1152 bits/neuron, ~35.6x less
    at_m64 = mm.mem_total_bits(p.n, p.f, p.c, p.m, p.k)
    assert at_m64 == pytest.approx(1152.0)
    assert conv / at_m64 == pytest.approx(35.56, abs=0.01)
    # the integer optimum at this point is within the hardware's M range
    assert 1 < mm.optimal_m_integer(p.n, p.f, p.c) <= p.c


def test_n_clusters_counts_ragged_tail():
    """n % c != 0 must round UP: 1000 neurons on 256-neuron cores need 4
    cores — floor division reported 3, silently dropping 232 neurons from
    feasibility/traffic numbers."""
    p = mm.RoutingParams(n=1000, f=64, c=256, m=8)
    assert p.n_clusters == 4
    assert p.n_clusters * p.c >= p.n  # every neuron is hosted
    assert mm.RoutingParams(n=1024, f=64, c=256, m=8).n_clusters == 4  # exact
    assert mm.RoutingParams(n=100, f=64, c=256, m=8).n_clusters == 1  # sub-core


def test_sram_cam_split_matches_prototype():
    p = mm.paper_prototype_params()
    assert p.k == 256 and p.n_clusters == 4
    # prototype: fan-out 4k via 64-way CAM words/neuron (K*M/C = 64)
    assert p.cam_words_per_neuron == 64
    assert p.stage1_fanout == 64
