"""Multi-tenant AER serving (DESIGN.md §12): session pool, slot surgery,
stream determinism, and the input-path hardening sweep.

The load-bearing contract is slot-reuse *isolation*: after a tenant is
evicted and the slot reset, a fresh session's outputs are bit-identical to
a solo run — in zero-latency mode (neuron state + spikes wiped) and in
fabric mode (the departing tenant's still-in-flight cross-tile events,
which are part of the slot's carry, wiped with it).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.cnn import compile_poker_cnn
from repro.core.event_engine import EventEngine
from repro.core.neuron import NeuronParams
from repro.core.routing import ChipConstants, Fabric
from repro.core.tags import NetworkSpec, compile_network
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource, symbol_dvs_events
from repro.serve.aer import (
    AerServeConfig,
    AerSessionPool,
    DvsSession,
    build_poker_engine,
)

DT = 1e-3


# ---------------------------------------------------------------------------
# deterministic, resumable DVS streams
# ---------------------------------------------------------------------------
def test_dvs_stream_deterministic_and_resumable():
    cfg = DvsStreamConfig(symbol=2, events_per_step=8, seed=3)
    a, b = DvsStreamSource(cfg, session_id=5), DvsStreamSource(cfg, session_id=5)
    for step in (0, 1, 17):  # pure function of step: replay from any cursor
        np.testing.assert_array_equal(a.events(step), b.events(step))
    assert a.events(0).shape == (8, 2)
    assert not np.array_equal(a.events(0), a.events(1))  # stream moves
    other = DvsStreamSource(cfg, session_id=6)
    assert not np.array_equal(a.events(0), other.events(0))  # sessions differ


def test_dvs_stream_events_in_range():
    for sym in range(4):
        cfg = DvsStreamConfig(symbol=sym, events_per_step=64, input_hw=32)
        ev = DvsStreamSource(cfg).events(0)
        assert ev.min() >= 0 and ev.max() < 32
    with pytest.raises(ValueError, match="symbol"):
        symbol_dvs_events(4, 8, np.random.default_rng(0))


# ---------------------------------------------------------------------------
# engine-level slot surgery
# ---------------------------------------------------------------------------
def _small_net(rng, n=32, cluster=8, k=32, edges=64, fabric=None):
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=16, max_sram_entries=8)
    seen = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        spec.connect(s, d, int(rng.integers(4)))
    return compile_network(spec, fabric=fabric)


def test_reset_slots_wipes_only_masked_slots():
    rng = np.random.default_rng(0)
    eng = EventEngine(_small_net(rng), queue_capacity=32)
    carry = eng.init_state(batch=3)
    inp = jnp.zeros((3, 4, 32)).at[:, :, :4].set(2.0)
    i_ext = jnp.full((3, 32), 5e3)
    for _ in range(4):
        carry, _ = eng.step(carry, inp, i_ext)
    assert float(np.abs(np.asarray(carry[0].v) - eng.params.v_rest).max()) > 0
    reset = eng.reset_slots(carry, np.array([True, False, True]))
    fresh = eng.init_state(batch=3)
    for got, want, old in zip(
        jax.tree_util.tree_leaves(reset),
        jax.tree_util.tree_leaves(fresh),
        jax.tree_util.tree_leaves(carry),
    ):
        got, want, old = np.asarray(got), np.asarray(want), np.asarray(old)
        np.testing.assert_array_equal(got[0], want[0])  # wiped
        np.testing.assert_array_equal(got[2], want[2])  # wiped
        np.testing.assert_array_equal(got[1], old[1])  # untouched, bit-exact


def test_reset_slots_requires_batched_carry():
    eng = EventEngine(_small_net(np.random.default_rng(0)))
    with pytest.raises(ValueError, match="batched carry"):
        eng.reset_slots(eng.init_state(), np.asarray(True))


# ---------------------------------------------------------------------------
# slot-reuse isolation: evict mid-run, admit fresh, bit-identical to solo
# ---------------------------------------------------------------------------
def _isolation_engine(mode):
    """2-slot engine on an 8-neuron, 2-cluster net with cross-cluster edges.

    In fabric mode the two clusters sit on different tiles with a 2-step
    mesh delay, so cross-tile events are genuinely in flight at eviction.
    """
    const = ChipConstants(latency_across_chip_s=2 * DT)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8, max_cam_words=64)
    # heavy cross-tile edges + strong synaptic gain so one source spike makes
    # the destination neuron fire (a leak must be visible in spike output)
    spec.connect_group([0], [(4, 0)], shared_tag=False, copies=32)
    spec.connect_group([1], [(5, 0)], shared_tag=False, copies=32)
    spec.connect_group([2], [(6, 0)], shared_tag=False, copies=32)
    tables = compile_network(spec, fabric=fab)
    params = NeuronParams(input_gain=3.0)
    if mode == "fabric":
        return EventEngine(tables, params, fabric=fab, fabric_options={"dt": DT})
    return EventEngine(tables, params, queue_capacity=8)


def _drive(neuron, on):
    i_ext = np.zeros((2, 8), np.float32)
    if on:
        i_ext[0, neuron] = 5e3
    i_ext[1, 7] = 5e3  # slot 1's tenant keeps running throughout
    return jnp.asarray(i_ext)


@pytest.mark.parametrize("mode", ["queued", "fabric"])
def test_slot_reuse_isolation_bit_exact(mode):
    eng = _isolation_engine(mode)
    zero_inp = jnp.zeros((2, 2, 8))

    def run_session(carry, neuron, t_on, t_total):
        """Kick ``neuron`` in slot 0 for t_on steps; record slot-0 spikes."""
        spikes = []
        for t in range(t_total):
            carry, out = eng.step(carry, zero_inp, _drive(neuron, t < t_on))
            s = out[0] if isinstance(out, tuple) else out
            spikes.append(np.asarray(s)[0])
        return carry, np.stack(spikes)

    # tenant A runs in slot 0 and is evicted with events still in transit
    carry = eng.init_state(batch=2)
    carry, _ = run_session(carry, neuron=0, t_on=3, t_total=3)
    if mode == "fabric":
        # the eviction-time hazard is real: A's cross-tile events are on the
        # mesh right now, addressed to this slot's network
        assert float(np.abs(np.asarray(carry[2])[0]).sum()) > 0
    carry = eng.reset_slots(carry, np.array([True, False]))
    if mode == "fabric":
        assert float(np.abs(np.asarray(carry[2])[0]).sum()) == 0

    # fresh tenant C reuses slot 0 while slot 1's tenant keeps running
    _, spikes_reused = run_session(carry, neuron=2, t_on=3, t_total=10)

    # solo reference: C admitted into a never-used pool
    _, spikes_solo = run_session(eng.init_state(batch=2), neuron=2, t_on=3, t_total=10)

    assert spikes_solo.sum() > 0  # C's session does produce output spikes
    np.testing.assert_array_equal(spikes_reused, spikes_solo)


@pytest.mark.parametrize("mode", ["queued", "fabric"])
def test_no_reset_leaks_inflight_state(mode):
    """Control for the isolation test: skipping the reset DOES leak tenant
    A's state into C's run — proving the assertion above is load-bearing."""
    eng = _isolation_engine(mode)
    zero_inp = jnp.zeros((2, 2, 8))

    def run_session(carry, neuron, t_on, t_total):
        spikes = []
        for t in range(t_total):
            carry, out = eng.step(carry, zero_inp, _drive(neuron, t < t_on))
            s = out[0] if isinstance(out, tuple) else out
            spikes.append(np.asarray(s)[0])
        return carry, np.stack(spikes)

    carry = eng.init_state(batch=2)
    carry, _ = run_session(carry, neuron=0, t_on=3, t_total=3)
    _, spikes_dirty = run_session(carry, neuron=2, t_on=3, t_total=10)
    _, spikes_solo = run_session(eng.init_state(batch=2), neuron=2, t_on=3, t_total=10)
    assert not np.array_equal(spikes_dirty, spikes_solo)


# ---------------------------------------------------------------------------
# session pool over the compiled CNN
# ---------------------------------------------------------------------------
def _poker_pool(pool_size=2, **cfg_kw):
    cc = compile_poker_cnn()
    eng = build_poker_engine(cc.tables)
    cfg = AerServeConfig(pool_size=pool_size, max_steps=25, **cfg_kw)
    return cc, AerSessionPool(cc, eng, cfg)


def _session(i, symbol):
    return DvsSession(
        i,
        DvsStreamSource(DvsStreamConfig(symbol=symbol, events_per_step=16, seed=9),
                        session_id=i),
        label=symbol,
    )


def test_pool_admit_evict_lifecycle():
    _, pool = _poker_pool(pool_size=2)
    s0 = pool.admit(_session(0, 0))
    s1 = pool.admit(_session(1, 1))
    assert sorted((s0, s1)) == [0, 1] and not pool.free_slots
    with pytest.raises(RuntimeError, match="full"):
        pool.admit(_session(2, 2))
    pool.step()
    r = pool.evict(s0)
    assert r.session_id == 0 and r.latency_steps == 1
    assert pool.free_slots == [s0]
    with pytest.raises(ValueError, match="not occupied"):
        pool.evict(s0)
    # the freed slot is immediately reusable
    assert pool.admit(_session(3, 3)) == s0


def test_pool_serves_sessions_with_continuous_batching():
    _, pool = _poker_pool(pool_size=2)
    sessions = [_session(i, i % 4) for i in range(5)]
    results = pool.serve(sessions)
    assert len(results) == 5
    assert {r.session_id for r in results} == set(range(5))
    for r in results:
        assert 0 < r.latency_steps <= 25
        assert 0 <= r.prediction < 4
        assert r.counts.shape == (4,)
    # more sessions than slots were served: slots really were reused
    assert pool.n_steps < 5 * 25
    assert all(s is None for s in pool.slots)  # pool drained


class _BadPacketSource:
    """Well-formed stream that emits one garbage packet at ``bad_at``."""

    def __init__(self, bad_at: int):
        self.bad_at = bad_at

    def events(self, step: int) -> np.ndarray:
        if step == self.bad_at:
            return np.array([[5, -1]])  # negative coordinate
        return np.array([[15, 15], [16, 15]])


def test_malformed_packet_faults_session_not_pool():
    """Under on_invalid='raise' a bad packet terminates the offending
    session with SessionResult.error set; other tenants are untouched."""
    _, pool = _poker_pool(pool_size=2)
    good = _session(0, 1)
    bad = DvsSession(1, _BadPacketSource(bad_at=3), label=1)
    results = {r.session_id: r for r in pool.serve([good, bad])}
    assert len(results) == 2
    assert results[1].error is not None and "outside" in results[1].error
    assert not results[1].decided
    assert results[1].latency_steps == 4  # faulted on its 4th step
    assert results[0].error is None  # the good tenant was served to completion
    assert results[0].latency_steps <= 25
    assert all(s is None for s in pool.slots)  # pool drained, not crashed


def test_faulted_session_retries_with_clean_slate():
    """Re-admitting a previously-faulted session must clear the stale error
    (the deterministic sources make evict-and-retry a designed flow)."""
    _, pool = _poker_pool(pool_size=1)
    sess = DvsSession(7, _BadPacketSource(bad_at=0), label=1)
    first = pool.serve([sess])[0]
    assert first.error is not None
    sess.source = DvsStreamSource(
        DvsStreamConfig(symbol=1, events_per_step=16, seed=9), session_id=7
    )
    retry = pool.serve([sess])[0]
    assert retry.error is None
    assert retry.latency_steps > 1  # actually ran, not insta-terminated


def test_evict_many_single_reset():
    _, pool = _poker_pool(pool_size=3)
    slots = [pool.admit(_session(i, i % 4)) for i in range(3)]
    pool.step()
    results = pool.evict_many(slots[:2])
    assert [r.session_id for r in results] == [0, 1]
    assert sorted(pool.free_slots) == sorted(slots[:2])
    assert pool.occupied == [slots[2]]
    # atomic: a bad id must not free (without resetting) the valid ones
    with pytest.raises(ValueError, match="not occupied"):
        pool.evict_many([slots[2], slots[0]])
    assert pool.occupied == [slots[2]]
    with pytest.raises(ValueError, match="out of range"):
        pool.evict_many([99])
    # duplicates collapse to one eviction
    assert len(pool.evict_many([slots[2], slots[2]])) == 1


def test_per_tenant_inflight_cap_schedules_fairly():
    """max_inflight_per_tenant: a tenant with many queued sessions cannot
    occupy every slot — capped admission interleaves tenants; without the
    cap, FIFO admission serves the hog's whole backlog first. Deterministic
    (same symbol -> same latency for every session), so completion order is
    the exact test vector."""
    def sessions():
        # tenant "A" floods 4 sessions; tenant "B" queues 2 behind them
        return [
            DvsSession(
                i,
                DvsStreamSource(
                    DvsStreamConfig(symbol=1, events_per_step=16, seed=9),
                    session_id=i,
                ),
                label=1,
                tenant="A" if i < 4 else "B",
            )
            for i in range(6)
        ]

    cc = compile_poker_cnn()

    def serve(cap):
        pool = AerSessionPool(
            cc,
            build_poker_engine(cc.tables),
            AerServeConfig(
                pool_size=2, max_steps=25, max_inflight_per_tenant=cap
            ),
        )
        return [r.session_id for r in pool.serve(sessions())]

    assert serve(None) == [0, 1, 2, 3, 4, 5]  # FIFO: the hog wins
    assert serve(1) == [0, 4, 1, 5, 2, 3]  # capped: tenants interleave


def test_tenant_cap_never_deadlocks_single_tenant():
    """A cap of 1 with only one tenant still drains every session (slots go
    idle rather than starve, and the queue keeps moving)."""
    cc = compile_poker_cnn()
    pool = AerSessionPool(
        cc,
        build_poker_engine(cc.tables),
        AerServeConfig(pool_size=2, max_steps=25, max_inflight_per_tenant=1),
    )
    res = pool.serve([_session(i, 1) for i in range(3)])
    assert [r.session_id for r in res] == [0, 1, 2]


def test_pool_rejects_mismatched_engine():
    cc = compile_poker_cnn()
    other = EventEngine(_small_net(np.random.default_rng(1)))
    with pytest.raises(ValueError, match="neurons"):
        AerSessionPool(cc, other, AerServeConfig(pool_size=2))


# ---------------------------------------------------------------------------
# input-path hardening (the bugfix sweep)
# ---------------------------------------------------------------------------
class TestInputActivityHardening:
    @pytest.fixture(scope="class")
    def cc(self):
        return compile_poker_cnn()

    def test_negative_coordinate_raises_by_default(self, cc):
        with pytest.raises(ValueError, match="outside"):
            cc.input_activity(np.array([[5, -1]]))

    def test_coordinate_past_sensor_raises_by_default(self, cc):
        # used to build tag >= 1024 and break the pixel-block broadcast
        with pytest.raises(ValueError, match="outside"):
            cc.input_activity(np.array([[32, 0]]))
        with pytest.raises(ValueError, match="outside"):
            cc.input_activity(np.array([[0, 32]]))

    def test_clip_matches_pre_clipped_events(self, cc):
        bad = np.array([[-3, 40], [10, 10], [31, -1]])
        good = np.clip(bad, 0, 31)
        np.testing.assert_array_equal(
            cc.input_activity(bad, on_invalid="clip"), cc.input_activity(good)
        )

    def test_drop_keeps_only_valid_events(self, cc):
        mixed = np.array([[5, 5], [-1, 0], [40, 40], [6, 6]])
        np.testing.assert_array_equal(
            cc.input_activity(mixed, on_invalid="drop"),
            cc.input_activity(np.array([[5, 5], [6, 6]])),
        )
        all_bad = np.array([[-1, -1]])
        assert cc.input_activity(all_bad, on_invalid="drop").sum() == 0

    def test_batch_threads_policy(self, cc):
        streams = [np.array([[5, -1]]), np.array([[3, 3]])]
        with pytest.raises(ValueError, match="outside"):
            cc.input_activity_batch(streams)
        out = cc.input_activity_batch(streams, on_invalid="drop")
        assert out.shape[0] == 2 and out[0].sum() == 0 and out[1].sum() > 0

    def test_bad_policy_and_shape_rejected(self, cc):
        with pytest.raises(ValueError, match="on_invalid"):
            cc.input_activity(np.zeros((1, 2)), on_invalid="ignore")
        with pytest.raises(ValueError, match="n_ev, 2"):
            cc.input_activity(np.zeros((3, 3)))

    def test_empty_stream_still_fine(self, cc):
        assert cc.input_activity(np.zeros((0, 2))).sum() == 0


# ---------------------------------------------------------------------------
# carry donation (DESIGN.md §14): serving default, CPU no-op, result parity
# ---------------------------------------------------------------------------
def test_donate_carry_kwargs_by_backend(monkeypatch):
    """Donation resolves per platform: on CPU the jit gets no donate kwargs
    (XLA:CPU would warn on every compile), on accelerators the carry
    (argument 0) is donated."""
    from repro.core import event_engine as ee

    monkeypatch.setattr(ee.jax, "default_backend", lambda: "cpu")
    assert ee._donate_carry_kwargs() == {}
    for plat in ("tpu", "gpu"):
        monkeypatch.setattr(ee.jax, "default_backend", lambda p=plat: p)
        assert ee._donate_carry_kwargs() == {"donate_argnums": (0,)}


def test_build_poker_engine_donates_by_default():
    """Serving flips the engine's conservative default: build_poker_engine
    requests donation unless opted out (the pool always threads the
    returned carry, so donation is safe there)."""
    import inspect

    from repro.serve.aer import build_poker_engine

    sig = inspect.signature(build_poker_engine)
    assert sig.parameters["donate_carry"].default is True
    assert (
        inspect.signature(EventEngine.__init__).parameters["donate_carry"].default
        is False
    )


def test_donation_flag_does_not_change_results():
    """donate on vs off: bit-identical spikes and carry over a run (on CPU
    donation no-ops; on accelerators the donated buffers are reused in
    place but the values must match — the pool never re-reads a stepped
    carry, so this is the only observable surface)."""
    rng = np.random.default_rng(21)
    tables = _small_net(rng)
    t, b = 6, 2
    inp = jnp.asarray(
        (np.random.default_rng(22).random((t, b, 4, 32)) < 0.2) * 3.0, jnp.float32
    )
    outs = []
    for donate in (False, True):
        eng = EventEngine(tables, queue_capacity=16, donate_carry=donate)
        carry, (spikes, stats) = eng.run(eng.init_state(batch=b), inp)
        outs.append((carry, spikes, stats))
    (c0, s0, st0), (c1, s1, st1) = outs
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    for a, bb in zip(jax.tree_util.tree_leaves((c0, st0)),
                     jax.tree_util.tree_leaves((c1, st1))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
