"""Fault injection, degraded-mode repair, and checkpointed recovery (§15).

The chaos harness for the robustness PR: declarative fault loads
(core/faults.py) must degrade the executable fabric *identically* on both
delivery paths (ring fast path vs roll oracle), the repair pipeline
(compiler.repair_placement -> EventEngine.extract/splice_slots ->
serve/health.migrate_pool) must bring the Table-V poker workload back to
100% accuracy around 25% failed mesh links, and a pool killed mid-serve
must resume bit-exactly from its checkpoint.
"""

import dataclasses
from collections import deque

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.cnn import (
    CnnConfig,
    compile_poker_cnn,
    hebbian_readout_select,
    poker_neuron_params,
)
from repro.core.compiler import repair_placement
from repro.core.event_engine import EventEngine
from repro.core.faults import (
    FaultSpec,
    apply_table_faults,
    entry_alive_mask,
    fault_blast_radius,
    mesh_links,
    pair_fault_matrices,
    tile_fault_matrices,
    xy_path,
)
from repro.core.neuron import NeuronParams
from repro.core.routing import ChipConstants, Fabric
from repro.core.tags import NetworkSpec, compile_network
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource, symbol_dvs_events
from repro.serve.aer import (
    AerServeConfig,
    AerSessionPool,
    DvsSession,
    PoolFullError,
    SlotError,
    build_poker_engine,
)
from repro.serve.health import (
    FaultEvent,
    Watchdog,
    WatchdogConfig,
    migrate_pool,
    serve_resilient,
)

DT = 1e-3
# 25% of the default 3x3 board's 24 directed links, chosen to sever the
# compiled poker placement's tile-0 -> tile-1 forward path in both directions
DEAD25 = ((0, 1), (1, 0), (0, 3), (3, 0), (1, 2), (2, 1))


# ---------------------------------------------------------------------------
# topology model: XY routes vs the fault set
# ---------------------------------------------------------------------------
def test_mesh_links_and_xy_path():
    fab = Fabric()  # 3x3
    links = mesh_links(fab)
    assert len(links) == 24 and len(set(links)) == 24
    assert xy_path(fab, 0, 0) == []
    assert xy_path(fab, 0, 2) == [(0, 1), (1, 2)]  # X first
    assert xy_path(fab, 0, 8) == [(0, 1), (1, 2), (2, 5), (5, 8)]  # then Y
    assert xy_path(fab, 8, 0) == [(8, 7), (7, 6), (6, 3), (3, 0)]
    for path in (xy_path(fab, 0, 8), xy_path(fab, 8, 0)):
        assert all(link in set(links) for link in path)


def test_fault_spec_validation():
    fab = Fabric()
    with pytest.raises(ValueError, match="out of range"):
        FaultSpec(dead_tiles=(9,)).validate(fab)
    with pytest.raises(ValueError, match="not a directed adjacent"):
        FaultSpec(dead_links=((0, 2),)).validate(fab)  # not adjacent
    with pytest.raises(ValueError, match="outside"):
        FaultSpec(link_drop_rate=1.5)
    with pytest.raises(ValueError, match="outside"):
        FaultSpec(link_drop_rate={(0, 1): -0.1})
    assert not FaultSpec().routes_faulted
    assert FaultSpec(dead_links=((0, 1),)).routes_faulted
    assert FaultSpec(link_drop_rate=0.1).routes_faulted


def test_tile_fault_matrices_dead_link_and_tile():
    fab = Fabric()
    alive, rate = tile_fault_matrices(fab, FaultSpec(dead_links=((0, 1),)))
    assert not alive[0, 1] and not alive[0, 2]  # route 0->2 crosses 0->1
    assert alive[1, 0] and alive[2, 0]  # reverse direction untouched
    assert not alive[0, 4]  # 0->4 = X to 1 then Y: crosses the dead link
    assert alive[0, 3] and alive[3, 4]
    # dead tile kills endpoints AND pass-through routes
    alive, _ = tile_fault_matrices(fab, FaultSpec(dead_tiles=(1,)))
    assert not alive[1, 1] and not alive[0, 1] and not alive[1, 2]
    assert not alive[0, 2]  # XY route 0->2 passes through tile 1
    assert alive[0, 3]
    # stochastic rates compound along the path
    _, rate = tile_fault_matrices(fab, FaultSpec(link_drop_rate=0.1))
    np.testing.assert_allclose(rate[0, 2], 1 - 0.9**2)
    np.testing.assert_allclose(rate[0, 8], 1 - 0.9**4)
    assert rate[0, 0] == 0.0


def test_pair_fault_matrices_stuck_cluster_severs_outbound_only():
    fab = Fabric()
    tiles = np.array([0, 1], dtype=np.int32)
    alive, _ = pair_fault_matrices(fab, tiles, FaultSpec(stuck_clusters=(0,)))
    assert not alive[0, 1] and not alive[0, 0]  # nothing leaves cluster 0
    assert alive[1, 0]  # delivery TO it still works
    with pytest.raises(ValueError, match="out of range"):
        pair_fault_matrices(fab, tiles, FaultSpec(stuck_clusters=(5,)))


# ---------------------------------------------------------------------------
# fabric engines under faults: ring/roll parity, drop accounting
# ---------------------------------------------------------------------------
def _two_tile_tables():
    """8-neuron, 2-cluster net on a 1x2 mesh with heavy cross-tile traffic."""
    const = ChipConstants(latency_across_chip_s=2 * DT)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8, max_cam_words=64)
    spec.connect_group([0], [(4, 0)], shared_tag=False, copies=32)
    spec.connect_group([1], [(5, 0)], shared_tag=False, copies=32)
    spec.connect_group([2], [(3, 1)], shared_tag=False, copies=2)  # same-tile
    return compile_network(spec, fabric=fab), fab


def _run_faulted(tables, fab, faults, ring, steps=8, seed=0):
    eng = EventEngine(
        tables,
        NeuronParams(input_gain=3.0, dt=DT),
        fabric=fab,
        queue_capacity=8,
        fabric_options={"dt": DT, "ring": ring, **({"faults": faults} if faults else {})},
    )
    carry = eng.init_state(batch=2)
    rng = np.random.default_rng(seed)
    link_dropped = delivered = n_spikes = 0
    for _ in range(steps):
        i_ext = jnp.asarray((rng.random((2, 8)) < 0.5) * 5e3, jnp.float32)
        carry, (spikes, stats) = eng.step(carry, jnp.zeros((2, 2, 8)), i_ext)
        link_dropped += int(np.asarray(stats.link_dropped).sum())
        delivered += int(np.asarray(stats.delivered).sum())
        n_spikes += int(np.asarray(spikes).sum())
    return link_dropped, delivered, n_spikes


@pytest.mark.parametrize(
    "faults",
    [
        FaultSpec(dead_links=((0, 1),)),
        FaultSpec(link_drop_rate=0.5, seed=3),
        FaultSpec(stuck_clusters=(0,)),
    ],
    ids=["dead-link", "lossy-link", "stuck-cluster"],
)
def test_ring_roll_fault_parity(faults):
    """Both delivery paths consume the same fault mask: identical drop
    counts, delivered counts and spike totals under every fault class."""
    tables, fab = _two_tile_tables()
    ring = _run_faulted(tables, fab, faults, ring=True)
    roll = _run_faulted(tables, fab, faults, ring=False)
    assert ring == roll
    healthy = _run_faulted(tables, fab, None, ring=True)
    assert ring[0] > healthy[0] == 0  # fault drops counted as link drops


def test_dead_link_severs_only_crossing_routes():
    tables, fab = _two_tile_tables()
    ld_dead, delivered_dead, _ = _run_faulted(
        tables, fab, FaultSpec(dead_links=((0, 1),)), ring=True
    )
    _, delivered_healthy, _ = _run_faulted(tables, fab, None, ring=True)
    assert ld_dead > 0
    # same-tile route (2 -> cluster 0's neuron 3) still delivers
    assert delivered_dead > 0
    assert delivered_dead + ld_dead == delivered_healthy


def test_stochastic_erasure_is_deterministic():
    tables, fab = _two_tile_tables()
    fs = FaultSpec(link_drop_rate=0.5, seed=11)
    a = _run_faulted(tables, fab, fs, ring=True)
    b = _run_faulted(tables, fab, fs, ring=True)
    assert a == b  # same seed -> bit-identical fault load
    c = _run_faulted(tables, fab, FaultSpec(link_drop_rate=0.5, seed=12), ring=True)
    assert a != c  # the seed actually drives the draw
    assert 0 < a[0]  # some loss at p=0.5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_entry_alive_mask_properties(seed):
    """Empty entries stay alive; dead pairs are always severed; the draw is
    a pure function of the spec seed."""
    from repro.core.routing import build_delivery_model

    tables, fab = _two_tile_tables()
    fs = FaultSpec(dead_links=((1, 0),), link_drop_rate=0.3, seed=seed)
    model = build_delivery_model(
        fab, 2, DT, tile_of_cluster=tables.tile_of_cluster, faults=fs
    )
    m1 = entry_alive_mask(tables.src_tag, tables.src_dest, 4, model)
    m2 = entry_alive_mask(tables.src_tag, tables.src_dest, 4, model)
    np.testing.assert_array_equal(m1, m2)
    assert m1[np.asarray(tables.src_tag) < 0].all()  # empty entries alive
    occ = np.asarray(tables.src_tag) >= 0
    dead_pair = occ & (np.arange(8)[:, None] // 4 == 1) & (tables.src_dest == 0)
    assert not m1[dead_pair].any()  # cluster1 -> cluster0 rides the dead link


def test_sharded_step_rejects_faults():
    tables, fab = _two_tile_tables()
    eng = EventEngine(
        tables,
        NeuronParams(input_gain=3.0, dt=DT),
        fabric=fab,
        fabric_options={"dt": DT, "faults": FaultSpec(dead_links=((0, 1),))},
    )
    mesh = jax.sharding.Mesh(np.array(jax.devices("cpu")[:1]), ("data",))
    with pytest.raises(NotImplementedError, match="fault injection"):
        eng.make_sharded_step(mesh, axis="data")


# ---------------------------------------------------------------------------
# memory faults: table corruption + blast radius
# ---------------------------------------------------------------------------
def test_apply_table_faults_blast_radius():
    tables, _ = _two_tile_tables()
    spec = FaultSpec(cam_bit_flips=4, sram_bit_flips=4, seed=5)
    corrupted, report = apply_table_faults(tables, spec)
    assert len(report) == 8
    for f in report:
        assert f["table"] in {"cam_tag", "src_tag", "src_dest"}
        assert f["old"] >= 0  # only programmed words are corrupted
    # fields stay loadable after clipping
    assert np.asarray(corrupted.cam_tag).max() < tables.k_tags
    assert np.asarray(corrupted.src_dest).max() < tables.n_clusters
    radius = fault_blast_radius(tables, corrupted)
    assert radius["connections_before"] > 0
    assert radius["connections_lost"] + radius["connections_kept"] == (
        radius["connections_before"]
    )
    assert radius["blast_fraction"] > 0  # 8 flips on this net must show up
    # same seed -> same corruption (bit-reproducible chaos)
    corrupted2, report2 = apply_table_faults(tables, spec)
    assert report == report2
    np.testing.assert_array_equal(
        np.asarray(corrupted.cam_tag), np.asarray(corrupted2.cam_tag)
    )


def test_apply_table_faults_zero_flips_is_identity():
    tables, _ = _two_tile_tables()
    corrupted, report = apply_table_faults(tables, FaultSpec())
    assert report == []
    np.testing.assert_array_equal(
        np.asarray(corrupted.cam_tag), np.asarray(tables.cam_tag)
    )
    assert fault_blast_radius(tables, corrupted)["blast_fraction"] == 0.0


# ---------------------------------------------------------------------------
# degraded-mode routing repair
# ---------------------------------------------------------------------------
def test_repair_placement_routes_around_25pct_dead_links():
    cc = compile_poker_cnn()
    fs = FaultSpec(dead_links=DEAD25)
    placement, report = repair_placement(cc.tables, Fabric(), fs, seed=0)
    assert report["feasible"]
    assert report["unreachable_traffic"] == 0.0
    alive, _ = tile_fault_matrices(Fabric(), fs)
    from repro.core.compiler import traffic_matrix

    traffic = traffic_matrix(cc.tables)
    src, dst = np.nonzero(traffic > 0)
    for a, b in zip(src, dst):
        if placement[a] != placement[b]:
            assert alive[placement[a], placement[b]]


def test_repair_placement_avoids_dead_tiles():
    cc = compile_poker_cnn()
    fs = FaultSpec(dead_tiles=(0, 1))
    placement, report = repair_placement(cc.tables, Fabric(), fs, seed=0)
    assert report["feasible"]
    assert not set(placement.tolist()) & {0, 1}
    assert report["moved_clusters"]  # default placement used tiles 0 and 1


def test_repair_placement_capacity_error():
    cc = compile_poker_cnn()  # 6 clusters, 4 cores/tile
    fs = FaultSpec(dead_tiles=tuple(range(1, 9)))  # one 4-core tile left
    with pytest.raises(ValueError, match="cannot fit|spare capacity"):
        repair_placement(cc.tables, Fabric(), fs)


# ---------------------------------------------------------------------------
# slot migration: extract_slots / splice_slots
# ---------------------------------------------------------------------------
def _engines_pair():
    tables, fab = _two_tile_tables()
    params = NeuronParams(input_gain=3.0, dt=DT)
    mk = lambda ring: EventEngine(
        tables, params, fabric=fab, queue_capacity=8,
        fabric_options={"dt": DT, "ring": ring},
    )
    return mk(True), mk(False)


@pytest.mark.parametrize("src_ring,dst_ring", [(True, True), (True, False),
                                               (False, True), (False, False)])
def test_extract_splice_cross_mode_bit_exact(src_ring, dst_ring):
    """A slot extracted mid-run (events genuinely in flight, ring cursor at
    an arbitrary phase) and spliced into a fresh engine of either delivery
    mode continues bit-exactly."""
    eng_r, eng_l = _engines_pair()
    src = eng_r if src_ring else eng_l
    dst = eng_r if dst_ring else eng_l
    rng = np.random.default_rng(1)
    carry = src.init_state(batch=2)
    for _ in range(5):  # 5 % (max_delay + 1) != 0: cursor mid-phase
        i_ext = jnp.asarray((rng.random((2, 8)) < 0.5) * 5e3, jnp.float32)
        carry, _ = src.step(carry, jnp.zeros((2, 2, 8)), i_ext)
    moved = dst.splice_slots(
        dst.init_state(batch=2), [0, 1], src.extract_slots(carry, [0, 1])
    )
    rng_a, rng_b = np.random.default_rng(7), np.random.default_rng(7)
    for _ in range(6):
        ia = jnp.asarray((rng_a.random((2, 8)) < 0.5) * 5e3, jnp.float32)
        ib = jnp.asarray((rng_b.random((2, 8)) < 0.5) * 5e3, jnp.float32)
        carry, (sa, _) = src.step(carry, jnp.zeros((2, 2, 8)), ia)
        moved, (sb, _) = dst.step(moved, jnp.zeros((2, 2, 8)), ib)
        np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))


def test_extract_splice_partial_slots_leave_others_untouched():
    eng, _ = _engines_pair()
    rng = np.random.default_rng(2)
    carry = eng.init_state(batch=3)
    for _ in range(4):
        i_ext = jnp.asarray((rng.random((3, 8)) < 0.5) * 5e3, jnp.float32)
        carry, _ = eng.step(carry, jnp.zeros((3, 2, 8)), i_ext)
    sc = eng.extract_slots(carry, [1])
    target = eng.splice_slots(carry, [2], sc)  # copy slot 1 onto slot 2
    for cur, new in zip(
        jax.tree_util.tree_leaves(carry), jax.tree_util.tree_leaves(target)
    ):
        cur, new = np.asarray(cur), np.asarray(new)
        if cur.ndim == 0:  # shared ring cursor
            np.testing.assert_array_equal(cur, new)
            continue
        np.testing.assert_array_equal(cur[0], new[0])  # untouched, bit-exact
        np.testing.assert_array_equal(cur[1], new[1])
        np.testing.assert_array_equal(cur[1], new[2])  # spliced copy


def test_extract_splice_validation():
    eng, _ = _engines_pair()
    carry = eng.init_state(batch=2)
    with pytest.raises(ValueError, match="unique"):
        eng.extract_slots(carry, [0, 0])
    with pytest.raises(ValueError, match="out of range"):
        eng.extract_slots(carry, [5])
    with pytest.raises(ValueError, match="leading batch dim"):
        eng.extract_slots(eng.init_state(), [0])
    sc = eng.extract_slots(carry, [0])
    with pytest.raises(ValueError, match="slots but SlotCarry"):
        eng.splice_slots(carry, [0, 1], sc)
    other = EventEngine(compile_poker_cnn().tables, poker_neuron_params())
    with pytest.raises(ValueError, match="neurons"):
        other.splice_slots(other.init_state(batch=2), [0], sc)


# ---------------------------------------------------------------------------
# checkpointed pool recovery: kill mid-serve, restore, bit-exact resume
# ---------------------------------------------------------------------------
def _poker_sessions(n, seed=11):
    return [
        DvsSession(
            i,
            DvsStreamSource(
                DvsStreamConfig(symbol=i % 4, events_per_step=16, seed=seed),
                session_id=i,
            ),
            label=i % 4,
        )
        for i in range(n)
    ]


def _result_key(results):
    return sorted(
        (r.session_id, r.prediction, r.latency_steps, r.decided, tuple(r.counts))
        for r in results
    )


@pytest.mark.parametrize("mode", ["queued", "fabric"])
def test_kill_mid_serve_restore_resumes_bit_exact(mode, tmp_path):
    """The §15 acceptance bar: checkpoint at an arbitrary mid-serve step,
    "crash" (rebuild engine + pool from disk), and every surviving
    session's decision AND decision step match the uninterrupted run — in
    queued mode and in fabric-ring mode (the time-wheel ring slab and its
    cursor are part of the checkpoint)."""
    backend = "fabric" if mode == "fabric" else "reference"
    cc = compile_poker_cnn()
    cfg = AerServeConfig(pool_size=2, max_steps=20)
    eng = build_poker_engine(cc.tables, backend=backend, donate_carry=False)
    baseline = AerSessionPool(cc, eng, cfg).serve(_poker_sessions(4))

    ck = Checkpointer(str(tmp_path))
    pool = AerSessionPool(cc, eng, cfg)
    pending = deque(_poker_sessions(4))
    results, killed, k = [], False, 0
    while pending or pool.occupied:
        while pending and pool.free_slots:
            pool.admit(pending.popleft())
        pool.step()
        k += 1
        if k == 5 and not killed:
            pool.checkpoint(ck, blocking=True)
            rest = list(pending)  # the un-admitted backlog outlives the pool
            del pool
            eng2 = build_poker_engine(cc.tables, backend=backend, donate_carry=False)
            pool = AerSessionPool.restore(cc, eng2, cfg, ck)
            assert pool.n_steps == 5 and len(pool.occupied) == 2
            pending = deque(rest)
            killed = True
            continue
        finished = pool.finished_slots()
        if finished:
            results.extend(pool.evict_many(finished))
    assert killed
    assert _result_key(results) == _result_key(baseline)


def test_restore_unknown_source_requires_factory(tmp_path):
    cc = compile_poker_cnn()
    cfg = AerServeConfig(pool_size=2, max_steps=20)
    eng = build_poker_engine(cc.tables, donate_carry=False)
    pool = AerSessionPool(cc, eng, cfg)

    class _Opaque:
        def events(self, step):
            return np.array([[15, 15]])

    pool.admit(DvsSession(0, _Opaque(), label=1))
    pool.step()
    ck = Checkpointer(str(tmp_path))
    pool.checkpoint(ck, blocking=True)
    with pytest.raises(TypeError, match="source_factory"):
        AerSessionPool.restore(cc, eng, cfg, ck)
    rebuilt = AerSessionPool.restore(
        cc, eng, cfg, ck, source_factory=lambda meta: _Opaque()
    )
    assert rebuilt.slots[0].session_id == 0 and rebuilt.slots[0].step == 1


def test_restore_without_checkpoint_raises(tmp_path):
    cc = compile_poker_cnn()
    cfg = AerServeConfig(pool_size=2)
    eng = build_poker_engine(cc.tables, donate_carry=False)
    with pytest.raises(FileNotFoundError, match="no complete checkpoint"):
        AerSessionPool.restore(cc, eng, cfg, Checkpointer(str(tmp_path)))


# ---------------------------------------------------------------------------
# pool typed errors + quarantine (satellite: typed lifecycle errors)
# ---------------------------------------------------------------------------
def test_pool_typed_errors_and_quarantine():
    cc = compile_poker_cnn()
    eng = build_poker_engine(cc.tables, donate_carry=False)
    pool = AerSessionPool(cc, eng, AerServeConfig(pool_size=2, max_steps=20))
    sessions = _poker_sessions(3)
    pool.admit(sessions[0])
    pool.admit(sessions[1])
    with pytest.raises(PoolFullError):
        pool.admit(sessions[2])
    assert issubclass(PoolFullError, RuntimeError)  # legacy handlers survive
    assert issubclass(SlotError, ValueError)
    with pytest.raises(SlotError, match="out of range"):
        pool.evict(99)
    with pytest.raises(SlotError, match="occupied; evict"):
        pool.quarantine_slot(0)
    pool.evict(0)
    with pytest.raises(SlotError, match="not occupied"):
        pool.evict(0)
    pool.quarantine_slot(0)
    assert pool.free_slots == []  # slot 0 quarantined, slot 1 occupied
    with pytest.raises(PoolFullError, match="quarantined"):
        pool.admit(sessions[2])
    with pytest.raises(SlotError, match="out of range"):
        pool.quarantine_slot(-1)


# ---------------------------------------------------------------------------
# watchdog + resilient serve loop
# ---------------------------------------------------------------------------
class _AlwaysBadSource:
    def events(self, step):
        return np.array([[5, -1]])  # malformed on every step


def test_serve_resilient_retries_then_quarantines():
    """Escalation ladder: a faulting tenant retries with backoff through the
    admission queue; when its slot keeps faulting the slot is quarantined;
    with every lane quarantined the backlog fails explicitly."""
    cc = compile_poker_cnn()
    eng = build_poker_engine(cc.tables, donate_carry=False)
    pool = AerSessionPool(cc, eng, AerServeConfig(pool_size=1, max_steps=20))
    wd = Watchdog(WatchdogConfig(max_retries=1, backoff_base=1, quarantine_after=2))
    bad = DvsSession(0, _AlwaysBadSource(), label=1)
    results, events = serve_resilient(pool, [bad], watchdog=wd)
    assert len(results) == 1 and results[0].error is not None
    kinds = [e.kind for e in events]
    assert kinds.count("session-error") == 2  # original + one retry
    assert "slot-quarantined" in kinds
    assert pool.quarantined == {0}
    # the pool is now lane-dead: new work fails fast instead of spinning
    results2, _ = serve_resilient(pool, _poker_sessions(1), watchdog=wd)
    assert results2[0].error == "pool exhausted: all slots quarantined"


def test_serve_resilient_healthy_path_matches_serve():
    cc = compile_poker_cnn()
    cfg = AerServeConfig(pool_size=2, max_steps=20)
    eng = build_poker_engine(cc.tables, donate_carry=False)
    baseline = AerSessionPool(cc, eng, cfg).serve(_poker_sessions(4))
    # silence threshold above the net's readout warm-up horizon: healthy
    # tenants must not be timed out while spikes propagate to the readout
    wd = Watchdog(WatchdogConfig(silence_steps=30))
    results, events = serve_resilient(
        AerSessionPool(cc, eng, cfg), _poker_sessions(4), watchdog=wd
    )
    assert _result_key(results) == _result_key(baseline)
    assert events == []


def test_watchdog_flags_silent_sessions():
    """A fully-severed forward path gives zero readout progress: the
    watchdog times the session out, the loop converts that into a session
    fault, and (retries exhausted) the error result surfaces."""
    cc = compile_poker_cnn()
    fs = FaultSpec(dead_links=((0, 1), (1, 0)))  # severs conv -> pool/out
    eng = build_poker_engine(cc.tables, backend="fabric", donate_carry=False,
                             faults=fs)
    pool = AerSessionPool(cc, eng, AerServeConfig(pool_size=2, max_steps=40))
    wd = Watchdog(WatchdogConfig(silence_steps=6, max_retries=0,
                                 link_drop_threshold=2.0))  # isolate silence
    results, events = serve_resilient(pool, _poker_sessions(2), watchdog=wd)
    assert any(e.kind == "session-silent" for e in events)
    assert all(r.error and "no readout progress" in r.error for r in results)
    assert all(r.latency_steps < 40 for r in results)  # faster than max_steps


# ---------------------------------------------------------------------------
# the degradation acceptance bar: 25% failed links, repair, 100% accuracy
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def tuned_cc():
    """Table-V poker CNN with the offline-Hebbian readout calibration the
    §V example uses — the configuration that actually hits 100% accuracy."""
    rng = np.random.default_rng(7)
    cc0 = compile_poker_cnn()
    eng = EventEngine(cc0.tables, poker_neuron_params())
    streams = [symbol_dvs_events(s, 400, rng) for s in range(4) for _ in range(3)]
    act = cc0.input_activity_batch(streams) / 40 * 10.0
    inp = jnp.broadcast_to(jnp.asarray(act)[None], (40, *act.shape))
    _, spikes = eng.run(eng.init_state(batch=len(streams)), inp)
    rates = (
        np.asarray(spikes)[:, :, cc0.pool[0]: cc0.pool[1]]
        .sum(0).reshape(4, 3, -1).sum(1)
    )
    return compile_poker_cnn(CnnConfig(), fc_select=hebbian_readout_select(rates))


def _serve_poker(cc, faults=None, n=8, pool_size=4):
    eng = build_poker_engine(cc.tables, backend="fabric", donate_carry=False,
                             faults=faults)
    results = AerSessionPool(cc, eng, AerServeConfig(pool_size=pool_size)).serve(
        _poker_sessions(n)
    )
    acc = float(np.mean([r.correct for r in results]))
    return acc, sum(r.link_dropped for r in results)


def test_degraded_repair_restores_full_accuracy(tuned_cc):
    """25% of mesh links dead: the unrepaired fabric visibly degrades;
    repair_placement routes around the faults and the same workload is back
    to 100% accuracy with strictly fewer measured link drops."""
    cc = tuned_cc
    fs = FaultSpec(dead_links=DEAD25)
    acc_healthy, ld_healthy = _serve_poker(cc, None)
    assert acc_healthy == 1.0 and ld_healthy == 0
    acc_faulted, ld_faulted = _serve_poker(cc, fs)
    assert acc_faulted < 1.0 and ld_faulted > 0

    placement, report = repair_placement(cc.tables, Fabric(), fs, seed=0)
    assert report["feasible"]
    tables_r = dataclasses.replace(cc.tables, tile_of_cluster=placement)
    cc_r = dataclasses.replace(cc, tables=tables_r)
    eng_r = build_poker_engine(tables_r, backend="fabric", donate_carry=False,
                               faults=fs)
    results = AerSessionPool(cc_r, eng_r, AerServeConfig(pool_size=4)).serve(
        _poker_sessions(8)
    )
    acc_repaired = float(np.mean([r.correct for r in results]))
    ld_repaired = sum(r.link_dropped for r in results)
    assert acc_repaired == 1.0
    assert ld_repaired < ld_faulted


def test_degraded_pool_migrates_mid_flight_to_repaired_engine(tuned_cc):
    """Full escalation: watchdog detects the sustained link-drop rate,
    serve_resilient hands the pool to on_degraded, the sessions migrate via
    extract/splice onto an engine with the repaired placement, and the
    workload finishes at 100% accuracy without restarting anyone."""
    cc = tuned_cc
    fs = FaultSpec(dead_links=DEAD25)
    eng_f = build_poker_engine(cc.tables, backend="fabric", donate_carry=False,
                               faults=fs)
    pool = AerSessionPool(cc, eng_f, AerServeConfig(pool_size=4))
    migrations = []

    def on_degraded(p, ev):
        placement, report = repair_placement(cc.tables, Fabric(), fs, seed=0)
        assert report["feasible"]
        tables_r = dataclasses.replace(cc.tables, tile_of_cluster=placement)
        eng_r = build_poker_engine(tables_r, backend="fabric",
                                   donate_carry=False, faults=fs)
        migrations.append(ev.value)
        return migrate_pool(p, eng_r)

    wd = Watchdog(WatchdogConfig(window=4, link_drop_threshold=0.2,
                                 silence_steps=30))
    results, events = serve_resilient(pool, _poker_sessions(8), watchdog=wd,
                                      on_degraded=on_degraded)
    assert len(migrations) == 1 and migrations[0] >= 0.2
    assert [e.kind for e in events].count("pool-degraded") == 1
    assert len(results) == 8
    assert float(np.mean([r.correct for r in results])) == 1.0
