"""Optimizer, checkpointing, data pipeline, fault-tolerant driver."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, make_source
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, schedule


def _quadratic_converges(state_dtype):
    cfg = OptConfig(lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0,
                    state_dtype=state_dtype)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    opt = init_opt_state(params, cfg)
    target = jnp.asarray([1.0, 1.0, 1.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, cfg)
    return float(loss(params))


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "q8"])
def test_adamw_converges(state_dtype):
    assert _quadratic_converges(state_dtype) < 1e-2


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] < lrs[1] < lrs[2]
    assert lrs[2] == pytest.approx(1e-3, rel=1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_grad_clipping_bounds_update():
    cfg = OptConfig(lr=1.0, clip_norm=1.0, warmup_steps=0, total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    opt = init_opt_state(params, cfg)
    g = {"w": jnp.asarray([1e6, 1e6, 1e6])}
    new_params, _, metrics = adamw_update(g, opt, params, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.abs(new_params["w"]).max()) < 10.0  # clipped


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        ck.save(step, jax.tree.map(lambda x: x * step, tree), blocking=True)
    assert ck.steps() == [2, 3]  # retention keeps newest 2
    restored = ck.restore(3, tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(6).reshape(2, 3) * 3)
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity(tmp_path):
    """A stray .tmp dir (simulated crash) is never listed as a valid step."""
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, {"a": jnp.ones(3)}, blocking=True)
    os.makedirs(tmp_path / "step_2.tmp")
    assert ck.latest_step() == 1


def test_async_checkpoint_completes(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(7, {"a": jnp.ones(3)})
    ck.wait()
    assert ck.latest_step() == 7


# ---------------------------------------------------------------------------
def test_data_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=100, global_batch=4, seq_len=8, seed=3)
    src1 = make_source(cfg)
    src2 = make_source(cfg)
    b5a = src1.batch(5)
    # consume different steps first — batch(5) must not depend on history
    src2.batch(0), src2.batch(17)
    b5b = src2.batch(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    assert b5a["tokens"].shape == (4, 8)
    assert (b5a["labels"][:, :-1] == b5a["tokens"][:, 1:]).all()


def test_data_pipeline_host_sharding():
    cfg = DataConfig(vocab=100, global_batch=8, seq_len=4, seed=0)
    h0 = make_source(cfg, host_id=0, n_hosts=2).batch(0)
    h1 = make_source(cfg, host_id=1, n_hosts=2).batch(0)
    assert h0["tokens"].shape == (4, 4)
    assert not (h0["tokens"] == h1["tokens"]).all()


def test_file_source_roundtrip(tmp_path):
    toks = np.arange(1000, dtype=np.uint16) % 50
    path = tmp_path / "toks.bin"
    toks.tofile(path)
    cfg = DataConfig(vocab=50, global_batch=2, seq_len=9, path=str(path))
    src = make_source(cfg)
    b = src.batch(0)
    np.testing.assert_array_equal(b["tokens"][0], toks[:9].astype(np.int32))


# ---------------------------------------------------------------------------
def test_supervisor_restart_after_injected_failure(tmp_path):
    """End-to-end fault tolerance: crash at step 15, resume from ckpt 10."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "gemma3-1b", "--smoke",
         "--steps", "20", "--batch", "2", "--seq", "16", "--ckpt-dir", str(tmp_path),
         "--ckpt-every", "10", "--log-every", "20", "--fail-at", "15"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        timeout=600,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "failure #1" in out.stdout
    assert "resumed from step 10" in out.stdout
    assert "training complete" in out.stdout
