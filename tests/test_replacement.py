"""Profile-guided live re-placement (DESIGN.md §18).

:class:`ReplacementController` closes the measure -> optimize -> recompile
loop on a live pool. The claims under test:

  * the controller refuses a pool that cannot feed it (no traffic profile)
    and an absent model — typed errors, not silent no-ops;
  * the drift / min_steps / cooldown gates actually gate: no judgement on
    thin evidence, no thrash after a swap, no swap below threshold;
  * a swap registers a fresh model *version* on previously-unoccupied
    tiles and mid-flight sessions are BYTE-EQUAL to an unswapped control
    pool through it — the bit-exact rung of the §15/§16 ladder;
  * when no free tiles exist the bit-exact rung raises and points at
    :func:`migrate_pool` (the best-effort rung) instead of silently
    degrading;
  * retarget + drain complete the version lifecycle: new admissions land
    on the new version, the old one unloads only once its tenants left.
"""

import dataclasses
import functools

import numpy as np
import pytest

from repro.core.cnn import compile_poker_cnn
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
from repro.serve.aer import AerServeConfig, AerSessionPool, DvsSession
from repro.serve.health import ReplacementConfig, ReplacementController


@functools.lru_cache(maxsize=1)
def _poker_cc():
    return compile_poker_cnn()


def _session(i, model=None, seed=9):
    return DvsSession(
        session_id=i,
        source=DvsStreamSource(
            DvsStreamConfig(symbol=i % 4, events_per_step=16, seed=seed),
            session_id=i,
        ),
        label=i % 4,
        model=model,
    )


def _pool(models=None, per_link=True, backend="fabric", pool_size=2):
    cc = _poker_cc()
    cfg = AerServeConfig(pool_size=pool_size, max_steps=10**6)
    fo = None
    if backend == "fabric":
        fo = {"per_link_stats": True} if per_link else {}
    return AerSessionPool.from_models(
        models or {"poker": cc}, cfg, backend=backend, fabric_options=fo)


def _fill(pool, model=None, seed=9):
    for i in range(pool.cfg.pool_size):
        pool.admit(_session(i, model=model, seed=seed))


# ---------------------------------------------------------------------------
# typed refusal
# ---------------------------------------------------------------------------
def test_controller_requires_traffic_profile():
    with pytest.raises(ValueError, match="per_link_stats"):
        ReplacementController(_pool(backend="reference"))
    with pytest.raises(ValueError, match="per_link_stats"):
        ReplacementController(_pool(per_link=False))


def test_controller_requires_resident_model():
    pool = _pool()
    with pytest.raises(ValueError, match="not resident"):
        ReplacementController(pool, model="nope")


# ---------------------------------------------------------------------------
# gates
# ---------------------------------------------------------------------------
def test_min_steps_threshold_and_cooldown_gates():
    pool = _pool()
    ctl = ReplacementController(pool, cfg=ReplacementConfig(
        drift_threshold=0.05, min_steps=6, cooldown_steps=50))
    assert ctl.maybe_replace() is None  # nothing observed yet
    _fill(pool)
    for _ in range(3):
        pool.step()
    assert ctl.maybe_replace() is None  # below min_steps: evidence too thin
    for _ in range(3):
        pool.step()
    assert ctl.drift() >= 0.05  # poker traffic is far from uniform
    report = ctl.maybe_replace()
    assert report is not None and report["name"] == "poker@r1"
    assert ctl.current == "poker@r1" and ctl.retired == ["poker"]
    assert "poker@r1" in pool.models and ctl.history == [report]
    # the new version lives on tiles the old one does not occupy
    old_tiles = set(np.asarray(pool.models["poker"].tables.tile_of_cluster))
    assert not old_tiles & set(report["placement"])
    # the swap reset the observation window, then the cooldown holds even
    # after min_steps of fresh evidence accumulates again
    assert pool.profile.steps == 0
    assert ctl.maybe_replace() is None
    for _ in range(6):
        pool.step()
    assert ctl.maybe_replace() is None  # cooldown_steps=50 not yet elapsed


def test_below_threshold_never_swaps():
    pool = _pool()
    ctl = ReplacementController(pool, cfg=ReplacementConfig(
        drift_threshold=0.99, min_steps=2, cooldown_steps=0))
    _fill(pool)
    for _ in range(8):
        pool.step()
    assert 0.0 < ctl.drift() < 0.99
    assert ctl.maybe_replace() is None
    assert ctl.version == 0 and list(pool.models) == ["poker"]


# ---------------------------------------------------------------------------
# the bit-exact rung
# ---------------------------------------------------------------------------
def test_forced_swap_is_byte_equal_for_mid_flight_sessions():
    pool_a, pool_b = _pool(), _pool()  # B is the unswapped control
    _fill(pool_a, seed=23)
    _fill(pool_b, seed=23)
    for _ in range(10):
        pool_a.step()
        pool_b.step()
    ctl = ReplacementController(pool_a, cfg=ReplacementConfig(
        min_steps=1, cooldown_steps=0))
    report = ctl.maybe_replace(force=True)
    assert report is not None
    # same observed matrix in -> lower observed cost out
    assert report["cost_observed_new"] <= report["cost_observed_old"]
    for _ in range(6):
        pool_a.step()
        pool_b.step()
    for sa, sb in zip(pool_a.slots, pool_b.slots):
        assert sa.step == sb.step
        np.testing.assert_array_equal(np.asarray(sa.counts),
                                      np.asarray(sb.counts))
        assert sa.dropped == sb.dropped and sa.link_dropped == sb.link_dropped


def test_versioned_swap_byte_equal_in_queued_mode():
    """The controller itself needs fabric per-link stats, but the swap
    primitive it rides — a versioned ``load_model`` rebind — is
    backend-agnostic: registering a re-placed version under live sessions
    leaves a queued reference pool byte-equal to an unswapped control."""
    cc = _poker_cc()

    def placed(tiles):
        # concat is all-or-none on placement: stamp both versions explicitly
        return dataclasses.replace(cc, tables=dataclasses.replace(
            cc.tables, tile_of_cluster=np.asarray(tiles, np.int32)))

    base = placed([0, 0, 1, 1, 2, 2])
    pool_a = _pool(models={"poker": base}, backend="reference")
    pool_b = _pool(models={"poker": base}, backend="reference")
    _fill(pool_a, seed=31)
    _fill(pool_b, seed=31)
    for _ in range(8):
        pool_a.step()
        pool_b.step()
    pool_a.load_model("poker@r1", placed([3, 4, 5, 6, 7, 8]))
    for _ in range(6):
        pool_a.step()
        pool_b.step()
    for sa, sb in zip(pool_a.slots, pool_b.slots):
        assert sa.step == sb.step
        np.testing.assert_array_equal(np.asarray(sa.counts),
                                      np.asarray(sb.counts))
        assert sa.dropped == sb.dropped


def test_no_free_tiles_raises_toward_best_effort_rung():
    cc = _poker_cc()

    def placed(tiles):
        t = dataclasses.replace(
            cc.tables, tile_of_cluster=np.asarray(tiles, np.int32))
        return dataclasses.replace(cc, tables=t)

    # two residents between them occupy every tile of the 3x3 mesh
    pool = _pool(models={"a": placed([0, 1, 2, 3, 4, 5]),
                         "b": placed([3, 4, 5, 6, 7, 8])})
    _fill(pool, model="a")
    for _ in range(4):
        pool.step()
    ctl = ReplacementController(pool, model="a")
    with pytest.raises(RuntimeError, match="migrate_pool"):
        ctl.maybe_replace(force=True)


# ---------------------------------------------------------------------------
# version lifecycle
# ---------------------------------------------------------------------------
def test_retarget_and_drain_retire_the_old_version():
    pool = _pool()
    _fill(pool)
    for _ in range(4):
        pool.step()
    ctl = ReplacementController(pool)
    assert ctl.maybe_replace(force=True) is not None
    # the old version still has live tenants: drain must refuse to unload
    assert ctl.drain_retired() == []
    assert set(pool.models) == {"poker", "poker@r1"}
    # a new admission retargets to the new version and serves alongside
    pool.evict(0)
    s_new = ctl.retarget(_session(7))
    assert s_new.model == "poker@r1"
    pool.admit(s_new)
    for _ in range(3):
        pool.step()
    assert pool.slots[0].step == 3  # the retargeted session is serving
    # once the last old-version tenant leaves, drain frees the slab
    pool.evict(1)
    assert ctl.drain_retired() == ["poker"]
    assert set(pool.models) == {"poker@r1"} and ctl.retired == []
