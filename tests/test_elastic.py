"""distributed/elastic.py unit coverage: remesh resolution and resharding.

The elastic primitives are the substrate under both training restarts
(DESIGN.md §6) and the serving fleet's elastic restore (§17,
tests/test_sharded_serving.py) — here they are covered directly: pytrees
round-trip across two fake meshes of different shape without value changes,
and ``remesh_pspecs`` re-resolves a real model's logical axes on both.
Multi-device cases run in a subprocess so the main pytest process keeps its
single-device view (same pattern as tests/test_distributed.py).
"""

import os
import subprocess
import sys
import textwrap

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=timeout,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_reshard_tree_round_trip_across_meshes():
    """A pytree sharded on mesh A lands on mesh B and back, bit-identical,
    and every leaf really carries the target mesh's sharding."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.elastic import reshard_tree
        from repro.launch.mesh import make_mesh
        mesh_a = make_mesh((2, 4), ("data", "model"))
        mesh_b = make_mesh((4, 2), ("data", "model"))
        tree = {
            "w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.arange(8, dtype=jnp.float32),
            "nested": {"scale": jnp.float32(3.5)},
        }
        specs = {"w": P("data", "model"), "b": P("model"),
                 "nested": {"scale": P()}}
        on_a = reshard_tree(tree, specs, mesh_a)
        on_b = reshard_tree(on_a, specs, mesh_b)
        back = reshard_tree(on_b, specs, mesh_a)
        assert on_b["w"].sharding.mesh.shape["data"] == 4
        assert on_b["b"].sharding.spec == P("model")
        for k in ("w", "b"):
            assert bool((on_b[k] == tree[k]).all()), k
            assert bool((back[k] == tree[k]).all()), k
        assert float(on_b["nested"]["scale"]) == 3.5
        # round trip restores mesh A's layout exactly
        assert back["w"].sharding.mesh.shape["data"] == 2
        print("OK")
    """)


def test_remesh_pspecs_resolves_on_both_meshes():
    """The same model's logical axes resolve to valid specs on two mesh
    shapes; divisibility is respected on each (the elastic restart
    guarantee: any surviving mesh gets legal shardings, no special cases)."""
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import ModelConfig
        from repro.distributed.elastic import remesh_pspecs
        from repro.launch.mesh import make_mesh
        from repro.models.model import Model
        cfg = ModelConfig(d_model=32, n_heads=4, head_dim=8, d_ff=64,
                          vocab=96, n_periods=2)
        model = Model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        for mesh_shape in ((2, 4), (4, 2), (1, 8)):
            mesh = make_mesh(mesh_shape, ("data", "model"))
            specs = remesh_pspecs(model, shapes, mesh)
            leaves = jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            assert leaves and all(isinstance(s, P) for s in leaves)
            # every resolved spec divides its tensor's dims on THIS mesh
            def check(spec, shaped):
                for dim, axes in zip(shaped.shape, tuple(spec)):
                    if axes is None:
                        continue
                    axes = (axes,) if isinstance(axes, str) else axes
                    size = 1
                    for a in axes:
                        size *= mesh.shape[a]
                    assert dim % size == 0, (spec, shaped.shape, mesh_shape)
            jax.tree.map(check, specs, shapes,
                         is_leaf=lambda x: isinstance(x, P))
        print("OK")
    """)


def test_reshard_state_moves_params_and_opt():
    """reshard_state: params land under their new-mesh specs, optimizer
    moments follow, values unchanged — the live-migration half of §6."""
    _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.elastic import remesh_pspecs, reshard_state
        from repro.configs.base import ModelConfig
        from repro.launch.mesh import make_mesh
        from repro.models.model import Model
        cfg = ModelConfig(d_model=32, n_heads=4, head_dim=8, d_ff=64,
                          vocab=96, n_periods=2)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        state = {"params": params,
                 "opt": {"m": jax.tree.map(jnp.zeros_like, params),
                         "count": jnp.int32(7)}}
        mesh_b = make_mesh((4, 2), ("data", "model"))
        specs_b = remesh_pspecs(model, shapes, mesh_b)
        out = reshard_state(state, specs_b, mesh_b)
        flat_in = jax.tree.leaves(state["params"])
        flat_out = jax.tree.leaves(out["params"])
        assert all(bool((a == b).all()) for a, b in zip(flat_in, flat_out))
        assert int(out["opt"]["count"]) == 7
        # at least one big tensor actually sharded over the new mesh
        sharded = [x for x in flat_out
                   if not x.sharding.is_fully_replicated]
        assert sharded, "expected some parameter to shard on the new mesh"
        print("OK")
    """)
