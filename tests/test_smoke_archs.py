"""Per-architecture smoke tests (assignment requirement): reduced config,
one forward/train step on CPU, asserting output shapes + no NaNs; plus
prefill+decode == full-forward logits for every arch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import OptConfig


def _batch(cfg, rng, b=2, s=16):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embeddings"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_prefix_embeddings, cfg.d_model)), jnp.float32
        )
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    rng = np.random.default_rng(0)
    state = init_train_state(model, jax.random.PRNGKey(0), OptConfig(total_steps=10))
    step = make_train_step(model, OptConfig(total_steps=10))
    batch = _batch(cfg, rng)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    # params updated
    l0 = jax.tree.leaves(state["params"])[0]
    assert not bool(jnp.isnan(l0).any())


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_full(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    rng = np.random.default_rng(1)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    batch = _batch(cfg, rng, B, S)
    toks = batch["tokens"]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    h, _, _ = model.forward(params, toks, pos, None, batch)
    logits_full = model._unembed(params, h)
    assert logits_full.shape == (B, S, cfg.vocab)

    caches = model.init_caches(B, S, jnp.float32)
    lp, caches = model.prefill(params, toks[:, :8], caches, batch)
    errs = [float(jnp.abs(lp[:, 0] - logits_full[:, 7]).max())]
    for t in range(8, S):
        ld, caches = model.decode_step(params, toks[:, t : t + 1], pos[:, t : t + 1], caches)
        errs.append(float(jnp.abs(ld[:, 0] - logits_full[:, t]).max()))
    assert max(errs) < 3e-4, f"{arch}: prefill/decode diverges from full forward"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_layer_count(arch):
    """Full (non-smoke) configs carry the assignment's exact stack depth."""
    cfg = get_config(arch)
    expected = {
        "gemma2-27b": 46, "glm4-9b": 40, "yi-34b": 60, "gemma3-1b": 26,
        "zamba2-2.7b": 63,  # 54 mamba + 9 shared-attn applications
        "whisper-base": 6,  # decoder; +6 encoder via n_enc_layers
        "rwkv6-3b": 32, "deepseek-v3-671b": 61, "deepseek-moe-16b": 28,
        "internvl2-76b": 80,
    }[arch]
    assert cfg.n_layers == expected
    if arch == "whisper-base":
        assert cfg.n_enc_layers == 6
