"""Per-link traffic attribution + TrafficProfile (DESIGN.md §18).

The contract under test:
  * per-link stats mode widens ``DeliveryStats.link_dropped`` to a flat
    ``[n_tiles * n_tiles]`` directed-link histogram and ``delivered`` to a
    flat ``[n_clusters * n_clusters]`` (src, dst) pair histogram, while the
    trailing-axis sums reproduce the scalar-mode counters EXACTLY — the
    widened mode refines, never re-measures;
  * the hand-built 2-tile overflow attributes its drop to the one directed
    link that overflowed (the ``.sum((-1, -2))`` collapse this replaces
    could only say "somewhere");
  * the ring fast path and the roll reference agree bit-for-bit on the
    widened arrays, and spikes are unchanged vs scalar mode;
  * the sharded fabric step psum-reduces the widened arrays consistently
    (specs shorter than rank leave the new trailing axes replicated);
  * all sources spiking drop-free for one step reproduces the compiler's
    ``traffic_matrix`` exactly — the observed-profile-vs-assumption
    conformance that makes ``TrafficProfile.drift`` meaningful.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compiler import TrafficProfile, traffic_matrix
from repro.core.dispatch import DeliveryStats, FabricBackend
from repro.core.event_engine import EventEngine
from repro.core.routing import ChipConstants, Fabric
from repro.core.tags import NetworkSpec, compile_network

DT = 1e-3


def _random_net(rng, n=64, cluster=8, k=64, edges=120, fabric=None):
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=32, max_sram_entries=16)
    seen = set()
    for _ in range(edges):
        s, d = int(rng.integers(n)), int(rng.integers(n))
        if (s, d) in seen:
            continue
        seen.add((s, d))
        spec.connect(s, d, int(rng.integers(4)))
    return compile_network(spec, fabric=fabric)


# ---------------------------------------------------------------------------
# hand-built 2-tile overflow: the drop lands on ITS link
# ---------------------------------------------------------------------------
def _two_tile(per_link_stats):
    const = ChipConstants(latency_across_chip_s=3 * DT)
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=1, constants=const)
    spec = NetworkSpec(n_neurons=8, cluster_size=4, k_tags=8)
    spec.connect(0, 4)  # cross-tile (tile 0 -> 1), lowest source id -> wins
    spec.connect(1, 5)  # cross-tile, contends for the same link -> dropped
    spec.connect(2, 3)  # intra-tile control
    tables = compile_network(spec, fabric=fab)
    backend = FabricBackend(fabric=fab, tile_of_cluster=tables.tile_of_cluster,
                            dt=DT, link_capacity=1,
                            per_link_stats=per_link_stats)
    return fab, tables, backend


def test_two_tile_overflow_attributed_to_its_link():
    fab, tables, backend = _two_tile(per_link_stats=True)
    args = (
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )
    spikes = jnp.zeros((8,)).at[jnp.asarray([0, 1, 2])].set(1.0)
    inflight = backend.init_inflight(tables.n_clusters, tables.k_tags)
    _, _, stats = backend.deliver_fabric(spikes, *args, inflight=inflight)
    # link bins are src_tile * n_tiles + dst_tile on the 2-tile line
    link = np.asarray(stats.link_dropped)
    assert link.shape == (fab.n_tiles * fab.n_tiles,)
    np.testing.assert_array_equal(link, [0, 1, 0, 0])  # only tile0 -> tile1
    # pair bins are src_cl * n_clusters + dst_cl; kept: 2->3 intra (0, 0)
    # and 0->4 cross (0, 1); the dropped 1->5 is counted nowhere
    pair = np.asarray(stats.delivered)
    assert pair.shape == (tables.n_clusters * tables.n_clusters,)
    np.testing.assert_array_equal(pair, [1, 1, 0, 0])


def test_two_tile_scalar_mode_unchanged():
    _, tables, backend = _two_tile(per_link_stats=False)
    args = (
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )
    spikes = jnp.zeros((8,)).at[jnp.asarray([0, 1, 2])].set(1.0)
    inflight = backend.init_inflight(tables.n_clusters, tables.k_tags)
    _, _, stats = backend.deliver_fabric(spikes, *args, inflight=inflight)
    assert np.asarray(stats.link_dropped).shape == ()
    assert int(stats.link_dropped) == 1 and int(stats.delivered) == 2


# ---------------------------------------------------------------------------
# widened sums == scalar counters, ring == roll, spikes unchanged
# ---------------------------------------------------------------------------
def _run_engine(tables, fab, per_link_stats, ring, steps=6, batch=2):
    eng = EventEngine(
        tables, fabric=fab, queue_capacity=tables.n_neurons,
        fabric_options={"dt": DT, "link_capacity": 1, "ring": ring,
                        "per_link_stats": per_link_stats},
    )
    inp = jnp.zeros((batch, tables.n_clusters, tables.k_tags))
    inp = inp.at[:, :, :4].set(3.0)
    ev = jnp.broadcast_to(inp, (steps, *inp.shape))
    i_ext = jnp.full((batch, tables.n_neurons), 5e3)
    _, (spikes, stats) = eng.run(eng.init_state(batch=batch), ev, i_ext)
    return np.asarray(spikes), jax.tree.map(np.asarray, stats)


def test_per_link_sums_match_scalar_and_ring_matches_roll():
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2)
    tables = _random_net(np.random.default_rng(3), fabric=fab)
    sp_scalar, st_scalar = _run_engine(tables, fab, False, ring=True)
    sp_ring, st_ring = _run_engine(tables, fab, True, ring=True)
    sp_roll, st_roll = _run_engine(tables, fab, True, ring=False)

    t2, c2 = fab.n_tiles ** 2, tables.n_clusters ** 2
    assert st_ring.link_dropped.shape[-1] == t2
    assert st_ring.delivered.shape[-1] == c2
    # refinement, not re-measurement: trailing sums == scalar mode exactly
    np.testing.assert_array_equal(
        st_ring.link_dropped.sum(-1), st_scalar.link_dropped)
    np.testing.assert_array_equal(
        st_ring.delivered.sum(-1), st_scalar.delivered)
    assert int(st_scalar.link_dropped.sum()) > 0  # the sweep did overflow
    # spikes are stats-mode invariant, and ring == roll on the widened stats
    np.testing.assert_array_equal(sp_scalar, sp_ring)
    np.testing.assert_array_equal(sp_ring, sp_roll)
    np.testing.assert_array_equal(st_ring.link_dropped, st_roll.link_dropped)
    np.testing.assert_array_equal(st_ring.delivered, st_roll.delivered)


def test_sharded_step_reduces_per_link_axes():
    """The widened stats arrays flow through the shard_map psum unchanged:
    a single-device model mesh must reproduce the local step's per-link
    histograms bit-for-bit (PartitionSpecs shorter than the widened rank
    leave the trailing attribution axes replicated)."""
    fab = Fabric(grid_x=2, grid_y=1, cores_per_tile=4)
    tables = _random_net(np.random.default_rng(5), fabric=fab)
    eng = EventEngine(
        tables, fabric=fab, queue_capacity=tables.n_neurons,
        fabric_options={"dt": DT, "link_capacity": 1,
                        "per_link_stats": True},
    )
    mesh = jax.make_mesh((1,), ("model",))
    sharded = eng.make_sharded_step(mesh, "model")
    state, prev, ring, cur = eng.init_state()
    prev = prev.at[jnp.arange(0, tables.n_neurons, 2)].set(1.0)
    inp = jnp.zeros((tables.n_clusters, tables.k_tags)).at[:, 0].set(4.0)
    i_ext = jnp.zeros((tables.n_neurons,))
    for _ in range(5):
        (st_l, sp_l, ring_l, cur_l), (_, stats_l) = eng.step(
            (state, prev, ring, cur), inp)
        st_s, sp_s, ring_s, cur_s, stats_s = sharded(
            eng.tables, state, prev, ring, cur, inp, i_ext)
        np.testing.assert_array_equal(np.asarray(sp_l), np.asarray(sp_s))
        np.testing.assert_array_equal(
            np.asarray(stats_l.link_dropped), np.asarray(stats_s.link_dropped))
        np.testing.assert_array_equal(
            np.asarray(stats_l.delivered), np.asarray(stats_s.delivered))
        state, prev, ring, cur = st_l, sp_l, ring_l, cur_l


# ---------------------------------------------------------------------------
# observed-profile conformance with the compiler's traffic model
# ---------------------------------------------------------------------------
def test_all_sources_spiking_reproduces_traffic_matrix():
    """Drop-free, batch=1, every source spiking once: the observed pair
    histogram IS the compiler's assumed traffic matrix — the conformance
    that anchors TrafficProfile.drift at 0 for a workload matching the
    compile-time assumption."""
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2)
    tables = _random_net(np.random.default_rng(7), fabric=fab)
    backend = FabricBackend(fabric=fab, tile_of_cluster=tables.tile_of_cluster,
                            dt=DT, per_link_stats=True)  # no link capacity
    args = (
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )
    inflight = backend.init_inflight(tables.n_clusters, tables.k_tags)
    _, _, stats = backend.deliver_fabric(
        jnp.ones((tables.n_neurons,)), *args, inflight=inflight)
    nc = tables.n_clusters
    observed = np.asarray(stats.delivered).reshape(nc, nc)
    np.testing.assert_array_equal(observed, traffic_matrix(tables))

    prof = TrafficProfile.empty(nc, fab.n_tiles)
    prof.observe(stats)
    assert prof.steps == 1
    np.testing.assert_array_equal(prof.matrix(), traffic_matrix(tables))
    assert prof.drift(traffic_matrix(tables)) == pytest.approx(0.0)
    assert prof.total_link_dropped == 0.0


def test_traffic_profile_accumulation_and_validation():
    nc, nt = 3, 4
    prof = TrafficProfile.empty(nc, nt)
    assert prof.drift(np.ones((nc, nc))) == 0.0  # nothing observed yet
    pair = np.zeros(nc * nc, np.int32)
    pair[1] = 6  # all traffic on (0 -> 1)
    link = np.zeros(nt * nt, np.int32)
    link[2] = 2
    stats = DeliveryStats(
        dropped=np.int32(1), link_dropped=link, delivered=pair,
        hops=None, latency_s=None, energy_j=None,
    )
    prof.observe(stats)
    prof.observe(stats)
    assert prof.steps == 2 and prof.dropped == 2.0
    assert prof.total_link_dropped == 4.0
    assert prof.matrix()[0, 1] == pytest.approx(6.0)
    np.testing.assert_array_equal(prof.last, prof.pair_delivered / 2)
    # drift: observed mass all on (0, 1) vs assumed all on (1, 0) -> TV = 1
    assumed = np.zeros((nc, nc))
    assumed[1, 0] = 1.0
    assert prof.drift(assumed) == pytest.approx(1.0)
    # per-cluster rates spread the row marginal over occupied entries
    rng = np.random.default_rng(11)
    tables = _random_net(rng, n=24, cluster=8, k=32, edges=30)
    prof2 = TrafficProfile.empty(tables.n_clusters, nt)
    assert prof2.rates(tables).shape == (tables.n_neurons,)
    # scalar stats are rejected with a pointer at the engine option
    scalar = DeliveryStats(
        dropped=np.int32(0), link_dropped=np.int32(0),
        delivered=np.int32(5), hops=None, latency_s=None, energy_j=None,
    )
    with pytest.raises(ValueError, match="per_link_stats"):
        prof.observe(scalar)


def test_batched_delivery_observes_batch_times_matrix():
    """B identical all-spiking streams deliver B copies of the matrix —
    observe() sums the batch axis into one per-step total."""
    fab = Fabric(grid_x=2, grid_y=2, cores_per_tile=2)
    tables = _random_net(np.random.default_rng(9), fabric=fab)
    backend = FabricBackend(fabric=fab, tile_of_cluster=tables.tile_of_cluster,
                            dt=DT, per_link_stats=True)
    args = (
        jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn),
        tables.cluster_size, tables.k_tags,
    )
    b = 3
    inflight = backend.init_inflight(tables.n_clusters, tables.k_tags, batch=b)
    _, _, stats = backend.deliver_fabric(
        jnp.ones((b, tables.n_neurons)), *args, inflight=inflight)
    prof = TrafficProfile.empty(tables.n_clusters, fab.n_tiles)
    prof.observe(stats)
    np.testing.assert_array_equal(prof.matrix(), b * traffic_matrix(tables))
