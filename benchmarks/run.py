"""Benchmark driver — one module per paper table/figure.

  Table II  -> routing_throughput   Table III + Fig 11 -> energy
  Table IV  -> comparison           Table V + Fig 12   -> cnn_poker
  Fig 13 + §II headline -> memory_scaling
  beyond-paper (MoE dispatch mapping) -> dispatch
  §Roofline artifacts -> roofline

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        cnn_poker,
        comparison,
        dispatch,
        energy,
        memory_scaling,
        roofline,
        routing_throughput,
    )

    modules = [
        ("memory_scaling", memory_scaling),
        ("routing_throughput", routing_throughput),
        ("energy", energy),
        ("comparison", comparison),
        ("cnn_poker", cnn_poker),
        ("dispatch", dispatch),
        ("roofline", roofline),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in modules:
        try:
            for row, us, derived in mod.run():
                print(f"{row},{us:.1f},{derived}")
        except Exception:  # noqa: BLE001 — report per-bench failures, keep going
            failed += 1
            print(f"{name},nan,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
