"""Benchmark driver — one module per paper table/figure.

  Table II  -> routing_throughput   Table III + Fig 11 -> energy
  Table IV  -> comparison           Table V + Fig 12   -> cnn_poker
  Fig 13 + §II headline -> memory_scaling
  compiler v2 placement/tag-reuse (DESIGN.md §13) -> routing_throughput
  (``compiler_*`` rows: measured mean hops + link drops + sessions/s,
  optimized vs default placement, and the v2-vs-v1 tag spend)
  beyond-paper (MoE dispatch mapping) -> dispatch
  beyond-paper (multi-tenant AER serving, DESIGN.md §12) -> serving
  §Roofline artifacts -> roofline

Prints ``name,us_per_call,derived`` CSV and writes the routing/dispatch rows
to ``BENCH_routing.json`` (machine-readable perf trajectory across PRs).

``--profile [DIR]`` wraps the whole sweep in a ``jax.profiler`` trace
(default ``/tmp/repro_bench_trace``) — open the directory with
TensorBoard / Perfetto to see per-kernel timings behind any row.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

# modules whose rows land in BENCH_routing.json (the event-delivery hot path)
_ROUTING_MODULES = ("routing_throughput", "dispatch", "serving")


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--profile",
        nargs="?",
        const="/tmp/repro_bench_trace",
        default=None,
        metavar="DIR",
        help="capture a jax.profiler trace of the sweep into DIR",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        metavar="N",
        help="fake N host-platform devices (sets "
        "--xla_force_host_platform_device_count before jax imports; the "
        "sharded serving rows then run shards on disjoint devices)",
    )
    ap.add_argument(
        "--only",
        default=None,
        metavar="MOD[,MOD...]",
        help="run only these benchmark modules (e.g. 'serving'); "
        "BENCH_routing.json is not rewritten unless BENCH_ROUTING_JSON "
        "is set (a partial sweep must not clobber the full trajectory)",
    )
    args = ap.parse_args(argv)
    if args.devices is not None:
        if "jax" in sys.modules:
            raise SystemExit(
                "--devices must take effect before jax is imported"
            )
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    only = args.only.split(",") if args.only else None
    if args.profile is not None:
        import jax

        with jax.profiler.trace(args.profile):
            _run_all(only)
        print(f"wrote profiler trace to {args.profile}", file=sys.stderr)
    else:
        _run_all(only)


def _run_all(only: list[str] | None = None) -> None:
    from benchmarks import (
        cnn_poker,
        comparison,
        dispatch,
        energy,
        memory_scaling,
        roofline,
        routing_throughput,
        serving,
    )

    modules = [
        ("memory_scaling", memory_scaling),
        ("routing_throughput", routing_throughput),
        ("energy", energy),
        ("comparison", comparison),
        ("cnn_poker", cnn_poker),
        ("dispatch", dispatch),
        ("serving", serving),
        ("roofline", roofline),
    ]
    if only is not None:
        unknown = set(only) - {name for name, _ in modules}
        if unknown:
            raise SystemExit(f"unknown benchmark modules: {sorted(unknown)}")
        modules = [(n, m) for n, m in modules if n in only]
    print("name,us_per_call,derived")
    failed = 0
    failed_routing = False
    routing_rows: list[dict] = []
    for name, mod in modules:
        try:
            for row, us, derived in mod.run():
                print(f"{row},{us:.1f},{derived}")
                if name in _ROUTING_MODULES:
                    routing_rows.append(
                        {"module": name, "name": row, "us_per_call": round(us, 2),
                         "derived": derived}
                    )
        except Exception:  # noqa: BLE001 — report per-bench failures, keep going
            failed += 1
            failed_routing |= name in _ROUTING_MODULES
            print(f"{name},nan,FAILED", file=sys.stderr)
            traceback.print_exc()
    json_path = os.environ.get("BENCH_ROUTING_JSON", "BENCH_routing.json")
    if failed_routing:  # keep the last good trajectory instead of clobbering it
        print(f"routing benchmark failed; NOT rewriting {json_path}", file=sys.stderr)
    elif only is not None and "BENCH_ROUTING_JSON" not in os.environ:
        # a partial sweep must not clobber the committed full trajectory
        print(f"--only given; NOT rewriting {json_path}", file=sys.stderr)
    else:
        with open(json_path, "w") as f:
            json.dump({"rows": routing_rows}, f, indent=2)
            f.write("\n")
        print(f"wrote {len(routing_rows)} routing rows to {json_path}", file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
