"""Fig. 13 + §II: routing-memory scaling — this work (linear) vs TrueNorth
(quadratic), and the paper's headline 160k vs ~1.2k bits/neuron example."""

from __future__ import annotations

import time

import numpy as np

from repro.core import memory_model as mm


def _truenorth_bits(n_neurons: float) -> float:
    """TrueNorth allocates extra routing cores for fan-out: cores ~ quadratic
    in model size (Fig. 13's fit). Each core: 256x410 bit crossbar+config."""
    cores = (n_neurons / 256.0) ** 2 * 1.2e-2 + n_neurons / 256.0
    return cores * 256 * 410


def run() -> list[tuple[str, float, str]]:
    out = []
    t0 = time.perf_counter()
    # paper headline: N=2^20, F=2^13, C=256
    conv = mm.conventional_bits(2**20, 2**13)
    opt = mm.mem_at_optimal_m(2**20, 2**13, 256)
    per_side = opt / 2
    out.append(("fig13_headline_conventional_bits", 0.0, f"{conv:.0f}"))
    out.append(("fig13_headline_optimized_bits_per_side", 0.0, f"{per_side:.1f}"))
    out.append(("fig13_headline_reduction_x", 0.0, f"{conv / opt:.1f}"))

    # Fig 13 curves: CNN model sizes vs total routing bits (KM/C=64, +2 bits
    # per word for 4 synapse types, as in the paper's plot).
    sizes = np.array([2**i for i in range(10, 21)], dtype=float)
    ours, tn = [], []
    for n in sizes:
        c, k, m = 256.0, 256.0, 64.0
        per_neuron = mm.mem_total_bits(n, f=4096, c=c, m=m, k=k) + 2 * 64
        ours.append(per_neuron * n)
        tn.append(_truenorth_bits(n))
    ours, tn = np.array(ours), np.array(tn)
    # linear vs quadratic: log-log slope
    slope_ours = np.polyfit(np.log(sizes), np.log(ours), 1)[0]
    slope_tn = np.polyfit(np.log(sizes), np.log(tn), 1)[0]
    dt = (time.perf_counter() - t0) * 1e6
    out.append(("fig13_loglog_slope_this_work", dt, f"{slope_ours:.2f}"))
    out.append(("fig13_loglog_slope_truenorth", dt, f"{slope_tn:.2f}"))
    out.append(
        ("fig13_crossover_advantage_at_1M", 0.0, f"{tn[-1] / ours[-1]:.1f}x")
    )
    return out
