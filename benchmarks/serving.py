"""Continuous-batching AER serving benchmark (DESIGN.md §12).

Serves synthetic poker-DVS sessions through the multi-tenant session pool
(serve/aer.py) over the compiled Table-V network and reports, per
(dispatch backend x pool size):

  * sessions/s — completed classifications per wall-clock second under
    sustained load (admissions backfill evictions every step);
  * p50/p99 decision latency in simulated ms (steps x dt);
  * the per-engine-step cost in us (the us_per_call column).

Backends: ``reference`` (zero-latency queued delivery), ``fused``
(single-kernel stage-1+2; jnp event-sparse reference off-TPU), ``fabric``
(delay lines + link FIFOs — per-tenant in-flight state, DESIGN.md §11).

``BENCH_SMOKE=1`` shrinks to a pool of 2 and a handful of steps; the CI
bench-smoke job asserts these rows land in BENCH_routing.json.
"""

from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.core.cnn import compile_poker_cnn, poker_neuron_params
from repro.core.compiler import repair_placement
from repro.core.faults import FaultSpec
from repro.core.routing import Fabric
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
from repro.serve.aer import (
    AerServeConfig,
    AerSessionPool,
    DvsSession,
    build_poker_engine,
)
from repro.serve.sharded import ShardConfig, ShardedSessionPool

SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"


def _sessions(n: int, seed: int = 11) -> list[DvsSession]:
    rng = np.random.default_rng(seed)
    suits = rng.integers(0, 4, n)
    return [
        DvsSession(
            i,
            DvsStreamSource(
                DvsStreamConfig(symbol=int(suits[i]), events_per_step=16, seed=seed),
                session_id=i,
            ),
            label=int(suits[i]),
        )
        for i in range(n)
    ]


def _tail_ms(lat: np.ndarray, dt_ms: float) -> str:
    """Labeled tail latency: true p99 needs samples — interpolating the 99th
    percentile from a couple dozen latencies is noise dressed as a
    percentile, so below 100 samples the tail is reported as the labeled
    max instead."""
    if lat.size >= 100:
        return f"p99_{np.percentile(lat, 99) * dt_ms:.0f}ms"
    return f"max_{lat.max() * dt_ms:.0f}ms"


def run() -> list[tuple[str, float, str]]:
    out = []
    # throughput benchmark: the default readout wiring decides just as fast
    # as the Hebbian-tuned one (examples/poker_dvs_serve.py tunes for
    # accuracy; here only the serving machinery is under measurement)
    cc = compile_poker_cnn()
    pools = (2,) if SMOKE else (8, 64)
    backends = ("reference", "fused", "fabric")
    max_steps = 12 if SMOKE else 60
    dt_ms = poker_neuron_params().dt * 1e3
    step_us: dict[tuple[str, int], float] = {}
    for backend in backends:
        engine = build_poker_engine(cc.tables, backend)
        for pool_size in pools:
            pool = AerSessionPool(
                cc, engine, AerServeConfig(pool_size=pool_size, max_steps=max_steps)
            )
            n_sessions = 2 * pool_size
            # warm the jitted step + reset paths outside the timed region
            pool.serve(_sessions(max(2, pool_size // 4), seed=5))
            steps0 = pool.n_steps
            t0 = time.perf_counter()
            results = pool.serve(_sessions(n_sessions))
            wall = time.perf_counter() - t0
            steps = pool.n_steps - steps0
            lat = np.array([r.latency_steps for r in results], dtype=np.float64)
            sess_s = len(results) / wall
            p50 = np.percentile(lat, 50) * dt_ms
            step_us[(backend, pool_size)] = wall / steps * 1e6
            out.append(
                (
                    f"serving_{backend}_pool{pool_size}",
                    wall / steps * 1e6,
                    f"{sess_s:.1f}sess_s_p50_{p50:.0f}ms_{_tail_ms(lat, dt_ms)}",
                )
            )
            # throughput as the ROW VALUE: the row above records step-us in
            # the us_per_call column (all serving_* rows do), so a tracker
            # diffing row values never saw sessions/s regress — these
            # sibling rows put the headline number where values are compared
            out.append(
                (
                    f"serving_{backend}_pool{pool_size}_sess_s",
                    sess_s,
                    f"{sess_s:.1f}sess_s_value_row",
                )
            )
    # the realism-tax headline (DESIGN.md §14): executable-fabric serving
    # within 2x of the fused fast path at the top pool size. CI bench-smoke
    # parses the ratio out of this row and asserts < 2.0.
    top = pools[-1]
    ratio = step_us[("fabric", top)] / step_us[("fused", top)]
    out.append(
        (
            "serving_fabric_vs_fused_ratio",
            ratio,
            f"{ratio:.2f}x_fabric_step_vs_fused_pool{top}",
        )
    )

    # degradation curve (DESIGN.md §15): the same serving loop on the
    # executable fabric with dead mesh links — first unrepaired (events are
    # lost on the severed routes), then with the placement re-annealed
    # around the fault set by compiler.repair_placement. The rows carry
    # accuracy and measured link drops so the curve, not just the speed,
    # is regression-tracked. CI chaos-smoke asserts these rows exist.
    dead = (
        ((0, 1),)
        if SMOKE
        else ((0, 1), (1, 0), (0, 3), (3, 0), (1, 2), (2, 1))  # 25% of links
    )
    faults = FaultSpec(dead_links=dead)
    placement, report = repair_placement(cc.tables, Fabric(), faults, seed=0)
    cc_repaired = dataclasses.replace(
        cc, tables=dataclasses.replace(cc.tables, tile_of_cluster=placement)
    )
    pool_size = pools[0]
    scenarios = [
        (f"{len(dead)}link", cc),
        ("repaired", cc_repaired if report["feasible"] else cc),
    ]
    for tag, c in scenarios:
        engine = build_poker_engine(c.tables, "fabric", faults=faults)
        pool = AerSessionPool(
            c, engine, AerServeConfig(pool_size=pool_size, max_steps=max_steps)
        )
        pool.serve(_sessions(2, seed=5))  # warm the jitted faulted step
        steps0 = pool.n_steps
        t0 = time.perf_counter()
        results = pool.serve(_sessions(2 * pool_size))
        wall = time.perf_counter() - t0
        steps = pool.n_steps - steps0
        acc = float(np.mean([r.correct for r in results]))
        drops = int(sum(r.link_dropped for r in results))
        out.append(
            (
                f"serving_degraded_{tag}_pool{pool_size}",
                wall / steps * 1e6,
                f"acc_{acc:.2f}_drops_{drops}_{len(results) / wall:.1f}sess_s",
            )
        )

    # multi-model residency (DESIGN.md §16): two compiled networks resident
    # in ONE pool, sessions naming their model at admission. Three rows:
    # mixed-tenancy throughput, the SpikeHard-style model-load overhead
    # (load+first-step cost vs a steady-state invocation), and serving
    # throughput across a hot model load under live sessions.
    pool_size = pools[0]
    mm_cfg = AerServeConfig(pool_size=pool_size, max_steps=max_steps)

    def _mixed(n, seed):
        sessions = _sessions(n, seed=seed)
        for i, s in enumerate(sessions):
            s.model = "a" if i % 2 == 0 else "b"
        return sessions

    pool = AerSessionPool.from_models({"a": cc, "b": cc}, mm_cfg)
    pool.serve(_mixed(2, seed=5))  # warm the combined-slab step
    steps0 = pool.n_steps
    t0 = time.perf_counter()
    results = pool.serve(_mixed(2 * pool_size, seed=13))
    wall = time.perf_counter() - t0
    steps = pool.n_steps - steps0
    out.append(
        (
            f"multimodel_2model_pool{pool_size}",
            wall / steps * 1e6,
            f"{len(results) / wall:.1f}sess_s_2models_1engine",
        )
    )

    single = AerSessionPool.from_models({"a": cc}, mm_cfg)
    single.serve(_sessions(1, seed=5))  # warm the 1-model step
    t0 = time.perf_counter()
    single.load_model("b", cc)
    single.step()  # first post-load step compiles the grown engine
    load_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n_probe = 5
    for _ in range(n_probe):
        single.step()
    step_s = (time.perf_counter() - t0) / n_probe
    out.append(
        (
            "multimodel_load_overhead",
            load_s * 1e6,
            f"load_{load_s * 1e3:.0f}ms_vs_step_{step_s * 1e6:.0f}us_"
            f"{load_s / step_s:.0f}x",
        )
    )

    # swap under load: sessions on model a are mid-flight when model b is
    # hot-loaded; every in-flight session finishes and b's tenants follow
    from collections import deque

    swap = AerSessionPool.from_models({"a": cc}, mm_cfg)
    warm = _sessions(2, seed=5)
    for s in warm:
        s.model = "a"
    swap.serve(warm)
    traffic = _sessions(2 * pool_size, seed=17)
    for i, s in enumerate(traffic):
        s.model = "a" if i < pool_size else "b"
    pending = deque(traffic)
    done: list = []
    steps0 = swap.n_steps
    t0 = time.perf_counter()
    while pending or swap.occupied:
        if pending and pending[0].model not in swap.models:
            swap.load_model(pending[0].model, cc)  # hot load, live sessions
        while pending and swap.free_slots and pending[0].model in swap.models:
            swap.admit(pending.popleft())
        swap.step()
        fin = swap.finished_slots()
        if fin:
            done.extend(swap.evict_many(fin))
    wall = time.perf_counter() - t0
    steps = swap.n_steps - steps0
    assert len(done) == len(traffic), "swap-under-load lost sessions"
    out.append(
        (
            f"multimodel_swap_pool{pool_size}",
            wall / steps * 1e6,
            f"{len(done) / wall:.1f}sess_s_across_hot_load",
        )
    )

    # sharded fleet (DESIGN.md §17): the same sustained-load loop over a
    # ShardedSessionPool — the fleet total pool is split across `dev`
    # single-device shards (disjoint devices when the process has that many,
    # e.g. `python -m benchmarks.run --devices 4`; oversubscribed on one
    # otherwise — same code path either way). CI sharded-serving-smoke
    # asserts the dev{1,2,4} rows land in BENCH_routing.json.
    totals = (4,) if SMOKE else (8, 64)
    for total in totals:
        for dev in (1, 2, 4):
            if total % dev:
                continue
            fleet = ShardedSessionPool(
                cc,
                AerServeConfig(pool_size=total // dev, max_steps=max_steps),
                ShardConfig(n_shards=dev, queue_depth=total, backend="fabric"),
            )
            fleet.serve(_sessions(max(2, total // 4), seed=5))  # warm shards
            steps0 = fleet.n_steps
            t0 = time.perf_counter()
            results = fleet.serve(_sessions(2 * total))
            wall = time.perf_counter() - t0
            steps = fleet.n_steps - steps0
            lat = np.array(
                [r.latency_steps for r in results], dtype=np.float64
            )
            out.append(
                (
                    f"serving_sharded_pool{total}_dev{dev}",
                    wall / steps * 1e6,
                    f"{len(results) / wall:.1f}sess_s"
                    f"_p50_{np.percentile(lat, 50) * dt_ms:.0f}ms"
                    f"_{_tail_ms(lat, dt_ms)}",
                )
            )
            out.append(
                (
                    f"serving_sharded_pool{total}_dev{dev}_sess_s",
                    len(results) / wall,
                    f"{len(results) / wall:.1f}sess_s_value_row",
                )
            )

    # profile-guided re-placement (DESIGN.md §18): a pool compiled with a
    # deliberately scattered ("stale") placement under tight link FIFOs
    # drops events; the ReplacementController observes the measured
    # (cluster, cluster) traffic, re-runs optimize_placement on it, and
    # swaps the re-placed tables in as a fresh model version under the live
    # sessions. The row records link drops over equal observation windows
    # before and after the swap, and whether the mid-flight cohort stayed
    # byte-equal to an undisturbed control pool across the swap. CI
    # bench-smoke parses drops_pre/drops_post and asserts post <= pre.
    from repro.serve.health import ReplacementConfig, ReplacementController

    pool_size = pools[0]
    window = 8 if SMOKE else 16
    # corners-first placement maximizes mesh distance between the clusters
    # that talk (the compiled CNN's traffic is layer-local)
    stale = np.array([0, 8, 2, 6, 4, 5][: cc.tables.n_clusters], np.int32)
    cc_stale = dataclasses.replace(
        cc, tables=dataclasses.replace(cc.tables, tile_of_cluster=stale)
    )
    fo = {"link_capacity": 2, "per_link_stats": True}
    rp_cfg = AerServeConfig(pool_size=pool_size, max_steps=10**6)

    def _rp_pool():
        return AerSessionPool.from_models(
            {"m": cc_stale}, rp_cfg, backend="fabric", fabric_options=dict(fo)
        )

    pool_a, pool_b = _rp_pool(), _rp_pool()  # b: undisturbed control
    for p in (pool_a, pool_b):
        for s in _sessions(pool_size, seed=23):
            s.model = "m"
            p.admit(s)
    for _ in range(window):
        pool_a.step()
        pool_b.step()
    drops_pre = float(pool_a.profile.total_link_dropped)
    ctl = ReplacementController(
        pool_a, cfg=ReplacementConfig(min_steps=window // 2, cooldown_steps=0)
    )
    drift = ctl.drift()
    t0 = time.perf_counter()
    swap = ctl.maybe_replace(force=True)
    swap_s = time.perf_counter() - t0
    assert swap is not None, "replacement_drift: forced swap did not happen"
    # mid-flight cohort keeps serving on the old version through the swap —
    # byte-equal to the control pool that never swapped
    for _ in range(window // 2):
        pool_a.step()
        pool_b.step()
    bitexact = all(
        sa is not None
        and sb is not None
        and np.array_equal(sa.counts, sb.counts)
        and sa.dropped == sb.dropped
        and sa.link_dropped == sb.link_dropped
        for sa, sb in zip(pool_a.slots, pool_b.slots)
    )
    # drain the old cohort, then measure the same window on the re-placed
    # version only (drain_retired's rebind restarts the observation window)
    for i, s in enumerate(list(pool_a.slots)):
        if s is not None:
            pool_a.evict(i)
    ctl.drain_retired()
    cohort2 = _sessions(pool_size, seed=23)
    for s in cohort2:
        pool_a.admit(ctl.retarget(s))
    steps0 = pool_a.n_steps
    t0 = time.perf_counter()
    for _ in range(window):
        pool_a.step()
    wall = time.perf_counter() - t0
    drops_post = float(pool_a.profile.total_link_dropped)
    ratio = drops_pre / max(drops_post, 1.0)
    out.append(
        (
            f"replacement_drift_pool{pool_size}",
            wall / (pool_a.n_steps - steps0) * 1e6,
            f"drops_pre_{int(drops_pre)}_post_{int(drops_post)}_"
            f"{ratio:.1f}x_fewer_drift_{drift:.2f}_bitexact_{int(bitexact)}_"
            f"swap_{swap_s * 1e3:.0f}ms",
        )
    )

    # live-migration overhead (§17 layer 3): cost of moving one mid-flight
    # tenant between shards, against the fleet step it displaces
    fleet = ShardedSessionPool(
        cc,
        AerServeConfig(pool_size=2, max_steps=10**6),
        ShardConfig(n_shards=2, queue_depth=4, backend="fabric"),
    )
    for s in _sessions(2, seed=5):
        fleet.submit(s)
    for _ in range(4):
        fleet.step()  # warms the step; leaves in-flight fabric state to move
    fleet.migrate(0, fleet.locate(0)[0] ^ 1)  # warm extract/splice jit paths
    n_moves = 4 if SMOKE else 16
    t0 = time.perf_counter()
    for _ in range(n_moves):
        fleet.migrate(0, fleet.locate(0)[0] ^ 1)
    mig_us = (time.perf_counter() - t0) / n_moves * 1e6
    n_probe = 4 if SMOKE else 16
    t0 = time.perf_counter()
    for _ in range(n_probe):
        fleet.step()
    fleet_step_us = (time.perf_counter() - t0) / n_probe * 1e6
    out.append(
        (
            "serving_migration_overhead",
            mig_us,
            f"{mig_us / fleet_step_us:.1f}x_fleet_step_per_move",
        )
    )
    return out
