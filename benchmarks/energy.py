"""Table III + Fig 11: per-operation energy and chip power vs firing rate."""

from __future__ import annotations

from repro.core.routing import Fabric


def run() -> list[tuple[str, float, str]]:
    out = []
    fab = Fabric()
    e = fab.constants.energy_j
    for vdd in (1.8, 1.3):
        for op, val in e[vdd].items():
            out.append((f"table3_{op}_at_{vdd}V_pJ", 0.0, f"{val * 1e12:.0f}"))
    # local vs cross-chip delivered-spike energy (1.3 V)
    out.append(("table3_local_event_total_nJ", 0.0, f"{fab.energy_j(0, 0, 1.3) * 1e9:.2f}"))
    out.append(("table3_crosschip_event_total_nJ", 0.0, f"{fab.energy_j(0, 16, 1.3) * 1e9:.2f}"))

    # Fig 11: power at all-neuron firing, 25% connectivity, 4 cores (model)
    n_neurons, fan = 1024, 256
    for rate in (10.0, 50.0, 100.0):
        spikes_s = n_neurons * rate
        e13 = e[1.3]
        # spike + encode per source event; broadcast+extend per destination core (4)
        p = spikes_s * (e13["spike"] + e13["encode"]) + spikes_s * 4 * (
            e13["broadcast"] / 256 * fan / 4 + e13["route_core"]
        )
        out.append((f"fig11_power_at_{rate:.0f}hz_uW", 0.0, f"{p * 1e6:.1f}"))
    return out
