"""Table V / Fig 12: the spiking-CNN poker experiment (synthetic DVS events).

Compiles the Table-V network, Hebbian-selects the readout (paper §V), streams
synthetic card-symbol events, and reports classification accuracy +
latency-to-decision (paper: 100 % on 4 suits, <30 ms decisions)."""

from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def run() -> list[tuple[str, float, str]]:
    from examples.poker_dvs_cnn import pool_activity, symbol_events
    from repro.core.cnn import CnnConfig, compile_poker_cnn
    from repro.core.event_engine import EventEngine
    from repro.core.neuron import NeuronParams

    params = NeuronParams(refrac=1e-3, b_adapt=1e-3, input_gain=0.3,
                          w_syn=(1.0, 3.0, 1.0, 1.0))
    rng = np.random.default_rng(7)
    cc0 = compile_poker_cnn()
    eng0 = EventEngine(cc0.tables, params)
    # all 4 class presentations as one batched dispatch
    acts, _ = pool_activity(cc0, eng0, [symbol_events(sym, 400, rng) for sym in range(4)])
    sel = acts - acts.mean(0, keepdims=True)
    fc_select = np.stack([np.argsort(-sel[c])[:64] for c in range(4)])
    cc = compile_poker_cnn(CnnConfig(), fc_select=fc_select)
    eng = EventEngine(cc.tables, params)

    t_steps = 40
    correct, latencies = 0, []
    t0 = time.perf_counter()
    eval_rng = np.random.default_rng(99)
    n = 8
    syms = [i % 4 for i in range(n)]
    _, outs = pool_activity(
        cc, eng, [symbol_events(sym, 400, eval_rng) for sym in syms], t_steps
    )  # one batched dispatch for the whole eval set
    for sym, out in zip(syms, outs):
        counts = out.sum((0, 2))
        correct += int(np.argmax(counts)) == sym
        cum = out.sum(2).cumsum(0)
        lead = np.nonzero((cum.argmax(1) == sym) & (cum.max(1) > 2))[0]
        latencies.append(int(lead[0]) + 1 if len(lead) else t_steps)
    dt_us = (time.perf_counter() - t0) / n * 1e6
    return [
        ("table5_cnn_accuracy", dt_us, f"{correct}/{n}"),
        ("fig12_decision_latency_ms", 0.0, f"{float(np.mean(latencies)):.0f}ms_sim"),
        ("table5_network_neurons", 0.0, str(cc.tables.n_neurons)),
    ]
