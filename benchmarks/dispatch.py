"""Beyond-paper mapping (DESIGN.md §3): two-stage tag dispatch as MoE routing.

Compares the paper's scheme against dense (one-hot) dispatch on the axes the
paper optimizes — routing-state memory and wall time — for a deepseek-moe-like
shape. Dense dispatch stores a [T, E, cap] combine tensor; two-stage stores
(tag, cluster) per assignment = the MEM_S entry of eq. (2)."""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.moe import init_moe, moe_local, moe_reference

SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"


def run() -> list[tuple[str, float, str]]:
    out = []
    cfg = ModelConfig(d_model=256, n_experts=32, top_k=4, moe_d_ff=128, capacity_factor=1.5)
    params = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    t = 256 if SMOKE else 2048
    x = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model))

    # routing-state bytes
    cap = int(t * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    dense_state = t * cfg.n_experts * cap * 4  # combine tensor fp32
    two_stage_state = t * cfg.top_k * (
        (np.ceil(np.log2(cfg.n_experts)) + 32) / 8
    )  # (tag,cluster) id + fp32 weight per assignment
    out.append(("dispatch_state_dense_MB", 0.0, f"{dense_state / 1e6:.1f}"))
    out.append(("dispatch_state_two_stage_MB", 0.0, f"{two_stage_state / 1e6:.3f}"))
    out.append(("dispatch_state_reduction_x", 0.0, f"{dense_state / two_stage_state:.0f}"))

    # wall time (CPU): two-stage sort dispatch vs dense all-experts reference
    f_two = jax.jit(lambda p, xx: moe_local(p, xx, cfg)[0])
    f_ref = jax.jit(lambda p, xx: moe_reference(p, xx, cfg)[0])
    for name, f in (("two_stage", f_two), ("dense_ref", f_ref)):
        y = f(params, x)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(10):
            y = f(params, x)
        jax.block_until_ready(y)
        out.append((f"dispatch_{name}_wall", (time.perf_counter() - t0) / 10 * 1e6, "us"))

    # batched event delivery (core/dispatch.py backends): events/s vs batch
    # size for the full stage-1 + stage-2 path on the chip's core geometry.
    from repro.core.dispatch import get_backend

    rng = np.random.default_rng(0)
    n, cluster, k, s = 512, 256, 512, 32
    src_tag = jnp.asarray(rng.integers(0, k, (n, 8)), jnp.int32)
    src_dest = jnp.asarray(rng.integers(0, n // cluster, (n, 8)), jnp.int32)
    cam_tag = jnp.asarray(rng.integers(-1, k, (n, s)), jnp.int32)
    cam_syn = jnp.asarray(rng.integers(0, 4, (n, s)), jnp.int32)
    backend = get_backend("reference")
    events_per_stream = int(src_tag.size)
    for b in (1, 8) if SMOKE else (1, 8, 64):
        spikes = jnp.asarray(rng.random((b, n)) < 0.5, jnp.float32)
        f = jax.jit(
            lambda sp: backend.deliver(sp, src_tag, src_dest, cam_tag, cam_syn, cluster, k)
        )
        jax.block_until_ready(f(spikes))
        t0 = time.perf_counter()
        for _ in range(10):
            y = f(spikes)
        jax.block_until_ready(y)
        us = (time.perf_counter() - t0) / 10 * 1e6
        ev_s = b * events_per_stream / (us / 1e6)
        out.append((f"deliver_reference_B{b}", us, f"{ev_s / 1e6:.1f}Mev_s"))
    return out
