"""Table IV: average distance + energy/hop vs flat-mesh architectures."""

from __future__ import annotations

import numpy as np

from repro.core.routing import Fabric, avg_distance_hierarchical, avg_distance_mesh


def run() -> list[tuple[str, float, str]]:
    out = []
    for n in (1024, 4096, 65536):
        mesh = avg_distance_mesh(n)
        hier = avg_distance_hierarchical(n, cluster=4)
        out.append((f"table4_avg_dist_mesh_n{n}", 0.0, f"{mesh:.1f}(2sqrtN/3={2*np.sqrt(n)/3:.1f})"))
        out.append((f"table4_avg_dist_hier_n{n}", 0.0, f"{hier:.1f}(sqrtN/3={np.sqrt(n)/3:.1f})"))
    fab = Fabric()
    out.append(("table4_energy_per_hop_pJ_1.3V", 0.0, f"{fab.constants.energy_per_hop_j * 1e12:.0f}"))
    out.append(("table4_fan_in_out", 0.0, "64/4k"))
    return out
