"""§Roofline reader: aggregates experiments/dryrun/*.json into the table.

Prints one row per (arch x shape x mesh): the three terms (seconds), the
dominant bottleneck, MODEL_FLOPS/HLO_FLOPS, and the MFU upper bound.
"""

from __future__ import annotations

import json
import os

ART = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun"),
)


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    if not os.path.isdir(ART):
        return cells
    for fn in sorted(os.listdir(ART)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(ART, fn)) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        cells.append(r)
    return cells


def run() -> list[tuple[str, float, str]]:
    out = []
    for r in load_cells():
        rf = r["roofline"]
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        ratio = rf.get("model_flops_ratio")
        derived = (
            f"c={rf['compute_s']:.3e}s|m={rf['memory_s']:.3e}s|x={rf['collective_s']:.3e}s"
            f"|dom={rf['dominant']}|useful={ratio:.2f}|mfu_ub={rf['mfu_upper_bound']:.4f}"
            if ratio
            else f"dom={rf['dominant']}"
        )
        out.append((name, 0.0, derived))
    if not out:
        out.append(("roofline_no_artifacts", 0.0, "run repro.launch.dryrun first"))
    return out


def table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful-FLOP ratio | MFU bound |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_cells(mesh):
        rf = r["roofline"]
        ratio = rf.get("model_flops_ratio") or 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | {rf['memory_s']:.3e} "
            f"| {rf['collective_s']:.3e} | {rf['dominant']} | {ratio:.2f} "
            f"| {rf['mfu_upper_bound'] if rf['mfu_upper_bound'] else 0:.4f} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    import sys

    print(table(sys.argv[1] if len(sys.argv) > 1 else "single"))
