"""Table II: throughput/latency — simulated event-engine throughput on CPU
plus the fabric model's analytical broadcast/R3 figures.

Rows (DESIGN.md §10):
  * ``batched_dispatch_B*``      — engine step with the AER event queue (the
                                   production delivery path), B event streams
  * ``batched_dispatch_dense_*`` — same step on the dense no-queue path
  * ``*_scan_step``              — per-step time inside one whole-scan jit of
                                   ``EventEngine.run`` (separates delivery
                                   cost from Python dispatch overhead)
  * ``sparse_*``                 — deliver-only events/s at 1% / 10% / 100%
                                   activity, event-queued vs dense: the
                                   event-sparsity headline
  * ``fabric_*``                 — zero-latency vs fabric-mode engine step
                                   (delay lines + link FIFOs + stats,
                                   DESIGN.md §11): the cost of making the
                                   mesh executable
  * ``table4_measured_hops_*``   — mean mesh hops measured from simulated
                                   traffic, hierarchical vs flat placement
                                   (the empirical Table IV reproduction)
  * ``compiler_*``               — routing compiler v2 (DESIGN.md §13):
                                   traffic-aware placement vs the
                                   hierarchical-linear default on the
                                   Table-IV geometry — measured mean mesh
                                   hops, link-FIFO drops, fabric-mode
                                   sessions/s, and the tag-reuse saving

``BENCH_SMOKE=1`` shrinks geometry and iteration counts for CI smoke runs.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.event_engine import EventEngine
from repro.core.routing import Fabric
from repro.core.tags import NetworkSpec, compile_network

SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"


def _tables(n=1024, cluster=256, k=1024, fan=16):
    """Clustered connectivity (the paper's regime): each source projects its
    fan-out into one cluster under a single tag — K stays bounded."""
    if SMOKE:
        n, cluster, k, fan = 256, 64, 256, 8
    rng = np.random.default_rng(0)
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=64, max_sram_entries=16)
    n_clusters = n // cluster
    for s in range(n):
        cl = int(rng.integers(n_clusters))
        dsts = cl * cluster + rng.choice(cluster, size=fan, replace=False)
        spec.connect_one_to_many(s, [int(d) for d in dsts], int(rng.integers(4)))
    return compile_network(spec)


def _time_loop(f, *args, iters):
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e6, r  # us


def run() -> list[tuple[str, float, str]]:
    out = []
    fab = Fabric()
    c = fab.constants
    out.append(("table2_broadcast_time_ns", 0.0, f"{c.broadcast_time_s * 1e9:.1f}"))
    out.append(("table2_broadcast_bandwidth_Mev_s", 0.0, f"{1e-6 / c.broadcast_time_s:.1f}"))
    out.append(("table2_r3_throughput_Mev_s", 0.0, f"{c.r3_throughput_eps / 1e6:.0f}"))
    out.append(("table2_latency_across_chip_ns", 0.0, f"{c.latency_across_chip_s * 1e9:.1f}"))
    out.append(("table2_fan_in_at_20hz", 0.0, f"{fab.max_fan_in(20.0):.0f}"))
    out.append(("table2_fan_in_at_100hz", 0.0, f"{fab.max_fan_in(100.0):.0f}"))

    tables = _tables()
    # the AER queue is the production delivery path; capacity models the
    # per-core FIFO depth (1/8 of the population — lossless on this workload)
    # no donate_carry: the timing loops below re-feed the same carry, which a
    # donated step would invalidate on accelerators
    q_cap = max(32, tables.n_neurons // 8)
    eng = EventEngine(tables, queue_capacity=q_cap)
    eng_dense = EventEngine(tables)
    n_iter = 5 if SMOKE else 50
    n_iter_b = 3 if SMOKE else 20
    batch_sizes = (1, 8) if SMOKE else (1, 8, 64)
    b_top = batch_sizes[-1]

    # simulated engine throughput (the chip's 1k-neuron configuration)
    carry = eng_dense.init_state()
    inp = jnp.zeros((eng.n_clusters, eng.k_tags)).at[:, :8].set(2.0)
    step = jax.jit(lambda cr: eng_dense.step(cr, inp))
    dt_us, _ = _time_loop(step, carry, iters=n_iter)
    # every step delivers all active source events through both stages
    events = int((eng.tables.src_tag >= 0).sum())
    out.append(
        ("table2_sim_step_1k_neurons", dt_us, f"{events / (dt_us / 1e6) / 1e6:.2f}Mev_s_sim")
    )

    # batched dispatch: B concurrent event streams through ONE delivery
    # (many users / DVS sensors on shared routing tables), event-queued.
    # Throughput is simulated events/s across the whole batch; the gain over
    # B=1 is the batched-speedup headline.
    base_ev_s = None
    for b in batch_sizes:
        carry_b = eng.init_state(batch=b)
        inp_b = jnp.broadcast_to(inp, (b, *inp.shape))
        step_b = jax.jit(lambda cr: eng.step(cr, inp_b))
        dt_b_us, _ = _time_loop(step_b, carry_b, iters=n_iter_b)
        ev_s = b * events / (dt_b_us / 1e6)
        if base_ev_s is None:
            base_ev_s = ev_s
        out.append(
            (f"batched_dispatch_B{b}", dt_b_us,
             f"{ev_s / 1e6:.2f}Mev_s_{ev_s / base_ev_s:.1f}x_vs_B1")
        )

    # dense no-queue comparison at the top batch size (the pre-§10 path)
    carry_b = eng_dense.init_state(batch=b_top)
    inp_b = jnp.broadcast_to(inp, (b_top, *inp.shape))
    step_d = jax.jit(lambda cr: eng_dense.step(cr, inp_b))
    dt_d_us, _ = _time_loop(step_d, carry_b, iters=n_iter_b)
    out.append(
        (f"batched_dispatch_dense_B{b_top}", dt_d_us,
         f"{b_top * events / (dt_d_us / 1e6) / 1e6:.2f}Mev_s")
    )

    # whole-scan throughput: run() jits the T-step scan once, so per-step
    # Python dispatch overhead is excluded — delivery cost only.
    t_scan = 5 if SMOKE else 20
    inp_t = jnp.broadcast_to(inp, (t_scan, b_top, *inp.shape))
    run_fn = jax.jit(lambda cr, it: eng.run(cr, it))
    dt_scan_us, _ = _time_loop(run_fn, eng.init_state(batch=b_top), inp_t,
                               iters=max(2, n_iter_b // 2))
    per_step_us = dt_scan_us / t_scan
    out.append(
        (f"batched_dispatch_B{b_top}_scan_step", per_step_us,
         f"{b_top * events / (per_step_us / 1e6) / 1e6:.2f}Mev_s_scanned")
    )

    # sparsity sweep: deliver-only events/s at 1% / 10% / 100% activity —
    # the event-sparse path scales with actual event traffic (DVS streams
    # are ~1-5% active), the dense path pays N x E regardless.
    from repro.core.dispatch import get_backend

    backend = get_backend("reference")
    entries_per_src = np.asarray((tables.src_tag >= 0).sum(1))
    rng = np.random.default_rng(7)
    n = tables.n_neurons
    for pct, act in ((1, 0.01), (10, 0.10), (100, 1.0)):
        spikes_np = rng.random((b_top, n)) < act
        spikes = jnp.asarray(spikes_np, jnp.float32)
        ev = int(entries_per_src[np.nonzero(spikes_np)[1]].sum())  # routed events
        cap = min(n, max(32, int(act * n * 2)))  # 2x headroom: lossless

        def dense_deliver(sp):
            return backend.deliver(
                sp, eng.tables.src_tag, eng.tables.src_dest, eng.tables.cam_tag,
                eng.tables.cam_syn, eng.cluster_size, eng.k_tags,
                syn_onehot=eng.tables.cam_syn_onehot,
            )

        def queued_deliver(sp):
            return backend.deliver(
                sp, eng.tables.src_tag, eng.tables.src_dest, eng.tables.cam_tag,
                eng.tables.cam_syn, eng.cluster_size, eng.k_tags,
                queue_capacity=cap, syn_onehot=eng.tables.cam_syn_onehot,
            )

        dt_dense_us, _ = _time_loop(jax.jit(dense_deliver), spikes, iters=n_iter_b)
        dt_queue_us, _ = _time_loop(jax.jit(queued_deliver), spikes, iters=n_iter_b)
        ev_s_dense = ev / (dt_dense_us / 1e6)
        ev_s_queue = ev / (dt_queue_us / 1e6)
        out.append(
            (f"sparse_{pct}pct_dense_B{b_top}", dt_dense_us,
             f"{ev_s_dense / 1e6:.2f}Mev_s")
        )
        out.append(
            (f"sparse_{pct}pct_queue_B{b_top}", dt_queue_us,
             f"{ev_s_queue / 1e6:.2f}Mev_s_{ev_s_queue / ev_s_dense:.1f}x_vs_dense")
        )

    # dispatch autotuner (DESIGN.md §18): measure the dense/queued/fused
    # crossover at each sparsity point and record the picked backend beside
    # an independent re-measurement (fresh seed, fresh spikes) — the derived
    # string says whether the decision reproduces. At 100% activity the
    # queued path's compaction is pure overhead, so the winner there must
    # not be "queued" (the regression this pass retires by construction).
    from repro.core.dispatch import autotune_backend

    for pct, act in ((1, 0.01), (10, 0.10), (100, 1.0)):
        cap = min(n, max(32, int(act * n * 2)))
        tune_kw = dict(
            activity=act, batch=b_top, queue_capacity=cap,
            iters=max(5, n_iter_b),
        )
        decision = autotune_backend(
            tables.src_tag, tables.src_dest, tables.cam_tag, tables.cam_syn,
            eng.cluster_size, eng.k_tags, seed=7, **tune_kw,
        )
        check = autotune_backend(
            tables.src_tag, tables.src_dest, tables.cam_tag, tables.cam_syn,
            eng.cluster_size, eng.k_tags, seed=8, **tune_kw,
        )
        # the pick reproduces if it re-measures (fresh spikes, fresh
        # timings) within noise of the independent run's fastest — at a
        # genuine crossover point two candidates are equal and wall-clock
        # jitter flips the argmin, which is not a wrong decision
        m2 = dict(check.measurements)
        agree = (
            "match"
            if m2[decision.winner] <= 1.25 * min(m2.values())
            else "mismatch"
        )
        winner_us = dict(decision.measurements)[decision.winner]
        out.append(
            (f"autotune_{pct}pct_B{b_top}",
             winner_us,
             f"{decision.winner}_remeasured_{check.winner}_{agree}")
        )

    # fabric-mode execution (DESIGN.md §11): the same network stepped with
    # zero-latency delivery vs through delay lines + link FIFOs + stats.
    grid, cl_f, b_f = (2, 8, 2) if SMOKE else (4, 16, 8)
    hier = Fabric(grid_x=grid, grid_y=grid, cores_per_tile=4)
    flat = Fabric(grid_x=2 * grid, grid_y=2 * grid, cores_per_tile=1)
    n_cores, k_f = hier.n_cores, 64

    def _fabric_net(fab):
        rng = np.random.default_rng(11)
        nf = n_cores * cl_f
        spec = NetworkSpec(n_neurons=nf, cluster_size=cl_f, k_tags=k_f)
        fan = min(8, cl_f)
        for s in range(nf):
            cl = int(rng.integers(n_cores))
            dsts = cl * cl_f + rng.choice(cl_f, size=fan, replace=False)
            spec.connect_one_to_many(s, [int(d) for d in dsts], int(rng.integers(4)))
        return compile_network(spec, fabric=fab)

    tables_h = _fabric_net(hier)
    ev_f = int((np.asarray(tables_h.src_tag) >= 0).sum())
    q_f = max(32, tables_h.n_neurons // 8)
    inp_f = jnp.zeros((b_f, n_cores, k_f)).at[:, :, :8].set(2.0)
    times = {}
    for label, e in (
        ("fabric_off", EventEngine(tables_h, queue_capacity=q_f)),
        ("fabric_on", EventEngine(tables_h, queue_capacity=q_f, fabric=hier)),
    ):
        step_f = jax.jit(lambda cr, e=e: e.step(cr, inp_f))
        dt_f_us, _ = _time_loop(step_f, e.init_state(batch=b_f), iters=n_iter_b)
        times[label] = dt_f_us
        ev_s = b_f * ev_f / (dt_f_us / 1e6)
        extra = "" if label == "fabric_off" else (
            f"_{times['fabric_on'] / times['fabric_off']:.2f}x_cost_vs_off"
        )
        out.append((f"{label}_step_B{b_f}", dt_f_us, f"{ev_s / 1e6:.2f}Mev_s{extra}"))

    # fabric sparsity sweep: the ring fast path's deliver-only events/s at
    # 1% / 10% / 100% activity — the static entry table makes fabric delivery
    # event-proportional, so the rate should hold up as activity climbs
    e_fab = EventEngine(tables_h, queue_capacity=q_f, fabric=hier)
    be_fab = e_fab.fabric_backend
    fab_entries = e_fab._fabric_entries
    entries_per_src_f = np.asarray((np.asarray(tables_h.src_tag) >= 0).sum(1))
    rng_f = np.random.default_rng(13)
    nf = tables_h.n_neurons
    for pct, act in ((1, 0.01), (10, 0.10), (100, 1.0)):
        spikes_np = rng_f.random((b_f, nf)) < act
        spikes_f = jnp.asarray(spikes_np, jnp.float32)
        ev_batch = int(entries_per_src_f[np.nonzero(spikes_np)[1]].sum())
        ring0, cur0 = be_fab.init_ring(n_cores, k_f, batch=b_f)

        def fabric_deliver(sp, ring, cur):
            return be_fab.deliver_fabric_ring(
                sp, fab_entries, e_fab.tables.cam_tag, e_fab.tables.cam_syn,
                cl_f, k_f, ring, cur, queue_capacity=q_f,
                syn_onehot=e_fab.tables.cam_syn_onehot,
            )

        dt_fs_us, _ = _time_loop(
            jax.jit(fabric_deliver), spikes_f, ring0, cur0, iters=n_iter_b
        )
        ev_s = ev_batch / (dt_fs_us / 1e6)
        out.append(
            (f"fabric_sparse_{pct}pct_B{b_f}", dt_fs_us, f"{ev_s / 1e6:.2f}Mev_s")
        )

    # empirical Table IV: mean mesh hops under the same traffic, hierarchical
    # (4 cores/tile) vs flat (1 core/tile) placement of identical clusters
    def _mean_hops(tables, fab):
        e = EventEngine(tables, fabric=fab)
        state, spikes, *delay = e.init_state()
        carry = (state, jnp.ones_like(spikes), *delay)  # every source emits
        _, (_, stats) = e.step(
            carry, jnp.zeros((tables.n_clusters, tables.k_tags))
        )
        return float(stats.hops) / float(stats.delivered)

    mh = _mean_hops(tables_h, hier)
    mf = _mean_hops(_fabric_net(flat), flat)
    out.append(("table4_measured_hops_hier", 0.0, f"{mh:.2f}"))
    out.append(
        ("table4_measured_hops_flat", 0.0, f"{mf:.2f}_{mf / mh:.2f}x_vs_hier")
    )

    # routing compiler v2 (DESIGN.md §13): traffic-aware placement vs the
    # hierarchical-linear default on the Table-IV geometry. The workload is
    # shuffle traffic (cluster c fans into cluster perm(c)) — structured
    # communication the linear map scatters across the mesh, the regime
    # Appendix A's clustered placement targets.
    from repro.core.compiler import compile_network_v2
    from repro.core.tags import NetworkSpec as _Spec

    grid_c = 2 if SMOKE else 4
    fab_c = Fabric(grid_x=grid_c, grid_y=grid_c, cores_per_tile=4)
    nc_c, cl_c, k_c = fab_c.n_cores, (4 if SMOKE else 8), 64

    def _compiler_net():
        rng = np.random.default_rng(17)
        perm = rng.permutation(nc_c)
        spec = _Spec(n_neurons=nc_c * cl_c, cluster_size=cl_c, k_tags=k_c)
        fan = min(4, cl_c)
        for s in range(spec.n_neurons):
            dst_cl = int(perm[s // cl_c])
            # two connect-groups per source into the same destination cluster
            # (e.g. an excitatory and a modulatory projection): v1 burns two
            # tags + two SRAM entries per source, v2's conflict-graph pass
            # shares one
            for syn in (0, int(1 + rng.integers(3))):
                dsts = dst_cl * cl_c + rng.choice(cl_c, size=fan, replace=False)
                spec.connect_one_to_many(s, [int(d) for d in dsts], syn)
        return spec

    spec_c = _compiler_net()
    tables_def = compile_network(spec_c, fabric=fab_c)  # v1 + linear default
    res_opt = compile_network_v2(spec_c, fabric=fab_c, seed=0)
    rep = res_opt.report
    out.append(
        ("compiler_tags", 0.0,
         f"v2_{int(rep.tags_used.sum())}_vs_v1_{int(rep.tags_v1.sum())}")
    )
    hops_def = _mean_hops(tables_def, fab_c)
    hops_opt = _mean_hops(res_opt.tables, fab_c)
    out.append(("compiler_hops_default", 0.0, f"{hops_def:.2f}"))
    out.append(
        ("compiler_hops_optimized", 0.0,
         f"{hops_opt:.2f}_{hops_def / max(hops_opt, 1e-9):.2f}x_fewer")
    )

    # link-FIFO drops under capacity-1 links, all sources spiking once
    def _link_drops(tables):
        e = EventEngine(tables, fabric=fab_c,
                        fabric_options={"link_capacity": 1})
        state, spikes, *delay = e.init_state()
        carry = (state, jnp.ones_like(spikes), *delay)
        _, (_, stats) = e.step(carry, jnp.zeros((nc_c, k_c)))
        return int(np.asarray(stats.link_dropped))

    ld_def, ld_opt = _link_drops(tables_def), _link_drops(res_opt.tables)
    out.append(("compiler_linkdrops_default", 0.0, f"{ld_def}"))
    out.append(("compiler_linkdrops_optimized", 0.0, f"{ld_opt}"))

    # fabric-mode serving rate: B concurrent sessions x T steps per run
    b_s, t_s = (2, 4) if SMOKE else (8, 16)
    inp_s = jnp.zeros((t_s, b_s, nc_c, k_c)).at[:, :, :, :4].set(2.0)
    for label, tables in (("default", tables_def), ("optimized", res_opt.tables)):
        e = EventEngine(tables, fabric=fab_c, queue_capacity=tables.n_neurons)
        run_s = jax.jit(lambda cr, it, e=e: e.run(cr, it))
        dt_us, _ = _time_loop(run_s, e.init_state(batch=b_s), inp_s,
                              iters=max(2, n_iter_b // 2))
        out.append(
            (f"compiler_sessions_s_{label}", dt_us,
             f"{b_s / (dt_us / 1e6):.0f}sessions_s")
        )
    return out
