"""Table II: throughput/latency — simulated event-engine throughput on CPU
plus the fabric model's analytical broadcast/R3 figures."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.event_engine import EventEngine
from repro.core.routing import Fabric
from repro.core.tags import NetworkSpec, compile_network


def _engine(n=1024, cluster=256, k=1024, fan=16):
    """Clustered connectivity (the paper's regime): each source projects its
    fan-out into one cluster under a single tag — K stays bounded."""
    rng = np.random.default_rng(0)
    spec = NetworkSpec(n_neurons=n, cluster_size=cluster, k_tags=k,
                       max_cam_words=64, max_sram_entries=16)
    n_clusters = n // cluster
    for s in range(n):
        cl = int(rng.integers(n_clusters))
        dsts = cl * cluster + rng.choice(cluster, size=fan, replace=False)
        spec.connect_one_to_many(s, [int(d) for d in dsts], int(rng.integers(4)))
    return EventEngine(compile_network(spec))


def run() -> list[tuple[str, float, str]]:
    out = []
    fab = Fabric()
    c = fab.constants
    out.append(("table2_broadcast_time_ns", 0.0, f"{c.broadcast_time_s * 1e9:.1f}"))
    out.append(("table2_broadcast_bandwidth_Mev_s", 0.0, f"{1e-6 / c.broadcast_time_s:.1f}"))
    out.append(("table2_r3_throughput_Mev_s", 0.0, f"{c.r3_throughput_eps / 1e6:.0f}"))
    out.append(("table2_latency_across_chip_ns", 0.0, f"{c.latency_across_chip_s * 1e9:.1f}"))
    out.append(("table2_fan_in_at_20hz", 0.0, f"{fab.max_fan_in(20.0):.0f}"))
    out.append(("table2_fan_in_at_100hz", 0.0, f"{fab.max_fan_in(100.0):.0f}"))

    # simulated engine throughput (the chip's 1k-neuron configuration)
    eng = _engine()
    carry = eng.init_state()
    inp = jnp.zeros((eng.n_clusters, eng.k_tags)).at[:, :8].set(2.0)
    step = jax.jit(lambda cr: eng.step(cr, inp))
    carry, _ = step(carry)  # compile
    jax.block_until_ready(carry[0].v)
    n_iter = 50
    t0 = time.perf_counter()
    for _ in range(n_iter):
        carry, spikes = step(carry)
    jax.block_until_ready(spikes)
    dt_us = (time.perf_counter() - t0) / n_iter * 1e6
    # every step delivers all active source events through both stages
    events = int((eng.tables.src_tag >= 0).sum())
    out.append(
        ("table2_sim_step_1k_neurons", dt_us, f"{events / (dt_us / 1e6) / 1e6:.2f}Mev_s_sim")
    )

    # batched dispatch: B concurrent event streams through ONE delivery
    # (many users / DVS sensors on shared routing tables). Throughput is
    # simulated events/s across the whole batch; the gain over B=1 is the
    # batched-speedup headline.
    base_ev_s = None
    for b in (1, 8, 64):
        carry_b = eng.init_state(batch=b)
        inp_b = jnp.broadcast_to(inp, (b, *inp.shape))
        step_b = jax.jit(lambda cr: eng.step(cr, inp_b))
        carry_b, _ = step_b(carry_b)  # compile
        jax.block_until_ready(carry_b[0].v)
        n_iter_b = 20
        t0 = time.perf_counter()
        for _ in range(n_iter_b):
            carry_b, spikes_b = step_b(carry_b)
        jax.block_until_ready(spikes_b)
        dt_b_us = (time.perf_counter() - t0) / n_iter_b * 1e6
        ev_s = b * events / (dt_b_us / 1e6)
        if base_ev_s is None:
            base_ev_s = ev_s
        out.append(
            (f"batched_dispatch_B{b}", dt_b_us,
             f"{ev_s / 1e6:.2f}Mev_s_{ev_s / base_ev_s:.1f}x_vs_B1")
        )
    return out
