"""Elastic scaling demo (DESIGN.md §6): lose a pod, continue on the survivor.

Runs in a subprocess with 8 fake devices: trains on a (2,2,2) pod/data/model
mesh, checkpoints, then restores the SAME checkpoint onto a (1,2,2) mesh
(one pod lost) with re-resolved shardings and continues training — loss
curve continues smoothly because the deterministic pipeline keys batches by
step.

Run: PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import subprocess
import sys
import textwrap

BODY = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.model import build_model
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import OptConfig
from repro.data.pipeline import DataConfig, make_source
from repro.checkpoint.checkpointer import Checkpointer
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import model_param_pspecs
import tempfile

cfg = get_config("gemma3-1b", smoke=True)
model = build_model(cfg)
opt_cfg = OptConfig(lr=1e-3, total_steps=40, warmup_steps=2)
data = make_source(DataConfig(vocab=cfg.vocab, global_batch=4, seq_len=32, seed=0))
step_fn = jax.jit(make_train_step(model, opt_cfg))

def shard_state(state, mesh):
    pspecs = model_param_pspecs(model, jax.eval_shape(lambda: state["params"]), mesh)
    put = lambda x, s: jax.device_put(x, NamedSharding(mesh, s))
    params = jax.tree.map(put, state["params"], pspecs, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))
    return {"params": params, "opt": jax.tree.map(jax.device_put, state["opt"])}

with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d)
    mesh_a = make_mesh((2, 2, 2), ("pod", "data", "model"))
    state = init_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    print(f"[pod A+B] training on mesh {dict(mesh_a.shape)}")
    for step in range(10):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, m = step_fn(state, batch)
    print(f"[pod A+B] step 10 loss={float(m['loss']):.4f}")
    ck.save(10, state, blocking=True)

    # ---- pod B dies; restart on the 4-device survivor mesh --------------
    mesh_b = make_mesh((1, 2, 2), ("pod", "data", "model"))
    print(f"[pod A only] restoring ckpt onto mesh {dict(mesh_b.shape)}")
    restored = ck.restore(10, state)
    restored = shard_state(restored, mesh_b)
    for step in range(10, 20):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        restored, m = step_fn(restored, batch)
    print(f"[pod A only] step 20 loss={float(m['loss']):.4f}")
    print("elastic restart OK: training continued on the degraded mesh")
"""


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(BODY)],
                         env=env, cwd=root, text=True)
    raise SystemExit(out.returncode)


if __name__ == "__main__":
    main()
