"""Paper §V as a *service*: continuous-batching multi-tenant DVS classification.

Where examples/poker_dvs_cnn.py presents a fixed batch of card flashes,
this example runs the same compiled Table-V network as a server
(serve/aer.py, DESIGN.md §12): a fixed pool of session slots over the
batched event engine, each slot one user's live DVS stream, with sessions
admitted and evicted independently — the slot a finished user vacates is
surgically reset (neuron state, FIFO stats, fabric in-flight events) and
backfilled from the waiting queue the same step, so the fabric never
drains between users.

Per session it reports the majority-rule prediction and latency-to-decision
(steps = ms at dt = 1 ms; paper: <30 ms); aggregate, sessions/s and p50/p99
decision latency.

Run: PYTHONPATH=src python examples/poker_dvs_serve.py
     PYTHONPATH=src python examples/poker_dvs_serve.py --backend fabric --pool 32 --sessions 64
"""

import argparse
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.cnn import (
    CnnConfig,
    compile_poker_cnn,
    hebbian_readout_select,
    poker_neuron_params,
)
from repro.core.compiler import Geometry, artifact_from_tables
from repro.core.event_engine import EventEngine
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource, symbol_dvs_events
from repro.serve.aer import AerServeConfig, AerSessionPool, DvsSession, build_poker_engine

SUITS = ["diamond(|)", "club(-)", "spade(^)", "heart(v)"]


def tune_readout(rng) -> np.ndarray:
    """Offline-Hebbian readout selection (one batched calibration run)."""
    cc = compile_poker_cnn()
    eng = EventEngine(cc.tables, poker_neuron_params())
    t_steps, reps = 40, 3
    streams = [symbol_dvs_events(sym, 400, rng) for sym in range(4) for _ in range(reps)]
    act = cc.input_activity_batch(streams) / t_steps * 10.0
    inp = jnp.broadcast_to(jnp.asarray(act)[None], (t_steps, *act.shape))
    _, spikes = eng.run(eng.init_state(batch=len(streams)), inp)
    pool_rates = (
        np.asarray(spikes)[:, :, cc.pool[0]: cc.pool[1]].sum(0).reshape(4, reps, -1).sum(1)
    )
    return hebbian_readout_select(pool_rates)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas", "fused", "fabric"])
    ap.add_argument("--pool", type=int, default=32)
    ap.add_argument("--sessions", type=int, default=64)
    ap.add_argument("--events-per-step", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    fc_select = tune_readout(rng)
    cc = compile_poker_cnn(CnnConfig(), fc_select=fc_select)

    # second resident model (DESIGN.md §16): the SAME Table-V network bound
    # to a 2x2-chip geometry (2 cores/chip — the smallest mesh its 6 cores
    # fit). Placement-only retarget: the CNN's spliced input taps live in
    # the CAM words, so the tables are re-placed, never recompiled.
    geo2 = Geometry(grid_x=2, grid_y=2, cores_per_tile=2, neurons_per_core=256)
    art2 = artifact_from_tables(cc.tables, geo2, optimize=False)
    # the 2x2 placement binds to the 2x2 mesh, not the pool's shared serving
    # fabric — placements compose all-or-none across residents (DESIGN.md
    # §18), so the resident copy is stripped back to the fabric default and
    # art2 keeps the feasibility story
    cc2 = dataclasses.replace(
        cc, tables=dataclasses.replace(art2.tables, tile_of_cluster=None)
    )
    models = {"tableV-3x3": cc, "tableV-2x2": cc2}
    pool = AerSessionPool.from_models(
        models, AerServeConfig(pool_size=args.pool), backend=args.backend
    )
    print(f"Table-V network ({cc.tables.n_neurons} neurons, "
          f"{cc.tables.n_clusters} cores) resident twice — 3x3-chip and "
          f"2x2-chip placements ({pool.engine.n_neurons} neurons combined) — "
          f"served via backend={args.backend!r}, pool of {args.pool} slots, "
          f"{args.sessions} sessions "
          f"(2x2 binding budget: {art2.feasibility.binding} at "
          f"{art2.feasibility.utilization[art2.feasibility.binding]:.0%})")

    names = list(models)
    suits = rng.integers(0, 4, args.sessions)
    sessions = [
        DvsSession(
            i,
            DvsStreamSource(
                DvsStreamConfig(symbol=int(suits[i]),
                                events_per_step=args.events_per_step,
                                seed=args.seed),
                session_id=i,
            ),
            label=int(suits[i]),
            model=names[i % 2],
        )
        for i in range(args.sessions)
    ]
    model_of = {s.session_id: s.model for s in sessions}

    t0 = time.time()
    results = pool.serve(sessions)
    wall = time.time() - t0

    for r in results[: min(8, len(results))]:
        tick = "ok " if r.correct else "MISS"
        print(f"  session {r.session_id:3d}  {SUITS[r.label]:12s} -> "
              f"{SUITS[r.prediction]:12s} {tick} latency {r.latency_steps:2d} ms")
    if len(results) > 8:
        print(f"  ... {len(results) - 8} more")

    dt_ms = pool.engine.params.dt * 1e3
    print(f"\nper-model results (paper: 100% on the 4-suit task, <30 ms):")
    for name in names:
        rs = [r for r in results if model_of[r.session_id] == name]
        acc_m = float(np.mean([r.correct for r in rs]))
        lat_m = np.array([r.latency_steps for r in rs], dtype=np.float64)
        print(f"  {name:12s}  accuracy {acc_m:.0%} over {len(rs)} sessions, "
              f"latency p50 {np.percentile(lat_m, 50) * dt_ms:.0f} ms / "
              f"p99 {np.percentile(lat_m, 99) * dt_ms:.0f} ms")
    acc = float(np.mean([r.correct for r in results]))
    lat = np.array([r.latency_steps for r in results], dtype=np.float64)
    print(f"combined accuracy: {acc:.0%} over {len(results)} sessions")
    print(f"decision latency: p50 {np.percentile(lat, 50) * dt_ms:.0f} ms, "
          f"p99 {np.percentile(lat, 99) * dt_ms:.0f} ms (paper: <30 ms)")
    print(f"throughput: {len(results) / wall:.1f} sessions/s "
          f"({pool.n_steps} engine steps, {wall:.1f}s wall)")
    dropped = sum(r.dropped for r in results)
    linkd = sum(r.link_dropped for r in results)
    print(f"event loss: {dropped} AER-queue drops, {linkd} link-FIFO drops")


if __name__ == "__main__":
    main()
