"""Paper §V as a *service*: continuous-batching multi-tenant DVS classification.

Where examples/poker_dvs_cnn.py presents a fixed batch of card flashes,
this example runs the same compiled Table-V network as a server
(serve/aer.py, DESIGN.md §12): a fixed pool of session slots over the
batched event engine, each slot one user's live DVS stream, with sessions
admitted and evicted independently — the slot a finished user vacates is
surgically reset (neuron state, FIFO stats, fabric in-flight events) and
backfilled from the waiting queue the same step, so the fabric never
drains between users.

Per session it reports the majority-rule prediction and latency-to-decision
(steps = ms at dt = 1 ms; paper: <30 ms); aggregate, sessions/s and p50/p99
decision latency.

Run: PYTHONPATH=src python examples/poker_dvs_serve.py
     PYTHONPATH=src python examples/poker_dvs_serve.py --backend fabric --pool 32 --sessions 64
"""

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core.cnn import (
    CnnConfig,
    compile_poker_cnn,
    hebbian_readout_select,
    poker_neuron_params,
)
from repro.core.event_engine import EventEngine
from repro.data.pipeline import DvsStreamConfig, DvsStreamSource, symbol_dvs_events
from repro.serve.aer import AerServeConfig, AerSessionPool, DvsSession, build_poker_engine

SUITS = ["diamond(|)", "club(-)", "spade(^)", "heart(v)"]


def tune_readout(rng) -> np.ndarray:
    """Offline-Hebbian readout selection (one batched calibration run)."""
    cc = compile_poker_cnn()
    eng = EventEngine(cc.tables, poker_neuron_params())
    t_steps, reps = 40, 3
    streams = [symbol_dvs_events(sym, 400, rng) for sym in range(4) for _ in range(reps)]
    act = cc.input_activity_batch(streams) / t_steps * 10.0
    inp = jnp.broadcast_to(jnp.asarray(act)[None], (t_steps, *act.shape))
    _, spikes = eng.run(eng.init_state(batch=len(streams)), inp)
    pool_rates = (
        np.asarray(spikes)[:, :, cc.pool[0]: cc.pool[1]].sum(0).reshape(4, reps, -1).sum(1)
    )
    return hebbian_readout_select(pool_rates)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas", "fused", "fabric"])
    ap.add_argument("--pool", type=int, default=32)
    ap.add_argument("--sessions", type=int, default=64)
    ap.add_argument("--events-per-step", type=int, default=16)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()

    rng = np.random.default_rng(args.seed)
    fc_select = tune_readout(rng)
    cc = compile_poker_cnn(CnnConfig(), fc_select=fc_select)
    engine = build_poker_engine(cc.tables, args.backend)
    pool = AerSessionPool(cc, engine, AerServeConfig(pool_size=args.pool))
    print(f"Table-V network ({cc.tables.n_neurons} neurons, "
          f"{cc.tables.n_clusters} cores) served via backend={args.backend!r}, "
          f"pool of {args.pool} slots, {args.sessions} sessions")

    suits = rng.integers(0, 4, args.sessions)
    sessions = [
        DvsSession(
            i,
            DvsStreamSource(
                DvsStreamConfig(symbol=int(suits[i]),
                                events_per_step=args.events_per_step,
                                seed=args.seed),
                session_id=i,
            ),
            label=int(suits[i]),
        )
        for i in range(args.sessions)
    ]

    t0 = time.time()
    results = pool.serve(sessions)
    wall = time.time() - t0

    for r in results[: min(8, len(results))]:
        tick = "ok " if r.correct else "MISS"
        print(f"  session {r.session_id:3d}  {SUITS[r.label]:12s} -> "
              f"{SUITS[r.prediction]:12s} {tick} latency {r.latency_steps:2d} ms")
    if len(results) > 8:
        print(f"  ... {len(results) - 8} more")

    acc = float(np.mean([r.correct for r in results]))
    lat = np.array([r.latency_steps for r in results], dtype=np.float64)
    dt_ms = engine.params.dt * 1e3
    print(f"\naccuracy: {acc:.0%} over {len(results)} sessions "
          f"(paper: 100% on the 4-suit task)")
    print(f"decision latency: p50 {np.percentile(lat, 50) * dt_ms:.0f} ms, "
          f"p99 {np.percentile(lat, 99) * dt_ms:.0f} ms (paper: <30 ms)")
    print(f"throughput: {len(results) / wall:.1f} sessions/s "
          f"({pool.n_steps} engine steps, {wall:.1f}s wall)")
    dropped = sum(r.dropped for r in results)
    linkd = sum(r.link_dropped for r in results)
    print(f"event loss: {dropped} AER-queue drops, {linkd} link-FIFO drops")


if __name__ == "__main__":
    main()
