"""Batched serving example (deliverable b): prefill + decode with ring-buffer
KV caches, greedy + temperature sampling, throughput report.

Run: PYTHONPATH=src python examples/serve_lm.py [--arch gemma2-27b]
(all archs run via their smoke configs on CPU; serving semantics — cache
layouts, window eviction, MLA absorbed decode — are identical to full scale.)
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.7)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n / 1e6:.2f}M params (smoke config of {args.arch})")

    extras = None
    if cfg.frontend == "audio_stub":
        extras = {"frames": jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)}

    for temp, label in ((0.0, "greedy"), (args.temperature, f"T={args.temperature}")):
        eng = Engine(model, params, ServeConfig(max_len=args.prompt_len + args.max_new + 8,
                                                temperature=temp))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32
        )
        t0 = time.time()
        out = eng.generate(prompts, args.max_new, extras)
        dt = time.time() - t0
        print(f"[{label}] generated {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
              f"({out.size / dt:.0f} tok/s incl. compile)")
        print("   first sequences:", out[0, :10].tolist())


if __name__ == "__main__":
    main()
