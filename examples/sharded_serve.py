"""Multi-host elastic serving on the compiled Table-V network.

The fleet layer over examples/poker_dvs_serve.py (serve/sharded.py,
DESIGN.md §17): serving capacity is partitioned into shards, each an
independent session pool over its own device mesh, with

  * admission control — sessions route to the least-loaded shard by the
    compiler's traffic model, behind bounded waiting queues;
  * live migration — mid-flight tenants move between shards (the demo
    drains a shard for "maintenance" while its users keep their state);
  * elastic restart — the fleet checkpoints atomically, one shard is
    killed mid-serve, and its tenants recover from the checkpoint onto
    the survivors, finishing bit-exactly as if nothing had died.

Run: PYTHONPATH=src python examples/sharded_serve.py
     PYTHONPATH=src python examples/sharded_serve.py --shards 4 --sessions 24
     PYTHONPATH=src python examples/sharded_serve.py --devices 4 --backend fabric

``--devices N`` fakes N host devices (must be set before jax initializes),
giving each shard a disjoint device set as on a real multi-host fleet.
"""

import argparse
import os
import sys
import tempfile
import time


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--pool", type=int, default=4, help="slots per shard")
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--backend", default="fabric",
                    choices=["reference", "fused", "fabric"])
    ap.add_argument("--devices", type=int, default=None,
                    help="fake N host devices (shards get disjoint sets)")
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args()

    if args.devices is not None:
        if "jax" in sys.modules:
            raise SystemExit("--devices must be set before jax is imported")
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={args.devices}"
        ).strip()

    import numpy as np

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.core.cnn import compile_poker_cnn
    from repro.data.pipeline import DvsStreamConfig, DvsStreamSource
    from repro.serve.aer import AerServeConfig, DvsSession
    from repro.serve.sharded import ShardConfig, ShardedSessionPool

    suits = ["diamond(|)", "club(-)", "spade(^)", "heart(v)"]
    cc = compile_poker_cnn()
    rng = np.random.default_rng(args.seed)

    def session(i):
        sym = int(rng.integers(0, 4))
        return DvsSession(
            i,
            DvsStreamSource(
                DvsStreamConfig(symbol=sym, events_per_step=16, seed=args.seed),
                session_id=i,
            ),
            label=sym,
        )

    def fleet_():
        return ShardedSessionPool(
            cc,
            AerServeConfig(pool_size=args.pool, max_steps=60),
            ShardConfig(n_shards=args.shards, queue_depth=2 * args.pool,
                        backend=args.backend),
        )

    # -- sustained load through the fleet -----------------------------------
    fleet = fleet_()
    t0 = time.perf_counter()
    results = fleet.serve([session(i) for i in range(args.sessions)])
    wall = time.perf_counter() - t0
    acc = float(np.mean([r.correct for r in results]))
    lat = np.array([r.latency_steps for r in results], dtype=np.float64)
    print(f"fleet: {args.shards} shards x {args.pool} slots, "
          f"backend={args.backend}")
    print(f"  {len(results)} sessions in {wall:.2f}s "
          f"({len(results) / wall:.1f} sess/s), accuracy {acc:.2f}, "
          f"p50 latency {np.percentile(lat, 50):.0f} steps")
    stats = fleet.fleet_stats()
    if stats is not None and stats.delivered is not None:
        print(f"  fleet last-step delivery: {int(stats.delivered)} events, "
              f"{int(stats.link_dropped or 0)} link drops")

    # -- live migration: drain a shard under load ---------------------------
    # one tenant per shard, so the rest of the fleet always has room
    fleet = fleet_()
    for i in range(args.shards):
        fleet.submit(session(100 + i))
    for _ in range(5):
        fleet.step()
    moved = fleet.drain_shard(0)
    print(f"drained shard 0 under load: {moved} tenants migrated mid-flight "
          f"(occupancy now {fleet.occupancy()})")
    done = {r.session_id for r in fleet.serve([])}
    print(f"  all {len(done)} drained tenants finished on the other shards")

    # -- elastic restart: kill a shard, recover from the checkpoint ---------
    fleet = fleet_()
    for i in range(args.shards):
        fleet.submit(session(200 + i))
    for _ in range(3):
        fleet.step()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        fleet.checkpoint(ck, blocking=True)
        fleet.step()
        victim = args.shards - 1
        held = [s.session_id for s in fleet.pools[victim].slots
                if s is not None]
        fleet.kill_shard(victim)
        n = fleet.recover_shard(ck, victim)
        print(f"killed shard {victim} (held sessions {held}); recovered "
              f"{n} tenants from the checkpoint onto the survivors")
    res = {r.session_id: r for r in fleet.serve([])}
    ok = all(res[sid].prediction is not None for sid in held)
    print(f"  recovered tenants finished: {ok} "
          f"(deterministic replay -> results match an undisturbed run)")
    for sid in held:
        r = res[sid]
        mark = "+" if r.correct else "-"
        print(f"    session {sid}: predicted {suits[r.prediction]} "
              f"[{mark}] in {r.latency_steps} steps")


if __name__ == "__main__":
    main()
