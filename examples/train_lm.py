"""End-to-end LM training driver example (deliverable b).

Trains a ~100M-parameter gemma3-family model with the full stack: synthetic
deterministic data pipeline, AdamW, async checkpointing, fault-tolerant
supervisor. On this container's single CPU core the default runs a reduced
~10M model for 200 steps; pass ``--full`` for the 100M configuration (same
code path, just slower per step).

Run: PYTHONPATH=src python examples/train_lm.py [--full] [--steps 200]
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import BlockSpec, ModelConfig
from repro.launch import train as train_driver


def model_100m() -> ModelConfig:
    """~100M params: 12 layers, d=640, 10 heads, vocab 32k."""
    return ModelConfig(
        name="lm-100m", d_model=640, n_heads=10, n_kv_heads=5, head_dim=64,
        d_ff=2560, vocab=32000,
        period=(BlockSpec(kind="attn", ffn="dense"),), n_periods=12,
        remat="none", param_dtype="float32", compute_dtype="float32",
    )


def model_10m() -> ModelConfig:
    return ModelConfig(
        name="lm-10m", d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=1024, vocab=8192,
        period=(BlockSpec(kind="attn", ffn="dense"),), n_periods=6,
        remat="none", param_dtype="float32", compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="100M model")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = model_100m() if args.full else model_10m()
    total, _ = cfg.param_count()
    print(f"training {cfg.name}: {total / 1e6:.1f}M params, "
          f"{args.steps} steps @ batch {args.batch} x seq {args.seq}")

    # reuse the fault-tolerant driver with an injected config
    import repro.configs as configs

    configs.ARCHS = dict(configs.ARCHS)
    mod = type(sys)("example_cfg")
    mod.config = lambda: cfg
    mod.smoke = lambda: cfg
    sys.modules["example_cfg"] = mod
    configs.ARCHS[cfg.name] = "example_cfg"

    ns = argparse.Namespace(
        arch=cfg.name, smoke=False, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=3e-4, seed=0, microbatches=1, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10, max_failures=3, restart_delay=0.5, fail_at=None,
    )
    raise SystemExit(train_driver.run(ns))


if __name__ == "__main__":
    main()
