"""Quickstart: build a two-stage tag-routed network and run it.

Demonstrates the paper's §II claim end-to-end:
  1. describe clustered connectivity,
  2. compile to distributed SRAM/CAM routing tables,
  3. run the event engine and verify against dense connectivity,
  4. compare memory against conventional (flat-address) routing,
  5. serve a batch of independent event streams in one dispatch
     (the batched, backend-pluggable delivery path).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import memory_model as mm
from repro.core.event_engine import EventEngine, dense_weights_from_tables
from repro.core.tags import NetworkSpec, SynapseType, compile_network


def main():
    rng = np.random.default_rng(0)
    # 256 neurons in 4 clusters ("cores") of 64; K = 64 tags per core.
    spec = NetworkSpec(n_neurons=256, cluster_size=64, k_tags=64,
                       max_cam_words=32, max_sram_entries=8)

    # clustered connectivity: populations project within/between clusters
    for src_cluster in range(4):
        srcs = list(range(src_cluster * 64, src_cluster * 64 + 16))
        dst_cluster = (src_cluster + 1) % 4
        tgts = [(dst_cluster * 64 + i, SynapseType.FAST_EXC) for i in range(24)]
        spec.connect_group(srcs, tgts, shared_tag=True)  # 1 tag per cluster!
    # plus some specific point-to-point connections
    for _ in range(60):
        spec.connect(int(rng.integers(256)), int(rng.integers(256)),
                     int(rng.integers(4)))

    tables = compile_network(spec)
    print(f"compiled: {tables.n_neurons} neurons, {tables.n_clusters} cores")
    print(f"  source (SRAM) bits used: {tables.sram_bits()}")
    print(f"  target (CAM)  bits used: {tables.cam_bits()}")
    n_conn = len(tables.dense_equivalent())
    conv_bits = n_conn * np.log2(256)  # flat addressing needs log2(N)/connection
    print(f"  connections realized: {n_conn}; flat routing would need "
          f"{conv_bits:.0f} bits ({conv_bits / (tables.sram_bits() + tables.cam_bits()):.1f}x)")

    # theory: the same network at brain scale
    print("\npaper §II at scale (N=2^20, F=2^13, C=256):")
    print(f"  conventional: {mm.conventional_bits(2**20, 2**13):.0f} bits/neuron")
    print(f"  two-stage optimum: {mm.mem_at_optimal_m(2**20, 2**13, 256):.0f} bits/neuron "
          f"(M* = {mm.optimal_m(2**20, 2**13, 256):.0f})")

    # run the engine: stimulate cluster 0's shared tag, watch activity propagate
    eng = EventEngine(tables)
    carry = eng.init_state()
    inp = jnp.zeros((80, tables.n_clusters, tables.k_tags)).at[:, 0, :6].set(6.0)
    carry, spikes = eng.run(carry, inp)
    per_cluster = np.asarray(spikes).sum(0).reshape(4, 64).sum(1)
    print(f"\nspikes per core over 80 steps: {per_cluster} (stimulus -> core0 -> core1 ...)")

    # verify two-stage delivery == dense connectivity on a random state
    dense = dense_weights_from_tables(tables)
    s = (rng.random(256) < 0.2).astype(np.float32)
    from repro.core.two_stage import two_stage_deliver

    drive = two_stage_deliver(
        jnp.asarray(s), jnp.asarray(tables.src_tag), jnp.asarray(tables.src_dest),
        jnp.asarray(tables.cam_tag), jnp.asarray(tables.cam_syn), 64, 64,
    )
    ref = np.einsum("dst,s->dt", dense, s)
    print(f"two-stage == dense connectivity: max err = {np.abs(np.asarray(drive) - ref).max():.2e}")

    # batched serving: B independent event streams through ONE dispatch.
    # Each stream stimulates a different core; spikes stay per-stream.
    b = 4
    inp_b = jnp.zeros((80, b, tables.n_clusters, tables.k_tags))
    for stream in range(b):
        inp_b = inp_b.at[:, stream, stream % 4, :6].set(6.0)
    carry_b, spikes_b = eng.run(eng.init_state(batch=b), inp_b)
    per_stream = np.asarray(spikes_b).sum(axis=(0, 2)).astype(int)
    print(f"\nbatched run (B={b}, one stimulus core per stream): "
          f"spikes per stream = {per_stream}")
    # stream 0 stimulates core 0 exactly like the single run above
    assert np.allclose(np.asarray(spikes_b)[:, 0], np.asarray(spikes)), "stream 0 != single run"
    print("stream 0 of the batch reproduces the single-stream run exactly")

    from repro.core.dispatch import available_backends

    print(f"dispatch backends available: {', '.join(available_backends())} "
          "(EventEngine(tables, backend=...))")


if __name__ == "__main__":
    main()
