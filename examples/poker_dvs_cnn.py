"""Paper §V application: event-driven spiking CNN classifying poker suits.

Reproduces the experiment's structure on synthetic DVS event streams (the
original poker-DVS recordings are not redistributable here): Table-V network
(conv 4x8x8/2 -> pool 2x2 -> 4x64 output populations), ternary edge kernels
in CAM synapse types, majority-rule readout, latency-to-decision report.

Run: PYTHONPATH=src python examples/poker_dvs_cnn.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core.cnn import compile_poker_cnn, hebbian_readout_select, poker_neuron_params
from repro.core.event_engine import EventEngine
from repro.data.pipeline import symbol_dvs_events

SUITS = ["diamond(|)", "club(-)", "spade(^)", "heart(v)"]


def symbol_events(symbol: int, n_events: int, rng, jitter: float = 1.0) -> np.ndarray:
    """Synthetic DVS event cloud for one card flash (suit-specific edges)."""
    return symbol_dvs_events(symbol, n_events, rng, input_hw=32, jitter=jitter)


def pool_activity(cc, eng, event_streams, t_steps=40, drive=10.0):
    """Run DVS streams through the engine in ONE batched dispatch.

    ``event_streams``: list of B event arrays -> per-stream pool rates
    [B, 256] and output spikes [B, t_steps, 4, 64]. A single [n_ev, 2]
    array is treated as a batch of one and returned unbatched.
    """
    single = not isinstance(event_streams, (list, tuple))
    if single:
        event_streams = [event_streams]
    act = cc.input_activity_batch(event_streams) / t_steps * drive  # [B, nc, K]
    inp = jnp.broadcast_to(jnp.asarray(act)[None], (t_steps, *act.shape))
    _, spikes = eng.run(eng.init_state(batch=len(event_streams)), inp)
    s = np.asarray(spikes)  # [T, B, N]
    pool = s[:, :, cc.pool[0]: cc.pool[1]].sum(0)
    out = np.moveaxis(
        s[:, :, cc.out[0]: cc.out[1]].reshape(t_steps, len(event_streams), 4, -1), 1, 0
    )
    return (pool[0], out[0]) if single else (pool, out)


def main():
    from repro.core.cnn import CnnConfig

    rng = np.random.default_rng(7)
    params = poker_neuron_params()

    # ---- offline Hebbian readout tuning (paper §V): find the 64 pool
    # neurons most selective for each class, wire them to its population ----
    # compiler v2 (DESIGN.md §13): conflict-graph tag reuse — bit-exact vs
    # the greedy baseline, fewer tags whenever source sets repeat
    cc0 = compile_poker_cnn(allocator="reuse")
    eng0 = EventEngine(cc0.tables, params)
    print(f"Table-V network: {cc0.tables.n_neurons} neurons on {cc0.tables.n_clusters} cores")
    # all 4 classes x 3 presentations = 12 streams in ONE batched run
    streams = [symbol_events(sym, 400, rng) for sym in range(4) for _ in range(3)]
    pa, _ = pool_activity(cc0, eng0, streams)  # [12, 256]
    acts = pa.reshape(4, 3, -1).sum(1)  # [4, 256]
    fc_select = hebbian_readout_select(acts)
    print("Hebbian-selected pool neurons per class:",
          [int((fc_select[c] // 64 == c).sum()) for c in range(4)],
          "(from own feature map)")

    cc = compile_poker_cnn(
        CnnConfig(), fc_select=fc_select, allocator="reuse", with_report=True
    )
    eng = EventEngine(cc.tables, params)
    print("\ncompiler v2 report (Table-V CNN):")
    print("  " + cc.report.summary().replace("\n", "\n  "), "\n")

    # ---- evaluation on fresh event streams --------------------------------
    t_steps, trials = 40, 5
    correct, latencies = 0, []
    t0 = time.time()
    eval_rng = np.random.default_rng(1234)
    for trial in range(trials):
        # one batched dispatch per trial: the 4 suits are 4 concurrent streams
        _, outs = pool_activity(
            cc, eng, [symbol_events(sym, 400, eval_rng) for sym in range(4)], t_steps
        )
        for sym in range(4):
            out = outs[sym]  # [T, 4, 64]
            counts = out.sum((0, 2))
            pred = int(np.argmax(counts))
            correct += pred == sym
            cum = out.sum(2).cumsum(0)
            lead = np.nonzero((cum.argmax(1) == sym) & (cum.max(1) > 2))[0]
            latencies.append(int(lead[0]) + 1 if len(lead) else t_steps)
            if trial == 0:
                print(f"  {SUITS[sym]:12s} -> pred {SUITS[pred]:12s} counts={counts.astype(int)}")
    n = trials * 4
    print(f"\naccuracy: {correct}/{n} = {correct / n:.0%} (paper: 100% on the 4-suit task)")
    print(f"mean decision latency: {np.mean(latencies):.1f} sim-steps "
          f"(~{np.mean(latencies):.0f} ms at 1 ms/step; paper: <30 ms)")
    print(f"wall time: {time.time() - t0:.1f}s for {n} presentations")


if __name__ == "__main__":
    main()
