"""Async, atomic, reshardable checkpointing (no tensorstore dependency).

Layout per step::

    <dir>/step_<n>.tmp/           (written)
    <dir>/step_<n>/               (atomic rename on completion)
        manifest.json             tree structure + shapes/dtypes
        leaf_<i>.npy              one file per pytree leaf

Properties needed at 1000-node scale, implemented here at single-host scale
with the same interface:
* atomicity: a crash mid-write leaves only a .tmp dir — ``latest_step`` never
  sees it; restart resumes from the previous complete step.
* async: ``save`` snapshots to host memory and writes on a worker thread so
  the train loop is blocked only for the device->host copy.
* reshard-on-load: ``restore(..., shardings=...)`` device_puts each leaf with
  the *target* sharding — a checkpoint written on mesh A restores onto mesh B
  (elastic scaling; see distributed/elastic.py).
* retention: ``keep`` newest checkpoints are retained.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

_SEP = "/"

# numpy round-trips custom dtypes (bfloat16 etc.) as void — encode them as
# same-width unsigned views and record the true dtype in the manifest.
_CUSTOM_DTYPES = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode(a: np.ndarray) -> tuple[np.ndarray, str]:
    name = str(a.dtype)
    if name in _CUSTOM_DTYPES:
        return a.view(_CUSTOM_DTYPES[name][1]), name
    return a, name


def _decode(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _CUSTOM_DTYPES:
        return a.view(_CUSTOM_DTYPES[dtype_name][0])
    return a


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, jax.tree.structure(tree)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        # an async write's exception must not vanish with its daemon thread:
        # it is captured here and re-raised on the next wait()/save()
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()  # one outstanding save at a time; re-raises a failed one
        keys, leaves, _ = _flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for i, (k, a) in enumerate(zip(keys, host_leaves)):
                enc, dtype_name = _encode(a)
                np.save(os.path.join(tmp, f"leaf_{i}.npy"), enc)
                manifest["leaves"].append(
                    {"key": k, "file": f"leaf_{i}.npy", "dtype": dtype_name, "shape": list(a.shape)}
                )
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        def _write_guarded():
            # atomicity on failure too: the rename never ran, so only the
            # .tmp dir can exist — remove it so a half-written snapshot is
            # not even visible as debris
            try:
                _write()
            except BaseException as e:  # noqa: BLE001 — re-raised in wait()
                self._error = e
                shutil.rmtree(
                    os.path.join(self.dir, f"step_{step}.tmp"),
                    ignore_errors=True,
                )

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write_guarded, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join the outstanding async save; re-raise its failure, if any."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from e

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, like, shardings=None):
        """``like``: pytree prototype (structure only). ``shardings``: optional
        matching tree of jax.sharding.Sharding for reshard-on-load."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        keys, proto, treedef = _flatten(like)
        by_key = {e["key"]: e for e in manifest["leaves"]}
        arrays = []
        for k, p in zip(keys, proto):
            if k not in by_key:
                raise ValueError(
                    f"checkpoint step {step} has no leaf {k!r} — the saved "
                    "tree's structure differs from the restore prototype"
                )
            e = by_key[k]
            # a silent shape mismatch would splice another geometry's state
            # into the caller's tree; fixed-size prototypes must match
            # exactly (variable-length leaves opt out with a 0-size proto)
            want = tuple(np.shape(p))
            got = tuple(e["shape"])
            if want != got and np.size(p) != 0:
                raise ValueError(
                    f"checkpoint step {step} leaf {k!r} has shape {got}, "
                    f"restore prototype expects {want}"
                )
            arrays.append(_decode(np.load(os.path.join(path, e["file"])), e["dtype"]))
        tree = jax.tree.unflatten(treedef, arrays)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree
