"""Logical-axis -> PartitionSpec resolution.

Models annotate every parameter with logical axis names ("embed", "heads",
"mlp", "experts", ...). This module resolves those names against a concrete
mesh with a *priority + divisibility* policy: each logical name carries an
ordered list of candidate mesh axes; the resolver assigns the first candidate
whose size divides the dimension and whose mesh axes are still unused in that
tensor. Tensors whose preferred dim is not divisible fall back gracefully
(e.g. yi-34b's 56 heads on a 16-way model axis -> shard the embed dim
instead, row-parallel), so every architecture shards without special-casing.

Expert tensors prefer the widest mesh ("data"+"model" jointly = in-pod EP256
for deepseek-v3) and fall back to "model" only (EP16) — the pod axis never
carries expert shards, mirroring the paper's locality hierarchy (events
resolved inside a tile stay off the R3 mesh).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ordered candidates per logical axis name; each candidate is a mesh-axis
# name or a tuple of names (sharded over their product).
RULES: dict[str, tuple] = {
    "experts": (("data", "model"), "model", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "heads_flat": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "vocab_in": (),
    "inner": ("model",),
    "ssm_heads": ("model",),
    "embed": ("model",),  # used only as fallback via priority ordering
    "kv_lora": (),
    "q_lora": (),
    "head_dim": (),
    "embed_out": (),
}

# resolution priority: lower = claimed first
PRIORITY = {
    "experts": 0,
    "heads": 1,
    "kv_heads": 1,
    "heads_flat": 1,
    "mlp": 1,
    "vocab": 1,
    "inner": 1,
    "ssm_heads": 1,
    "embed": 5,
}

# activation / input logical axes
BATCH_AXES = ("pod", "data")
SEQ_AXES = ("data",)


def _axes_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        if a not in mesh.shape:
            return 0  # axis absent from this mesh -> candidate unusable
        size *= mesh.shape[a]
    return size


def _flat_axes(axes):
    return (axes,) if isinstance(axes, str) else tuple(axes)


def resolve(logical: tuple, shape: tuple, mesh: Mesh) -> P:
    """One tensor: logical axis names + concrete shape -> PartitionSpec."""
    assert len(logical) == len(shape), (logical, shape)
    assignment: list = [None] * len(logical)
    used: set[str] = set()
    order = sorted(
        range(len(logical)),
        key=lambda i: PRIORITY.get(logical[i] or "", 9),
    )
    total_elems = 1
    for d in shape:
        total_elems *= int(d)
    for i in order:
        name = logical[i]
        if name is None:
            continue
        if name == "embed" and total_elems < EMBED_FALLBACK_MIN_ELEMS:
            # replicating a small weight beats row-parallel all-reduces
            continue
        for cand in RULES.get(name, ()):
            size = _axes_size(mesh, cand)
            flat = _flat_axes(cand)
            if size > 1 and shape[i] % size == 0 and not (set(flat) & used):
                assignment[i] = cand
                used.update(flat)
                break
    return P(*assignment)


def tree_pspecs(spec_tree: Any, params_shape_tree: Any, mesh: Mesh, prefix_none: int = 0):
    """Resolve a whole spec tree against a shape tree (jax.eval_shape output).

    ``prefix_none`` prepends unsharded leading dims (the stacked-period axis).
    """

    def _one(spec, shaped):
        logical = (None,) * prefix_none + tuple(spec)
        return resolve(logical, shaped.shape, mesh)

    return jax.tree.map(_one, spec_tree, params_shape_tree, is_leaf=lambda x: isinstance(x, tuple))


def batch_pspec(global_batch: int, mesh: Mesh) -> P:
    """Shard the batch dim over as many of (pod, data) as divide it."""
    axes = [a for a in BATCH_AXES if a in mesh.shape]
    while axes and global_batch % int(np.prod([mesh.shape[a] for a in axes])) != 0:
        axes.pop(0)
    return P(tuple(axes) if axes else None)


def token_pspec(global_batch: int, seq: int, mesh: Mesh) -> P:
    bspec = batch_pspec(global_batch, mesh)
    b_axes = bspec[0]
    used = set(_flat_axes(b_axes)) if b_axes else set()
    seq_axes = [a for a in SEQ_AXES if a in mesh.shape and a not in used and seq % mesh.shape[a] == 0]
    return P(b_axes, seq_axes[0] if seq_axes else None)


def cache_pspec(shape: tuple, kind: tuple, mesh: Mesh) -> P:
    """KV-cache style tensors: kind names each dim from
    {"batch","seq","kv_heads","heads","head_dim","state",None}."""
    assignment: list = [None] * len(shape)
    used: set[str] = set()
    for i, (name, dim) in enumerate(zip(kind, shape)):
        if name == "batch":
            axes = [a for a in BATCH_AXES if a in mesh.shape and a not in used]
            while axes and dim % int(np.prod([mesh.shape[a] for a in axes])) != 0:
                axes.pop(0)
            if axes:
                assignment[i] = tuple(axes)
                used.update(axes)
        elif name == "seq":
            for a in SEQ_AXES:
                if a in mesh.shape and a not in used and dim % mesh.shape[a] == 0:
                    assignment[i] = a
                    used.add(a)
                    break
        elif name in ("kv_heads", "heads", "state"):
            if "model" not in used and "model" in mesh.shape and dim % mesh.shape["model"] == 0:
                assignment[i] = "model"
                used.add("model")
    return P(*assignment)


def named(mesh: Mesh, pspec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# activation sharding constraints (perf: pin layouts GSPMD would otherwise
# lose through scan/reshape chains — see EXPERIMENTS.md §Perf iteration A1)
# ---------------------------------------------------------------------------
import contextlib

_ACTIVE_MESH: list = [None]

# minimum tensor size (elements) for the row-parallel "embed" fallback; below
# this, replicating the weight beats per-matmul all-reduces (gemma3-1b/glm4
# small-head attention — §Perf iteration B1).
EMBED_FALLBACK_MIN_ELEMS = 2**25


@contextlib.contextmanager
def activation_mesh(mesh: Mesh):
    """Enable with-sharding-constraints on activations while tracing."""
    _ACTIVE_MESH.append(mesh)
    try:
        yield
    finally:
        _ACTIVE_MESH.pop()


def active_axis_size(name: str) -> int:
    mesh = _ACTIVE_MESH[-1]
    return int(mesh.shape.get(name, 0)) if mesh is not None else 0


def constrain(x, dims: tuple):
    """Pin ``x`` to a layout. ``dims`` entries: "batch" (pod+data), "seq"
    (data), "model" (heads/vocab/mlp dim), a mesh-axis tuple, or None.
    No-op without an active mesh; skips non-divisible/absent axes."""
    mesh = _ACTIVE_MESH[-1]
    if mesh is None:
        return x
    spec: list = []
    used: set[str] = set()
    for name, dim in zip(dims, x.shape):
        entry = None
        if name is None:
            spec.append(None)
            continue
        if name == "batch":
            axes = [a for a in BATCH_AXES if a in mesh.shape and a not in used]
            while axes and dim % int(np.prod([mesh.shape[a] for a in axes])) != 0:
                axes.pop(0)
            if axes:
                entry = tuple(axes) if len(axes) > 1 else axes[0]
        elif name == "seq":
            for a in SEQ_AXES:
                if a in mesh.shape and a not in used and dim % mesh.shape[a] == 0:
                    entry = a
                    break
        else:
            cands = (name,) if isinstance(name, str) else tuple(name)
            flat = tuple(c for c in cands)
            if all(a in mesh.shape for a in flat) and not (set(flat) & used):
                size = int(np.prod([mesh.shape[a] for a in flat]))
                if size > 1 and dim % size == 0:
                    entry = flat if len(flat) > 1 else flat[0]
        if entry is not None:
            used.update((entry,) if isinstance(entry, str) else entry)
        spec.append(entry)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
