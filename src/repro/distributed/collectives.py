"""Hierarchical collectives + gradient compression (DESIGN.md §3, §6).

The paper's R1/R2/R3 hierarchy concentrates local traffic so only a residue
crosses the expensive global fabric (Table IV: mean distance sqrt(N)/3 vs
2*sqrt(N)/3 flat). The TPU analogues implemented here (all shard_map-level,
operating on per-device local arrays):

* ``hierarchical_all_reduce``: reduce-scatter inside the pod (R1/R2, cheap
  ICI), all-reduce the 1/pod_size-sized shard across pods (R3, the only
  cross-pod bytes), all-gather locally. Cross-pod bytes drop by the in-pod
  size vs a flat all-reduce ring spanning pods.
* ``hierarchical_all_to_all``: two-stage a2a for multi-pod EP — concentrate
  per-destination-pod traffic inside the pod first, exchange pod-to-pod once.
* ``compress_int8`` / ``decompress_int8`` + ``ef_all_reduce``: int8 quantized
  cross-pod gradient exchange with error feedback (the residual of the
  quantization is fed back into the next step's gradient — standard deep
  gradient compression, applied ONLY to the R3 hop).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.shard_compat import axis_size


# ---------------------------------------------------------------------------
# hierarchical all-reduce (inside shard_map)
# ---------------------------------------------------------------------------
def hierarchical_all_reduce(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """psum over (inner, outer) with the cross-outer hop at 1/inner the bytes.

    Equivalent to ``jax.lax.psum(x, (inner_axis, outer_axis))`` — tests assert
    bit-equivalence (up to fp reduction order).
    """
    n_inner = axis_size(inner_axis)
    orig_shape = x.shape
    n = x.size
    flat = x.reshape(-1)
    pad = (-n) % n_inner
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # R1/R2: reduce-scatter inside the pod
    shard = jax.lax.psum_scatter(
        flat.reshape(n_inner, -1), inner_axis, scatter_dimension=0, tiled=False
    )
    # R3: only 1/n_inner of the bytes cross pods
    shard = jax.lax.psum(shard, outer_axis)
    # R1/R2: all-gather back
    full = jax.lax.all_gather(shard, inner_axis, axis=0, tiled=False).reshape(-1)
    return full[:n].reshape(orig_shape)


def flat_all_reduce(x: jax.Array, axes) -> jax.Array:
    return jax.lax.psum(x, axes)


# ---------------------------------------------------------------------------
# hierarchical all-to-all (two-stage: in-pod concentrate, cross-pod exchange)
# ---------------------------------------------------------------------------
def hierarchical_all_to_all(x: jax.Array, inner_axis: str, outer_axis: str) -> jax.Array:
    """x: [n_total, ...] with n_total = n_inner * n_outer destination slabs.

    Equivalent to all_to_all over (outer, inner) jointly with destination
    index d = outer * n_inner + inner. Stage A exchanges *within* the pod so
    that afterwards each device holds all the pod's traffic for its "column"
    of remote devices; stage B does one cross-pod exchange. The cross-pod hop
    then moves each byte exactly once (no multi-hop forwarding on the slow
    fabric) — the R3 XY-routing argument.
    """
    n_inner = axis_size(inner_axis)
    n_outer = axis_size(outer_axis)
    n_total = n_inner * n_outer
    assert x.shape[0] == n_total, (x.shape, n_total)
    rest = x.shape[1:]
    # view as [outer_dest, inner_dest, ...] -> concentrate inner_dest locally
    x = x.reshape(n_outer, n_inner, *rest)
    x = jnp.moveaxis(x, 1, 0)  # [inner_dest, outer_dest, ...]
    # stage A (R1/R2): in-pod exchange — afterwards rows are [src_inner, outer_dest]
    x = jax.lax.all_to_all(x, inner_axis, split_axis=0, concat_axis=0, tiled=False)
    # stage B (R3): one pod-to-pod exchange on the outer_dest dim
    x = jax.lax.all_to_all(x, outer_axis, split_axis=1, concat_axis=1, tiled=False)
    # [src_inner, src_outer, ...] -> linear source index (outer * inner + i)
    x = jnp.moveaxis(x, 1, 0)
    return x.reshape(n_total, *rest)


# ---------------------------------------------------------------------------
# int8 compression with error feedback (cross-pod hop only)
# ---------------------------------------------------------------------------
def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return q.astype(dtype) * scale.astype(dtype)


def ef_all_reduce(
    grad: jax.Array, error: jax.Array, outer_axis: str, inner_axis: str | None = None
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback compressed all-reduce across ``outer_axis``.

    grad is first (optionally) reduce-scattered in-pod at full precision;
    the cross-pod all-reduce runs on int8 with the quantization residual
    carried in ``error`` to the next step. Returns (averaged grad, new error).
    """
    n_outer = axis_size(outer_axis)
    x = grad + error
    q, scale = compress_int8(x)
    sent = decompress_int8(q, scale, x.dtype)
    new_error = x - sent
    # the wire carries int8 payload + one fp32 scale; the reduction itself
    # happens on the decompressed values (mean across pods).
    reduced = jax.lax.psum(sent, outer_axis) / n_outer
    return reduced, new_error


# ---------------------------------------------------------------------------
# byte accounting (used by benchmarks + EXPERIMENTS.md §Perf napkin math)
# ---------------------------------------------------------------------------
def all_reduce_cross_pod_bytes(
    n_bytes: int, n_pods: int, in_pod_size: int, hierarchical: bool
) -> float:
    """Bytes crossing the inter-pod cut for one all-reduce of ``n_bytes``.

    flat: a ring spanning all devices pushes every byte across the cut
    (2(P-1)/P factor); hierarchical: only the in-pod reduce-scattered shard
    (1/in_pod_size of the bytes) crosses — the paper's 'concentrate locally,
    few long-range connections' scaling.
    """
    if n_pods <= 1:
        return 0.0
    ring = 2 * (n_pods - 1) / n_pods
    if hierarchical:
        return n_bytes / in_pod_size * ring
    return n_bytes * ring
