"""Elastic scaling: reshard a training state onto a different mesh.

Scenario (DESIGN.md §6): a pod is lost mid-run. The supervisor restarts on
the surviving mesh — e.g. (2,16,16) -> (1,16,16) — restores the latest
checkpoint with ``Checkpointer.restore(shardings=remesh(...))`` and continues
with the data-parallel degree halved (global batch either halved or held via
2x microbatching; the deterministic pipeline keys batches by step, so the
token stream stays consistent).

``remesh_pspecs`` re-resolves every parameter's logical axes against the new
mesh — because resolution is pure (priority + divisibility), the same params
land on valid shardings for any mesh shape.

The same machinery serves the event-serving fleet (DESIGN.md §17): a
:class:`~repro.serve.sharded.ShardedSessionPool` restoring after a shard
loss lands each surviving shard's checkpointed engine carry on its own mesh
with :func:`reshard_tree` under the engine's ``carry_pspecs()``.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as shd

__all__ = ["remesh_pspecs", "reshard_state", "reshard_tree"]


def reshard_tree(tree, pspec_tree, new_mesh: Mesh):
    """device_put every leaf of ``tree`` onto ``new_mesh`` under the matching
    :class:`~jax.sharding.PartitionSpec` of ``pspec_tree``.

    The generic core of :func:`reshard_state`, shared with the serving
    fleet: a checkpoint written under mesh A (or host memory) lands sharded
    on mesh B without shape changes — elasticity is a placement move, never
    a value move.
    """

    def put(x, spec):
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    return jax.tree.map(put, tree, pspec_tree)


def remesh_pspecs(model, params_shapes, new_mesh: Mesh):
    """Resolve the model's param spec tree against a new mesh."""
    spec_tree = model.param_specs()
    stack_specs = spec_tree["stack"]

    def build(tree, shapes, prefix_none=0):
        return shd.tree_pspecs(tree, shapes, new_mesh, prefix_none=prefix_none)

    out = {}
    for k, sub in spec_tree.items():
        if k == "stack":
            sub_out = {}
            for name, blk in sub.items():
                pn = 1 if name == "periods" else 0
                sub_out[name] = build(blk, params_shapes["stack"][name], prefix_none=pn)
            out[k] = sub_out
        else:
            out[k] = build(sub, params_shapes[k])
    return out


def reshard_state(state, pspec_tree_params, new_mesh: Mesh):
    """device_put an in-memory state onto the new mesh (for live migration;
    checkpoint-restore covers the crash path)."""
    params = reshard_tree(state["params"], pspec_tree_params, new_mesh)
    # optimizer moments follow their parameter's sharding; scalars replicate
    def put_like(x):
        return jax.device_put(x)

    opt = jax.tree.map(put_like, state["opt"])
    return {"params": params, "opt": opt}
