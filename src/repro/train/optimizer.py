"""AdamW with sharded/quantized state — no optax dependency.

Features used at scale (DESIGN.md §6):
* moment dtype: fp32 (default), bf16, or blockwise-int8 ("q8") — the q8
  path stores m/v as int8 with one fp32 scale per 256-element block (the
  8-bit-Adam trick), cutting optimizer HBM 4x for the deepseek-v3 cell.
* ZeRO-1: moments get an *additional* sharding over spare mesh axes via
  with_sharding_constraint (see zero1_pspecs in launch/dryrun.py).
* global-norm clipping, linear-warmup + cosine schedule, decoupled weight
  decay (skipped for norms/bias via dimensionality: decay only ndim >= 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Q_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"  # "float32" | "bfloat16" | "q8"


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


# ---------------------------------------------------------------------------
# blockwise int8 moment codec
# ---------------------------------------------------------------------------
def _q8_encode(x: jax.Array) -> dict:
    """Blockwise int8 over the LAST axis only — leading dims (and their
    shardings: experts/heads/mlp) are preserved, so quantized moments shard
    exactly like their parameters (no GSPMD resharding in the update)."""
    x = x.astype(jnp.float32)
    last = x.shape[-1] if x.ndim else 1
    block = min(Q_BLOCK, last) if last else 1
    pad = (-last) % block
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = x.reshape(*x.shape[:-1], -1, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-20
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _q8_decode(enc: dict, shape, dtype=jnp.float32) -> jax.Array:
    x = (enc["q"].astype(jnp.float32) * enc["scale"])
    x = x.reshape(*x.shape[:-2], -1)  # merge (blocks, block)
    last = shape[-1] if shape else 1
    return x[..., :last].reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# state init / update
# ---------------------------------------------------------------------------
def _zeros_like_state(p: jax.Array, cfg: OptConfig):
    if cfg.state_dtype == "q8":
        last = p.shape[-1] if p.ndim else 1
        block = min(Q_BLOCK, last) if last else 1
        nblocks = max(1, (last + block - 1) // block)
        lead = p.shape[:-1] if p.ndim else ()
        return {
            "q": jnp.zeros((*lead, nblocks, block), jnp.int8),
            "scale": jnp.zeros((*lead, nblocks, 1), jnp.float32),
        }
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def init_opt_state(params: Any, cfg: OptConfig) -> dict:
    return {
        "m": jax.tree.map(lambda p: _zeros_like_state(p, cfg), params),
        "v": jax.tree.map(lambda p: _zeros_like_state(p, cfg), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: OptConfig
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))

    is_q8 = cfg.state_dtype == "q8"
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _q8_decode(m, p.shape) if is_q8 else m.astype(jnp.float32)
        v_f = _q8_decode(v, p.shape) if is_q8 else v.astype(jnp.float32)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd_ = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        if p.ndim >= 2:
            upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        m_new = _q8_encode(m_f) if is_q8 else m_f.astype(m.dtype)
        v_new = _q8_encode(v_f) if is_q8 else v_f.astype(v.dtype)
        return p_new, m_new, v_new

    is_leaf = (lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}) if is_q8 else None
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_leaf)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_leaf)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
