"""Train step factory: loss + grad + clip + AdamW + MoE bias update.

``make_train_step(model, opt_cfg)`` builds the pure function lowered by the
dry-run and jitted by the training driver. Supports microbatch gradient
accumulation (scan over microbatches — the compute/comm overlap unit) and the
deepseek-v3 aux-free router-bias update (applied outside the gradient).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

TrainState = dict  # {"params": ..., "opt": ..., }


def init_train_state(model, key, opt_cfg: OptConfig) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def _update_router_bias(params: Any, aux: dict, u: float = 1e-3) -> Any:
    """deepseek-v3 bias-based load balancing: b_e += u * sign(mean - load_e).

    Uses the per-period load stack so every scanned MoE layer gets its own
    correction. Applied to params['stack']['periods'][bX]['ffn']['router_bias']
    (the only router_bias tensors with a leading period dim).
    """
    if "moe_load_periods" not in aux:
        return params
    load = aux["moe_load_periods"]  # [n_periods, E]
    delta = u * jnp.sign(load.mean(-1, keepdims=True) - load)

    def walk(node, in_periods=False):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "router_bias" and in_periods and v.ndim == 2:
                    out[k] = v + delta.astype(v.dtype)
                else:
                    out[k] = walk(v, in_periods or k == "periods")
            return out
        return node

    return walk(params)


def make_train_step(model, opt_cfg: OptConfig, microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        loss, aux = model.loss(params, batch)
        return loss, aux

    def train_step(state: TrainState, batch: dict):
        params = state["params"]
        if microbatches == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc, aux_acc = carry
                (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                aux_acc = {
                    k: aux_acc.get(k, 0.0) + v
                    for k, v in aux.items()
                    if isinstance(v, jax.Array)
                }
                return (g_acc, l_acc + l, aux_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = _python_accum(acc_step, g0, micro, microbatches)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        new_params, new_opt, metrics = adamw_update(grads, state["opt"], params, opt_cfg)
        new_params = _update_router_bias(new_params, aux)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def _python_accum(acc_step, g0, micro, n):
    """Unrolled accumulation (microbatch trees may be ragged pytrees)."""
    carry = (g0, jnp.zeros(()), {})
    for i in range(n):
        mb = jax.tree.map(lambda x: x[i], micro)
        carry, _ = acc_step(carry, mb)
    return carry, None
