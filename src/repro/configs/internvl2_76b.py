"""internvl2-76b [vlm] — 80L d8192 64H (GQA kv=8) ff28672 v128256.

Llama-3-70B-style language backbone; InternViT frontend is a STUB per the
assignment (``input_specs`` provides 256 precomputed patch embeddings that
overwrite the first token positions). [arXiv:2404.16821; unverified]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab=128256,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=80,
        rope_theta=500000.0,
        frontend="vision_stub",
        n_prefix_embeddings=256,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b-smoke",
        family="vlm",
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab=512,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=3,
        frontend="vision_stub",
        n_prefix_embeddings=4,
        tie_embeddings=False,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
