"""deepseek-v3-671b [moe] — 61L d7168, MLA 128H, 1 shared + 256 routed top-8.

First 3 layers dense (ff18432), remaining 58 MoE (per-expert ff2048),
v129280, MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128), MTP depth
1, aux-free sigmoid router. [arXiv:2412.19437; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,
        vocab=129280,
        prefix_layers=(BlockSpec(kind="mla", ffn="dense"),) * 3,
        period=(BlockSpec(kind="mla", ffn="moe"),),
        n_periods=58,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        n_experts=256,
        n_shared_experts=1,
        top_k=8,
        moe_d_ff=2048,
        router_aux_free=True,
        mtp_depth=1,
        rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b-smoke",
        family="moe",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        prefix_layers=(BlockSpec(kind="mla", ffn="dense"),),
        period=(BlockSpec(kind="mla", ffn="moe"),),
        n_periods=2,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        n_experts=8,
        n_shared_experts=1,
        top_k=2,
        moe_d_ff=32,
        capacity_factor=4.0,
        router_aux_free=True,
        mtp_depth=1,
        tie_embeddings=False,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
