"""Architecture registry: ``get_config(arch, smoke=False)`` + shape cells.

The 10 assigned architectures plus the paper's own DYNAPs CNN (core/cnn.py
owns that config). Shapes are the per-arch input-shape set from the
assignment; ``cells()`` enumerates the 40 (arch x shape) dry-run cells with
their applicability flags (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import BlockSpec, ModelConfig

ARCHS = {
    "gemma2-27b": "repro.configs.gemma2_27b",
    "glm4-9b": "repro.configs.glm4_9b",
    "yi-34b": "repro.configs.yi_34b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "whisper-base": "repro.configs.whisper_base",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "internvl2-76b": "repro.configs.internvl2_76b",
}


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = (
    Shape("train_4k", 4096, 256, "train"),
    Shape("prefill_32k", 32768, 32, "prefill"),
    Shape("decode_32k", 32768, 128, "decode"),
    Shape("long_500k", 524288, 1, "decode"),
)

# archs allowed to run long_500k (sub-quadratic families; DESIGN.md §5)
LONG_OK = {"zamba2-2.7b", "rwkv6-3b"}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(ARCHS[arch])
    return mod.smoke() if smoke else mod.config()


def cells():
    """All 40 (arch, shape, runnable, skip_reason) dry-run cells."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES:
            skip = None
            if shape.name == "long_500k" and arch not in LONG_OK:
                skip = "full-attention family: long_500k skipped per shape rules"
            out.append((arch, shape, skip is None, skip))
    return out


__all__ = ["ARCHS", "SHAPES", "LONG_OK", "Shape", "ModelConfig", "BlockSpec", "get_config", "cells"]
