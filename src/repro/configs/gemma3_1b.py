"""gemma3-1b [dense] — 26L d1152 4H (GQA kv=1) ff6912 v262144.

5:1 local(512):global pattern, 128k context, qk-norm, head_dim 256.
[hf:google/gemma-3-1b-pt; unverified]
"""

from repro.configs.base import BlockSpec, ModelConfig

_LOCAL = BlockSpec(kind="attn", window=512, ffn="dense")
_GLOBAL = BlockSpec(kind="attn", window=None, ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        head_dim=256,
        d_ff=6912,
        vocab=262144,
        period=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        n_periods=4,
        remainder=(_LOCAL, _LOCAL),
        qk_norm=True,
        post_block_norm=True,
        scale_embeddings=True,
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b-smoke",
        family="dense",
        d_model=48,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=96,
        vocab=512,
        period=(
            BlockSpec(kind="attn", window=8, ffn="dense"),
            BlockSpec(kind="attn", window=8, ffn="dense"),
            BlockSpec(kind="attn", window=None, ffn="dense"),
        ),
        n_periods=2,
        remainder=(BlockSpec(kind="attn", window=8, ffn="dense"),),
        qk_norm=True,
        post_block_norm=True,
        scale_embeddings=True,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
