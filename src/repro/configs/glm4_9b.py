"""glm4-9b [dense] — 40L d4096 32H (GQA kv=2) ff13696 v151552. RoPE, GQA.

[hf:THUDM/glm-4-9b; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b",
        family="dense",
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab=151552,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=40,
        rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b-smoke",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab=512,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=3,
        tie_embeddings=False,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
