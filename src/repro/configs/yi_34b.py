"""yi-34b [dense] — 60L d7168 56H (GQA kv=8) ff20480 v64000. llama-arch GQA.

[arXiv:2403.04652; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b",
        family="dense",
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=20480,
        vocab=64000,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=60,
        rope_theta=5_000_000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="yi-34b-smoke",
        family="dense",
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        head_dim=8,
        d_ff=128,
        vocab=512,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=3,
        tie_embeddings=False,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
