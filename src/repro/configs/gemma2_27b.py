"""gemma2-27b [dense] — 46L d4608 32H (GQA kv=16) ff36864 v256000.

Local(4096):global alternating, attn softcap 50, final softcap 30, post-block
norms, embedding scaling. [arXiv:2408.00118; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig

_LOCAL = BlockSpec(kind="attn", window=4096, ffn="dense")
_GLOBAL = BlockSpec(kind="attn", window=None, ffn="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        d_model=4608,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab=256000,
        period=(_LOCAL, _GLOBAL),
        n_periods=23,
        attn_softcap=50.0,
        final_softcap=30.0,
        attn_scale=(4608 / 32) ** -0.5,  # query scaled by d_model/n_heads
        post_block_norm=True,
        scale_embeddings=True,
        rope_theta=10000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke",
        family="dense",
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab=512,
        period=(
            BlockSpec(kind="attn", window=8, ffn="dense"),
            BlockSpec(kind="attn", window=None, ffn="dense"),
        ),
        n_periods=2,
        attn_softcap=50.0,
        final_softcap=30.0,
        post_block_norm=True,
        scale_embeddings=True,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
