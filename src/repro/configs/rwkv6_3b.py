"""rwkv6-3b [ssm] — Finch: 32L d2560 (attn-free) ff8960 v65536.

Data-dependent decay linear attention; channel-mix realized as the gated MLP
(deviation from the relu^2 channel-mix noted in DESIGN.md).
[arXiv:2404.05892; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        head_dim=64,
        d_ff=8960,
        vocab=65536,
        period=(BlockSpec(kind="rwkv6", ffn="dense"),),
        n_periods=32,
        rwkv_lora_w=64,
        rwkv_lora_mix=32,
        ssm_chunk=64,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        family="ssm",
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        head_dim=12,
        d_ff=96,
        vocab=512,
        period=(BlockSpec(kind="rwkv6", ffn="dense"),),
        n_periods=2,
        rwkv_lora_w=8,
        rwkv_lora_mix=4,
        ssm_chunk=8,
        tie_embeddings=False,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
