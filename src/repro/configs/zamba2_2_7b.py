"""zamba2-2.7b [hybrid] — 54 Mamba2 layers d2560 + shared attention block.

32H (kv=32, head_dim 80) shared transformer block applied every 6 Mamba2
blocks with a single parameter set; ff10240 in the shared block; v32000;
ssm_state=64. [arXiv:2411.15242; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig

_M = BlockSpec(kind="mamba2", ffn="none")
_SHARED = BlockSpec(kind="attn", ffn="dense", shared=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab=32000,
        period=(_M, _M, _M, _M, _M, _M, _SHARED),
        n_periods=9,
        ssm_state=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_heads=80,  # d_inner 5120 / 64
        ssm_chunk=128,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b-smoke",
        family="hybrid",
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        head_dim=12,
        d_ff=96,
        vocab=512,
        period=(
            BlockSpec(kind="mamba2", ffn="none"),
            BlockSpec(kind="mamba2", ffn="none"),
            BlockSpec(kind="attn", ffn="dense", shared=True),
        ),
        n_periods=2,
        ssm_state=8,
        ssm_expand=2,
        ssm_heads=4,
        ssm_chunk=8,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
