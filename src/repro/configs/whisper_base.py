"""whisper-base [audio] — 6L enc + 6L dec, d512 8H ff2048 v51865.

Enc-dec; the conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed 1500-frame embeddings. [arXiv:2212.04356; unverified]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="audio",
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=6,
        n_enc_layers=6,
        enc_seq=1500,
        frontend="audio_stub",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-base-smoke",
        family="audio",
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        head_dim=12,
        d_ff=96,
        vocab=512,
        period=(BlockSpec(kind="attn", ffn="dense"),),
        n_periods=2,
        n_enc_layers=2,
        enc_seq=24,
        frontend="audio_stub",
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
