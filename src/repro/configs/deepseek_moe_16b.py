"""deepseek-moe-16b [moe] — 28L d2048 16H (kv=16), 2 shared + 64 routed top-6.

Fine-grained experts (ff1408 each), first layer dense (ff10944), v102400,
softmax router with aux loss. [arXiv:2401.06066; hf]
"""

from repro.configs.base import BlockSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10944,
        vocab=102400,
        prefix_layers=(BlockSpec(kind="attn", ffn="dense"),),
        period=(BlockSpec(kind="attn", ffn="moe"),),
        n_periods=27,
        n_experts=64,
        n_shared_experts=2,
        top_k=6,
        moe_d_ff=1408,
        router_aux_free=False,
        rope_theta=10000.0,
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        prefix_layers=(BlockSpec(kind="attn", ffn="dense"),),
        period=(BlockSpec(kind="attn", ffn="moe"),),
        n_periods=2,
        n_experts=8,
        n_shared_experts=2,
        top_k=2,
        moe_d_ff=32,
        capacity_factor=4.0,
        router_aux_free=False,
        tie_embeddings=False,
        remat="none",
        param_dtype="float32",
        compute_dtype="float32",
    )
