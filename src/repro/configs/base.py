"""Model / run configuration schema.

One ``ModelConfig`` describes every architecture in the assigned pool; the
block pattern is expressed as a repeating *period* of block descriptors so
heterogeneous stacks (local:global attention, hybrid Mamba+shared-attention)
compile as a single ``lax.scan`` over periods (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

BlockKind = Literal["attn", "mla", "mamba2", "rwkv6"]


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One block of the repeating period."""

    kind: BlockKind = "attn"
    window: int | None = None  # sliding-window size; None = global attention
    ffn: Literal["dense", "moe", "none"] = "dense"
    shared: bool = False  # zamba2: block re-uses the single shared param set


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"] = "dense"

    # -- dimensions -------------------------------------------------------
    d_model: int = 1024
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    d_ff: int = 4096
    vocab: int = 32000

    # -- stack ------------------------------------------------------------
    period: tuple[BlockSpec, ...] = (BlockSpec(),)
    n_periods: int = 12
    remainder: tuple[BlockSpec, ...] = ()  # extra blocks after the scan
    prefix_layers: tuple[BlockSpec, ...] = ()  # blocks before the scan (dsv3 dense-first)

    # -- attention --------------------------------------------------------
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float | None = None  # gemma2: 50.0
    final_softcap: float | None = None  # gemma2: 30.0
    attn_scale: float | None = None  # override 1/sqrt(head_dim) (gemma2 uses d/ n_heads)

    # -- MLA (deepseek) ----------------------------------------------------
    q_lora_rank: int = 0  # 0 = no q compression
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0  # per-expert hidden
    capacity_factor: float = 1.25
    router_aux_free: bool = True  # deepseek-v3 bias-based load balancing
    moe_two_stage: bool = True  # use the paper's two-stage tag dispatch

    # -- SSM (mamba2) -------------------------------------------------------
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0  # 0 -> d_inner / 64
    ssm_chunk: int = 128

    # -- rwkv6 ---------------------------------------------------------------
    rwkv_lora_w: int = 64  # decay lora rank
    rwkv_lora_mix: int = 32

    # -- encoder-decoder (whisper) -------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 1500  # stub frame-embedding count

    # -- modality frontend stub ----------------------------------------------
    frontend: Literal["none", "audio_stub", "vision_stub"] = "none"
    n_prefix_embeddings: int = 0  # vlm: vision tokens prepended (stubbed)

    # -- embeddings / norm -----------------------------------------------------
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma: x *= sqrt(d_model)
    norm_eps: float = 1e-6
    post_block_norm: bool = False  # gemma2/3: extra norms after attn/ffn

    # -- MTP (deepseek-v3) -------------------------------------------------------
    mtp_depth: int = 0

    # -- numerics / training ------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: Literal["none", "dots", "full"] = "full"

    @property
    def n_layers(self) -> int:
        return (
            len(self.prefix_layers)
            + self.n_periods * len(self.period)
            + len(self.remainder)
        )

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.ssm_heads or self.d_inner // 64

    def param_count(self) -> tuple[int, int]:
        """(total, active) parameter estimate — used for MODEL_FLOPS."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb

        def block_params(b: BlockSpec) -> tuple[int, int]:
            t = a = 0
            if b.kind == "attn":
                qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
                o = self.n_heads * self.head_dim * d
                t = a = qkv + o
            elif b.kind == "mla":
                t = d * self.kv_lora_rank + d * self.qk_rope_dim
                q_in = self.q_lora_rank or d
                if self.q_lora_rank:
                    t += d * self.q_lora_rank
                t += q_in * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                t += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                t += self.n_heads * self.v_head_dim * d
                a = t
            elif b.kind == "mamba2":
                di = self.d_inner
                t = d * (2 * di + 2 * self.ssm_state + self.n_ssm_heads) + di * d
                a = t
            elif b.kind == "rwkv6":
                t = d * d * 4 + d * (self.rwkv_lora_w + self.rwkv_lora_mix) * 2
                a = t
            if b.ffn == "dense":
                f = 3 * d * self.d_ff
                t += f
                a += f
            elif b.ffn == "moe":
                fe = 3 * d * self.moe_d_ff
                t += self.n_experts * fe + self.n_shared_experts * fe + d * self.n_experts
                a += (self.top_k + self.n_shared_experts) * fe + d * self.n_experts
            return t, a

        blocks = (
            list(self.prefix_layers)
            + list(self.period) * self.n_periods
            + list(self.remainder)
        )
        seen_shared = False
        for b in blocks:
            t, a = block_params(b)
            if b.shared:  # one param set, many applications
                if not seen_shared:
                    total += t
                    seen_shared = True
                active += a  # compute happens on every application
            else:
                total += t
                active += a
        # encoder stack (whisper): same attn+ffn blocks without KV grouping
        if self.n_enc_layers:
            qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim
            o = self.n_heads * self.head_dim * d
            f = 3 * d * self.d_ff
            cross = qkv + o
            total += self.n_enc_layers * (qkv + o + f) + self.n_layers * cross
            active += self.n_enc_layers * (qkv + o + f) + self.n_layers * cross
        return int(total), int(active)
