"""Generic two-stage tag dispatch in JAX (the paper's §II scheme, executable).

Stage 1 (point-to-point, "R1-SRAM -> fabric"): every active source emits its
stage-1 entries ``(tag, dest_cluster)``; all events are accumulated into a
tag-activity matrix ``A[n_clusters, K]`` — entry ``A[c, t]`` is the summed
event weight arriving at cluster ``c`` under tag ``t`` this step. On hardware
this is the SRAM memory-address loop + mesh routing; on TPU it is a
scatter-add, and across devices a reduce-scatter over the cluster axis
(each device owns a contiguous slab of clusters = "cores").

Stage 2 (broadcast + CAM match, "R1 -> core"): each cluster broadcasts its
activity row to all member neurons; every CAM word that matches contributes
its event weight to the synapse-type accumulator of its neuron. This is the
compute hot-spot and has a Pallas kernel (kernels/cam_match); the functions
here are the pure-jnp implementations used as reference and CPU fallback.

Both stages are **batch-native** (DESIGN.md §9): ``spikes`` may carry any
leading batch shape ``[..., N]`` (many concurrent event streams / network
instances over shared routing tables), producing ``A[..., n_clusters, K]``
and drive ``[..., N, 4]``. The batch dimension is carried through a single
scatter / gather, not an outer ``vmap``, so backends can tile it natively.

The same two functions implement MoE dispatch in models/moe.py:
clusters = expert groups, tags = expert ids, CAM subscription = expert
residency. See DESIGN.md §3.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["stage1_route", "stage2_cam_match", "two_stage_deliver", "N_SYN_TYPES"]

N_SYN_TYPES = 4  # fast-exc, slow-exc, subtractive-inh, shunting-inh


def stage1_route(
    spikes: jax.Array,  # [..., N] float event weights (0/1 spikes or rates)
    src_tag: jax.Array,  # [N, E] int32, -1 = empty
    src_dest: jax.Array,  # [N, E] int32 cluster ids
    n_clusters: int,
    k_tags: int,
) -> jax.Array:
    """Scatter stage-1 events into the tag-activity matrix ``A[..., n_clusters, K]``.

    The routing tables are shared across the batch (one compiled network,
    many event streams); each batch element scatters into its own slab of a
    single flat accumulator, so the whole batch is one scatter-add.
    """
    valid = src_tag >= 0
    size = n_clusters * k_tags
    # flat index into A; invalid entries are routed to a sentinel slot.
    flat = jnp.where(valid, src_dest * k_tags + src_tag, size)  # [N, E]
    weights = spikes[..., None] * valid.astype(spikes.dtype)  # [..., N, E]
    batch_shape = spikes.shape[:-1]
    if not batch_shape:
        a = jnp.zeros((size,), dtype=spikes.dtype)
        a = a.at[flat.reshape(-1)].add(weights.reshape(-1), mode="drop")
        return a.reshape(n_clusters, k_tags)
    b = math.prod(batch_shape)
    # per-batch slab of width size+1: slot ``size`` absorbs invalid entries.
    offsets = jnp.arange(b, dtype=flat.dtype)[:, None] * (size + 1)
    flat_b = flat.reshape(1, -1) + offsets  # [B, N*E]
    a = jnp.zeros((b * (size + 1),), dtype=spikes.dtype)
    a = a.at[flat_b.reshape(-1)].add(weights.reshape(b, -1).reshape(-1), mode="drop")
    a = a.reshape(b, size + 1)[:, :size]
    return a.reshape(*batch_shape, n_clusters, k_tags)


def stage2_cam_match(
    activity: jax.Array,  # [..., n_clusters, K]
    cam_tag: jax.Array,  # [N, S] int32, -1 = empty
    cam_syn: jax.Array,  # [N, S] int32 in [0, N_SYN_TYPES)
    cluster_size: int,
) -> jax.Array:
    """Broadcast + CAM match: returns synaptic drive ``I[..., N, N_SYN_TYPES]``.

    Pure-jnp reference; the Pallas kernel in kernels/cam_match computes the
    same quantity blocked over (batch, cluster, neuron-tile) with the
    activity row pinned in VMEM.
    """
    n, s = cam_tag.shape
    n_clusters, k = activity.shape[-2:]
    batch_shape = activity.shape[:-2]
    assert n == n_clusters * cluster_size, (n, n_clusters, cluster_size)
    # [n_clusters, C, S] view of the CAM; gather each cluster's activity row.
    tags = cam_tag.reshape(n_clusters, cluster_size, s)
    valid = tags >= 0
    idx = jnp.clip(tags, 0, k - 1)
    rows = jnp.broadcast_to(
        activity[..., :, None, :], (*batch_shape, n_clusters, cluster_size, k)
    )
    vals = jnp.take_along_axis(
        rows, jnp.broadcast_to(idx, (*batch_shape, n_clusters, cluster_size, s)), axis=-1
    )
    vals = jnp.where(valid, vals, jnp.zeros((), activity.dtype))  # [..., nc, C, S]
    syn = cam_syn.reshape(n_clusters, cluster_size, s)
    onehot = jax.nn.one_hot(syn, N_SYN_TYPES, dtype=vals.dtype)  # [nc, C, S, T]
    out = jnp.einsum("...ncs,ncst->...nct", vals, onehot)
    return out.reshape(*batch_shape, n, N_SYN_TYPES)


def two_stage_deliver(
    spikes: jax.Array,
    src_tag: jax.Array,
    src_dest: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    k_tags: int,
    external_activity: jax.Array | None = None,
    backend: str | object = "reference",
) -> jax.Array:
    """Full event delivery: spikes -> synaptic drive per neuron & synapse type.

    ``external_activity`` injects input events (the chip's Input Interface /
    FPGA path) directly as tag activity. ``backend`` selects the dispatch
    implementation by name or instance (core/dispatch.py registry); it
    replaces the old ``use_kernel`` bool.
    """
    from repro.core.dispatch import get_backend

    return get_backend(backend).deliver(
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        external_activity=external_activity,
    )
