"""Generic two-stage tag dispatch in JAX (the paper's §II scheme, executable).

Stage 1 (point-to-point, "R1-SRAM -> fabric"): every active source emits its
stage-1 entries ``(tag, dest_cluster)``; all events are accumulated into a
tag-activity matrix ``A[n_clusters, K]`` — entry ``A[c, t]`` is the summed
event weight arriving at cluster ``c`` under tag ``t`` this step. On hardware
this is the SRAM memory-address loop + mesh routing; on TPU it is a
scatter-add, and across devices a reduce-scatter over the cluster axis
(each device owns a contiguous slab of clusters = "cores").

Stage 2 (broadcast + CAM match, "R1 -> core"): each cluster broadcasts its
activity row to all member neurons; every CAM word that matches contributes
its event weight to the synapse-type accumulator of its neuron. This is the
compute hot-spot and has Pallas kernels (kernels/cam_match and the fused
kernels/fused_deliver); the functions here are the pure-jnp implementations
used as reference and CPU fallback.

Both stages are **batch-native** (DESIGN.md §9): ``spikes`` may carry any
leading batch shape ``[..., N]`` (many concurrent event streams / network
instances over shared routing tables), producing ``A[..., n_clusters, K]``
and drive ``[..., N, 4]``.

**Event-sparse delivery** (DESIGN.md §10): the fabric carries *events*, not
dense activity — on the chip only neurons that spiked occupy the AER bus.
:func:`compact_events` models the core's output FIFO: active sources are
compacted (in arbiter scan order) into a fixed-capacity ``(src, weight)``
queue with an overflow/drop counter matching the chip's congestion
behavior. :func:`stage1_route_events` then scatters only the queued events'
SRAM entries, so stage-1 cost scales with event count, not network size.

**Fabric-mode stage 1** (DESIGN.md §11): :func:`stage1_route_events_fabric`
bins the queued events by (source, destination) tile pair, arbitrates each
directed inter-tile link's bandwidth FIFO (via :func:`dispatch_slots`, bins =
tile pairs), and scatters survivors into a delay-indexed buffer so cross-tile
events arrive hop-latency steps later.

The same functions implement MoE dispatch in models/moe.py:
clusters = expert groups, tags = expert ids, CAM subscription = expert
residency; :func:`dispatch_slots` is the shared sort-based slot assignment.
See DESIGN.md §3.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "stage1_route",
    "stage2_cam_match",
    "two_stage_deliver",
    "compact_events",
    "stage1_route_events",
    "stage1_route_events_fabric",
    "FabricRouteResult",
    "gather_event_entries",
    "precompute_syn_onehot",
    "dispatch_slots",
    "EventQueue",
    "N_SYN_TYPES",
]

N_SYN_TYPES = 4  # fast-exc, slow-exc, subtractive-inh, shunting-inh

_INT32_MAX = jnp.iinfo(jnp.int32).max


# ---------------------------------------------------------------------------
# AER event queue (the core's output FIFO)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class EventQueue:
    """Fixed-capacity compaction of one step's active sources.

    ``src[..., Q]`` holds source neuron ids in arbiter scan order (lowest id
    first — the chip's priority encoder), ``-1`` marks empty slots past the
    last event. ``weight`` is the event weight (``spikes[src]``); ``dropped``
    counts events that did not fit (the FIFO-overflow / congestion counter).
    """

    src: jax.Array  # [..., Q] int32, -1 = empty
    weight: jax.Array  # [..., Q]
    dropped: jax.Array  # [...] int32


jax.tree_util.register_dataclass(
    EventQueue, data_fields=["src", "weight", "dropped"], meta_fields=[]
)


def compact_events(spikes: jax.Array, capacity: int) -> EventQueue:
    """Compact active spikes into a fixed-capacity AER queue (jit-able).

    The hardware analogue is the core's arbitrated output FIFO: sources are
    scanned in id order and the first ``capacity`` active ones win the bus;
    the rest are dropped and counted. Queue slot ``s`` holds the (s+1)-th
    active source — a binary search of ``s+1`` in the running active count,
    so compaction is one cumsum + Q binary searches per stream (no sort, no
    scatter; ~5-10x cheaper than a ``top_k`` formulation on CPU).
    """
    n = spikes.shape[-1]
    q = min(int(capacity), n)
    if q <= 0:
        raise ValueError(f"queue capacity must be positive, got {capacity}")
    batch_shape = spikes.shape[:-1]
    active = spikes != 0
    pos = jnp.cumsum(active, axis=-1, dtype=jnp.int32)  # running active count
    targets = jnp.arange(1, q + 1, dtype=jnp.int32)
    src = jax.vmap(lambda p: jnp.searchsorted(p, targets, side="left"))(
        pos.reshape(-1, n)
    ).reshape(*batch_shape, q)
    kept = src < n  # slot beyond the last active source -> empty
    src = jnp.where(kept, src, -1).astype(jnp.int32)
    weight = jnp.where(
        kept,
        jnp.take_along_axis(spikes, jnp.clip(src, 0), axis=-1),
        jnp.zeros((), spikes.dtype),
    )
    n_active = active.sum(axis=-1, dtype=jnp.int32)
    dropped = n_active - kept.sum(axis=-1, dtype=jnp.int32)
    return EventQueue(src=src, weight=weight, dropped=dropped)


def gather_event_entries(
    queue: EventQueue,
    src_tag: jax.Array,  # [N, E] int32, -1 = empty
    src_dest: jax.Array,  # [N, E] int32 cluster ids
) -> tuple[jax.Array, jax.Array]:
    """Fetch the queued events' SRAM rows: ``(ev_tag, ev_dest) [..., Q, E]``.

    This is the per-event "SRAM memory-address loop": only queued sources'
    entries are read. Empty queue slots yield ``ev_tag = -1`` rows.
    """
    safe = jnp.clip(queue.src, 0, src_tag.shape[0] - 1)
    ev_tag = jnp.take(src_tag, safe, axis=0)  # [..., Q, E]
    ev_dest = jnp.take(src_dest, safe, axis=0)
    ev_tag = jnp.where(queue.src[..., None] >= 0, ev_tag, -1)
    return ev_tag, ev_dest


# ---------------------------------------------------------------------------
# stage 1 — scatter-add into the tag-activity matrix
# ---------------------------------------------------------------------------
def _accumulate_activity(
    flat: jax.Array,  # [B, M] int32 per-batch flat indices; invalid -> size
    weights: jax.Array,  # [B, M]
    size: int,
    _force_path: str | None = None,  # tests only: "flat32" | "flat64" | "2d"
) -> jax.Array:  # [B, size]
    """Batched scatter-add into per-batch activity slabs, int32-overflow-safe.

    The fast path linearizes (batch, slot) into one flat index so the whole
    batch is a single 1-D scatter. When ``b * (size + 1)`` exceeds the int32
    range that index would wrap, so offsets are computed in int64 when x64 is
    enabled, and otherwise the scatter falls back to 2-D (batch, slot)
    indices — each component stays comfortably within int32.
    """
    b, _ = flat.shape
    span = size + 1  # slot ``size`` absorbs invalid entries
    path = _force_path
    if path is None:
        if b * span - 1 <= _INT32_MAX:
            path = "flat32"
        elif jax.config.jax_enable_x64:
            path = "flat64"
        else:
            path = "2d"
    if path in ("flat32", "flat64"):
        dt = jnp.int32 if path == "flat32" else jnp.int64
        offsets = jnp.arange(b, dtype=dt)[:, None] * span
        flat_b = flat.astype(dt) + offsets
        a = jnp.zeros((b * span,), dtype=weights.dtype)
        a = a.at[flat_b.reshape(-1)].add(weights.reshape(-1), mode="drop")
        return a.reshape(b, span)[:, :size]
    bidx = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], flat.shape)
    a = jnp.zeros((b, span), dtype=weights.dtype)
    a = a.at[bidx.reshape(-1), flat.reshape(-1)].add(weights.reshape(-1), mode="drop")
    return a[:, :size]


def _accumulate_into(
    buf: jax.Array,  # [B, size] existing per-batch accumulator (e.g. the ring)
    flat: jax.Array,  # [B, M] or [M] int32 in-range flat indices
    weights: jax.Array,  # [B, M]
    _force_path: str | None = None,  # tests only: "flat32" | "flat64" | "2d"
) -> jax.Array:  # [B, size]
    """Scatter-add into an EXISTING accumulator, int32-overflow-safe.

    The time-wheel ring fast path (kernels/fabric_deliver) scatters each
    step's events into the carried ring buffer in place — unlike
    :func:`_accumulate_activity` there is no sentinel slot, so every index
    must already be in ``[0, size)`` and masked-out events must carry weight
    exactly 0 (adding 0.0 is the no-op). Path selection mirrors
    :func:`_accumulate_activity`: flat int32 offsets while they fit, int64
    under x64, else 2-D (batch, slot) indices.
    """
    b, size = buf.shape
    if flat.ndim == 1:
        flat = jnp.broadcast_to(flat[None, :], (b, flat.shape[0]))
    path = _force_path
    if path is None:
        if b * size - 1 <= _INT32_MAX:
            path = "flat32"
        elif jax.config.jax_enable_x64:
            path = "flat64"
        else:
            path = "2d"
    if path in ("flat32", "flat64"):
        dt = jnp.int32 if path == "flat32" else jnp.int64
        offsets = jnp.arange(b, dtype=dt)[:, None] * size
        flat_b = flat.astype(dt) + offsets
        a = buf.reshape(b * size)
        a = a.at[flat_b.reshape(-1)].add(weights.reshape(-1), mode="drop")
        return a.reshape(b, size)
    bidx = jnp.broadcast_to(jnp.arange(b, dtype=jnp.int32)[:, None], flat.shape)
    return buf.at[bidx.reshape(-1), flat.reshape(-1)].add(
        weights.reshape(-1), mode="drop"
    )


def _scatter_count(
    mask: jax.Array,  # [..., Q, E] bool events to count
    bins: jax.Array,  # [..., Q, E] int32 bin per event (value under ~mask ignored)
    size: int,
) -> jax.Array:  # [..., size] int32
    """Per-bin event counts — the attribution-preserving form of ``mask.sum()``.

    Used by the ``per_link_stats`` mode of :func:`stage1_route_events_fabric`
    to keep drops per directed link and deliveries per cluster pair instead
    of collapsing them to scalars. Masked-out events land in a sentinel slot
    that is sliced off, so out-of-range bins never alias a real counter.
    """
    flat = jnp.where(mask, jnp.clip(bins, 0, size - 1), size)
    counts = mask.astype(jnp.int32)
    batch_shape = mask.shape[:-2]
    if not batch_shape:
        out = jnp.zeros((size + 1,), jnp.int32)
        out = out.at[flat.reshape(-1)].add(counts.reshape(-1), mode="drop")
        return out[:size]
    b = math.prod(batch_shape)
    m = mask.shape[-2] * mask.shape[-1]
    out = _accumulate_activity(flat.reshape(b, m), counts.reshape(b, m), size)
    return out.reshape(*batch_shape, size)


def stage1_route(
    spikes: jax.Array,  # [..., N] float event weights (0/1 spikes or rates)
    src_tag: jax.Array,  # [N, E] int32, -1 = empty
    src_dest: jax.Array,  # [N, E] int32 cluster ids
    n_clusters: int,
    k_tags: int,
) -> jax.Array:
    """Scatter stage-1 events into the tag-activity matrix ``A[..., n_clusters, K]``.

    Dense path: all ``N x E`` SRAM entries are scattered regardless of
    activity (cost scales with network size). For event-sparse delivery use
    :func:`compact_events` + :func:`stage1_route_events` instead. The routing
    tables are shared across the batch; each batch element scatters into its
    own slab of a single flat accumulator.
    """
    valid = src_tag >= 0
    size = n_clusters * k_tags
    # flat index into A; invalid entries are routed to a sentinel slot.
    flat = jnp.where(valid, src_dest * k_tags + src_tag, size)  # [N, E]
    weights = spikes[..., None] * valid.astype(spikes.dtype)  # [..., N, E]
    batch_shape = spikes.shape[:-1]
    if not batch_shape:
        a = jnp.zeros((size,), dtype=spikes.dtype)
        a = a.at[flat.reshape(-1)].add(weights.reshape(-1), mode="drop")
        return a.reshape(n_clusters, k_tags)
    b = math.prod(batch_shape)
    flat_b = jnp.broadcast_to(flat.reshape(1, -1), (b, flat.size))
    a = _accumulate_activity(flat_b, weights.reshape(b, -1), size)
    return a.reshape(*batch_shape, n_clusters, k_tags)


def stage1_route_events(
    queue: EventQueue,  # src [..., Q], weight [..., Q]
    src_tag: jax.Array,  # [N, E]
    src_dest: jax.Array,  # [N, E]
    n_clusters: int,
    k_tags: int,
) -> jax.Array:
    """Event-sparse stage 1: scatter only the queued events' SRAM entries.

    Cost is ``O(Q x E)`` per stream — event count, not network size. Produces
    the same ``A[..., n_clusters, K]`` as :func:`stage1_route` whenever the
    queue holds every active source (no overflow).
    """
    ev_tag, ev_dest = gather_event_entries(queue, src_tag, src_dest)
    valid = ev_tag >= 0
    size = n_clusters * k_tags
    flat = jnp.where(valid, ev_dest * k_tags + ev_tag, size)  # [..., Q, E]
    weights = queue.weight[..., None] * valid.astype(queue.weight.dtype)
    batch_shape = queue.src.shape[:-1]
    if not batch_shape:
        a = jnp.zeros((size,), dtype=weights.dtype)
        a = a.at[flat.reshape(-1)].add(weights.reshape(-1), mode="drop")
        return a.reshape(n_clusters, k_tags)
    b = math.prod(batch_shape)
    a = _accumulate_activity(flat.reshape(b, -1), weights.reshape(b, -1), size)
    return a.reshape(*batch_shape, n_clusters, k_tags)


# ---------------------------------------------------------------------------
# stage 1, fabric mode — tile binning, link FIFOs, delay-indexed scatter
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FabricRouteResult:
    """Outcome of one fabric-mode stage-1 pass (DESIGN.md §11).

    ``buffer[..., d, c, t]`` is the tag activity arriving at cluster ``c``
    under tag ``t`` in ``d`` steps (``d = 0`` = this step); ``link_dropped``
    counts events lost to inter-tile link-FIFO overflow; ``delivered``
    counts routed (kept) events. ``hops`` / ``latency_s`` / ``energy_j``
    are per-step sums over delivered events of the Table II-IV per-event
    figures (``None`` when the matrices were not supplied).

    With ``per_link_stats`` (DESIGN.md §18) the two counters keep their
    attribution instead of collapsing to scalars: ``link_dropped`` becomes
    ``[..., n_tiles * n_tiles]`` (flat directed tile pair; fault drops of
    intra-tile entries land on the ``src == dst`` diagonal) and
    ``delivered`` becomes ``[..., n_clusters * n_clusters]`` (flat
    (src_cluster, dst_cluster) pair — the observed traffic matrix). Both
    sum over their trailing axis to exactly the scalar-mode values.
    """

    buffer: jax.Array  # [..., max_delay + 1, n_clusters, K]
    link_dropped: jax.Array  # [...] int32, or [..., T*T] per-link
    delivered: jax.Array  # [...] int32, or [..., nc*nc] per-pair
    hops: jax.Array | None = None  # [...] int32
    latency_s: jax.Array | None = None  # [...] float32
    energy_j: jax.Array | None = None  # [...] float32


jax.tree_util.register_dataclass(
    FabricRouteResult,
    data_fields=["buffer", "link_dropped", "delivered", "hops", "latency_s", "energy_j"],
    meta_fields=[],
)


def stage1_route_events_fabric(
    queue: EventQueue,  # src [..., Q] LOCAL neuron ids into src_tag's rows
    src_tag: jax.Array,  # [N_local, E]
    src_dest: jax.Array,  # [N_local, E] GLOBAL destination cluster ids
    n_clusters: int,  # global cluster count
    k_tags: int,
    cluster_size: int,
    cluster_tile: jax.Array,  # [n_clusters] int32 linear tile id per cluster
    delay_steps: jax.Array,  # [n_clusters, n_clusters] int32 arrival delays
    n_tiles: int,
    max_delay: int,
    link_capacity: int | None,  # events per directed tile pair per step; None = inf
    mesh_hops: jax.Array | None = None,  # [nc, nc] optional stats matrices
    latency_s: jax.Array | None = None,
    energy_j: jax.Array | None = None,
    src_cluster_offset: int | jax.Array = 0,  # sharded: global id of local cluster 0
    cursor: jax.Array | None = None,  # time-wheel write cursor (ring addressing)
    entry_alive: jax.Array | None = None,  # [N_local, E] bool fault mask (§15)
    per_link_stats: bool = False,  # keep drop/delivered attribution (§18)
) -> FabricRouteResult:
    """Event-sparse stage 1 through the R1/R2/R3 fabric.

    The zero-latency :func:`stage1_route_events` scatters every queued
    event's SRAM entries straight into this step's activity. Here each entry
    is first *binned by its (source tile, destination tile) pair*:

      * intra-tile entries (R1/R2 only) keep the zero-latency path — they
        land in ``buffer[0]``;
      * cross-tile entries contend for their directed link's FIFO — the
        first ``link_capacity`` events per link (arbiter order: queue slot
        order, i.e. lowest source id first) win, the rest are dropped and
        counted (:func:`dispatch_slots` semantics, bins = tile pairs);
      * surviving cross-tile entries land ``delay_steps[src, dst]`` slots
        deep in the buffer — the delay line the engine's scan carries.

    Per-event stats are summed over *delivered* entries only (each SRAM
    entry is one AER event on the fabric, regardless of its weight).

    With ``cursor`` set, the buffer is addressed as a **time-wheel ring**
    (DESIGN.md §14): an event with arrival delay ``d`` lands in slot
    ``(cursor + d) % (max_delay + 1)`` instead of slot ``d``, so the caller
    can carry the buffer across steps with a pointer bump instead of the
    dense :func:`~repro.core.dispatch.advance_inflight` shift. Everything
    else — arbitration, drops, stats — is bit-identical to the roll layout.

    With ``per_link_stats`` the drop and delivered counters are scattered
    instead of summed (see :class:`FabricRouteResult`): link-FIFO drops at
    their directed (src_tile, dst_tile) link, fault drops at the same link
    (or the tile's self-link diagonal for intra-tile entries, so the
    per-link sum stays exactly equal to the scalar mode), and delivered
    events at their (src_cluster, dst_cluster) pair — the empirical traffic
    matrix that feeds :class:`repro.core.compiler.TrafficProfile`.

    ``entry_alive`` is the static per-SRAM-entry fault mask of
    :func:`repro.core.faults.entry_alive_mask`: a ``False`` entry's events
    are dropped before link arbitration (they never consume a live link's
    FIFO slots) and counted in ``link_dropped`` — a dead link is a
    zero-capacity link. Same semantics as the severed entries of the ring
    fast path, so ring/roll parity holds under faults too.
    """
    ev_tag, ev_dest = gather_event_entries(queue, src_tag, src_dest)  # [..., Q, E]
    valid = ev_tag >= 0
    fault_mask = None
    if entry_alive is not None:
        safe = jnp.clip(queue.src, 0, src_tag.shape[0] - 1)
        ev_alive = jnp.take(entry_alive, safe, axis=0)  # [..., Q, E]
        fault_mask = valid & ~ev_alive
        valid = valid & ev_alive
    src_cl = jnp.where(
        queue.src >= 0, queue.src // cluster_size + src_cluster_offset, 0
    ).astype(jnp.int32)
    src_cl_e = jnp.broadcast_to(src_cl[..., None], ev_tag.shape)  # [..., Q, E]
    dst_cl = jnp.clip(ev_dest, 0, n_clusters - 1)
    pair = src_cl_e * n_clusters + dst_cl  # flat [nc, nc] index
    src_tile = jnp.take(cluster_tile, src_cl_e, mode="clip")
    dst_tile = jnp.take(cluster_tile, dst_cl, mode="clip")
    cross = (src_tile != dst_tile) & valid

    if link_capacity is None:
        keep_cross = jnp.ones_like(cross)
    else:
        bins = jnp.where(cross, src_tile * n_tiles + dst_tile, -1)
        batch_shape = bins.shape[:-2]
        flat_bins = bins.reshape(-1, bins.shape[-2] * bins.shape[-1])
        _, keep_flat = jax.vmap(
            lambda e: dispatch_slots(e, n_tiles * n_tiles, link_capacity)
        )(flat_bins)
        keep_cross = keep_flat.reshape(*batch_shape, *bins.shape[-2:])

    kept = valid & (~cross | keep_cross)
    if per_link_stats:
        link_bins = src_tile * n_tiles + dst_tile
        link_dropped = _scatter_count(cross & ~keep_cross, link_bins, n_tiles * n_tiles)
        if fault_mask is not None:
            # intra-tile fault drops land on the tile's self-link diagonal so
            # the per-link sum equals the scalar-mode count exactly
            fault_bins = jnp.where(
                src_tile != dst_tile, link_bins, src_tile * n_tiles + src_tile
            )
            link_dropped = link_dropped + _scatter_count(
                fault_mask, fault_bins, n_tiles * n_tiles
            )
        delivered = _scatter_count(kept, pair, n_clusters * n_clusters)
    else:
        link_dropped = (cross & ~keep_cross).sum((-1, -2), dtype=jnp.int32)
        if fault_mask is not None:
            link_dropped = link_dropped + fault_mask.sum((-1, -2), dtype=jnp.int32)
        delivered = kept.sum((-1, -2), dtype=jnp.int32)

    delay = jnp.take(delay_steps.reshape(-1), pair, mode="clip")
    slot = delay if cursor is None else (cursor + delay) % (max_delay + 1)
    size = (max_delay + 1) * n_clusters * k_tags
    flat = jnp.where(
        kept, (slot * n_clusters + dst_cl) * k_tags + jnp.clip(ev_tag, 0), size
    )
    weights = queue.weight[..., None] * kept.astype(queue.weight.dtype)
    batch_shape = queue.src.shape[:-1]
    if not batch_shape:
        a = jnp.zeros((size,), dtype=weights.dtype)
        a = a.at[flat.reshape(-1)].add(weights.reshape(-1), mode="drop")
    else:
        b = math.prod(batch_shape)
        a = _accumulate_activity(flat.reshape(b, -1), weights.reshape(b, -1), size)
    buffer = a.reshape(*batch_shape, max_delay + 1, n_clusters, k_tags)

    def _sum_over_kept(matrix, dtype):
        if matrix is None:
            return None
        vals = jnp.take(matrix.reshape(-1), pair, mode="clip")
        return jnp.where(kept, vals, 0).sum((-1, -2), dtype=dtype)

    return FabricRouteResult(
        buffer=buffer,
        link_dropped=link_dropped,
        delivered=delivered,
        hops=_sum_over_kept(mesh_hops, jnp.int32),
        latency_s=_sum_over_kept(latency_s, jnp.float32),
        energy_j=_sum_over_kept(energy_j, jnp.float32),
    )


# ---------------------------------------------------------------------------
# stage 2 — broadcast + CAM match
# ---------------------------------------------------------------------------
def precompute_syn_onehot(cam_syn: jax.Array, dtype=jnp.float32) -> jax.Array:
    """One-hot synapse-type plane ``[N, S, N_SYN_TYPES]`` for stage 2.

    A per-table constant (the CAM's synapse-type wiring never changes at
    run time) — precompute once and pass to :func:`stage2_cam_match` to keep
    the one-hot expansion out of the per-step cost.
    """
    return jax.nn.one_hot(cam_syn, N_SYN_TYPES, dtype=dtype)


def stage2_cam_match(
    activity: jax.Array,  # [..., n_clusters, K]
    cam_tag: jax.Array,  # [N, S] int32, -1 = empty
    cam_syn: jax.Array,  # [N, S] int32 in [0, N_SYN_TYPES)
    cluster_size: int,
    syn_onehot: jax.Array | None = None,  # [N, S, N_SYN_TYPES] precomputed
) -> jax.Array:
    """Broadcast + CAM match: returns synaptic drive ``I[..., N, N_SYN_TYPES]``.

    Pure-jnp reference. CAM word ``(j, s)`` reads exactly one activity cell —
    ``activity[cluster_of(j), cam_tag[j, s]]`` — so the gather is a direct
    advanced-indexing ``take`` on the flattened activity; no intermediate
    ``[..., n_clusters, cluster_size, K]`` broadcast is ever materialized
    (that tensor is ~1 GB at B=64 on the benchmark geometry). The Pallas
    kernels in kernels/cam_match and kernels/fused_deliver compute the same
    quantity with the activity row pinned in VMEM.
    """
    n, s = cam_tag.shape
    n_clusters, k = activity.shape[-2:]
    batch_shape = activity.shape[:-2]
    assert n == n_clusters * cluster_size, (n, n_clusters, cluster_size)
    valid = cam_tag >= 0
    # flat (cluster, tag) address of each CAM word; invalid words clamped.
    cluster_of_word = jnp.arange(n, dtype=jnp.int32)[:, None] // cluster_size
    flat_word = cluster_of_word * k + jnp.clip(cam_tag, 0, k - 1)  # [N, S]
    act_flat = activity.reshape(*batch_shape, n_clusters * k)
    vals = jnp.take(act_flat, flat_word, axis=-1, mode="clip")  # [..., N, S]
    vals = jnp.where(valid, vals, jnp.zeros((), activity.dtype))
    if syn_onehot is None:
        syn_onehot = precompute_syn_onehot(cam_syn, dtype=vals.dtype)
    out = jnp.einsum("...ns,nst->...nt", vals, syn_onehot.astype(vals.dtype))
    return out.reshape(*batch_shape, n, N_SYN_TYPES)


def two_stage_deliver(
    spikes: jax.Array,
    src_tag: jax.Array,
    src_dest: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    k_tags: int,
    external_activity: jax.Array | None = None,
    backend: str | object = "reference",
    queue_capacity: int | None = None,
    syn_onehot: jax.Array | None = None,
    with_stats: bool = False,
):
    """Full event delivery: spikes -> synaptic drive per neuron & synapse type.

    ``external_activity`` injects input events (the chip's Input Interface /
    FPGA path) directly as tag activity. ``backend`` selects the dispatch
    implementation by name or instance (core/dispatch.py registry).
    ``queue_capacity`` enables event-sparse delivery through a fixed-capacity
    AER queue (DESIGN.md §10); with ``with_stats=True`` the return value is
    ``(drive, DeliveryStats)`` carrying the queue's drop counter.
    """
    from repro.core.dispatch import backend_deliver, get_backend

    return backend_deliver(
        get_backend(backend),
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        external_activity=external_activity,
        queue_capacity=queue_capacity,
        syn_onehot=syn_onehot,
        with_stats=with_stats,
    )


# ---------------------------------------------------------------------------
# shared sort-based slot assignment (AER queue / MoE expert buffers)
# ---------------------------------------------------------------------------
def dispatch_slots(flat_e: jax.Array, n_bins: int, cap: int):
    """Assign each event a slot in its bin's fixed-capacity buffer.

    ``flat_e [A]`` is a bin id per event (out-of-range = inactive); returns
    ``(slot [A], keep [A])`` where ``slot = bin * cap + position`` for the
    first ``cap`` events of each bin (stable order) and ``keep`` masks the
    rest — the same FIFO-overflow semantics as :func:`compact_events`, for
    many bins at once. Used by the MoE expert-dispatch path (models/moe.py),
    where bins are experts/shards and ``cap`` is the expert capacity.
    """
    a = flat_e.shape[0]
    # normalize inactive markers: a negative bin would sort BEFORE the valid
    # bins (inflating their in-bin positions) and wrap in the counts scatter —
    # fold them onto the high sentinel the masking already handles
    flat_e = jnp.where(flat_e < 0, n_bins, flat_e)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((n_bins,), jnp.int32).at[sorted_e].add(1, mode="drop")
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(a, dtype=jnp.int32) - starts[sorted_e]
    keep = (pos_in_e < cap) & (sorted_e >= 0) & (sorted_e < n_bins)
    slot_sorted = jnp.where(keep, sorted_e * cap + pos_in_e, -1)
    # undo the sort: slot for the original assignment order
    slot = jnp.zeros((a,), jnp.int32).at[order].set(slot_sorted)
    return slot, slot >= 0
