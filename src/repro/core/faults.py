"""Declarative fault injection for the executable fabric (DESIGN.md §15).

Real deployments of this architecture treat partial failure as a normal
operating condition: dead tiles, broken mesh links, lossy channels, stuck
cores, and corrupted CAM/SRAM words. This module is the single declarative
description of such a fault load (:class:`FaultSpec`) plus the *functional*
machinery that applies it:

  * **Topology faults** (dead tiles / dead directed mesh links / per-link
    stochastic drop rates) are resolved against the mesh's deterministic XY
    routes into per-tile-pair reachability and compound drop-rate matrices
    (:func:`tile_fault_matrices`), then gathered through the placement into
    per-cluster-pair form (:func:`pair_fault_matrices`). ``routing.
    build_delivery_model(..., faults=...)`` stores them on the delivery
    model, and the per-SRAM-entry liveness mask (:func:`entry_alive_mask`)
    feeds both fabric delivery paths — the ring fast path bakes it into the
    static entry table, the roll oracle threads it per step — so ring and
    roll stay bit-identical under faults. Fault-severed events are counted
    in ``DeliveryStats.link_dropped`` (a dead link is a zero-capacity link).
  * **Stochastic link loss** is modeled as route-level erasure: a link with
    drop rate ``p`` severs each SRAM entry routed across it independently
    with probability ``p`` (compounded along the XY path), drawn once from
    ``FaultSpec.seed`` — deterministic and bit-reproducible thereafter, so
    parity oracles and checkpointed resume stay exact under injected loss.
  * **Memory faults** (:func:`apply_table_faults`) flip bits of programmed
    CAM/SRAM words at compile output — downstream of the compiler, upstream
    of the engine — and :func:`fault_blast_radius` quantifies the damage
    against the ``dense_equivalent`` parity oracle (connections lost /
    gained / rewired).

Everything here is host-side numpy; nothing mutates shared state. A faulted
engine is just an engine built from a faulted model/tables.
"""

from __future__ import annotations

import dataclasses
import math
from collections.abc import Mapping

import numpy as np

__all__ = [
    "FaultSpec",
    "mesh_links",
    "xy_path",
    "tile_fault_matrices",
    "pair_fault_matrices",
    "entry_alive_mask",
    "apply_table_faults",
    "fault_blast_radius",
]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault load against a :class:`~repro.core.routing.Fabric`.

    ``dead_tiles`` — linear tile ids whose routers (and hosted cores) are
    gone: clusters placed there neither send nor receive, and XY routes
    *through* them are severed.
    ``dead_links`` — failed directed physical mesh links as adjacent
    ``(from_tile, to_tile)`` pairs; every cluster pair whose XY route uses
    the link becomes unreachable (zero capacity).
    ``link_drop_rate`` — stochastic per-event loss: a global float applied
    to every directed link, or a mapping ``{(from, to): p}``; rates
    compound along multi-hop XY paths.
    ``stuck_clusters`` — cores whose output bus is stuck: no routed events
    leave them (their neurons still integrate external input).
    ``cam_bit_flips`` / ``sram_bit_flips`` — number of single-bit
    corruptions injected into programmed CAM / SRAM words at compile output
    (:func:`apply_table_faults`).
    ``seed`` — drives both the Bernoulli route erasure and the bit-flip
    positions; same spec + same seed = bit-identical fault load.
    """

    dead_tiles: tuple[int, ...] = ()
    dead_links: tuple[tuple[int, int], ...] = ()
    link_drop_rate: float | Mapping[tuple[int, int], float] = 0.0
    stuck_clusters: tuple[int, ...] = ()
    cam_bit_flips: int = 0
    sram_bit_flips: int = 0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "dead_tiles", tuple(int(t) for t in self.dead_tiles))
        object.__setattr__(
            self,
            "dead_links",
            tuple((int(a), int(b)) for a, b in self.dead_links),
        )
        object.__setattr__(
            self, "stuck_clusters", tuple(int(c) for c in self.stuck_clusters)
        )
        if self.cam_bit_flips < 0 or self.sram_bit_flips < 0:
            raise ValueError("bit-flip counts must be non-negative")
        if not isinstance(self.link_drop_rate, Mapping):
            rate = float(self.link_drop_rate)
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"link_drop_rate {rate} outside [0, 1]")
        else:
            for link, rate in self.link_drop_rate.items():
                if not 0.0 <= float(rate) <= 1.0:
                    raise ValueError(f"link_drop_rate[{link}]={rate} outside [0, 1]")

    # ------------------------------------------------------------------
    @property
    def routes_faulted(self) -> bool:
        """True when the spec affects event routing (not just table words)."""
        has_rate = (
            bool(self.link_drop_rate)
            if isinstance(self.link_drop_rate, Mapping)
            else float(self.link_drop_rate) > 0.0
        )
        return bool(self.dead_tiles or self.dead_links or self.stuck_clusters or has_rate)

    def validate(self, fabric) -> None:
        """Check tile ids and link adjacency against a fabric geometry."""
        for t in self.dead_tiles:
            if not 0 <= t < fabric.n_tiles:
                raise ValueError(
                    f"dead tile {t} out of range ({fabric.n_tiles} tiles)"
                )
        links = set(mesh_links(fabric))
        named = list(self.dead_links)
        if isinstance(self.link_drop_rate, Mapping):
            named += [tuple(k) for k in self.link_drop_rate]
        for link in named:
            if tuple(link) not in links:
                raise ValueError(
                    f"link {link} is not a directed adjacent mesh link of a "
                    f"{fabric.grid_x}x{fabric.grid_y} fabric"
                )

    def rate_of(self, link: tuple[int, int]) -> float:
        if isinstance(self.link_drop_rate, Mapping):
            return float(self.link_drop_rate.get(tuple(link), 0.0))
        return float(self.link_drop_rate)


# ---------------------------------------------------------------------------
# Topology: XY routes vs the fault set
# ---------------------------------------------------------------------------
def mesh_links(fabric) -> list[tuple[int, int]]:
    """All directed adjacent (from_tile, to_tile) physical mesh links."""
    links = []
    for t in range(fabric.n_tiles):
        x, y = fabric.tile_xy(t)
        if x + 1 < fabric.grid_x:
            r = t + 1
            links += [(t, r), (r, t)]
        if y + 1 < fabric.grid_y:
            d = t + fabric.grid_x
            links += [(t, d), (d, t)]
    return links


def xy_path(fabric, t_src: int, t_dst: int) -> list[tuple[int, int]]:
    """Directed physical links on the deterministic X-then-Y route."""
    sx, sy = fabric.tile_xy(t_src)
    dx, dy = fabric.tile_xy(t_dst)
    path = []
    x, y = sx, sy
    step_x = 1 if dx > sx else -1
    while x != dx:
        nxt = x + step_x
        path.append((y * fabric.grid_x + x, y * fabric.grid_x + nxt))
        x = nxt
    step_y = 1 if dy > sy else -1
    while y != dy:
        nxt = y + step_y
        path.append((y * fabric.grid_x + x, nxt * fabric.grid_x + x))
        y = nxt
    return path


def tile_fault_matrices(fabric, spec: FaultSpec) -> tuple[np.ndarray, np.ndarray]:
    """Per-ordered-tile-pair ``(alive [T,T] bool, drop_rate [T,T] float64)``.

    A pair is dead when either endpoint tile is dead, any intermediate tile
    on the XY route is dead, or any link on the route is in ``dead_links``.
    The stochastic rate compounds along the route:
    ``1 - prod(1 - p_link)``. The diagonal is alive (rate 0) unless the
    tile itself is dead.
    """
    spec.validate(fabric)
    n = fabric.n_tiles
    dead_tiles = set(spec.dead_tiles)
    dead_links = set(spec.dead_links)
    alive = np.ones((n, n), dtype=bool)
    rate = np.zeros((n, n), dtype=np.float64)
    for a in range(n):
        for b in range(n):
            if a in dead_tiles or b in dead_tiles:
                alive[a, b] = False
                continue
            survive = 1.0
            for link in xy_path(fabric, a, b):
                if link in dead_links or link[1] in dead_tiles:
                    alive[a, b] = False
                    break
                survive *= 1.0 - spec.rate_of(link)
            else:
                rate[a, b] = 1.0 - survive
    return alive, rate


def pair_fault_matrices(
    fabric, tile_of_cluster: np.ndarray, spec: FaultSpec
) -> tuple[np.ndarray, np.ndarray]:
    """Cluster-pair ``(alive [nc,nc] bool, drop_rate [nc,nc] float32)``.

    Gathers the tile matrices through the placement and severs every route
    *out of* a stuck cluster (its output bus is stuck; delivery to it still
    works — external input bypasses the R1 output arbiter, Fig. 7).
    """
    tiles = np.asarray(tile_of_cluster)
    t_alive, t_rate = tile_fault_matrices(fabric, spec)
    alive = t_alive[tiles[:, None], tiles[None, :]].copy()
    rate = t_rate[tiles[:, None], tiles[None, :]].astype(np.float32)
    for c in spec.stuck_clusters:
        if not 0 <= c < tiles.shape[0]:
            raise ValueError(f"stuck cluster {c} out of range ({tiles.shape[0]})")
        alive[c, :] = False
    return alive, rate


def entry_alive_mask(
    src_tag: np.ndarray,  # [N, E] int32, -1 = empty
    src_dest: np.ndarray,  # [N, E] int32 destination cluster ids
    cluster_size: int,
    model,  # routing.FabricDeliveryModel with pair_alive/pair_drop_rate set
) -> np.ndarray | None:
    """Static per-SRAM-entry liveness ``[N, E]`` bool, or ``None`` (healthy).

    The one fault mask both fabric delivery paths consume: the ring fast
    path bakes it into the static entry table (a severed entry always drops
    and is counted in ``link_dropped``), the roll oracle gathers it per
    queued event. Entries on dead pairs are deterministically severed;
    entries on lossy pairs are severed i.i.d. with the pair's compound
    drop rate, drawn once from ``FaultSpec.seed`` (route-level erasure —
    see the module docstring). Empty entries stay "alive" (they carry no
    events, so liveness is moot and the mask stays congruent with
    ``valid``-style filtering downstream).
    """
    if model.pair_alive is None:
        return None
    src_tag = np.asarray(src_tag)
    src_dest = np.asarray(src_dest)
    n, e = src_tag.shape
    nc = model.pair_alive.shape[0]
    src_cl = (np.arange(n) // cluster_size)[:, None]
    dst_cl = np.clip(src_dest, 0, nc - 1)
    alive = model.pair_alive[np.broadcast_to(src_cl, (n, e)), dst_cl].copy()
    rate = model.pair_drop_rate[np.broadcast_to(src_cl, (n, e)), dst_cl]
    if (rate > 0).any():
        seed = model.faults.seed if model.faults is not None else 0
        u = np.random.default_rng(seed).random((n, e))
        alive &= u >= rate
    alive[src_tag < 0] = True
    return alive


# ---------------------------------------------------------------------------
# Memory faults: CAM/SRAM bit corruption at compile output
# ---------------------------------------------------------------------------
def _flip_words(rng, table, n_flips, n_bits, clip_max):
    """Flip ``n_flips`` random bits in occupied entries of ``table`` (copy)."""
    out = np.array(table, dtype=np.int32, copy=True)
    occ = np.argwhere(out >= 0)
    flips = []
    if occ.size == 0 or n_flips == 0 or n_bits == 0:
        return out, flips
    for _ in range(n_flips):
        r, c = occ[int(rng.integers(occ.shape[0]))]
        bit = int(rng.integers(n_bits))
        old = int(out[r, c])
        new = min(old ^ (1 << bit), clip_max)
        out[r, c] = new
        flips.append({"pos": (int(r), int(c)), "bit": bit, "old": old, "new": new})
    return out, flips


def apply_table_faults(tables, spec: FaultSpec):
    """Inject ``spec``'s bit corruptions into compiled routing tables.

    Returns ``(corrupted RoutingTables, report)`` where the report lists
    every flip (table, position, bit, old/new word). Only *programmed*
    words are corrupted — an empty CAM/SRAM slot has no stored word to
    flip. CAM flips hit ``cam_tag`` (the match field: a flipped tag either
    deafens the synapse or re-aims it at another tag); SRAM flips alternate
    between ``src_tag`` (the emitted tag) and ``src_dest`` (the target
    cluster — a flipped dest bit physically misroutes the event). Flipped
    words are clipped into their field's range so the corrupted tables stay
    loadable. Purely functional: the input tables are untouched.
    """
    rng = np.random.default_rng([spec.seed, 0xFA017])
    tag_bits = max(1, math.ceil(math.log2(max(2, tables.k_tags))))
    dest_bits = max(1, math.ceil(math.log2(max(2, tables.n_clusters))))
    cam_tag, cam_flips = _flip_words(
        rng, tables.cam_tag, spec.cam_bit_flips, tag_bits, tables.k_tags - 1
    )
    n_dest = spec.sram_bit_flips // 2
    src_tag, sram_tag_flips = _flip_words(
        rng, tables.src_tag, spec.sram_bit_flips - n_dest, tag_bits,
        tables.k_tags - 1,
    )
    # dest words are only meaningful where the entry is programmed — mask
    # unprogrammed rows to -1 for occupancy selection, then restore
    dest_occ = np.where(np.asarray(tables.src_tag) >= 0, tables.src_dest, -1)
    src_dest_f, sram_dest_flips = _flip_words(
        rng, dest_occ, n_dest, dest_bits, tables.n_clusters - 1
    )
    src_dest = np.where(
        np.asarray(tables.src_tag) >= 0, src_dest_f, tables.src_dest
    ).astype(np.int32)
    report = (
        [{"table": "cam_tag", **f} for f in cam_flips]
        + [{"table": "src_tag", **f} for f in sram_tag_flips]
        + [{"table": "src_dest", **f} for f in sram_dest_flips]
    )
    corrupted = dataclasses.replace(
        tables, cam_tag=cam_tag, src_tag=src_tag, src_dest=src_dest
    )
    return corrupted, report


def fault_blast_radius(before, after) -> dict:
    """Parity-oracle damage report between two routing tables.

    Compares the ``dense_equivalent`` connection multisets: how many
    (src, dst, syn) connections the corruption removed, added, and kept.
    """
    from collections import Counter

    b = Counter(map(tuple, before.dense_equivalent()))
    a = Counter(map(tuple, after.dense_equivalent()))
    lost = sum((b - a).values())
    gained = sum((a - b).values())
    total = sum(b.values())
    return {
        "connections_before": total,
        "connections_lost": lost,
        "connections_gained": gained,
        "connections_kept": total - lost,
        "blast_fraction": (lost + gained) / total if total else 0.0,
    }
