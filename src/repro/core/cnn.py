"""Spiking-CNN compiler for the poker-DVS experiment (paper §V, Table V).

Maps the paper's three-layer event-driven CNN onto the two-stage routed
fabric:

  input 32x32 DVS events
   -> conv: 4 kernels 8x8, stride 2      -> 4 x 16 x 16 feature maps
   -> subsample 2x2 (pooling)            -> 4 x 8 x 8
   -> fully connected (64 strongest)     -> 4 populations x 64 output neurons

Mapping choices mirror the chip:

* The CAM word is 10 bits -> K = 1024 tags per core (alpha = K/C = 4).
* Input->conv uses *pixel-id tags*: tag(y, x) = y*32 + x, identical in every
  feature-map cluster. Each conv neuron subscribes to the <=64 pixels of its
  8x8 receptive field — exactly the 64 CAM words per neuron the chip provides.
  Kernel weights are realized by synapse TYPE (2-bit SRAM): positive taps use
  fast-exc DPI synapses, negative taps subtractive-inh; i.e. ternary kernels,
  the quantization the 4-synapse-type hardware imposes.
* conv->pool: the 4 conv neurons of a 2x2 field share one tag (weight
  sharing via shared tags = the paper's mechanism for linear memory scaling).
* pool->out: each class population subscribes to its 64 selected pool neurons
  ("the 64 most active pooling neurons are strongly connected", §V) — again
  exactly filling the 64-word CAM of each output neuron.

One cluster = one core of 256 neurons: clusters 0-3 hold the feature maps,
cluster 4 the pooling layer, cluster 5 the output populations (6 cores of the
9-chip board; the paper used 2560 neurons including input relays).

Input events are injected as external tag activity (the FPGA input path,
Fig. 7): ``input_activity()`` converts DVS events into [n_clusters, K] drive.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.tags import NetworkSpec, RoutingTables, SynapseType, compile_network

__all__ = [
    "CnnConfig",
    "CompiledCnn",
    "compile_poker_cnn",
    "edge_kernels",
    "hebbian_readout_select",
    "poker_neuron_params",
]


def poker_neuron_params():
    """The §V operating point: neuron/synapse biases tuned so the Table-V
    network classifies within the paper's <30 ms decision window.

    One definition shared by the batch example, the serving example, the
    serving benchmark, and the tests — the numbers they report are only
    comparable if they run the same network.
    """
    from repro.core.neuron import NeuronParams

    return NeuronParams(
        refrac=1e-3, b_adapt=1e-3, input_gain=0.3, w_syn=(1.0, 3.0, 1.0, 1.0)
    )


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    input_hw: int = 32
    n_kernels: int = 4
    kernel: int = 8
    stride: int = 2
    conv_hw: int = 16  # stride-2 with padding 5 -> 16x16 output (paper Table V)
    pool: int = 2
    n_classes: int = 4
    pop_per_class: int = 64
    cluster_size: int = 256  # one DYNAPs core
    k_tags: int = 1024  # 10-bit CAM tag field
    max_cam_words: int = 64
    max_sram_entries: int = 16


@dataclasses.dataclass
class CompiledCnn:
    tables: RoutingTables
    cfg: CnnConfig
    # neuron index ranges [start, stop)
    conv: tuple[int, int]
    pool: tuple[int, int]
    out: tuple[int, int]
    conv_clusters: tuple[int, ...]
    # compiler-v2 occupancy report (core/compiler.py), built after the
    # input-tap splice so cam_fill counts the pixel subscriptions too.
    # tags_used counts routed (SRAM-emitted) tags only — pixel tags are
    # external input addresses, not allocator spend.
    report: "object | None" = None

    def input_activity(self, events_yx, on_invalid: str = "raise") -> np.ndarray:
        """DVS events -> external tag activity.

        ``events_yx`` is either one stream ``[n_ev, 2]`` of (y, x) rows,
        giving ``[n_clusters, K]``, or a sequence of B streams (one per DVS
        sensor / user), giving batched activity ``[B, n_clusters, K]`` ready
        for the batched engine.

        Real sensor packets contain garbage: a coordinate outside
        ``[0, input_hw)`` would either build a tag past the pixel block or
        silently alias a *different* pixel (y=1, x=-1 is pixel (0, 31) under
        row-major flattening). ``on_invalid`` makes the policy explicit:

          * ``"raise"`` (default) — reject the packet with ``ValueError``;
            a server validates at the edge and never lets one bad packet
            poison a whole serving batch.
          * ``"clip"``  — clamp coordinates into range (what the synthetic
            generators in data/pipeline.py do at the source).
          * ``"drop"``  — discard out-of-range events, keep the rest.
        """
        if on_invalid not in ("raise", "clip", "drop"):
            raise ValueError(
                f"on_invalid must be 'raise', 'clip' or 'drop', got {on_invalid!r}"
            )
        if isinstance(events_yx, (list, tuple)):
            return self.input_activity_batch(events_yx, on_invalid)
        c = self.cfg
        a = np.zeros((self.tables.n_clusters, c.k_tags), dtype=np.float32)
        events_yx = np.asarray(events_yx)
        if events_yx.size == 0:
            return a
        if events_yx.ndim != 2 or events_yx.shape[1] != 2:
            raise ValueError(
                f"events must be [n_ev, 2] (y, x) rows, got shape {events_yx.shape}"
            )
        ev = events_yx.astype(np.int64)
        ok = ((ev >= 0) & (ev < c.input_hw)).all(axis=1)
        if not ok.all():
            if on_invalid == "raise":
                bad = ev[~ok][0]
                raise ValueError(
                    f"DVS event (y={bad[0]}, x={bad[1]}) outside the "
                    f"{c.input_hw}x{c.input_hw} sensor; pass on_invalid='clip' "
                    "or 'drop' to accept malformed packets"
                )
            if on_invalid == "clip":
                ev = np.clip(ev, 0, c.input_hw - 1)
            else:  # drop
                ev = ev[ok]
                if len(ev) == 0:
                    return a
        tags = ev[:, 0] * c.input_hw + ev[:, 1]
        counts = np.bincount(tags, minlength=c.input_hw * c.input_hw).astype(np.float32)
        for cl in self.conv_clusters:
            a[cl, : c.input_hw * c.input_hw] += counts
        return a

    def input_activity_batch(self, event_streams, on_invalid: str = "raise") -> np.ndarray:
        """B DVS streams (each [n_ev_i, 2]) -> batched activity [B, n_clusters, K]."""
        return np.stack(
            [self.input_activity(np.asarray(ev), on_invalid) for ev in event_streams]
        )


def edge_kernels(k: int = 8) -> np.ndarray:
    """4 ternary oriented detectors [4,k,k] in {-1,0,+1} (§V: vertical,
    horizontal edges; upward, downward vertices). Ternary because weights are
    realized by synapse type on the chip."""
    ks = np.zeros((4, k, k), dtype=np.float32)
    half = k // 2
    ks[0, :, half - 1 : half + 1] = 1.0  # vertical edge: center band +
    ks[0, :, : half - 2], ks[0, :, half + 2 :] = -1.0, -1.0
    ks[1] = ks[0].T  # horizontal edge
    for y in range(k):
        for x in range(k):
            d = y - abs(x - half)
            ks[2, y, x] = 1.0 if 0 <= d <= 1 else (-1.0 if d > 2 else 0.0)
    ks[3] = ks[2, ::-1, :]  # downward vertex
    return ks


def hebbian_readout_select(
    class_pool_rates: np.ndarray, pop_per_class: int = 64
) -> np.ndarray:
    """Offline-Hebbian readout selection (paper §V): per class, the
    ``pop_per_class`` pooling neurons most *selective* for that class.

    ``class_pool_rates [n_classes, n_pool]`` is the summed pooling-layer
    activity measured while presenting each class's stimuli. Selectivity is
    activity relative to the cross-class mean, so a neuron active for
    everything is not selected for anything. The result feeds
    :func:`compile_poker_cnn`'s ``fc_select`` — shared by the batch example
    and the serving path so both wire the same readout.
    """
    rates = np.asarray(class_pool_rates, dtype=np.float64)
    selectivity = rates - rates.mean(0, keepdims=True)
    return np.stack(
        [np.argsort(-selectivity[c])[:pop_per_class] for c in range(len(rates))]
    )


def compile_poker_cnn(
    cfg: CnnConfig = CnnConfig(),
    fc_select: np.ndarray | None = None,
    allocator: str = "greedy",
    with_report: bool = False,
):
    """Build + compile the Table-V network.

    ``fc_select``: [n_classes, <=64] pool-neuron indices feeding each class
    population (the offline-Hebbian selection). Default: class c reads its own
    feature map's 64 pool neurons.

    ``allocator`` selects the tag allocator (``"greedy"`` = v1 baseline,
    ``"reuse"`` = compiler-v2 conflict-graph tag sharing — bit-exact, and
    strictly fewer tags whenever the Hebbian selection picks one pool neuron
    for several classes). ``with_report=True`` attaches the v2
    ``CompileReport`` measured on the final (input-spliced) tables.
    """
    c = cfg
    n_conv = c.n_kernels * c.conv_hw * c.conv_hw  # 1024
    pool_hw = c.conv_hw // c.pool
    n_pool = c.n_kernels * pool_hw * pool_hw  # 256
    n_out = c.n_classes * c.pop_per_class  # 256
    n_neurons = n_conv + n_pool + n_out  # 1536 = 6 cores

    spec = NetworkSpec(
        n_neurons=n_neurons,
        cluster_size=c.cluster_size,
        k_tags=c.k_tags,
        max_cam_words=c.max_cam_words,
        max_sram_entries=c.max_sram_entries,
    )

    conv0, pool0, out0 = 0, n_conv, n_conv + n_pool
    map_size = c.conv_hw * c.conv_hw  # 256 = one cluster per feature map
    conv_clusters = tuple((conv0 + f * map_size) // c.cluster_size for f in range(c.n_kernels))

    def conv_idx(f: int, y: int, x: int) -> int:
        return conv0 + (f * c.conv_hw + y) * c.conv_hw + x

    def pool_idx(f: int, y: int, x: int) -> int:
        return pool0 + (f * pool_hw + y) * pool_hw + x

    def out_idx(cls: int, i: int) -> int:
        return out0 + cls * c.pop_per_class + i

    # ---- conv -> pool (shared tag per 2x2 field) ---------------------------
    for f in range(c.n_kernels):
        for py in range(pool_hw):
            for px in range(pool_hw):
                srcs = [
                    conv_idx(f, py * c.pool + dy, px * c.pool + dx)
                    for dy in range(c.pool)
                    for dx in range(c.pool)
                ]
                spec.connect_group(
                    srcs, [(pool_idx(f, py, px), SynapseType.FAST_EXC)],
                    shared_tag=True, copies=8,  # integer weight via repeated CAM words
                )

    # ---- pool -> output (64 selected sources per class) --------------------
    if fc_select is None:
        fc_select = np.arange(n_pool, dtype=np.int64).reshape(c.n_kernels, -1)[
            : c.n_classes
        ]  # class c <- feature map c's pool units
    for cls in range(c.n_classes):
        tgts = [(out_idx(cls, i), SynapseType.SLOW_EXC) for i in range(c.pop_per_class)]
        for p in np.asarray(fc_select[cls]).ravel():
            spec.connect_group([pool0 + int(p)], tgts, shared_tag=True)


    tables = compile_network(spec, allocator=allocator)

    # ---- input -> conv: splice pixel-id tags into conv CAMs ---------------
    # (input pixels are external sources — they occupy tag space, not SRAM)
    kernels = edge_kernels(c.kernel)
    pad = (c.conv_hw * c.stride + c.kernel - c.stride - c.input_hw) // 2  # = 5
    cam_tag = tables.cam_tag.copy()
    cam_syn = tables.cam_syn.copy()
    for f in range(c.n_kernels):
        for y in range(c.conv_hw):
            for x in range(c.conv_hw):
                neuron = conv_idx(f, y, x)
                entries = []
                for ky in range(c.kernel):
                    iy = y * c.stride - pad + ky
                    if not (0 <= iy < c.input_hw):
                        continue
                    for kx in range(c.kernel):
                        ix = x * c.stride - pad + kx
                        if not (0 <= ix < c.input_hw):
                            continue
                        w = float(kernels[f, ky, kx])
                        if w == 0.0:
                            continue
                        syn = SynapseType.FAST_EXC if w > 0 else SynapseType.SUB_INH
                        entries.append((iy * c.input_hw + ix, syn))
                row = cam_tag[neuron]
                free = np.flatnonzero(row < 0)
                if len(free) < len(entries):
                    raise ValueError(
                        f"CAM overflow at conv neuron {neuron}: "
                        f"{len(entries)} taps > {len(free)} free words"
                    )
                for slot, (tag, syn) in zip(free, entries):
                    cam_tag[neuron, slot] = tag
                    cam_syn[neuron, slot] = syn
    tables = dataclasses.replace(tables, cam_tag=cam_tag, cam_syn=cam_syn)

    report = None
    if with_report:
        from repro.core.compiler import build_report

        report = build_report(spec, tables)

    return CompiledCnn(
        tables=tables,
        cfg=c,
        conv=(conv0, n_conv),
        pool=(pool0, pool0 + n_pool),
        out=(out0, out0 + n_out),
        conv_clusters=conv_clusters,
        report=report,
    )
