"""AdExp-I&F neuron + 4-type DPI synapse dynamics (paper §IV, refs [2,17,29]).

The chip implements, per computing node: four DPI log-domain filters (one per
synapse type: fast-exc, slow-exc, subtractive-inh, shunting-inh) feeding one
Adaptive-Exponential Integrate & Fire neuron. We simulate the same structure
with exponential-Euler updates inside ``jax.lax.scan``.

Units are SI-ish but arbitrary-scaled (subthreshold analog circuits are tuned
by bias currents, not physical constants); defaults give biologically
plausible dynamics (tau_m ~ 20 ms, synaptic taus from 5 ms to 100 ms, matching
the paper's "fractions of us to hundreds of ms" range).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.two_stage import N_SYN_TYPES

__all__ = ["NeuronParams", "NeuronState", "init_state", "neuron_step"]


@dataclasses.dataclass(frozen=True)
class NeuronParams:
    dt: float = 1e-3  # simulation step [s]
    # AdExp membrane
    tau_m: float = 20e-3
    v_rest: float = -70e-3
    v_thresh: float = -50e-3  # exponential take-off V_T
    delta_t: float = 2e-3  # sharpness
    v_peak: float = 0.0  # spike detection
    v_reset: float = -65e-3
    refrac: float = 2e-3  # refractory period [s]
    # adaptation (negative-feedback block)
    tau_w: float = 100e-3
    a_adapt: float = 2.0  # subthreshold coupling [1/s scale]
    b_adapt: float = 8e-3  # spike-triggered increment [V equivalent]
    # DPI synapses: time constants + weights per type
    tau_syn: tuple[float, float, float, float] = (5e-3, 100e-3, 10e-3, 20e-3)
    w_syn: tuple[float, float, float, float] = (1.0, 0.3, 1.0, 1.0)
    shunt_gain: float = 5.0  # shunting inhibition multiplies leak conductance
    input_gain: float = 0.12  # synaptic current -> membrane drive [V/s per unit]


@dataclasses.dataclass
class NeuronState:
    v: jax.Array  # [..., N] membrane potential
    w: jax.Array  # [..., N] adaptation variable
    refrac: jax.Array  # [..., N] remaining refractory time
    i_syn: jax.Array  # [..., N, 4] DPI filter states


jax.tree_util.register_dataclass(
    NeuronState, data_fields=["v", "w", "refrac", "i_syn"], meta_fields=[]
)


def init_state(
    n: int,
    params: NeuronParams,
    dtype=jnp.float32,
    batch: int | tuple[int, ...] | None = None,
) -> NeuronState:
    """Fresh state for ``n`` neurons; ``batch`` prepends leading batch dims
    (B independent network instances sharing one set of routing tables)."""
    lead = () if batch is None else (batch,) if isinstance(batch, int) else tuple(batch)
    return NeuronState(
        v=jnp.full((*lead, n), params.v_rest, dtype=dtype),
        w=jnp.zeros((*lead, n), dtype=dtype),
        refrac=jnp.zeros((*lead, n), dtype=dtype),
        i_syn=jnp.zeros((*lead, n, N_SYN_TYPES), dtype=dtype),
    )


def neuron_step(
    state: NeuronState,
    drive: jax.Array,  # [..., N, 4] matched-event weight per synapse type (stage-2 output)
    params: NeuronParams,
    i_ext: jax.Array | None = None,  # [..., N] external (DC) input current
) -> tuple[NeuronState, jax.Array]:
    """One exponential-Euler step; returns (new_state, spikes[..., N] float32).

    Purely elementwise over the leading dims, so a batched state steps all
    instances at once with no outer vmap.
    """
    p = params
    dt = p.dt
    taus = jnp.asarray(p.tau_syn, dtype=state.i_syn.dtype)
    ws = jnp.asarray(p.w_syn, dtype=state.i_syn.dtype)

    # DPI filters: exponential decay + weighted pulse injection (PE -> DPI).
    decay = jnp.exp(-dt / taus)
    i_syn = state.i_syn * decay + drive * ws

    i_fast, i_slow, i_sub, i_shunt = (i_syn[..., k] for k in range(N_SYN_TYPES))
    exc = i_fast + i_slow
    leak_gain = 1.0 + p.shunt_gain * i_shunt  # shunting = divisive inhibition
    i_in = p.input_gain * (exc - i_sub)
    if i_ext is not None:
        i_in = i_in + i_ext

    # AdExp membrane (clip the exponential for numerical safety).
    v = state.v
    exp_term = p.delta_t * jnp.exp(jnp.clip((v - p.v_thresh) / p.delta_t, -20.0, 20.0))
    dv = (-(v - p.v_rest) * leak_gain + exp_term - state.w) / p.tau_m + i_in
    v_new = v + dt * dv
    # adaptation
    dw = (p.a_adapt * (v - p.v_rest) - state.w) / p.tau_w
    w_new = state.w + dt * dw

    in_refrac = state.refrac > 0.0
    v_new = jnp.where(in_refrac, p.v_reset, v_new)
    spikes = (v_new >= p.v_peak) & ~in_refrac
    spikes_f = spikes.astype(v_new.dtype)

    v_out = jnp.where(spikes, p.v_reset, v_new)
    w_out = jnp.where(spikes, w_new + p.b_adapt, w_new)
    refrac_out = jnp.where(spikes, p.refrac, jnp.maximum(state.refrac - dt, 0.0))

    return NeuronState(v=v_out, w=w_out, refrac=refrac_out, i_syn=i_syn), spikes_f
