"""DYNAPs core: two-stage tag routing theory + executable fabric.

Public surface of the paper's contribution:

- ``memory_model``: §II equations (memory-optimal routing design points)
- ``tags``: network compiler -> distributed SRAM/CAM routing tables
- ``two_stage``: executable stage-1 scatter + stage-2 CAM match (JAX)
- ``neuron``: AdExp-I&F + 4-type DPI synapse dynamics
- ``event_engine``: scan-able SNN engine, sharded via shard_map
- ``routing``: analytical R1/R2/R3 fabric model (latency/energy/traffic)
- ``cnn``: spiking-CNN compiler (paper §V application)
"""

from repro.core import cnn, event_engine, memory_model, neuron, routing, tags, two_stage

__all__ = ["cnn", "event_engine", "memory_model", "neuron", "routing", "tags", "two_stage"]
