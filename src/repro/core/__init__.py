"""DYNAPs core: two-stage tag routing theory + executable fabric.

Public surface of the paper's contribution:

- ``memory_model``: §II equations (memory-optimal routing design points)
- ``tags``: network compiler -> distributed SRAM/CAM routing tables
- ``compiler``: routing compiler v2 — conflict-graph tag reuse,
  traffic-aware placement, CompileReport (§13)
- ``two_stage``: executable stage-1 scatter + stage-2 CAM match (JAX)
- ``dispatch``: pluggable batched dispatch backends (reference/pallas/sharded)
- ``neuron``: AdExp-I&F + 4-type DPI synapse dynamics
- ``event_engine``: scan-able SNN engine, sharded via shard_map
- ``routing``: R1/R2/R3 fabric model (latency/energy/traffic) + the
  per-cluster-pair delivery model driving fabric-mode execution (§11)
- ``cnn``: spiking-CNN compiler (paper §V application)
- ``shard_compat``: version-portable shard_map import + kwargs
"""

from repro.core import (
    cnn,
    compiler,
    dispatch,
    event_engine,
    memory_model,
    neuron,
    routing,
    shard_compat,
    tags,
    two_stage,
)

__all__ = [
    "cnn",
    "compiler",
    "dispatch",
    "event_engine",
    "memory_model",
    "neuron",
    "routing",
    "shard_compat",
    "tags",
    "two_stage",
]
