"""Routing compiler v2: tag-reuse allocation + traffic-aware placement.

The paper's Appendix A argues two optimizations make two-stage tag routing
deployable: *tag re-assignment* (reusing the per-cluster tag space so K stays
bounded) and *clustered placement* (keeping traffic below the R3 mesh —
Table IV's ~2.1x mean-hop advantage). The v1 compiler (core/tags.py) does
neither: it burns a fresh tag per allocation unit until K is exhausted and
places clusters linearly. This module adds both, plus a compile report, while
staying **bit-exact**: a network compiled with v2 realizes the identical
dense connectivity (multiset of (src, dst, syn) connections, multiplicity
included) and delivers the identical spike-by-spike trajectory whenever no
events are dropped (the property-based conformance suite in
tests/test_compiler.py locks this against the dense oracle). Two capacity
caveats are inherent to doing *less* work: the AER output queue compacts
active sources — not SRAM entries — so queue-overflow drops are identical
under v1 and v2 tables; inter-tile link FIFOs, however, count routed
entries, and a reuse-merged source emits fewer of them, so under finite
link capacity v2 presents strictly less load and the surviving-event set
(always the lowest-source-id prefix per link) can differ from v1's.

Tag-reuse allocation (DESIGN.md §13)
------------------------------------
Broadcast semantics make most tag sharing unsound: an event (tag t, cluster
c) reaches *every* CAM word matching t in c, so merging two units' tags
cross-wires their sources into each other's audiences. The only merge that
is exact is between units with **identical source sets**: each shared source
then emits ONE event where it used to emit several, and the destination's
(unchanged, separately kept) CAM words still fire exactly the same multiset
of pulses. We therefore build, per cluster, a conflict graph whose vertices
are allocation units and whose edges join units with *different* source sets
(merging them would create cross-talk), and greedily color it — same color =
same tag. Because "identical source set" is an equivalence relation the
conflict graph is a disjoint union of cliques-complement, so greedy coloring
is exactly optimal for this compatibility relation: tags per cluster =
number of distinct source sets, always <= v1's unit count, and SRAM entries
(deduped per (source, tag, cluster)) and CAM words never exceed v1's.

Traffic-aware placement
-----------------------
``optimize_placement`` minimizes expected hop-weighted mesh traffic
``sum_{a,b} T[a,b] * H[tile(a), tile(b)]`` (T from per-neuron rates x SRAM
entries, H the XY-mesh hop matrix of routing.tile_hop_matrix) over
cluster->tile maps subject to ``validate_placement`` capacity, via simulated
annealing over pairwise swaps/relocations with a greedy-refinement finish,
seeded from the hierarchical-linear default — a classic QAP local search
with O(n_clusters) incremental cost deltas. ``device_slabs`` restricts moves
so each tile's clusters stay inside one contiguous cluster slab, which is
exactly the constraint the sharded fabric step (tiles -> devices,
DESIGN.md §11) enforces — optimized placements then run multi-device as-is.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from typing import Sequence

import numpy as np

from repro.core.tags import (
    AllocUnit,
    NetworkSpec,
    RoutingTables,
    compile_network,
    expand_units,
)

__all__ = [
    "CompileReport",
    "CompileResult",
    "Geometry",
    "FeasibilityReport",
    "InfeasibleGeometryError",
    "CompiledArtifact",
    "allocate_tags_reuse",
    "traffic_matrix",
    "TrafficProfile",
    "placement_cost",
    "optimize_placement",
    "device_slab_placement",
    "session_rate",
    "repair_placement",
    "build_report",
    "compile_network_v2",
    "artifact_from_tables",
    "retarget",
]


# ---------------------------------------------------------------------------
# tag-reuse allocation: conflict-graph coloring
# ---------------------------------------------------------------------------
def allocate_tags_reuse(spec: NetworkSpec, units: list[AllocUnit]):
    """Color each cluster's unit conflict graph: ``(tags, tags_used)``.

    Two units conflict (must take different tags) unless their source sets
    are identical — the only bit-exact merge under broadcast semantics (see
    module docstring). Greedy first-fit coloring in unit order; since the
    no-conflict relation is an equivalence, first-fit is optimal: each
    distinct (cluster, source-set) key gets the next free tag of its
    cluster, and later units with the same key reuse it. Raises the v2
    tag-overflow diagnostic naming the cluster and the binding constraint.
    """
    tags: list[int] = []
    tags_used = np.zeros(spec.n_clusters, dtype=np.int64)
    color_of_key: dict[tuple[int, tuple[int, ...]], int] = {}
    for u in units:
        key = (u.cluster, u.sources)
        color = color_of_key.get(key)
        if color is None:
            color = int(tags_used[u.cluster])
            if color >= spec.k_tags:
                raise ValueError(
                    f"tag overflow in cluster {u.cluster}: K={spec.k_tags} "
                    f"exhausted even with tag reuse — the cluster's CAM "
                    f"audience needs {color + 1}+ distinct source sets "
                    "(binding constraint: tags per cluster); increase alpha "
                    "(more tags) or re-cluster the network (Appendix A)"
                )
            tags_used[u.cluster] += 1
            color_of_key[key] = color
        tags.append(color)
    return tags, tags_used


# ---------------------------------------------------------------------------
# traffic model + placement optimization
# ---------------------------------------------------------------------------
def traffic_matrix(
    tables: RoutingTables, rates: np.ndarray | Sequence[float] | None = None
) -> np.ndarray:
    """Expected inter-cluster event traffic ``T[src_cluster, dst_cluster]``.

    Every occupied SRAM entry of neuron ``s`` is one AER event per spike of
    ``s``, so the expected events/s from cluster a to cluster b is the sum of
    ``rates[s]`` over entries ``(s -> b)`` with ``s`` in ``a``. ``rates``
    defaults to uniform (1.0 per neuron) — the placement objective then
    weights every SRAM entry equally, matching the fabric stats' per-entry
    hop accounting under all-sources-spiking traffic.
    """
    src_tag = np.asarray(tables.src_tag)
    src_dest = np.asarray(tables.src_dest)
    n = tables.n_neurons
    if rates is None:
        rates = np.ones(n, dtype=np.float64)
    else:
        rates = np.asarray(rates, dtype=np.float64)
        if rates.shape != (n,):
            raise ValueError(f"rates has shape {rates.shape}, expected ({n},)")
    src, ent = np.nonzero(src_tag >= 0)
    t = np.zeros((tables.n_clusters, tables.n_clusters), dtype=np.float64)
    np.add.at(t, (src // tables.cluster_size, src_dest[src, ent]), rates[src])
    return t


@dataclasses.dataclass
class TrafficProfile:
    """Measured inter-cluster traffic, accumulated from per-link DeliveryStats.

    The feedback half of the measure→optimize→recompile loop (DESIGN.md
    §18): a fabric engine built with ``per_link_stats`` emits ``delivered``
    per (src_cluster, dst_cluster) pair and ``link_dropped`` per directed
    tile link; :meth:`observe` folds each step's stats in, and the
    accumulated :meth:`matrix` is the *empirical* counterpart of
    :func:`traffic_matrix` — under all-sources-spiking, drop-free traffic
    the two are equal entry for entry (each delivered SRAM entry is one
    unit of entry-weighted traffic; the conformance test locks this). Feed
    :meth:`matrix` straight into :func:`optimize_placement`, or
    :meth:`rates` into :func:`traffic_matrix` when the tables' entry
    structure should re-derive the matrix.
    """

    n_clusters: int
    n_tiles: int
    pair_delivered: np.ndarray  # [nc, nc] cumulative delivered events
    link_dropped: np.ndarray  # [T, T] cumulative per-directed-link drops
    dropped: float = 0.0  # cumulative AER-queue drops
    steps: int = 0  # observed engine steps
    last: np.ndarray | None = None  # most recent observation's [nc, nc]

    @classmethod
    def empty(cls, n_clusters: int, n_tiles: int) -> "TrafficProfile":
        return cls(
            n_clusters=int(n_clusters),
            n_tiles=int(n_tiles),
            pair_delivered=np.zeros((n_clusters, n_clusters), dtype=np.float64),
            link_dropped=np.zeros((n_tiles, n_tiles), dtype=np.float64),
        )

    def observe(self, stats, steps: int = 1) -> None:
        """Fold one step's (or one stacked run's) per-link DeliveryStats in.

        ``stats.delivered`` must be the per-pair ``[..., nc*nc]`` form and
        ``stats.link_dropped`` the per-link ``[..., T*T]`` form — leading
        batch/time axes are summed (every stream shares the fabric).
        ``steps`` is how many engine steps the observation spans.
        """
        nc, t = self.n_clusters, self.n_tiles
        d = np.asarray(stats.delivered)
        if d.ndim == 0 or d.shape[-1] != nc * nc:
            raise ValueError(
                f"delivered has shape {d.shape}, expected [..., {nc * nc}] — "
                "was the engine built with per_link_stats?"
            )
        pair = d.reshape(-1, nc * nc).sum(0).astype(np.float64).reshape(nc, nc)
        ld = np.asarray(stats.link_dropped)
        if ld.ndim == 0 or ld.shape[-1] != t * t:
            raise ValueError(
                f"link_dropped has shape {ld.shape}, expected [..., {t * t}] — "
                "was the engine built with per_link_stats?"
            )
        self.pair_delivered += pair
        self.last = pair
        self.link_dropped += (
            ld.reshape(-1, t * t).sum(0).astype(np.float64).reshape(t, t)
        )
        self.dropped += float(np.asarray(stats.dropped).sum())
        self.steps += int(steps)

    @property
    def total_link_dropped(self) -> float:
        return float(self.link_dropped.sum())

    def matrix(self) -> np.ndarray:
        """Observed traffic ``[nc, nc]`` in events per step (empirical
        :func:`traffic_matrix`)."""
        return self.pair_delivered / max(self.steps, 1)

    def rates(self, tables: RoutingTables) -> np.ndarray:
        """Per-neuron empirical rate vector for :func:`traffic_matrix`.

        The fabric observes traffic per *cluster pair*, so the estimate is
        uniform within a source cluster: the cluster's observed events per
        step spread over its occupied SRAM entries. Exact whenever spiking
        is uniform within each cluster (e.g. the conformance workload);
        otherwise the best rank-respecting estimate the stats carry.
        """
        entries = (np.asarray(tables.src_tag) >= 0).sum(1).astype(np.float64)
        cs = tables.cluster_size
        per_cluster = entries.reshape(self.n_clusters, cs).sum(1)
        row = self.pair_delivered.sum(1) / max(self.steps, 1)
        r = np.divide(
            row, per_cluster, out=np.zeros_like(row), where=per_cluster > 0
        )
        return np.repeat(r, cs)

    def drift(self, assumed: np.ndarray) -> float:
        """Total-variation distance between the observed and assumed traffic
        distributions, in ``[0, 1]`` (0 = identical shape, 1 = disjoint).
        Returns 0.0 while either side is empty — no evidence, no drift."""
        obs = self.pair_delivered
        a = np.asarray(assumed, dtype=np.float64)
        if a.shape != obs.shape:
            raise ValueError(f"assumed has shape {a.shape}, expected {obs.shape}")
        so, sa = obs.sum(), a.sum()
        if so <= 0 or sa <= 0:
            return 0.0
        return float(0.5 * np.abs(obs / so - a / sa).sum())


def placement_cost(
    traffic: np.ndarray, hop_matrix: np.ndarray, placement: np.ndarray
) -> float:
    """Hop-weighted traffic ``sum_{a,b} T[a,b] * H[p[a], p[b]]``."""
    p = np.asarray(placement)
    return float((traffic * hop_matrix[p[:, None], p[None, :]]).sum())


def _swap_delta(s, h, p, i, j):
    """Cost change of swapping the tiles of clusters i and j (O(n_clusters)).

    ``s`` is the symmetrized traffic ``T + T.T`` so one row per cluster
    carries both directions; the k=i / k=j self terms are excluded (their
    hop contribution is invariant under the swap because H is symmetric)."""
    hpi, hpj = h[p[i]][p], h[p[j]][p]
    v = hpj - hpi
    delta = float((s[i] - s[j]) @ v)
    delta -= float((s[i, i] - s[j, i]) * v[i] + (s[i, j] - s[j, j]) * v[j])
    return delta


def _move_delta(s, h, p, i, t):
    """Cost change of relocating cluster i to tile t (O(n_clusters)).

    The self term needs care: after the move, cluster i's own-traffic hop
    count is H[t, t] = 0 (it moved *with* itself), not H[t, p_old[i]]."""
    d = float(s[i] @ (h[t][p] - h[p[i]][p]))
    return d - float(s[i, i] * h[t][p[i]])


def optimize_placement(
    traffic: np.ndarray,
    fabric,
    *,
    init: np.ndarray | None = None,
    seed: int = 0,
    anneal_steps: int | None = None,
    device_slabs: int | None = None,
    hop_matrix: np.ndarray | None = None,
    allowed_tiles: np.ndarray | None = None,
) -> tuple[np.ndarray, dict]:
    """Traffic-aware cluster->tile placement (simulated annealing + greedy).

    Minimizes :func:`placement_cost` subject to the fabric's per-tile core
    capacity, starting from ``init`` (default: the hierarchical linear
    placement). Returns ``(placement, info)`` where ``info`` records the
    initial/final cost and predicted mean hops per delivered event.

    ``hop_matrix`` overrides the fabric's XY-hop matrix as the objective —
    it must be symmetric (the incremental swap/move deltas assume it); the
    degraded-mode repair path (:func:`repair_placement`) passes a penalty
    matrix here so traffic is steered off dead links. ``allowed_tiles`` is
    a boolean ``[n_tiles]`` mask restricting the search (and ``init``,
    which must already comply) to live tiles.

    ``device_slabs=g`` restricts the search to placements where every tile's
    clusters lie inside one of ``g`` equal contiguous cluster slabs — the
    invariant ``EventEngine.make_sharded_step`` requires to map tiles onto
    ``g`` devices — by only swapping within a slab and relocating to tiles
    currently owned by the same slab (or empty). The seed placement must
    already satisfy it (the hierarchical linear default does whenever slabs
    align with whole tiles).

    Deterministic for a given ``seed``; annealing proposes random pairwise
    swaps (and relocations when tiles have spare capacity) with O(n_clusters)
    incremental deltas, then a greedy all-pairs refinement sweep runs until
    no improving swap remains.
    """
    from repro.core.routing import tile_hop_matrix, validate_placement

    traffic = np.asarray(traffic, dtype=np.float64)
    nc = traffic.shape[0]
    if traffic.shape != (nc, nc):
        raise ValueError(f"traffic must be square, got {traffic.shape}")
    p = validate_placement(fabric, nc, init).astype(np.int64).copy()
    if hop_matrix is None:
        h = tile_hop_matrix(fabric).astype(np.float64)
    else:
        h = np.asarray(hop_matrix, dtype=np.float64)
        if h.shape != (fabric.n_tiles, fabric.n_tiles):
            raise ValueError(
                f"hop_matrix has shape {h.shape}, expected "
                f"({fabric.n_tiles}, {fabric.n_tiles})"
            )
        if not np.array_equal(h, h.T):
            raise ValueError(
                "hop_matrix must be symmetric — the incremental swap/move "
                "deltas assume H[a, b] == H[b, a]"
            )
    allowed = None
    if allowed_tiles is not None:
        allowed = np.asarray(allowed_tiles, dtype=bool)
        if allowed.shape != (fabric.n_tiles,):
            raise ValueError(
                f"allowed_tiles has shape {allowed.shape}, expected "
                f"({fabric.n_tiles},)"
            )
        live_capacity = int(allowed.sum()) * fabric.cores_per_tile
        if live_capacity < nc:
            raise ValueError(
                f"{nc} clusters cannot fit on {int(allowed.sum())} live tiles "
                f"x {fabric.cores_per_tile} cores ({live_capacity} slots)"
            )
        if not allowed[p].all():
            bad = np.flatnonzero(~allowed[p])
            raise ValueError(
                f"init places clusters {bad.tolist()} on disallowed tiles "
                f"{np.unique(p[bad]).tolist()}"
            )
    s = traffic + traffic.T
    cost0 = placement_cost(traffic, h, p)
    total = float(traffic.sum())
    info = {
        "cost_init": cost0,
        "mean_hops_init": cost0 / total if total else 0.0,
    }

    slab_of = None
    if device_slabs is not None:
        if device_slabs <= 0 or nc % device_slabs:
            raise ValueError(
                f"device_slabs={device_slabs} must divide n_clusters={nc}"
            )
        slab_of = np.arange(nc) // (nc // device_slabs)
        tiles_of_slab = [set(p[slab_of == g]) for g in range(device_slabs)]
        for g in range(device_slabs):
            for g2 in range(g + 1, device_slabs):
                shared = tiles_of_slab[g] & tiles_of_slab[g2]
                if shared:
                    raise ValueError(
                        f"seed placement splits tiles {sorted(shared)} across "
                        f"device slabs {g} and {g2}"
                    )

    if nc >= 2 and fabric.n_tiles >= 2 and total > 0:
        rng = np.random.default_rng(seed)
        tile_count = np.bincount(p, minlength=fabric.n_tiles)
        # tile -> owning slab (-1 = empty), for the device_slabs constraint
        tile_owner = np.full(fabric.n_tiles, -1, dtype=np.int64)
        if slab_of is not None:
            tile_owner[p] = slab_of  # each tile has one owner by the check above
        steps = anneal_steps if anneal_steps is not None else 4000 + 250 * nc
        # temperature from the observed swap-delta scale
        probe = [
            abs(_swap_delta(s, h, p, *sorted(rng.choice(nc, 2, replace=False))))
            for _ in range(min(64, steps))
        ]
        t0 = max(1e-9, float(np.median([d for d in probe if d > 0] or [1.0])))
        t_end = t0 * 1e-3
        cool = (t_end / t0) ** (1.0 / max(1, steps))
        temp = t0
        for _ in range(steps):
            temp *= cool
            i = int(rng.integers(nc))
            spare = tile_count < fabric.cores_per_tile
            if allowed is not None:
                spare &= allowed
            if slab_of is not None:
                spare &= (tile_owner == -1) | (tile_owner == slab_of[i])
            do_move = spare.any() and rng.random() < 0.3
            if do_move:
                t = int(rng.choice(np.flatnonzero(spare)))
                if t == p[i]:
                    continue
                delta = _move_delta(s, h, p, i, t)
                if delta < 0 or rng.random() < math.exp(-delta / temp):
                    tile_count[p[i]] -= 1
                    if slab_of is not None and tile_count[p[i]] == 0:
                        tile_owner[p[i]] = -1
                    p[i] = t
                    tile_count[t] += 1
                    if slab_of is not None:
                        tile_owner[t] = slab_of[i]
            else:
                j = int(rng.integers(nc))
                if i == j or p[i] == p[j]:
                    continue
                if slab_of is not None and slab_of[i] != slab_of[j]:
                    continue
                delta = _swap_delta(s, h, p, i, j)
                if delta < 0 or rng.random() < math.exp(-delta / temp):
                    p[i], p[j] = p[j], p[i]
        # greedy refinement: all-pairs improving swaps to a local optimum
        improved = True
        sweeps = 0
        while improved and sweeps < 16:
            improved = False
            sweeps += 1
            for i in range(nc):
                for j in range(i + 1, nc):
                    if p[i] == p[j]:
                        continue
                    if slab_of is not None and slab_of[i] != slab_of[j]:
                        continue
                    if _swap_delta(s, h, p, i, j) < -1e-12:
                        p[i], p[j] = p[j], p[i]
                        improved = True

    placement = validate_placement(fabric, nc, p.astype(np.int32))
    cost1 = placement_cost(traffic, h, placement)
    info["cost_final"] = cost1
    info["mean_hops_final"] = cost1 / total if total else 0.0
    return placement, info


def device_slab_placement(
    tables: RoutingTables,
    fabric,
    n_slabs: int,
    *,
    rates: np.ndarray | Sequence[float] | None = None,
    seed: int = 0,
    anneal_steps: int | None = None,
) -> tuple[np.ndarray, dict]:
    """Traffic-aware placement constrained to ``n_slabs`` device slabs.

    ``EventEngine.make_sharded_step`` (and :class:`ShardedEventEngine`) maps
    ``n_slabs`` equal contiguous cluster slabs onto devices, which requires
    every tile's clusters to live inside one slab. The hierarchical linear
    default placement packs clusters densely and often violates that (the
    poker CNN's 6 clusters land 4-to-a-tile, straddling a 2-slab split), so
    ``optimize_placement(device_slabs=...)`` cannot seed from it. This
    helper builds a compliant seed — slab ``g`` gets its own contiguous run
    of tiles, clusters packed ``cores_per_tile`` to a tile within it — and
    anneals from there under the slab constraint. Returns ``(placement,
    info)`` like :func:`optimize_placement`.
    """
    if not isinstance(tables, RoutingTables) and hasattr(tables, "tables"):
        tables = tables.tables
    nc = tables.n_clusters
    if n_slabs <= 0 or nc % n_slabs:
        raise ValueError(f"n_slabs={n_slabs} must divide n_clusters={nc}")
    per_slab = nc // n_slabs
    tiles_per_slab = -(-per_slab // fabric.cores_per_tile)
    if tiles_per_slab * n_slabs > fabric.n_tiles:
        raise ValueError(
            f"{n_slabs} slabs x {per_slab} clusters need "
            f"{tiles_per_slab * n_slabs} tiles, fabric has {fabric.n_tiles}"
        )
    init = np.empty(nc, dtype=np.int32)
    for g in range(n_slabs):
        lo = g * per_slab
        local = np.arange(per_slab) // fabric.cores_per_tile
        init[lo : lo + per_slab] = g * tiles_per_slab + local
    return optimize_placement(
        traffic_matrix(tables, rates),
        fabric,
        init=init,
        seed=seed,
        anneal_steps=anneal_steps,
        device_slabs=n_slabs,
    )


def session_rate(tables: RoutingTables) -> float:
    """Predicted fabric event rate of ONE session of this model (events per
    neuron-spike-rate unit): the total expected inter-cluster AER traffic of
    the compiled network under uniform firing — :func:`traffic_matrix`
    summed. The admission controller (serve/sharded.py) scores shards by
    the summed predicted rate of their resident sessions, so a model with a
    heavy routing graph counts for proportionally more of a shard's budget
    than a sparse one (DESIGN.md §17).
    """
    if not isinstance(tables, RoutingTables) and hasattr(tables, "tables"):
        tables = tables.tables
    return float(traffic_matrix(tables).sum())


def repair_placement(
    tables: RoutingTables,
    fabric,
    faults,
    *,
    rates: np.ndarray | Sequence[float] | None = None,
    seed: int = 0,
    anneal_steps: int | None = None,
) -> tuple[np.ndarray, dict]:
    """Degraded-mode placement repair around a :class:`~repro.core.faults.FaultSpec`.

    Re-runs :func:`optimize_placement` with the fault-severed fabric masked
    out: dead tiles are excluded from the search, and tile pairs whose XY
    route crosses a dead link (either direction — the annealer's objective
    must be symmetric, so a pair is penalized if *either* direction is
    severed) cost a prohibitive penalty instead of their hop count; lossy
    links add a proportional bias so traffic prefers clean routes. The
    compiled placement (``tables.tile_of_cluster``) seeds the search, with
    clusters on dead tiles first relocated to the nearest live tile with
    spare capacity — surviving sessions can then migrate with
    ``EventEngine.splice_slots`` instead of restarting.

    Returns ``(placement, report)``. ``report["feasible"]`` is ``True`` iff
    no traffic remains on a *directionally* unreachable tile pair under the
    final placement (the symmetric penalty is conservative; feasibility is
    checked against the true directed reachability);
    ``report["unreachable_traffic"]`` / ``report["unreachable_pairs"]``
    quantify what is still stranded, ``report["moved_clusters"]`` lists the
    clusters whose tile changed, and the :func:`optimize_placement` cost
    figures ride along (computed against the penalty matrix) next to
    ``mean_hops_final_true`` (the real XY hop count of the result).
    """
    from repro.core.faults import tile_fault_matrices
    from repro.core.routing import default_tile_of_cluster, tile_hop_matrix

    if not isinstance(tables, RoutingTables) and hasattr(tables, "tables"):
        tables = tables.tables
    faults.validate(fabric)
    nc = tables.n_clusters
    alive, rate = tile_fault_matrices(fabric, faults)
    tile_ok = np.ones(fabric.n_tiles, dtype=bool)
    tile_ok[list(faults.dead_tiles)] = False
    h = tile_hop_matrix(fabric).astype(np.float64)
    penalty = (float(h.max()) + 1.0) * 1e6
    ok = alive & alive.T
    h_eff = np.where(ok, h, penalty)
    # lossy (but live) routes: bias proportional to the worse direction's
    # compound drop probability, scaled past any clean detour's hop cost
    h_eff = h_eff + np.maximum(rate, rate.T) * (float(h.max()) + 1.0)
    np.fill_diagonal(h_eff, 0.0)

    traffic = traffic_matrix(tables, rates)
    init = tables.tile_of_cluster
    if init is None:
        init = default_tile_of_cluster(nc, fabric)
    p0 = np.asarray(init, dtype=np.int64).copy()
    p = p0.copy()
    # evacuate dead tiles before seeding the annealer (its init must comply)
    tile_count = np.bincount(p, minlength=fabric.n_tiles)
    for c in np.flatnonzero(~tile_ok[p]):
        spare = tile_ok & (tile_count < fabric.cores_per_tile)
        if not spare.any():
            raise ValueError(
                f"cannot evacuate cluster {c} from dead tile {int(p[c])}: "
                "no live tile has spare capacity"
            )
        t = int(np.flatnonzero(spare)[np.argmin(h[p[c]][spare])])
        tile_count[p[c]] -= 1
        p[c] = t
        tile_count[t] += 1

    placement, info = optimize_placement(
        traffic,
        fabric,
        init=p.astype(np.int32),
        seed=seed,
        anneal_steps=anneal_steps,
        hop_matrix=h_eff,
        allowed_tiles=tile_ok,
    )
    pair_alive = alive[placement[:, None], placement[None, :]]
    stranded = traffic * ~pair_alive
    np.fill_diagonal(stranded, 0.0)  # a cluster's self-traffic stays on-tile
    bad = np.argwhere(stranded > 0)
    cost_true = placement_cost(traffic, h, placement)
    total = float(traffic.sum())
    report = {
        **info,
        "feasible": bool(stranded.sum() == 0),
        "unreachable_traffic": float(stranded.sum()),
        "unreachable_pairs": [(int(a), int(b)) for a, b in bad],
        "moved_clusters": np.flatnonzero(placement != p0).tolist(),
        "mean_hops_final_true": cost_true / total if total else 0.0,
    }
    return placement, report


# ---------------------------------------------------------------------------
# compile report
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CompileReport:
    """What the compiler actually spent, vs the paper's analytical model.

    ``tags_used[c]`` counts distinct routed tags per cluster (v2 occupancy);
    ``tags_v1[c]`` is the greedy baseline (one per allocation unit) for the
    same spec — the reuse saving is their difference. ``sram_fill[n]`` /
    ``cam_fill[n]`` are per-neuron occupied entries. ``eq2_bits_per_neuron``
    evaluates memory_model eq.(2) at the network's empirical fan-out F and
    broadcast fan-out M (mean CAM audience per SRAM entry);
    ``measured_bits_per_neuron`` is the occupied-bit count of the emitted
    tables. ``mean_hops`` is the traffic-weighted predicted mesh hops per
    delivered event under ``tile_of_cluster`` (None without a fabric).
    """

    k_tags: int
    cluster_size: int
    tags_used: np.ndarray  # [n_clusters] int64
    tags_v1: np.ndarray  # [n_clusters] int64
    sram_fill: np.ndarray  # [N] int64
    cam_fill: np.ndarray  # [N] int64
    sram_bits: int
    cam_bits: int
    eq2_bits_per_neuron: float
    measured_bits_per_neuron: float
    mean_hops: float | None = None
    tile_of_cluster: np.ndarray | None = None

    def summary(self) -> str:
        lines = [
            f"clusters={len(self.tags_used)} K={self.k_tags} "
            f"C={self.cluster_size}",
            f"tags/cluster: v2 max {int(self.tags_used.max(initial=0))} "
            f"(v1 greedy would use {int(self.tags_v1.max(initial=0))}), "
            f"total {int(self.tags_used.sum())} vs {int(self.tags_v1.sum())}",
            f"SRAM fill: mean {self.sram_fill.mean():.2f} max "
            f"{int(self.sram_fill.max(initial=0))} entries/neuron "
            f"({self.sram_bits} bits)",
            f"CAM fill: mean {self.cam_fill.mean():.2f} max "
            f"{int(self.cam_fill.max(initial=0))} words/neuron "
            f"({self.cam_bits} bits)",
            f"bits/neuron: measured {self.measured_bits_per_neuron:.1f} vs "
            f"eq.(2) {self.eq2_bits_per_neuron:.1f}",
        ]
        if self.mean_hops is not None:
            lines.append(f"predicted mean mesh hops/event: {self.mean_hops:.2f}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class CompileResult:
    """Routing tables + the report describing what compiling them cost."""

    tables: RoutingTables
    report: CompileReport


def build_report(
    spec: NetworkSpec,
    tables: RoutingTables,
    fabric=None,
    rates: np.ndarray | None = None,
) -> CompileReport:
    """Measure a compiled network's resource occupancy against the model."""
    src_tag = np.asarray(tables.src_tag)
    src_dest = np.asarray(tables.src_dest)
    cam_tag = np.asarray(tables.cam_tag)
    n, nc = tables.n_neurons, tables.n_clusters

    # encode (cluster, tag) pairs as flat ints for vectorized set/count ops;
    # span covers spliced external tags (cnn.py) that may sit past k_tags-1
    span = int(
        max(tables.k_tags, src_tag.max(initial=0) + 1, cam_tag.max(initial=0) + 1)
    )
    src, ent = np.nonzero(src_tag >= 0)
    entry_codes = src_dest[src, ent].astype(np.int64) * span + src_tag[src, ent]
    # per-cluster distinct routed tags (what the allocator actually spent)
    uniq_entry_codes = np.unique(entry_codes)
    tags_used = np.bincount(
        uniq_entry_codes // span, minlength=nc
    ).astype(np.int64)
    # v1 greedy baseline: one tag per allocation unit
    tags_v1 = np.zeros(nc, dtype=np.int64)
    for u in expand_units(spec):
        tags_v1[u.cluster] += 1

    sram_fill = (src_tag >= 0).sum(1).astype(np.int64)
    cam_fill = (cam_tag >= 0).sum(1).astype(np.int64)

    # empirical eq.(2): audience size per routed (cluster, tag) gives the
    # realized second-stage fan-out M; F is the realized dense fan-out.
    # Vectorized: count CAM words per (cluster, tag), then gather each SRAM
    # entry's audience — per ENTRY, not per distinct tag, since every entry
    # reaches its tag's whole audience (that sum is the dense connection
    # count)
    cam_j, cam_s = np.nonzero(cam_tag >= 0)
    cam_codes = (
        (cam_j // tables.cluster_size).astype(np.int64) * span
        + cam_tag[cam_j, cam_s]
    )
    aud_codes, aud_counts = np.unique(cam_codes, return_counts=True)
    pos = np.searchsorted(aud_codes, entry_codes)
    pos_c = np.clip(pos, 0, max(0, len(aud_codes) - 1))
    hit = (len(aud_codes) > 0) & (aud_codes[pos_c] == entry_codes)
    n_entries = int(sram_fill.sum())
    n_connections = int(np.where(hit, aud_counts[pos_c], 0).sum()) if n_entries else 0
    eq2 = 0.0
    if n_entries and n_connections:
        from repro.core import memory_model as mm

        f_emp = n_connections / n
        m_emp = n_connections / n_entries  # mean audience per SRAM entry
        eq2 = mm.mem_total_bits(
            n=max(2, n), f=f_emp, c=tables.cluster_size, m=m_emp,
            k=max(2, tables.k_tags),
        )
    measured = (tables.sram_bits() + tables.cam_bits()) / n

    mean_hops = None
    if fabric is not None and tables.tile_of_cluster is not None:
        from repro.core.routing import tile_hop_matrix

        t = traffic_matrix(tables, rates)
        h = tile_hop_matrix(fabric).astype(np.float64)
        total = float(t.sum())
        if total:
            mean_hops = placement_cost(t, h, tables.tile_of_cluster) / total

    return CompileReport(
        k_tags=tables.k_tags,
        cluster_size=tables.cluster_size,
        tags_used=tags_used,
        tags_v1=tags_v1,
        sram_fill=sram_fill,
        cam_fill=cam_fill,
        sram_bits=tables.sram_bits(),
        cam_bits=tables.cam_bits(),
        eq2_bits_per_neuron=float(eq2),
        measured_bits_per_neuron=float(measured),
        mean_hops=mean_hops,
        tile_of_cluster=tables.tile_of_cluster,
    )


# ---------------------------------------------------------------------------
# the v2 front-end
# ---------------------------------------------------------------------------
def compile_network_v2(
    spec: NetworkSpec,
    fabric=None,
    tile_of_cluster: np.ndarray | Sequence[int] | None = None,
    *,
    allocator: str = "reuse",
    optimize: bool = True,
    rates: np.ndarray | None = None,
    seed: int = 0,
    anneal_steps: int | None = None,
    device_slabs: int | None = None,
) -> CompileResult:
    """Routing compiler v2: reuse allocation + traffic-aware placement.

    Compiles ``spec`` with the tag-reuse allocator (bit-exact vs v1, never
    more tags/SRAM/CAM), then — when a ``fabric`` is given and no explicit
    ``tile_of_cluster`` pins the layout — optimizes the cluster->tile
    placement against the network's expected traffic (``rates`` per neuron,
    default uniform) with :func:`optimize_placement`. Returns the stamped
    :class:`RoutingTables` plus a :class:`CompileReport`.
    """
    tables = compile_network(spec, allocator=allocator)
    if tile_of_cluster is not None and fabric is None:
        raise ValueError("tile_of_cluster requires a fabric to validate against")
    if fabric is not None:
        from repro.core.routing import validate_placement

        if tile_of_cluster is not None or not optimize:
            placement = validate_placement(fabric, spec.n_clusters, tile_of_cluster)
        else:
            placement, _ = optimize_placement(
                traffic_matrix(tables, rates),
                fabric,
                seed=seed,
                anneal_steps=anneal_steps,
                device_slabs=device_slabs,
            )
        tables = dataclasses.replace(tables, tile_of_cluster=placement)
    report = build_report(spec, tables, fabric=fabric, rates=rates)
    return CompileResult(tables=tables, report=report)


# ---------------------------------------------------------------------------
# compiled-network artifacts + geometry retargeting (DESIGN.md §16)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Geometry:
    """A target hardware geometry: mesh extent, core layout, memory budgets.

    The paper's prototype fixes (3x3 chips, 4 cores/chip, 256 neurons/core,
    K = 1024, 64 CAM words, 16 SRAM entries) — those are the defaults here.
    :func:`retarget` recompiles a :class:`~repro.core.tags.NetworkSpec` to
    any other point of this space and reports which of eq. (2)'s budgets
    binds first.
    """

    grid_x: int = 3
    grid_y: int = 3
    cores_per_tile: int = 4
    neurons_per_core: int = 256  # cluster_size: cluster <-> core is 1:1
    k_tags: int = 1024
    max_cam_words: int = 64
    max_sram_entries: int = 16

    @property
    def n_tiles(self) -> int:
        return self.grid_x * self.grid_y

    @property
    def n_cores(self) -> int:
        return self.n_tiles * self.cores_per_tile

    @property
    def capacity(self) -> int:
        """Total neuron slots the geometry can host."""
        return self.n_cores * self.neurons_per_core

    def fabric(self):
        """The equivalent executable :class:`~repro.core.routing.Fabric`."""
        from repro.core.routing import Fabric

        return Fabric(
            grid_x=self.grid_x,
            grid_y=self.grid_y,
            cores_per_tile=self.cores_per_tile,
            neurons_per_core=self.neurons_per_core,
        )

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class FeasibilityReport:
    """Which resource budget binds a network on a geometry (per eq. (2)).

    ``utilization`` maps each constraint to its fraction of budget used:
    ``"tags"`` (max per-cluster distinct routed tags / K), ``"cam"`` (max
    CAM words per neuron / budget), ``"sram"`` (max SRAM entries per neuron
    / budget), ``"cores"`` (clusters / cores), and ``"link"`` (peak expected
    per-step directed-link load / link FIFO capacity, under the given
    rates). ``binding`` names the constraint with the highest utilization —
    on an infeasible geometry, the one that overflowed.
    """

    feasible: bool
    binding: str
    utilization: dict
    detail: str = ""

    def asdict(self) -> dict:
        return {
            "feasible": self.feasible,
            "binding": self.binding,
            "utilization": {k: float(v) for k, v in self.utilization.items()},
            "detail": self.detail,
        }


class InfeasibleGeometryError(ValueError):
    """A network does not fit a target geometry; ``.report`` names the
    binding constraint (:class:`FeasibilityReport` with ``feasible=False``)."""

    def __init__(self, message: str, report: FeasibilityReport):
        super().__init__(message)
        self.report = report


def _tags_used_per_cluster(tables: RoutingTables) -> np.ndarray:
    """Distinct routed (cluster, tag) pairs per destination cluster."""
    src_tag = np.asarray(tables.src_tag)
    src_dest = np.asarray(tables.src_dest)
    src, ent = np.nonzero(src_tag >= 0)
    if src.size == 0:
        return np.zeros(tables.n_clusters, dtype=np.int64)
    span = int(max(tables.k_tags, src_tag.max(initial=0) + 1))
    codes = src_dest[src, ent].astype(np.int64) * span + src_tag[src, ent]
    return np.bincount(
        np.unique(codes) // span, minlength=tables.n_clusters
    ).astype(np.int64)


def _link_peak_load(
    tables: RoutingTables,
    geometry: Geometry,
    placement: np.ndarray,
    rates: np.ndarray | None,
) -> float:
    """Peak expected per-step load on any directed inter-tile link."""
    t = traffic_matrix(tables, rates)
    p = np.asarray(placement, dtype=np.int64)
    nt = geometry.n_tiles
    pair = p[:, None] * nt + p[None, :]
    loads = np.bincount(
        pair.ravel(), weights=t.ravel(), minlength=nt * nt
    ).reshape(nt, nt)
    np.fill_diagonal(loads, 0.0)  # intra-tile traffic never touches a link
    return float(loads.max(initial=0.0))


def _feasibility(
    tables: RoutingTables,
    geometry: Geometry,
    placement: np.ndarray | None,
    rates: np.ndarray | None,
    dt: float,
) -> FeasibilityReport:
    """Measure a compiled table against a geometry's budgets."""
    src_tag = np.asarray(tables.src_tag)
    cam_tag = np.asarray(tables.cam_tag)
    # tag *values* must be addressable in the geometry's [0, K) space —
    # spliced external tags (cnn.py input taps) count like any other
    tag_span = int(
        max(src_tag.max(initial=-1), cam_tag.max(initial=-1)) + 1
    )
    util = {
        "tags": max(
            int(_tags_used_per_cluster(tables).max(initial=0)), tag_span
        ) / geometry.k_tags,
        "cam": int((cam_tag >= 0).sum(1).max(initial=0)) / geometry.max_cam_words,
        "sram": int((src_tag >= 0).sum(1).max(initial=0))
        / geometry.max_sram_entries,
        "cores": tables.n_clusters / geometry.n_cores,
    }
    if placement is not None:
        from repro.core.routing import build_delivery_model

        model = build_delivery_model(
            geometry.fabric(), tables.n_clusters, dt, tile_of_cluster=placement
        )
        util["link"] = (
            _link_peak_load(tables, geometry, placement, rates)
            / model.link_capacity
        )
    hard = ("tags", "cam", "sram", "cores")
    feasible = all(util[k] <= 1.0 for k in hard)
    binding = max(util, key=util.get)
    over = [k for k in hard if util[k] > 1.0]
    detail = (
        f"over budget: {', '.join(over)}"
        if over
        else f"tightest budget: {binding} at {util[binding]:.0%}"
    )
    return FeasibilityReport(
        feasible=feasible, binding=binding, utilization=util, detail=detail
    )


@dataclasses.dataclass(frozen=True)
class CompiledArtifact:
    """A self-contained, serializable compiled network (DESIGN.md §16).

    The unit of loading for multi-model serving: routing tables (with the
    physical placement stamped in), the geometry they were compiled for, a
    :class:`FeasibilityReport` naming the binding budget, and optionally the
    :class:`CompileReport`. ``fingerprint()`` identifies the artifact
    content-exactly; the fabric entry table is a pure function of the
    tables + geometry and is reconstructed deterministically by
    :meth:`entry_table` rather than stored.
    """

    tables: RoutingTables
    geometry: Geometry
    feasibility: FeasibilityReport
    report: CompileReport | None = None

    def fingerprint(self) -> str:
        h = hashlib.sha256()
        h.update(json.dumps(self.geometry.asdict(), sort_keys=True).encode())
        h.update(self.tables.fingerprint().encode())
        return h.hexdigest()

    def entry_table(self, dt: float = 1e-3):
        """Deterministically rebuild the static fabric entry table
        (:class:`~repro.kernels.fabric_deliver.ops.FabricEntries`)."""
        from repro.core.routing import build_delivery_model, default_tile_of_cluster
        from repro.kernels.fabric_deliver.ops import build_fabric_entries

        t = self.tables
        fab = self.geometry.fabric()
        placement = t.tile_of_cluster
        if placement is None:
            placement = default_tile_of_cluster(t.n_clusters, fab)
        model = build_delivery_model(
            fab, t.n_clusters, dt, tile_of_cluster=placement
        )
        return build_fabric_entries(
            t.src_tag, t.src_dest, t.cluster_size, t.k_tags, model
        )

    # -- serialization ------------------------------------------------------
    def save(self, path: str) -> str:
        """Write the artifact under directory ``path`` (created if needed):
        ``tables.npz`` holds every array, ``artifact.json`` the metadata and
        the content fingerprint (verified on :meth:`load`)."""
        os.makedirs(path, exist_ok=True)
        t = self.tables
        arrays = {
            "src_tag": np.asarray(t.src_tag),
            "src_dest": np.asarray(t.src_dest),
            "cam_tag": np.asarray(t.cam_tag),
            "cam_syn": np.asarray(t.cam_syn),
        }
        if t.tile_of_cluster is not None:
            arrays["tile_of_cluster"] = np.asarray(t.tile_of_cluster)
        rep_meta = None
        if self.report is not None:
            r = self.report
            for k in ("tags_used", "tags_v1", "sram_fill", "cam_fill"):
                arrays[f"report_{k}"] = np.asarray(getattr(r, k))
            if r.tile_of_cluster is not None:
                arrays["report_tile_of_cluster"] = np.asarray(r.tile_of_cluster)
            rep_meta = {
                "k_tags": r.k_tags,
                "cluster_size": r.cluster_size,
                "sram_bits": r.sram_bits,
                "cam_bits": r.cam_bits,
                "eq2_bits_per_neuron": r.eq2_bits_per_neuron,
                "measured_bits_per_neuron": r.measured_bits_per_neuron,
                "mean_hops": r.mean_hops,
            }
        np.savez(os.path.join(path, "tables.npz"), **arrays)
        meta = {
            "format": 1,
            "geometry": self.geometry.asdict(),
            "cluster_size": t.cluster_size,
            "k_tags": t.k_tags,
            "feasibility": self.feasibility.asdict(),
            "report": rep_meta,
            "fingerprint": self.fingerprint(),
        }
        with open(os.path.join(path, "artifact.json"), "w") as f:
            json.dump(meta, f, indent=1)
        return path

    @classmethod
    def load(cls, path: str) -> "CompiledArtifact":
        """Read an artifact saved by :meth:`save`; raises ``ValueError`` when
        the stored fingerprint does not match the loaded content."""
        with open(os.path.join(path, "artifact.json")) as f:
            meta = json.load(f)
        with np.load(os.path.join(path, "tables.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        tables = RoutingTables(
            src_tag=arrays["src_tag"],
            src_dest=arrays["src_dest"],
            cam_tag=arrays["cam_tag"],
            cam_syn=arrays["cam_syn"],
            cluster_size=int(meta["cluster_size"]),
            k_tags=int(meta["k_tags"]),
            tile_of_cluster=arrays.get("tile_of_cluster"),
        )
        report = None
        if meta["report"] is not None:
            rm = meta["report"]
            report = CompileReport(
                k_tags=int(rm["k_tags"]),
                cluster_size=int(rm["cluster_size"]),
                tags_used=arrays["report_tags_used"],
                tags_v1=arrays["report_tags_v1"],
                sram_fill=arrays["report_sram_fill"],
                cam_fill=arrays["report_cam_fill"],
                sram_bits=int(rm["sram_bits"]),
                cam_bits=int(rm["cam_bits"]),
                eq2_bits_per_neuron=float(rm["eq2_bits_per_neuron"]),
                measured_bits_per_neuron=float(rm["measured_bits_per_neuron"]),
                mean_hops=None if rm["mean_hops"] is None else float(rm["mean_hops"]),
                tile_of_cluster=arrays.get("report_tile_of_cluster"),
            )
        fz = meta["feasibility"]
        art = cls(
            tables=tables,
            geometry=Geometry(**meta["geometry"]),
            feasibility=FeasibilityReport(
                feasible=bool(fz["feasible"]),
                binding=str(fz["binding"]),
                utilization=dict(fz["utilization"]),
                detail=str(fz.get("detail", "")),
            ),
            report=report,
        )
        if art.fingerprint() != meta["fingerprint"]:
            raise ValueError(
                f"artifact at {path} is corrupt: content fingerprint "
                f"{art.fingerprint()[:12]}... does not match the recorded "
                f"{meta['fingerprint'][:12]}..."
            )
        return art


def artifact_from_tables(
    tables: RoutingTables | CompileResult,
    geometry: Geometry,
    *,
    spec: NetworkSpec | None = None,
    rates: np.ndarray | None = None,
    seed: int = 0,
    anneal_steps: int | None = None,
    optimize: bool = True,
    dt: float = 1e-3,
) -> CompiledArtifact:
    """Bind already-compiled tables to a geometry (placement-only retarget).

    The path for networks whose tables were post-processed after compilation
    (e.g. the poker CNN's spliced input taps, which a recompile would lose):
    budgets are validated against ``geometry``, a placement on its fabric is
    kept if the compiled one fits, else re-derived (traffic-optimized when
    ``optimize``), and the feasibility report is measured from the tables as
    they are. Raises :class:`InfeasibleGeometryError` when a hard budget
    (tags / CAM / SRAM / cores) overflows. ``spec`` additionally attaches a
    fresh :class:`CompileReport`.
    """
    report = None
    if isinstance(tables, CompileResult):
        tables, report = tables.tables, tables.report
    if tables.cluster_size != geometry.neurons_per_core:
        raise InfeasibleGeometryError(
            f"tables were compiled at cluster_size={tables.cluster_size} but "
            f"the geometry hosts {geometry.neurons_per_core} neurons/core — "
            "recompile with retarget() to re-cluster",
            FeasibilityReport(
                feasible=False,
                binding="cores",
                utilization={"cores": float("inf")},
                detail="cluster_size != neurons_per_core",
            ),
        )
    fz = _feasibility(tables, geometry, None, rates, dt)
    if not fz.feasible:
        raise InfeasibleGeometryError(
            f"network does not fit geometry ({fz.detail}); binding "
            f"constraint: {fz.binding}",
            fz,
        )
    fab = geometry.fabric()
    placement = tables.tile_of_cluster
    if placement is not None:
        from repro.core.routing import validate_placement

        try:
            placement = validate_placement(fab, tables.n_clusters, placement)
        except ValueError:
            placement = None  # compiled for another fabric: re-place
    if placement is None:
        if optimize:
            placement, _ = optimize_placement(
                traffic_matrix(tables, rates),
                fab,
                seed=seed,
                anneal_steps=anneal_steps,
            )
        else:
            from repro.core.routing import default_tile_of_cluster

            placement = default_tile_of_cluster(tables.n_clusters, fab)
    tables = dataclasses.replace(tables, tile_of_cluster=placement)
    fz = _feasibility(tables, geometry, placement, rates, dt)
    if spec is not None:
        report = build_report(spec, tables, fabric=fab, rates=rates)
    return CompiledArtifact(
        tables=tables, geometry=geometry, feasibility=fz, report=report
    )


def retarget(
    spec: NetworkSpec,
    geometry: Geometry,
    *,
    allocator: str = "reuse",
    rates: np.ndarray | None = None,
    seed: int = 0,
    anneal_steps: int | None = None,
    optimize: bool = True,
    dt: float = 1e-3,
) -> CompiledArtifact:
    """Recompile ``spec`` to an arbitrary geometry (DESIGN.md §16).

    Re-clusters the network at the geometry's ``neurons_per_core`` (padding
    the neuron count up to a whole number of cores — pad neurons are
    unconnected and silent, so the dense-equivalent connectivity is
    preserved bit-exactly), re-allocates tags under the geometry's K /
    CAM / SRAM budgets, places the clusters on the geometry's mesh, and
    returns a :class:`CompiledArtifact` whose feasibility report names the
    binding constraint. An overflowing budget raises
    :class:`InfeasibleGeometryError` with the same report attached.
    """
    cs = geometry.neurons_per_core
    n_padded = -(-spec.n_neurons // cs) * cs
    if n_padded > geometry.capacity:
        raise InfeasibleGeometryError(
            f"{spec.n_neurons} neurons need {n_padded // cs} cores; the "
            f"geometry has {geometry.n_cores} (binding constraint: cores)",
            FeasibilityReport(
                feasible=False,
                binding="cores",
                utilization={"cores": (n_padded // cs) / geometry.n_cores},
                detail=f"{n_padded // cs} clusters > {geometry.n_cores} cores",
            ),
        )
    respec = NetworkSpec(
        n_neurons=n_padded,
        cluster_size=cs,
        k_tags=geometry.k_tags,
        max_cam_words=geometry.max_cam_words,
        max_sram_entries=geometry.max_sram_entries,
    )
    # re-register every group: neuron ids are geometry-invariant, but
    # connect_group buckets targets by DESTINATION CLUSTER at insertion
    # time, so the groups must re-bucket at the new cluster size
    for srcs, by_cluster, shared, copies in spec._groups:
        tgts = [t for cl in sorted(by_cluster) for t in by_cluster[cl]]
        respec.connect_group(srcs, tgts, shared_tag=shared, copies=copies)
    try:
        tables = compile_network(respec, allocator=allocator)
    except ValueError as e:
        msg = str(e)
        binding = "tags"
        if "max_cam_words" in msg:
            binding = "cam"
        elif "max_sram_entries" in msg:
            binding = "sram"
        raise InfeasibleGeometryError(
            f"network does not fit geometry: {msg}",
            FeasibilityReport(
                feasible=False,
                binding=binding,
                utilization={binding: float("inf")},
                detail=msg,
            ),
        ) from e
    return artifact_from_tables(
        tables,
        geometry,
        spec=respec,
        rates=rates,
        seed=seed,
        anneal_steps=anneal_steps,
        optimize=optimize,
        dt=dt,
    )
