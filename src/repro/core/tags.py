"""Network compiler: connectivity -> two-stage routing tables (paper §II/§III).

The compiler takes an abstract connectivity description (who talks to whom,
with which synapse type) and emits the distributed routing state of the paper:

  source (SRAM) table, one row per neuron  — stage-1 point-to-point entries
      src_tag[i, e]  : tag id broadcast into the destination cluster
      src_dest[i, e] : destination cluster id
  target (CAM) table, one row per neuron   — stage-2 subscriptions
      cam_tag[j, s]  : tag this neuron's synapse s is subscribed to
      cam_syn[j, s]  : synapse type in {0: fast-exc, 1: slow-exc,
                                        2: subtractive-inh, 3: shunting-inh}

Tag semantics are exactly the paper's: an event (tag t -> cluster c) is
broadcast to ALL neurons of cluster c and accepted by every CAM word matching
t. Two sources sending the same tag to the same cluster are therefore
indistinguishable at the destination; the compiler only merges sources onto a
shared tag when the caller explicitly asks for it (population/weight-shared
connections, as used by the spiking-CNN compiler) — otherwise every
(source, cluster) pair gets a fresh tag, and exceeding K tags in any cluster
is a compile error ("increase alpha or re-cluster", Appendix A).

Compilation is host-side numpy; the result is a pytree of int32 arrays ready
for the JAX event engine / Pallas CAM kernel.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

if TYPE_CHECKING:  # placement typing only; no import cycle at runtime
    from repro.core.routing import Fabric

__all__ = ["SynapseType", "NetworkSpec", "RoutingTables", "compile_network"]


class SynapseType:
    FAST_EXC = 0
    SLOW_EXC = 1
    SUB_INH = 2
    SHUNT_INH = 3


@dataclasses.dataclass
class NetworkSpec:
    """Mutable builder for an event-routed network.

    Neurons are integers 0..n-1, statically grouped into clusters of size
    ``cluster_size`` (cluster id = neuron // cluster_size, the "core").
    """

    n_neurons: int
    cluster_size: int
    k_tags: int  # K: tags per cluster (address space within a core)
    max_cam_words: int = 64  # CAM words per neuron (paper prototype: 64)
    max_sram_entries: int = 16  # stage-1 fan-out F/M per neuron

    def __post_init__(self) -> None:
        if self.n_neurons % self.cluster_size != 0:
            raise ValueError("n_neurons must be a multiple of cluster_size")
        # groups: (sources, {cluster: [(target, syn_type)]}, shared, copies)
        self._groups: list = []

    @property
    def n_clusters(self) -> int:
        return self.n_neurons // self.cluster_size

    def cluster_of(self, neuron: int) -> int:
        return neuron // self.cluster_size

    # ------------------------------------------------------------------ API
    def connect(self, src: int, dst: int, syn_type: int = SynapseType.FAST_EXC,
                copies: int = 1) -> None:
        """Point connection: one source, one destination synapse."""
        self.connect_group([src], [(dst, syn_type)], shared_tag=False, copies=copies)

    def connect_one_to_many(
        self, src: int, dsts: Sequence[int], syn_type: int = SynapseType.FAST_EXC
    ) -> None:
        self.connect_group([src], [(d, syn_type) for d in dsts], shared_tag=False)

    def connect_group(
        self,
        sources: Iterable[int],
        targets: Iterable[tuple[int, int]],
        shared_tag: bool = True,
        copies: int = 1,
    ) -> None:
        """Connect every source to every (target, syn_type).

        ``shared_tag=True`` makes all sources of the group share one tag per
        destination cluster (population / weight-shared connectivity — the
        paper's mechanism for keeping K constant in clustered networks).
        With ``shared_tag=False`` each source gets its own tag per cluster.
        ``copies`` programs the same tag into several CAM words of each
        target — the chip's way of realizing integer synaptic weights
        (each match fires that many pulse generators).
        """
        by_cluster: dict[int, list[tuple[int, int]]] = defaultdict(list)
        for dst, syn in targets:
            if not (0 <= dst < self.n_neurons):
                raise ValueError(f"target {dst} out of range")
            by_cluster[self.cluster_of(dst)].append((dst, int(syn)))
        srcs = tuple(sorted(set(int(s) for s in sources)))
        for s in srcs:
            if not (0 <= s < self.n_neurons):
                raise ValueError(f"source {s} out of range")
        self._groups.append((srcs, dict(by_cluster), bool(shared_tag), int(copies)))


@dataclasses.dataclass(frozen=True)
class RoutingTables:
    """Compiled two-stage routing state (numpy int32; -1 = empty slot)."""

    src_tag: np.ndarray  # [N, E]
    src_dest: np.ndarray  # [N, E]
    cam_tag: np.ndarray  # [N, S]
    cam_syn: np.ndarray  # [N, S]  (valid only where cam_tag >= 0)
    cluster_size: int
    k_tags: int
    # optional physical placement: linear tile id hosting each cluster (core)
    # on a routing.Fabric — consumed by the fabric-mode event engine
    # (DESIGN.md §11). None = no placement compiled in.
    tile_of_cluster: np.ndarray | None = None

    @property
    def n_neurons(self) -> int:
        return self.src_tag.shape[0]

    @property
    def n_clusters(self) -> int:
        return self.n_neurons // self.cluster_size

    # -- paper bookkeeping -------------------------------------------------
    def sram_bits(self) -> int:
        """Occupied source-memory bits: entries * (log2 K + log2 n_clusters)."""
        ent = int((self.src_tag >= 0).sum())
        word = int(np.ceil(np.log2(max(2, self.k_tags)))) + int(
            np.ceil(np.log2(max(2, self.n_clusters)))
        )
        return ent * word

    def cam_bits(self) -> int:
        """Occupied target-memory bits: CAM words * (log2 K + 2 syn-type bits)."""
        ent = int((self.cam_tag >= 0).sum())
        return ent * (int(np.ceil(np.log2(max(2, self.k_tags)))) + 2)

    def dense_equivalent(self) -> np.ndarray:
        """Reference fan-out expansion: [n_connections, 3] rows (src, dst, syn).

        Semantics-faithful: a (src -> tag@cluster) entry reaches EVERY neuron
        of that cluster whose CAM holds the tag. Used as the oracle in tests.
        """
        n, e = self.src_tag.shape
        rows: list[tuple[int, int, int]] = []
        # cluster -> tag -> [(neuron, syn)]
        subs: dict[tuple[int, int], list[tuple[int, int]]] = defaultdict(list)
        for j in range(n):
            cl = j // self.cluster_size
            for s in range(self.cam_tag.shape[1]):
                t = int(self.cam_tag[j, s])
                if t >= 0:
                    subs[(cl, t)].append((j, int(self.cam_syn[j, s])))
        for i in range(n):
            for k in range(e):
                t = int(self.src_tag[i, k])
                if t < 0:
                    continue
                cl = int(self.src_dest[i, k])
                for j, syn in subs[(cl, t)]:
                    rows.append((i, j, syn))
        return np.asarray(sorted(rows), dtype=np.int32).reshape(-1, 3)


def compile_network(
    spec: NetworkSpec,
    fabric: "Fabric | None" = None,
    tile_of_cluster: np.ndarray | Sequence[int] | None = None,
) -> RoutingTables:
    """Greedy tag allocation (paper Appendix A: 'tag re-assignment').

    With ``fabric`` set the tables additionally carry a cluster->tile
    placement (``tile_of_cluster``, validated against the fabric geometry;
    default: hierarchical linear placement) so the fabric-mode event engine
    can derive per-event mesh hops, delays, and link assignments.
    """
    placement = None
    if tile_of_cluster is not None and fabric is None:
        raise ValueError("tile_of_cluster requires a fabric to validate against")
    if fabric is not None:
        from repro.core.routing import validate_placement

        placement = validate_placement(fabric, spec.n_clusters, tile_of_cluster)
    n = spec.n_neurons
    src_entries: list[list[tuple[int, int]]] = [[] for _ in range(n)]  # (tag, cluster)
    cam_entries: list[list[tuple[int, int]]] = [[] for _ in range(n)]  # (tag, syn)
    next_tag = np.zeros(spec.n_clusters, dtype=np.int64)

    def alloc_tag(cluster: int) -> int:
        t = int(next_tag[cluster])
        if t >= spec.k_tags:
            raise ValueError(
                f"tag overflow in cluster {cluster}: K={spec.k_tags} exhausted; "
                "increase alpha (more tags) or re-cluster the network (Appendix A)"
            )
        next_tag[cluster] += 1
        return t

    for srcs, by_cluster, shared, copies in spec._groups:
        if not srcs:
            # an empty source set sends nothing: allocating here (the shared
            # branch used to) burns one tag per destination cluster that no
            # SRAM entry emits and no CAM word needs
            continue
        for cluster, tgts in sorted(by_cluster.items()):
            if shared:
                tags_for_src = {s: None for s in srcs}
                tag = alloc_tag(cluster)
                for s in srcs:
                    tags_for_src[s] = tag
            else:
                tags_for_src = {s: alloc_tag(cluster) for s in srcs}
            # stage-1 entries (dedupe per (src, cluster, tag))
            for s in srcs:
                entry = (tags_for_src[s], cluster)
                if entry not in src_entries[s]:
                    src_entries[s].append(entry)
                    if len(src_entries[s]) > spec.max_sram_entries:
                        raise ValueError(
                            f"source {s}: stage-1 fan-out exceeds F/M="
                            f"{spec.max_sram_entries} SRAM entries"
                        )
            # stage-2 subscriptions
            if shared:
                uniq_tags = sorted(set(tags_for_src.values()))
            else:
                uniq_tags = sorted(tags_for_src.values())
            for dst, syn in tgts:
                for t in uniq_tags:
                    for _ in range(copies):
                        cam_entries[dst].append((t, syn))
                    if len(cam_entries[dst]) > spec.max_cam_words:
                        raise ValueError(
                            f"neuron {dst}: CAM capacity {spec.max_cam_words} exceeded"
                        )

    e, s_ = spec.max_sram_entries, spec.max_cam_words
    src_tag = np.full((n, e), -1, dtype=np.int32)
    src_dest = np.full((n, e), -1, dtype=np.int32)
    cam_tag = np.full((n, s_), -1, dtype=np.int32)
    cam_syn = np.zeros((n, s_), dtype=np.int32)
    for i, entries in enumerate(src_entries):
        for k, (t, c) in enumerate(entries):
            src_tag[i, k] = t
            src_dest[i, k] = c
    for j, entries in enumerate(cam_entries):
        for k, (t, syn) in enumerate(entries):
            cam_tag[j, k] = t
            cam_syn[j, k] = syn
    return RoutingTables(
        src_tag=src_tag,
        src_dest=src_dest,
        cam_tag=cam_tag,
        cam_syn=cam_syn,
        cluster_size=spec.cluster_size,
        k_tags=spec.k_tags,
        tile_of_cluster=placement,
    )
