"""Network compiler: connectivity -> two-stage routing tables (paper §II/§III).

The compiler takes an abstract connectivity description (who talks to whom,
with which synapse type) and emits the distributed routing state of the paper:

  source (SRAM) table, one row per neuron  — stage-1 point-to-point entries
      src_tag[i, e]  : tag id broadcast into the destination cluster
      src_dest[i, e] : destination cluster id
  target (CAM) table, one row per neuron   — stage-2 subscriptions
      cam_tag[j, s]  : tag this neuron's synapse s is subscribed to
      cam_syn[j, s]  : synapse type in {0: fast-exc, 1: slow-exc,
                                        2: subtractive-inh, 3: shunting-inh}

Tag semantics are exactly the paper's: an event (tag t -> cluster c) is
broadcast to ALL neurons of cluster c and accepted by every CAM word matching
t. Two sources sending the same tag to the same cluster are therefore
indistinguishable at the destination; the compiler only merges sources onto a
shared tag when the caller explicitly asks for it (population/weight-shared
connections, as used by the spiking-CNN compiler) — otherwise every
(source, cluster) pair gets a fresh tag, and exceeding K tags in any cluster
is a compile error ("increase alpha or re-cluster", Appendix A).

Compilation is host-side numpy; the result is a pytree of int32 arrays ready
for the JAX event engine / Pallas CAM kernel.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import defaultdict
from itertools import groupby
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

if TYPE_CHECKING:  # placement typing only; no import cycle at runtime
    from repro.core.routing import Fabric

__all__ = [
    "SynapseType",
    "NetworkSpec",
    "RoutingTables",
    "TableSlab",
    "AllocUnit",
    "expand_units",
    "compile_network",
    "concat_tables",
]


class SynapseType:
    FAST_EXC = 0
    SLOW_EXC = 1
    SUB_INH = 2
    SHUNT_INH = 3


@dataclasses.dataclass
class NetworkSpec:
    """Mutable builder for an event-routed network.

    Neurons are integers 0..n-1, statically grouped into clusters of size
    ``cluster_size`` (cluster id = neuron // cluster_size, the "core").
    """

    n_neurons: int
    cluster_size: int
    k_tags: int  # K: tags per cluster (address space within a core)
    max_cam_words: int = 64  # CAM words per neuron (paper prototype: 64)
    max_sram_entries: int = 16  # stage-1 fan-out F/M per neuron

    def __post_init__(self) -> None:
        if self.n_neurons % self.cluster_size != 0:
            raise ValueError("n_neurons must be a multiple of cluster_size")
        # groups: (sources, {cluster: [(target, syn_type)]}, shared, copies)
        self._groups: list = []

    @property
    def n_clusters(self) -> int:
        return self.n_neurons // self.cluster_size

    def cluster_of(self, neuron: int) -> int:
        return neuron // self.cluster_size

    # ------------------------------------------------------------------ API
    def connect(self, src: int, dst: int, syn_type: int = SynapseType.FAST_EXC,
                copies: int = 1) -> None:
        """Point connection: one source, one destination synapse."""
        self.connect_group([src], [(dst, syn_type)], shared_tag=False, copies=copies)

    def connect_one_to_many(
        self, src: int, dsts: Sequence[int], syn_type: int = SynapseType.FAST_EXC
    ) -> None:
        self.connect_group([src], [(d, syn_type) for d in dsts], shared_tag=False)

    def connect_group(
        self,
        sources: Iterable[int],
        targets: Iterable[tuple[int, int]],
        shared_tag: bool = True,
        copies: int = 1,
    ) -> None:
        """Connect every source to every (target, syn_type).

        ``shared_tag=True`` makes all sources of the group share one tag per
        destination cluster (population / weight-shared connectivity — the
        paper's mechanism for keeping K constant in clustered networks).
        With ``shared_tag=False`` each source gets its own tag per cluster.
        ``copies`` programs the same tag into several CAM words of each
        target — the chip's way of realizing integer synaptic weights
        (each match fires that many pulse generators).
        """
        by_cluster: dict[int, list[tuple[int, int]]] = defaultdict(list)
        for dst, syn in targets:
            if not (0 <= dst < self.n_neurons):
                raise ValueError(f"target {dst} out of range")
            by_cluster[self.cluster_of(dst)].append((dst, int(syn)))
        srcs = tuple(sorted(set(int(s) for s in sources)))
        for s in srcs:
            if not (0 <= s < self.n_neurons):
                raise ValueError(f"source {s} out of range")
        self._groups.append((srcs, dict(by_cluster), bool(shared_tag), int(copies)))


@dataclasses.dataclass(frozen=True)
class RoutingTables:
    """Compiled two-stage routing state (numpy int32; -1 = empty slot)."""

    src_tag: np.ndarray  # [N, E]
    src_dest: np.ndarray  # [N, E]
    cam_tag: np.ndarray  # [N, S]
    cam_syn: np.ndarray  # [N, S]  (valid only where cam_tag >= 0)
    cluster_size: int
    k_tags: int
    # optional physical placement: linear tile id hosting each cluster (core)
    # on a routing.Fabric — consumed by the fabric-mode event engine
    # (DESIGN.md §11). None = no placement compiled in.
    tile_of_cluster: np.ndarray | None = None

    @property
    def n_neurons(self) -> int:
        return self.src_tag.shape[0]

    @property
    def n_clusters(self) -> int:
        return self.n_neurons // self.cluster_size

    # -- paper bookkeeping -------------------------------------------------
    def sram_bits(self) -> int:
        """Occupied source-memory bits: entries * (log2 K + log2 n_clusters)."""
        ent = int((self.src_tag >= 0).sum())
        word = int(np.ceil(np.log2(max(2, self.k_tags)))) + int(
            np.ceil(np.log2(max(2, self.n_clusters)))
        )
        return ent * word

    def cam_bits(self) -> int:
        """Occupied target-memory bits: CAM words * (log2 K + 2 syn-type bits)."""
        ent = int((self.cam_tag >= 0).sum())
        return ent * (int(np.ceil(np.log2(max(2, self.k_tags)))) + 2)

    def fingerprint(self) -> str:
        """Content hash of the compiled routing state (DESIGN.md §16).

        Covers every field that determines delivery semantics: the four
        tables (values and shapes), the cluster/tag geometry, and the
        physical placement. Two tables with equal fingerprints produce
        bit-identical delivery; a checkpoint stamped with this hash can be
        refused when restored against a retargeted engine
        (serve.aer.CheckpointMismatchError).
        """
        h = hashlib.sha256()
        h.update(f"C{self.cluster_size}K{self.k_tags}".encode())
        for a in (self.src_tag, self.src_dest, self.cam_tag, self.cam_syn):
            a = np.ascontiguousarray(np.asarray(a, dtype=np.int64))
            h.update(str(a.shape).encode())
            h.update(a.tobytes())
        if self.tile_of_cluster is not None:
            p = np.ascontiguousarray(
                np.asarray(self.tile_of_cluster, dtype=np.int64)
            )
            h.update(b"P" + p.tobytes())
        return h.hexdigest()

    def dense_equivalent(self) -> np.ndarray:
        """Reference fan-out expansion: [n_connections, 3] rows (src, dst, syn).

        Semantics-faithful: a (src -> tag@cluster) entry reaches EVERY neuron
        of that cluster whose CAM holds the tag. Used as the oracle in tests.
        """
        n, e = self.src_tag.shape
        rows: list[tuple[int, int, int]] = []
        # cluster -> tag -> [(neuron, syn)]
        subs: dict[tuple[int, int], list[tuple[int, int]]] = defaultdict(list)
        for j in range(n):
            cl = j // self.cluster_size
            for s in range(self.cam_tag.shape[1]):
                t = int(self.cam_tag[j, s])
                if t >= 0:
                    subs[(cl, t)].append((j, int(self.cam_syn[j, s])))
        for i in range(n):
            for k in range(e):
                t = int(self.src_tag[i, k])
                if t < 0:
                    continue
                cl = int(self.src_dest[i, k])
                for j, syn in subs[(cl, t)]:
                    rows.append((i, j, syn))
        return np.asarray(sorted(rows), dtype=np.int32).reshape(-1, 3)


@dataclasses.dataclass(frozen=True)
class TableSlab:
    """One resident model's region of a concatenated multi-model table.

    Slabs partition both axes of the shared address space: neurons
    ``[neuron_lo, neuron_hi)`` and clusters ``[cluster_lo, cluster_hi)``
    belong exclusively to this model, and its tags live in ``[0, k_tags)``
    of every one of its clusters' tag spaces. Because clusters are disjoint,
    two models may use the same tag *ids* without collision — the (cluster,
    tag) pair is the routed address, and the cluster halves never overlap
    (the "tag-space partitioning" of DESIGN.md §16).
    """

    neuron_lo: int
    neuron_hi: int
    cluster_lo: int
    cluster_hi: int
    k_tags: int  # the model's own K (<= the combined table's K)

    @property
    def n_neurons(self) -> int:
        return self.neuron_hi - self.neuron_lo

    @property
    def n_clusters(self) -> int:
        return self.cluster_hi - self.cluster_lo


def concat_tables(
    tables_list: Sequence[RoutingTables],
) -> tuple[RoutingTables, list[TableSlab]]:
    """Concatenate per-model routing tables into one slab-addressed table.

    The combined table serves every model from a single engine: model ``m``
    occupies neurons ``[slab.neuron_lo, slab.neuron_hi)`` and clusters
    ``[slab.cluster_lo, slab.cluster_hi)``; its ``src_dest`` entries are
    rebased by the cluster offset so stage-1 events stay inside the slab.
    Entry/CAM/tag widths are padded to the per-model maxima (padding rows
    are empty, ``-1``); tag values are NOT rebased — cluster disjointness
    already makes (cluster, tag) addresses collision-free.

    All models must share ``cluster_size`` (slabs must tile the combined
    cluster grid uniformly — the engine derives cluster ids by integer
    division). Placements compose slab-wise: when every model carries a
    ``tile_of_cluster`` the combined table concatenates them (each slab
    keeps its compiled placement — live re-placement, DESIGN.md §18, swaps
    one slab's placement without disturbing the others); when none does the
    combined table carries no placement (the fabric's default applies); a
    mix raises, because silently defaulting some slabs would move clusters
    other models were placed around.
    """
    if not tables_list:
        raise ValueError("concat_tables needs at least one table")
    cs = tables_list[0].cluster_size
    for i, t in enumerate(tables_list):
        if t.cluster_size != cs:
            raise ValueError(
                f"model {i} has cluster_size={t.cluster_size}, expected {cs} "
                "— slabs must tile a uniform cluster grid"
            )
    e_max = max(t.src_tag.shape[1] for t in tables_list)
    s_max = max(t.cam_tag.shape[1] for t in tables_list)
    k_max = max(t.k_tags for t in tables_list)
    n_total = sum(t.n_neurons for t in tables_list)
    src_tag = np.full((n_total, e_max), -1, dtype=np.int32)
    src_dest = np.full((n_total, e_max), -1, dtype=np.int32)
    cam_tag = np.full((n_total, s_max), -1, dtype=np.int32)
    cam_syn = np.zeros((n_total, s_max), dtype=np.int32)
    slabs: list[TableSlab] = []
    n0 = 0
    for t in tables_list:
        n1 = n0 + t.n_neurons
        c0 = n0 // cs
        e, s = t.src_tag.shape[1], t.cam_tag.shape[1]
        src_tag[n0:n1, :e] = t.src_tag
        src_dest[n0:n1, :e] = np.where(t.src_dest >= 0, t.src_dest + c0, -1)
        cam_tag[n0:n1, :s] = t.cam_tag
        cam_syn[n0:n1, :s] = t.cam_syn
        slabs.append(
            TableSlab(
                neuron_lo=n0,
                neuron_hi=n1,
                cluster_lo=c0,
                cluster_hi=n1 // cs,
                k_tags=t.k_tags,
            )
        )
        n0 = n1
    placed = [t.tile_of_cluster is not None for t in tables_list]
    if any(placed) and not all(placed):
        raise ValueError(
            "cannot concatenate tables with and without tile_of_cluster — "
            "stamp an explicit placement on every model (or on none)"
        )
    tile_of_cluster = (
        np.concatenate([np.asarray(t.tile_of_cluster) for t in tables_list])
        if all(placed)
        else None
    )
    combined = RoutingTables(
        src_tag=src_tag,
        src_dest=src_dest,
        cam_tag=cam_tag,
        cam_syn=cam_syn,
        cluster_size=cs,
        k_tags=k_max,
        tile_of_cluster=tile_of_cluster,
    )
    return combined, slabs


@dataclasses.dataclass(frozen=True)
class AllocUnit:
    """One tag-allocation unit: a (connect-group, destination-cluster) pair.

    ``shared_tag=False`` groups expand into one unit per source (each source
    gets its own tag in v1), ``shared_tag=True`` groups into one unit per
    destination cluster. A unit is the atom both allocators reason about:
    v1 ("greedy") spends one fresh tag per unit; v2 ("reuse",
    core/compiler.py) lets units with *identical source sets* share a tag —
    the only merge that is bit-exact under broadcast semantics (DESIGN.md
    §13).
    """

    cluster: int  # destination cluster the tag lives in
    sources: tuple[int, ...]  # sorted, non-empty source neuron ids
    targets: tuple[tuple[int, int], ...]  # (dst neuron, syn type)
    copies: int  # CAM words per (target, tag) — integer weight
    group: int = 0  # originating connect-group index (CAM materialization
    # batches a group-cluster's units so word order matches pre-unit v1)


def expand_units(spec: NetworkSpec) -> list[AllocUnit]:
    """Expand the spec's connect-groups into allocation units, in the exact
    order v1 allocates tags (group order, then cluster id, then source id) —
    unit index therefore reproduces v1's tag numbering per cluster. Units of
    one (group, cluster) are emitted consecutively."""
    units: list[AllocUnit] = []
    for g, (srcs, by_cluster, shared, copies) in enumerate(spec._groups):
        if not srcs:
            # an empty source set sends nothing: allocating here (the shared
            # branch used to) burns one tag per destination cluster that no
            # SRAM entry emits and no CAM word needs
            continue
        for cluster, tgts in sorted(by_cluster.items()):
            tgts_t = tuple((int(d), int(sy)) for d, sy in tgts)
            if shared:
                units.append(AllocUnit(cluster, srcs, tgts_t, copies, g))
            else:
                units.extend(
                    AllocUnit(cluster, (s,), tgts_t, copies, g) for s in srcs
                )
    return units


def _allocate_unit_tags(spec: NetworkSpec, units: list[AllocUnit], allocator: str):
    """Assign a tag to every unit: ``(tags, tags_used_per_cluster)``.

    ``"greedy"`` (v1) burns one fresh tag per unit. ``"reuse"`` (v2) colors
    the per-cluster conflict graph so same-source-set units share a tag
    (core/compiler.py).
    """
    if allocator == "reuse":
        from repro.core.compiler import allocate_tags_reuse

        return allocate_tags_reuse(spec, units)
    if allocator != "greedy":
        raise ValueError(
            f"unknown allocator {allocator!r}; available: 'greedy' (v1, one "
            "tag per unit), 'reuse' (v2 conflict-graph tag sharing)"
        )
    next_tag = np.zeros(spec.n_clusters, dtype=np.int64)
    tags = []
    for u in units:
        t = int(next_tag[u.cluster])
        if t >= spec.k_tags:
            raise ValueError(
                f"tag overflow in cluster {u.cluster}: K={spec.k_tags} "
                f"exhausted (binding constraint: tags per cluster); "
                "increase alpha (more tags), re-cluster the network "
                "(Appendix A), or compile with allocator='reuse' to share "
                "tags between same-source connect-groups"
            )
        next_tag[u.cluster] += 1
        tags.append(t)
    return tags, next_tag.astype(np.int64)


def compile_network(
    spec: NetworkSpec,
    fabric: "Fabric | None" = None,
    tile_of_cluster: np.ndarray | Sequence[int] | None = None,
    allocator: str = "greedy",
) -> RoutingTables:
    """Tag allocation + table materialization (paper Appendix A).

    ``allocator`` selects the tag-assignment strategy: ``"greedy"`` (v1,
    the paper's baseline — a fresh tag per allocation unit, overflow is a
    compile error) or ``"reuse"`` (v2 — conflict-graph coloring that lets
    units with identical source sets share one tag, bit-exact by
    construction; see core/compiler.py and DESIGN.md §13). The routing
    compiler v2 front-end :func:`repro.core.compiler.compile_network_v2`
    adds traffic-aware placement and a :class:`~repro.core.compiler.CompileReport`
    on top of this function.

    With ``fabric`` set the tables additionally carry a cluster->tile
    placement (``tile_of_cluster``, validated against the fabric geometry;
    default: hierarchical linear placement) so the fabric-mode event engine
    can derive per-event mesh hops, delays, and link assignments.
    """
    placement = None
    if tile_of_cluster is not None and fabric is None:
        raise ValueError("tile_of_cluster requires a fabric to validate against")
    if fabric is not None:
        from repro.core.routing import validate_placement

        placement = validate_placement(fabric, spec.n_clusters, tile_of_cluster)
    n = spec.n_neurons
    units = expand_units(spec)
    unit_tags, _ = _allocate_unit_tags(spec, units, allocator)

    src_entries: list[list[tuple[int, int]]] = [[] for _ in range(n)]  # (tag, cluster)
    cam_entries: list[list[tuple[int, int]]] = [[] for _ in range(n)]  # (tag, syn)
    # materialize per (group, cluster) run — expand_units emits those
    # consecutively — so CAM word order stays target-outer / tag-inner,
    # bit-identical to the pre-unit v1 layout (a multi-source non-shared
    # group writes each target's words for ALL its tags contiguously)
    for _, run_iter in groupby(
        zip(units, unit_tags), key=lambda ut: (ut[0].group, ut[0].cluster)
    ):
        run = list(run_iter)
        # stage-1 entries (dedupe per (src, cluster, tag) — units sharing a
        # tag collapse to one SRAM entry per source, the v2 memory win)
        for u, tag in run:
            for s in u.sources:
                entry = (tag, u.cluster)
                if entry not in src_entries[s]:
                    src_entries[s].append(entry)
                    if len(src_entries[s]) > spec.max_sram_entries:
                        raise ValueError(
                            f"source {s} (cluster {spec.cluster_of(s)}): "
                            f"stage-1 fan-out exceeds F/M="
                            f"{spec.max_sram_entries} SRAM entries while "
                            f"adding its entry for cluster {u.cluster} "
                            f"(binding constraint: max_sram_entries)"
                        )
        # stage-2 subscriptions: one group-cluster's units share a target
        # list; each target subscribes to every unit tag, sorted
        u0 = run[0][0]
        run_tags = sorted(tag for _, tag in run)
        for dst, syn in u0.targets:
            for tag in run_tags:
                for _ in range(u0.copies):
                    cam_entries[dst].append((tag, syn))
                if len(cam_entries[dst]) > spec.max_cam_words:
                    raise ValueError(
                        f"neuron {dst} (cluster {spec.cluster_of(dst)}): CAM "
                        f"capacity {spec.max_cam_words} exceeded while "
                        f"subscribing to tag {tag} (binding constraint: "
                        f"max_cam_words)"
                    )

    e, s_ = spec.max_sram_entries, spec.max_cam_words
    src_tag = np.full((n, e), -1, dtype=np.int32)
    src_dest = np.full((n, e), -1, dtype=np.int32)
    cam_tag = np.full((n, s_), -1, dtype=np.int32)
    cam_syn = np.zeros((n, s_), dtype=np.int32)
    for i, entries in enumerate(src_entries):
        for k, (t, c) in enumerate(entries):
            src_tag[i, k] = t
            src_dest[i, k] = c
    for j, entries in enumerate(cam_entries):
        for k, (t, syn) in enumerate(entries):
            cam_tag[j, k] = t
            cam_syn[j, k] = syn
    return RoutingTables(
        src_tag=src_tag,
        src_dest=src_dest,
        cam_tag=cam_tag,
        cam_syn=cam_syn,
        cluster_size=spec.cluster_size,
        k_tags=spec.k_tags,
        tile_of_cluster=placement,
    )
