"""Analytical model of the hierarchical-mesh routing fabric (paper §III, §V).

The prototype's QDI circuits are asynchronous; XLA programs are not. What we
reproduce here is the paper's *quantitative* fabric model — hop counts,
latency, energy, and bandwidth of the R1/R2/R3 hierarchy — as an explicit
analytical model parameterized by the measured chip constants (Tables II/III).
Benchmarks use it to regenerate Tables II-IV and the average-distance claim
(hierarchy: sqrt(N)/3 vs flat mesh: 2*sqrt(N)/3).

Geometry: a ``grid_x x grid_y`` 2D mesh of tiles (chips); each tile has
``cores_per_tile`` cores behind one R2 tree and one R3 mesh router; each core
has ``neurons_per_core`` neurons behind an R1 router.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "ChipConstants",
    "Fabric",
    "FabricDeliveryModel",
    "build_delivery_model",
    "default_tile_of_cluster",
    "tile_hop_matrix",
    "validate_placement",
    "avg_distance_hierarchical",
    "avg_distance_mesh",
]


@dataclasses.dataclass(frozen=True)
class ChipConstants:
    """Measured prototype constants (Tables II and III)."""

    # Table II
    broadcast_time_s: float = 27e-9  # CAM broadcast+search+handshake per core
    latency_across_chip_s: float = 15.4e-9  # includes IO pads (measured)
    r3_latency_s: float = 2.5e-9  # internal R3 hop (0.18um)
    r3_throughput_eps: float = 400e6  # events/s per R3 router
    io_in_eps: float = 30e6
    io_out_eps: float = 21e6
    lut_read_bps: float = 750e6
    # Table III (energy per operation) keyed by core supply voltage
    energy_j: dict = dataclasses.field(
        default_factory=lambda: {
            1.8: {
                "spike": 883e-12,
                "encode": 883e-12,
                "broadcast": 6.84e-9,
                "route_core": 360e-12,
                "pulse_extend": 324e-12,
            },
            1.3: {
                "spike": 260e-12,
                "encode": 507e-12,
                "broadcast": 2.2e-9,
                "route_core": 78e-12,
                "pulse_extend": 26e-12,
            },
        }
    )
    # Table IV
    energy_per_hop_j: float = 17e-12  # @1.3V


@dataclasses.dataclass(frozen=True)
class Fabric:
    grid_x: int = 3
    grid_y: int = 3
    cores_per_tile: int = 4
    neurons_per_core: int = 256
    constants: ChipConstants = dataclasses.field(default_factory=ChipConstants)

    @property
    def n_tiles(self) -> int:
        return self.grid_x * self.grid_y

    @property
    def n_cores(self) -> int:
        return self.n_tiles * self.cores_per_tile

    @property
    def n_neurons(self) -> int:
        return self.n_cores * self.neurons_per_core

    # -- addressing ------------------------------------------------------
    def tile_index(self, core: int) -> int:
        """Linear tile id of a core. Raises on out-of-range ids — wrapping
        silently (core 36 on a 3x3x4 fabric aliasing core 0) hides mis-sized
        placements."""
        if not 0 <= core < self.n_cores:
            raise ValueError(
                f"core {core} out of range for a "
                f"{self.grid_x}x{self.grid_y}x{self.cores_per_tile} fabric "
                f"({self.n_cores} cores)"
            )
        return core // self.cores_per_tile

    def tile_of_core(self, core: int) -> tuple[int, int]:
        t = self.tile_index(core)
        return t % self.grid_x, t // self.grid_x

    def tile_xy(self, tile: int) -> tuple[int, int]:
        """(x, y) mesh coordinates of a linear tile id."""
        if not 0 <= tile < self.n_tiles:
            raise ValueError(f"tile {tile} out of range ({self.n_tiles} tiles)")
        return tile % self.grid_x, tile // self.grid_x

    def hops(self, src_core: int, dst_core: int) -> dict:
        """Router traversals for one event src->dst (XY routing for R3)."""
        sx, sy = self.tile_of_core(src_core)
        dx, dy = self.tile_of_core(dst_core)
        same_tile = (sx, sy) == (dx, dy)
        same_core = same_tile and src_core == dst_core
        mesh_hops = abs(sx - dx) + abs(sy - dy)
        return {
            "r1": 1 if same_core else 2,  # src R1 (+ dst R1 when leaving the core)
            "r2": 0 if same_core else 2,  # up through src R2, down through dst R2
            "r3": mesh_hops,
            "broadcast": 1,  # destination-core CAM broadcast always happens
        }

    def latency_s(self, src_core: int, dst_core: int) -> float:
        """Event latency along the hierarchy (analytical, Table II constants)."""
        c, h = self.constants, self.hops(src_core, dst_core)
        lat = h["broadcast"] * c.broadcast_time_s
        lat += h["r3"] * c.latency_across_chip_s  # chip-to-chip traversal
        # R1/R2 traversals are folded into broadcast + across-chip measurements
        # on the prototype; model them at the internal R3 hop cost.
        lat += (h["r1"] + h["r2"] - 2) * c.r3_latency_s if h["r2"] else 0.0
        return lat

    def energy_j(self, src_core: int, dst_core: int, vdd: float = 1.3) -> float:
        """Energy for one spike delivered src_core -> dst_core (Table III)."""
        e = self.constants.energy_j[vdd]
        h = self.hops(src_core, dst_core)
        total = e["spike"] + e["encode"] + e["broadcast"] + e["pulse_extend"]
        if h["r2"]:
            total += e["route_core"]
        total += h["r3"] * self.constants.energy_per_hop_j
        return total

    # -- aggregate traffic -------------------------------------------------
    def traffic(self, rates_hz: np.ndarray, dst_cores: list[list[int]]) -> dict:
        """Router-level event load for per-core mean spike rates.

        rates_hz[c]: summed neuron spike rate of core c;
        dst_cores[c]: stage-1 destination cores of core c's neurons.
        Returns events/s at each hierarchy level + utilization bounds.
        """
        if len(rates_hz) != self.n_cores:
            raise ValueError(
                f"rates_hz has {len(rates_hz)} entries, fabric has {self.n_cores} cores"
            )
        if len(dst_cores) != self.n_cores:
            raise ValueError(
                f"dst_cores has {len(dst_cores)} entries, fabric has {self.n_cores} cores"
            )
        c = self.constants
        r1 = np.zeros(self.n_cores)
        r3_total = 0.0
        broadcasts = np.zeros(self.n_cores)
        for src, dsts in enumerate(dst_cores):
            for d in dsts:
                h = self.hops(src, d)
                r1[src] += rates_hz[src]
                broadcasts[d] += rates_hz[src]
                r3_total += rates_hz[src] * h["r3"]
        bcast_limit = 1.0 / c.broadcast_time_s
        return {
            "r1_events_per_s": r1,
            "broadcast_events_per_s": broadcasts,
            "r3_events_per_s": r3_total,
            "broadcast_utilization": broadcasts.max() / bcast_limit if len(broadcasts) else 0.0,
            "r3_utilization": r3_total / (c.r3_throughput_eps * self.n_tiles),
        }

    def max_fan_in(self, rate_hz: float) -> float:
        """Paper §V: fan-in supportable at a given mean rate.

        Worst case (no source sharing): a core receives neurons_per_core * F
        events/s; bounding by the 1/27ns ~ 37 Mevents/s broadcast bandwidth
        gives F = bw / (256 * rate) — reproduces the paper's 7200 @ 20 Hz and
        1400 @ 100 Hz (the paper rounds).
        """
        bandwidth = 1.0 / self.constants.broadcast_time_s
        return bandwidth / (self.neurons_per_core * rate_hz)


# ---------------------------------------------------------------------------
# Executable delivery model: per-cluster-pair constants for the event engine
# ---------------------------------------------------------------------------
def default_tile_of_cluster(n_clusters: int, fabric: Fabric) -> np.ndarray:
    """Hierarchical (linear) placement: cluster c -> tile c // cores_per_tile.

    Consecutive clusters fill each tile's cores before moving to the next
    tile — the paper's hierarchy assumption (local traffic resolves below
    the R3 mesh).
    """
    if n_clusters > fabric.n_cores:
        raise ValueError(
            f"{n_clusters} clusters do not fit on a fabric with {fabric.n_cores} cores"
        )
    return (np.arange(n_clusters, dtype=np.int32) // fabric.cores_per_tile).astype(
        np.int32
    )


def tile_hop_matrix(fabric: Fabric) -> np.ndarray:
    """[n_tiles, n_tiles] int32 XY-Manhattan R3 hops between linear tile ids.

    The single definition of mesh distance shared by
    :func:`build_delivery_model` (per-cluster-pair delay/latency tables) and
    the traffic-aware placement optimizer (core/compiler.py), so the
    optimizer's objective and the executable fabric can never disagree on
    what a hop is.
    """
    t = np.arange(fabric.n_tiles, dtype=np.int32)
    tx, ty = t % fabric.grid_x, t // fabric.grid_x
    return (
        np.abs(tx[:, None] - tx[None, :]) + np.abs(ty[:, None] - ty[None, :])
    ).astype(np.int32)


def validate_placement(
    fabric: Fabric, n_clusters: int, tile_of_cluster: np.ndarray | None
) -> np.ndarray:
    """Normalize + validate a cluster->tile placement; O(n_clusters).

    ``None`` yields the hierarchical linear default. Checks shape, tile-id
    range, and per-tile core capacity. Shared by :func:`build_delivery_model`
    and ``tags.compile_network`` (which must not pay the model's O(nc^2)
    matrix build just to validate).
    """
    if tile_of_cluster is None:
        return default_tile_of_cluster(n_clusters, fabric)
    tiles = np.asarray(tile_of_cluster, dtype=np.int32)
    if tiles.shape != (n_clusters,):
        raise ValueError(
            f"tile_of_cluster has shape {tiles.shape}, expected ({n_clusters},)"
        )
    if tiles.size and (tiles.min() < 0 or tiles.max() >= fabric.n_tiles):
        raise ValueError(
            f"tile ids must lie in [0, {fabric.n_tiles}); got "
            f"[{tiles.min()}, {tiles.max()}]"
        )
    counts = np.bincount(tiles, minlength=fabric.n_tiles)
    if counts.max(initial=0) > fabric.cores_per_tile:
        raise ValueError(
            f"placement puts {counts.max()} clusters on one tile; the fabric "
            f"has {fabric.cores_per_tile} cores per tile"
        )
    return tiles


@dataclasses.dataclass(frozen=True)
class FabricDeliveryModel:
    """Per-cluster-pair constants driving executable fabric delivery.

    The event engine's fabric mode (core/dispatch.py ``FabricBackend``,
    DESIGN.md §11) gathers these [n_clusters, n_clusters] tables per routed
    event instead of calling the scalar :class:`Fabric` methods: mesh hop
    counts, arrival delays in integer timesteps, and the Table II/III
    latency/energy figures for the per-step accumulators (link-FIFO bins are
    derived from ``tile_of_cluster`` at routing time). Host-side numpy; the
    dispatch backend uploads them once as jnp constants.
    """

    tile_of_cluster: np.ndarray  # [nc] int32 linear tile id per cluster
    n_tiles: int
    mesh_hops: np.ndarray  # [nc, nc] int32 R3 (XY Manhattan) hops
    delay_steps: np.ndarray  # [nc, nc] int32 arrival delay, 0 = same step
    latency_s: np.ndarray  # [nc, nc] float32 per-event latency (Table II)
    energy_j: np.ndarray  # [nc, nc] float32 per-event energy (Table III/IV)
    link_capacity: int  # events per directed inter-tile link per step
    max_delay: int  # delay_steps.max()
    # fault injection (core/faults.py, DESIGN.md §15): None = healthy fabric.
    # pair_alive[a, b] False = cluster pair unreachable (dead tile/link on the
    # XY route — a dead link is a zero-capacity link); pair_drop_rate[a, b] is
    # the compound stochastic loss along the route.
    pair_alive: np.ndarray | None = None  # [nc, nc] bool
    pair_drop_rate: np.ndarray | None = None  # [nc, nc] float32
    faults: object | None = None  # the FaultSpec these matrices came from


def build_delivery_model(
    fabric: Fabric,
    n_clusters: int,
    dt: float,
    tile_of_cluster: np.ndarray | None = None,
    vdd: float = 1.3,
    link_capacity: int | None = None,
    faults=None,  # faults.FaultSpec | None
) -> FabricDeliveryModel:
    """Precompute the per-cluster-pair fabric constants for a placement.

    ``tile_of_cluster[c]`` is the linear tile id hosting engine cluster
    (core) ``c`` — default is the hierarchical linear placement. Distinct
    clusters on one tile are distinct cores (R2 hop, no mesh hops); only the
    diagonal is the same-core case. Cross-tile events arrive
    ``ceil(mesh_hops * latency_across_chip_s / dt)`` steps later — the
    broadcast/R1/R2 portion of the latency is far below any usable ``dt``
    and is folded into the engine's intrinsic one-step spike->drive delay.
    ``link_capacity`` defaults to ``r3_throughput_eps * dt`` events per
    directed tile pair per step (each pair modeled as a virtual channel;
    physical XY link sharing is not modeled).

    ``faults`` (a :class:`~repro.core.faults.FaultSpec`) injects topology
    faults: cluster pairs whose XY route crosses a dead tile/link become
    unreachable (``pair_alive`` False — zero effective capacity), lossy
    links compound into ``pair_drop_rate``. The fault matrices ride on the
    returned model so every delivery path derives its liveness masks from
    one place.
    """
    if dt <= 0:
        raise ValueError(f"dt must be positive, got {dt}")
    tiles = validate_placement(fabric, n_clusters, tile_of_cluster)
    c = fabric.constants
    hops = tile_hop_matrix(fabric)[tiles[:, None], tiles[None, :]]
    same_core = np.eye(n_clusters, dtype=bool)
    # vectorized Fabric.latency_s / Fabric.energy_j (r1/r2 follow same_core)
    r1 = np.where(same_core, 1, 2)
    r2 = np.where(same_core, 0, 2)
    latency = c.broadcast_time_s + hops * c.latency_across_chip_s
    latency = latency + np.where(r2 > 0, (r1 + r2 - 2) * c.r3_latency_s, 0.0)
    e = c.energy_j[vdd]
    energy = e["spike"] + e["encode"] + e["broadcast"] + e["pulse_extend"]
    energy = energy + np.where(r2 > 0, e["route_core"], 0.0)
    energy = energy + hops * c.energy_per_hop_j
    # arrival delay in steps; the 1e-9 guards float-ceil off-by-one on exact
    # multiples of dt
    delay = np.ceil(hops * c.latency_across_chip_s / dt - 1e-9).astype(np.int32)
    delay = np.maximum(delay, 0)
    if link_capacity is None:
        link_capacity = max(1, int(c.r3_throughput_eps * dt))
    elif link_capacity <= 0:
        raise ValueError(f"link_capacity must be positive, got {link_capacity}")
    pair_alive = pair_drop_rate = None
    if faults is not None and faults.routes_faulted:
        from repro.core.faults import pair_fault_matrices

        pair_alive, pair_drop_rate = pair_fault_matrices(fabric, tiles, faults)
    return FabricDeliveryModel(
        tile_of_cluster=tiles,
        n_tiles=fabric.n_tiles,
        mesh_hops=hops,
        delay_steps=delay,
        latency_s=latency.astype(np.float32),
        energy_j=energy.astype(np.float32),
        link_capacity=int(link_capacity),
        max_delay=int(delay.max(initial=0)),
        pair_alive=pair_alive,
        pair_drop_rate=pair_drop_rate,
        faults=faults if pair_alive is not None else None,
    )


# ---------------------------------------------------------------------------
# Average-distance scaling (Table IV)
# ---------------------------------------------------------------------------
def avg_distance_mesh(n_nodes: int) -> float:
    """Flat 2D mesh: mean Manhattan distance ~ 2*sqrt(N)/3."""
    side = int(np.ceil(np.sqrt(n_nodes)))
    xs = np.arange(side)
    d1 = np.abs(xs[:, None] - xs[None, :]).mean()  # mean |x1-x2| over a side
    return 2.0 * d1


def avg_distance_hierarchical(n_nodes: int, cluster: int = 4) -> float:
    """Hierarchy concentrates local traffic: distance ~ sqrt(N)/3.

    Model: fraction of traffic resolved below the mesh (R1/R2) contributes ~0
    mesh hops; the rest traverses the (sqrt(N)/cluster-side) reduced mesh.
    With 4 cores/tile the reduced mesh has N/4 nodes -> mean distance
    2*sqrt(N/4)/3 = sqrt(N)/3, matching the paper's Table IV entry.
    """
    return avg_distance_mesh(max(1, n_nodes // cluster))
