"""Analytical model of the hierarchical-mesh routing fabric (paper §III, §V).

The prototype's QDI circuits are asynchronous; XLA programs are not. What we
reproduce here is the paper's *quantitative* fabric model — hop counts,
latency, energy, and bandwidth of the R1/R2/R3 hierarchy — as an explicit
analytical model parameterized by the measured chip constants (Tables II/III).
Benchmarks use it to regenerate Tables II-IV and the average-distance claim
(hierarchy: sqrt(N)/3 vs flat mesh: 2*sqrt(N)/3).

Geometry: a ``grid_x x grid_y`` 2D mesh of tiles (chips); each tile has
``cores_per_tile`` cores behind one R2 tree and one R3 mesh router; each core
has ``neurons_per_core`` neurons behind an R1 router.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ChipConstants", "Fabric", "avg_distance_hierarchical", "avg_distance_mesh"]


@dataclasses.dataclass(frozen=True)
class ChipConstants:
    """Measured prototype constants (Tables II and III)."""

    # Table II
    broadcast_time_s: float = 27e-9  # CAM broadcast+search+handshake per core
    latency_across_chip_s: float = 15.4e-9  # includes IO pads (measured)
    r3_latency_s: float = 2.5e-9  # internal R3 hop (0.18um)
    r3_throughput_eps: float = 400e6  # events/s per R3 router
    io_in_eps: float = 30e6
    io_out_eps: float = 21e6
    lut_read_bps: float = 750e6
    # Table III (energy per operation) keyed by core supply voltage
    energy_j: dict = dataclasses.field(
        default_factory=lambda: {
            1.8: {
                "spike": 883e-12,
                "encode": 883e-12,
                "broadcast": 6.84e-9,
                "route_core": 360e-12,
                "pulse_extend": 324e-12,
            },
            1.3: {
                "spike": 260e-12,
                "encode": 507e-12,
                "broadcast": 2.2e-9,
                "route_core": 78e-12,
                "pulse_extend": 26e-12,
            },
        }
    )
    # Table IV
    energy_per_hop_j: float = 17e-12  # @1.3V


@dataclasses.dataclass(frozen=True)
class Fabric:
    grid_x: int = 3
    grid_y: int = 3
    cores_per_tile: int = 4
    neurons_per_core: int = 256
    constants: ChipConstants = dataclasses.field(default_factory=ChipConstants)

    @property
    def n_tiles(self) -> int:
        return self.grid_x * self.grid_y

    @property
    def n_cores(self) -> int:
        return self.n_tiles * self.cores_per_tile

    @property
    def n_neurons(self) -> int:
        return self.n_cores * self.neurons_per_core

    # -- addressing ------------------------------------------------------
    def tile_of_core(self, core: int) -> tuple[int, int]:
        t = core // self.cores_per_tile
        return t % self.grid_x, t // self.grid_x

    def hops(self, src_core: int, dst_core: int) -> dict:
        """Router traversals for one event src->dst (XY routing for R3)."""
        sx, sy = self.tile_of_core(src_core)
        dx, dy = self.tile_of_core(dst_core)
        same_tile = (sx, sy) == (dx, dy)
        same_core = same_tile and src_core == dst_core
        mesh_hops = abs(sx - dx) + abs(sy - dy)
        return {
            "r1": 1 if same_core else 2,  # src R1 (+ dst R1 when leaving the core)
            "r2": 0 if same_core else 2,  # up through src R2, down through dst R2
            "r3": mesh_hops,
            "broadcast": 1,  # destination-core CAM broadcast always happens
        }

    def latency_s(self, src_core: int, dst_core: int) -> float:
        """Event latency along the hierarchy (analytical, Table II constants)."""
        c, h = self.constants, self.hops(src_core, dst_core)
        lat = h["broadcast"] * c.broadcast_time_s
        lat += h["r3"] * c.latency_across_chip_s  # chip-to-chip traversal
        # R1/R2 traversals are folded into broadcast + across-chip measurements
        # on the prototype; model them at the internal R3 hop cost.
        lat += (h["r1"] + h["r2"] - 2) * c.r3_latency_s if h["r2"] else 0.0
        return lat

    def energy_j(self, src_core: int, dst_core: int, vdd: float = 1.3) -> float:
        """Energy for one spike delivered src_core -> dst_core (Table III)."""
        e = self.constants.energy_j[vdd]
        h = self.hops(src_core, dst_core)
        total = e["spike"] + e["encode"] + e["broadcast"] + e["pulse_extend"]
        if h["r2"]:
            total += e["route_core"]
        total += h["r3"] * self.constants.energy_per_hop_j
        return total

    # -- aggregate traffic -------------------------------------------------
    def traffic(self, rates_hz: np.ndarray, dst_cores: list[list[int]]) -> dict:
        """Router-level event load for per-core mean spike rates.

        rates_hz[c]: summed neuron spike rate of core c;
        dst_cores[c]: stage-1 destination cores of core c's neurons.
        Returns events/s at each hierarchy level + utilization bounds.
        """
        c = self.constants
        r1 = np.zeros(self.n_cores)
        r3_total = 0.0
        broadcasts = np.zeros(self.n_cores)
        for src, dsts in enumerate(dst_cores):
            for d in dsts:
                h = self.hops(src, d)
                r1[src] += rates_hz[src]
                broadcasts[d] += rates_hz[src]
                r3_total += rates_hz[src] * h["r3"]
        bcast_limit = 1.0 / c.broadcast_time_s
        return {
            "r1_events_per_s": r1,
            "broadcast_events_per_s": broadcasts,
            "r3_events_per_s": r3_total,
            "broadcast_utilization": broadcasts.max() / bcast_limit if len(broadcasts) else 0.0,
            "r3_utilization": r3_total / (c.r3_throughput_eps * self.n_tiles),
        }

    def max_fan_in(self, rate_hz: float) -> float:
        """Paper §V: fan-in supportable at a given mean rate.

        Worst case (no source sharing): a core receives neurons_per_core * F
        events/s; bounding by the 1/27ns ~ 37 Mevents/s broadcast bandwidth
        gives F = bw / (256 * rate) — reproduces the paper's 7200 @ 20 Hz and
        1400 @ 100 Hz (the paper rounds).
        """
        bandwidth = 1.0 / self.constants.broadcast_time_s
        return bandwidth / (self.neurons_per_core * rate_hz)


# ---------------------------------------------------------------------------
# Average-distance scaling (Table IV)
# ---------------------------------------------------------------------------
def avg_distance_mesh(n_nodes: int) -> float:
    """Flat 2D mesh: mean Manhattan distance ~ 2*sqrt(N)/3."""
    side = int(np.ceil(np.sqrt(n_nodes)))
    xs = np.arange(side)
    d1 = np.abs(xs[:, None] - xs[None, :]).mean()  # mean |x1-x2| over a side
    return 2.0 * d1


def avg_distance_hierarchical(n_nodes: int, cluster: int = 4) -> float:
    """Hierarchy concentrates local traffic: distance ~ sqrt(N)/3.

    Model: fraction of traffic resolved below the mesh (R1/R2) contributes ~0
    mesh hops; the rest traverses the (sqrt(N)/cluster-side) reduced mesh.
    With 4 cores/tile the reduced mesh has N/4 nodes -> mean distance
    2*sqrt(N/4)/3 = sqrt(N)/3, matching the paper's Table IV entry.
    """
    return avg_distance_mesh(max(1, n_nodes // cluster))
