"""Pluggable dispatch backends for batched event delivery (DESIGN.md §9).

A dispatch backend turns (spikes, routing tables, external tag activity)
into per-neuron synaptic drive — the full stage-1 + stage-2 path of the
paper — for a whole batch of concurrent event streams at once. All backends
consume ``spikes [..., N]`` / ``external_activity [..., n_clusters, K]`` and
return ``drive [..., N, N_SYN_TYPES]``; they differ only in *where* the
stage-2 CAM match runs:

  * ``reference`` — pure-jnp gather/einsum (oracle, CPU default)
  * ``pallas``    — the kernels/cam_match TPU kernel, grid (B, cluster,
                    neuron-tile): the activity row stays VMEM-pinned per
                    cluster while neurons and batch tile the MXU
  * ``sharded``   — shard_map over a 2-D mesh (batch over ``data``,
                    clusters over ``model``): stage-1 partials are
                    reduce-scattered to the owning cluster slab (the
                    R2/R3 point-to-point hop), stage-2 is fully local

Backends are selected by name through :func:`get_backend` — this registry
replaces the old ``use_kernel`` bool and the ad-hoc kernel import that used
to live inside ``two_stage_deliver``. Third-party backends can register via
:func:`register_backend`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.two_stage import N_SYN_TYPES, stage1_route, stage2_cam_match

__all__ = [
    "DispatchBackend",
    "ReferenceBackend",
    "PallasBackend",
    "ShardedBackend",
    "register_backend",
    "get_backend",
    "available_backends",
]

_REGISTRY: dict[str, type] = {}


def register_backend(name: str):
    """Class decorator: register a :class:`DispatchBackend` under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(spec: str | DispatchBackend | None = "reference", **options) -> DispatchBackend:
    """Resolve a backend by name (constructing it with ``options``) or pass
    an already-constructed instance through unchanged."""
    if isinstance(spec, DispatchBackend):
        if options:
            raise ValueError(
                f"backend options {sorted(options)} ignored: {spec.name!r} was "
                "passed as an instance — configure it at construction instead"
            )
        return spec
    if spec is None:
        spec = "reference"
    try:
        cls = _REGISTRY[spec]
    except KeyError:
        raise ValueError(
            f"unknown dispatch backend {spec!r}; available: {available_backends()}"
        ) from None
    return cls(**options)


class DispatchBackend:
    """Interface: batched stage-1 scatter shared, stage-2 pluggable."""

    name = "abstract"

    # -- stage 2 -----------------------------------------------------------
    def cam_match(
        self,
        activity: jax.Array,  # [..., n_clusters, K]
        cam_tag: jax.Array,  # [N, S]
        cam_syn: jax.Array,  # [N, S]
        cluster_size: int,
    ) -> jax.Array:  # [..., N, N_SYN_TYPES]
        raise NotImplementedError

    # -- full delivery -----------------------------------------------------
    def deliver(
        self,
        spikes: jax.Array,  # [..., N]
        src_tag: jax.Array,
        src_dest: jax.Array,
        cam_tag: jax.Array,
        cam_syn: jax.Array,
        cluster_size: int,
        k_tags: int,
        external_activity: jax.Array | None = None,
    ) -> jax.Array:
        n = spikes.shape[-1]
        a = stage1_route(spikes, src_tag, src_dest, n // cluster_size, k_tags)
        if external_activity is not None:
            a = a + external_activity
        return self.cam_match(a, cam_tag, cam_syn, cluster_size)


@register_backend("reference")
@dataclasses.dataclass(frozen=True)
class ReferenceBackend(DispatchBackend):
    """Pure-jnp stage 2 (gather + one-hot einsum)."""

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size):
        return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size)


@register_backend("pallas")
@dataclasses.dataclass(frozen=True)
class PallasBackend(DispatchBackend):
    """Stage 2 on the kernels/cam_match Pallas kernel.

    ``interpret=None`` (default) follows the platform policy of
    kernels/cam_match/ops: compiled kernel on TPU, fast jnp reference on
    other platforms — same behavior the old ``use_kernel`` bool had.
    ``interpret=True`` forces the kernel in interpret mode anywhere
    (slow — CPU validation only). ``block_c`` tiles neurons within a
    cluster; see kernels/cam_match.
    """

    block_c: int = 16
    interpret: bool | None = None

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size):
        if self.interpret is None:
            from repro.kernels.cam_match import ops as cam_ops

            return cam_ops.cam_match(
                activity, cam_tag, cam_syn, cluster_size, block_c=self.block_c
            )
        from repro.kernels.cam_match.cam_match import cam_match_pallas

        return cam_match_pallas(
            activity, cam_tag, cam_syn, cluster_size, block_c=self.block_c,
            interpret=self.interpret,
        )


def sharded_local_deliver(
    spikes: jax.Array,  # [..., N_local] this device's neuron slab
    src_tag: jax.Array,
    src_dest: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    n_clusters: int,  # GLOBAL cluster count (stage-1 targets any cluster)
    k_tags: int,
    cluster_axis: str,
    external_activity: jax.Array | None = None,  # [..., n_clusters/n_dev, K]
) -> jax.Array:
    """Per-device delivery body shared by ShardedBackend and
    ``EventEngine.make_sharded_step`` (runs INSIDE shard_map).

    Stage 1 scatters this device's sources into a partial activity matrix
    covering ALL clusters; the reduce-scatter over ``cluster_axis`` hands
    each owner its slab (the R2/R3 point-to-point hop); stage 2 is local.
    """
    a_partial = stage1_route(spikes, src_tag, src_dest, n_clusters, k_tags)
    a_local = jax.lax.psum_scatter(
        a_partial, cluster_axis, scatter_dimension=a_partial.ndim - 2, tiled=True
    )
    if external_activity is not None:
        a_local = a_local + external_activity
    return stage2_cam_match(a_local, cam_tag, cam_syn, cluster_size)


@register_backend("sharded")
class ShardedBackend(DispatchBackend):
    """Full delivery under shard_map on a 2-D (batch, cluster) mesh.

    ``batch_axis`` shards event streams (data parallel — no communication),
    ``cluster_axis`` shards clusters/cores (model parallel — stage-1 partial
    activity is reduce-scattered to the slab owner, DESIGN.md §2). A 1x1
    default mesh makes the backend runnable — and testable — on one device.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh | None = None,
        batch_axis: str = "data",
        cluster_axis: str = "model",
    ):
        if mesh is None:
            mesh = jax.make_mesh((1, 1), (batch_axis, cluster_axis))
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.cluster_axis = cluster_axis

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size):
        # stage 2 alone is embarrassingly parallel; the interesting
        # communication lives in deliver(). Reference semantics here.
        return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size)

    def deliver(
        self,
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        external_activity=None,
    ):
        from jax.sharding import PartitionSpec as P

        from repro.core.shard_compat import SM_CHECK_KW, shard_map

        # normalize any leading batch shape (incl. none) to one flat B
        batch_shape = spikes.shape[:-1]
        n = spikes.shape[-1]
        spikes = spikes.reshape(-1, n)
        b = spikes.shape[0]
        n_clusters = n // cluster_size
        n_cl_dev = self.mesh.shape[self.cluster_axis]
        n_b_dev = self.mesh.shape[self.batch_axis]
        assert n_clusters % n_cl_dev == 0, (n_clusters, n_cl_dev)
        assert b % n_b_dev == 0, (b, n_b_dev)
        if external_activity is None:
            external_activity = jnp.zeros((b, n_clusters, k_tags), spikes.dtype)
        else:  # broadcast shared (unbatched) stimulus like the other backends
            external_activity = jnp.broadcast_to(
                external_activity, (*batch_shape, n_clusters, k_tags)
            ).reshape(b, n_clusters, k_tags)

        ba, ca = self.batch_axis, self.cluster_axis

        def local(spk, s_tag, s_dest, c_tag, c_syn, ext):
            return sharded_local_deliver(
                spk, s_tag, s_dest, c_tag, c_syn, cluster_size, n_clusters,
                k_tags, ca, external_activity=ext,
            )

        f = shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(ba, ca), P(ca), P(ca), P(ca), P(ca), P(ba, ca)),
            out_specs=P(ba, ca),
            **SM_CHECK_KW,
        )
        drive = f(spikes, src_tag, src_dest, cam_tag, cam_syn, external_activity)
        return drive.reshape(*batch_shape, n, N_SYN_TYPES)
