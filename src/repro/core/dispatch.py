"""Pluggable dispatch backends for batched event delivery (DESIGN.md §9/§10).

A dispatch backend turns (spikes, routing tables, external tag activity)
into per-neuron synaptic drive — the full stage-1 + stage-2 path of the
paper — for a whole batch of concurrent event streams at once. All backends
consume ``spikes [..., N]`` / ``external_activity [..., n_clusters, K]`` and
return ``drive [..., N, N_SYN_TYPES]``; they differ in *where* the stage-2
CAM match runs and whether the two stages are fused:

  * ``reference`` — pure-jnp scatter + indexed gather (oracle, CPU default)
  * ``pallas``    — the kernels/cam_match TPU kernel, grid (B, cluster,
                    neuron-tile): the activity row stays VMEM-pinned per
                    cluster while neurons and batch tile the MXU
  * ``fused``     — the kernels/fused_deliver TPU kernel: stage-1 scatter
                    AND stage-2 CAM match in one kernel, the activity row
                    built and consumed in VMEM without an HBM round-trip;
                    always event-queued (DESIGN.md §10)
  * ``sharded``   — shard_map over a 2-D mesh (batch over ``data``,
                    clusters over ``model``): stage-1 partials are
                    reduce-scattered to the owning cluster slab (the
                    R2/R3 point-to-point hop), stage-2 is fully local
  * ``fabric``    — latency/bandwidth-aware delivery through the executable
                    R1/R2/R3 model (DESIGN.md §11): tile binning, per-link
                    FIFOs, delay lines, Table II-IV stats accumulators

Every backend supports **event-sparse delivery**: pass ``queue_capacity`` to
compact active spikes into a fixed-capacity AER queue (core/two_stage.py)
and scatter only queued events' SRAM entries in stage 1. ``with_stats=True``
additionally returns a :class:`DeliveryStats` with the queue's drop counter
(the chip's congestion behavior).

Backends are selected by name through :func:`get_backend`; third-party
backends can register via :func:`register_backend`.
"""

from __future__ import annotations

import dataclasses
import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.two_stage import (
    N_SYN_TYPES,
    compact_events,
    stage1_route,
    stage1_route_events,
    stage1_route_events_fabric,
    stage2_cam_match,
)

__all__ = [
    "DispatchBackend",
    "DeliveryStats",
    "ReferenceBackend",
    "PallasBackend",
    "FusedBackend",
    "ShardedBackend",
    "FabricBackend",
    "advance_inflight",
    "register_backend",
    "get_backend",
    "available_backends",
    "backend_deliver",
    "AutotuneDecision",
    "autotune_backend",
    "autotune_candidates",
]

_REGISTRY: dict[str, type] = {}


@dataclasses.dataclass(frozen=True)
class DeliveryStats:
    """Per-stream delivery statistics.

    ``dropped [...]`` int32 counts events lost to AER-queue overflow this
    step (0 everywhere on the dense path). The remaining fields are filled
    only by the fabric backend (DESIGN.md §11) and stay ``None`` elsewhere:
    ``link_dropped`` counts events lost to inter-tile link-FIFO overflow,
    ``delivered`` counts routed events, and ``hops`` / ``latency_s`` /
    ``energy_j`` are per-step sums of the Table II-IV per-event figures
    over delivered events.
    """

    dropped: jax.Array
    link_dropped: jax.Array | None = None
    delivered: jax.Array | None = None
    hops: jax.Array | None = None
    latency_s: jax.Array | None = None
    energy_j: jax.Array | None = None


jax.tree_util.register_dataclass(
    DeliveryStats,
    data_fields=["dropped", "link_dropped", "delivered", "hops", "latency_s", "energy_j"],
    meta_fields=[],
)


def register_backend(name: str):
    """Class decorator: register a :class:`DispatchBackend` under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(spec: str | DispatchBackend | None = "reference", **options) -> DispatchBackend:
    """Resolve a backend by name (constructing it with ``options``) or pass
    an already-constructed instance through unchanged."""
    if isinstance(spec, DispatchBackend):
        if options:
            raise ValueError(
                f"backend options {sorted(options)} ignored: {spec.name!r} was "
                "passed as an instance — configure it at construction instead"
            )
        return spec
    if spec is None:
        spec = "reference"
    try:
        cls = _REGISTRY[spec]
    except KeyError:
        raise ValueError(
            f"unknown dispatch backend {spec!r}; available: {available_backends()}"
        ) from None
    return cls(**options)


def _kwargs_accepted_by(fn) -> set[str] | None:
    """Names ``fn`` accepts as keywords; ``None`` means it takes ``**kwargs``."""
    sig = inspect.signature(fn)
    if any(p.kind is inspect.Parameter.VAR_KEYWORD for p in sig.parameters.values()):
        return None
    return set(sig.parameters)


def backend_deliver(
    backend: DispatchBackend,
    spikes: jax.Array,
    src_tag: jax.Array,
    src_dest: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    k_tags: int,
    external_activity: jax.Array | None = None,
    queue_capacity: int | None = None,
    syn_onehot: jax.Array | None = None,
    with_stats: bool = False,
):
    """Signature-tolerant ``deliver`` call (the engine/two_stage entry point).

    Third-party backends registered against the pre-§10 interface (no
    ``queue_capacity`` / ``syn_onehot`` / ``with_stats`` keywords) keep
    working: the new kwargs are forwarded only when the backend accepts
    them. ``syn_onehot`` is a pure optimization hint and is dropped
    silently; ``with_stats`` is synthesized (zero drops — a legacy backend
    is always dense); asking a legacy backend for ``queue_capacity`` is a
    semantic request it cannot honor and raises.
    """
    accepted = _kwargs_accepted_by(backend.deliver)
    kwargs = {"external_activity": external_activity}
    for name, value in (
        ("queue_capacity", queue_capacity),
        ("syn_onehot", syn_onehot),
        ("with_stats", with_stats),
    ):
        if accepted is None or name in accepted:
            kwargs[name] = value
        elif name == "queue_capacity" and queue_capacity is not None:
            raise ValueError(
                f"dispatch backend {backend.name!r} predates event-sparse "
                "delivery and does not support queue_capacity"
            )
    out = backend.deliver(
        spikes, src_tag, src_dest, cam_tag, cam_syn, cluster_size, k_tags, **kwargs
    )
    if with_stats and "with_stats" not in kwargs:
        return out, DeliveryStats(dropped=jnp.zeros(spikes.shape[:-1], jnp.int32))
    return out


def _stage1_activity(
    spikes: jax.Array,
    src_tag: jax.Array,
    src_dest: jax.Array,
    n_clusters: int,
    k_tags: int,
    queue_capacity: int | None,
) -> tuple[jax.Array, jax.Array]:
    """Stage-1 scatter, dense or event-queued: ``(activity, dropped)``."""
    if queue_capacity is None or queue_capacity >= spikes.shape[-1]:
        # capacity >= N makes the queue lossless AND makes compaction pure
        # overhead: the dense scatter visits the same nonzero entries in the
        # same (src, entry) order, adding only exact-0.0 terms for silent
        # sources — bit-identical activity, zero drops, no cumsum/searchsorted
        a = stage1_route(spikes, src_tag, src_dest, n_clusters, k_tags)
        dropped = jnp.zeros(spikes.shape[:-1], jnp.int32)
        return a, dropped
    queue = compact_events(spikes, queue_capacity)
    a = stage1_route_events(queue, src_tag, src_dest, n_clusters, k_tags)
    return a, queue.dropped


class DispatchBackend:
    """Interface: batched stage-1 scatter shared, stage-2 pluggable."""

    name = "abstract"

    # -- stage 2 -----------------------------------------------------------
    def cam_match(
        self,
        activity: jax.Array,  # [..., n_clusters, K]
        cam_tag: jax.Array,  # [N, S]
        cam_syn: jax.Array,  # [N, S]
        cluster_size: int,
        syn_onehot: jax.Array | None = None,  # [N, S, 4] per-table constant
    ) -> jax.Array:  # [..., N, N_SYN_TYPES]
        raise NotImplementedError

    # -- full delivery -----------------------------------------------------
    def deliver(
        self,
        spikes: jax.Array,  # [..., N]
        src_tag: jax.Array,
        src_dest: jax.Array,
        cam_tag: jax.Array,
        cam_syn: jax.Array,
        cluster_size: int,
        k_tags: int,
        external_activity: jax.Array | None = None,
        queue_capacity: int | None = None,
        syn_onehot: jax.Array | None = None,
        with_stats: bool = False,
    ):
        n = spikes.shape[-1]
        a, dropped = _stage1_activity(
            spikes, src_tag, src_dest, n // cluster_size, k_tags, queue_capacity
        )
        if external_activity is not None:
            a = a + external_activity
        # forward the one-hot hint only to stage-2 hooks that know it (a
        # subclass written against the pre-§10 cam_match signature still works)
        accepted = _kwargs_accepted_by(self.cam_match)
        cam_kwargs = (
            {"syn_onehot": syn_onehot} if accepted is None or "syn_onehot" in accepted
            else {}
        )
        drive = self.cam_match(a, cam_tag, cam_syn, cluster_size, **cam_kwargs)
        if with_stats:
            return drive, DeliveryStats(dropped=dropped)
        return drive


@register_backend("reference")
@dataclasses.dataclass(frozen=True)
class ReferenceBackend(DispatchBackend):
    """Pure-jnp stage 2 (direct indexed gather + synapse-type einsum)."""

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size, syn_onehot=None):
        return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size, syn_onehot)


@register_backend("pallas")
@dataclasses.dataclass(frozen=True)
class PallasBackend(DispatchBackend):
    """Stage 2 on the kernels/cam_match Pallas kernel.

    ``interpret=None`` (default) follows the platform policy of
    kernels/cam_match/ops: compiled kernel on TPU, fast jnp reference on
    other platforms — same behavior the old ``use_kernel`` bool had.
    ``interpret=True`` forces the kernel in interpret mode anywhere
    (slow — CPU validation only). ``block_c`` tiles neurons within a
    cluster; see kernels/cam_match.
    """

    block_c: int = 16
    interpret: bool | None = None

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size, syn_onehot=None):
        # the kernel builds its compare planes in-register; the precomputed
        # one-hot is a jnp-path optimization and is ignored here.
        if self.interpret is None:
            from repro.kernels.cam_match import ops as cam_ops

            return cam_ops.cam_match(
                activity, cam_tag, cam_syn, cluster_size, block_c=self.block_c
            )
        from repro.kernels.cam_match.cam_match import cam_match_pallas

        return cam_match_pallas(
            activity, cam_tag, cam_syn, cluster_size, block_c=self.block_c,
            interpret=self.interpret,
        )


@register_backend("fused")
@dataclasses.dataclass(frozen=True)
class FusedBackend(DispatchBackend):
    """Single-kernel delivery: stage-1 scatter + stage-2 CAM match fused.

    The kernels/fused_deliver kernel builds each (batch, cluster) activity
    row in VMEM from the queued events and immediately CAM-matches it — the
    ``[B, n_clusters, K]`` activity matrix never round-trips HBM. Always
    event-queued: ``queue_capacity=None`` sizes the queue to N (lossless).

    ``interpret=None`` follows the platform policy of fused_deliver/ops
    (compiled kernel on TPU, jnp event-sparse reference elsewhere);
    ``interpret=True`` forces the kernel in interpret mode (CPU validation).
    """

    block_c: int = 16
    interpret: bool | None = None

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size, syn_onehot=None):
        # stage 2 alone (no queue to fuse with): reference semantics.
        return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size, syn_onehot)

    def deliver(
        self,
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        external_activity=None,
        queue_capacity=None,
        syn_onehot=None,
        with_stats=False,
    ):
        from repro.kernels.fused_deliver import ops as fused_ops

        capacity = spikes.shape[-1] if queue_capacity is None else queue_capacity
        queue = compact_events(spikes, capacity)
        drive = fused_ops.fused_deliver(
            queue,
            src_tag,
            src_dest,
            cam_tag,
            cam_syn,
            cluster_size,
            k_tags,
            external_activity=external_activity,
            syn_onehot=syn_onehot,
            block_c=self.block_c,
            interpret=self.interpret,
        )
        if with_stats:
            return drive, DeliveryStats(dropped=queue.dropped)
        return drive


def advance_inflight(buffer, inflight, max_delay: int):
    """Advance the fabric delay line one step: ``(activity_now, new_inflight)``.

    ``buffer [..., max_delay + 1, nc, K]`` is this step's routed scatter
    (slot 0 = arriving now); ``inflight [..., max_delay, nc, K]`` is the
    carried tail, or ``None`` to collapse every delay slot into the current
    step (the single-shot statistical mode — returns ``None`` back). Shared
    by :class:`FabricBackend` and the engine's sharded fabric step so local
    and sharded execution cannot drift.
    """
    if inflight is None:
        return buffer.sum(axis=-3), None
    if max_delay == 0:
        return buffer[..., 0, :, :], inflight  # inflight is empty [..., 0, nc, K]
    a = buffer[..., 0, :, :] + inflight[..., 0, :, :]
    shifted = jnp.concatenate(
        [inflight[..., 1:, :, :], jnp.zeros_like(inflight[..., :1, :, :])], axis=-3
    )
    return a, shifted + buffer[..., 1:, :, :]


@register_backend("fabric")
class FabricBackend(DispatchBackend):
    """Latency/bandwidth-aware delivery over the R1/R2/R3 fabric (§11).

    Events are compacted into the AER queue, binned by (source, destination)
    tile pair, pushed through per-link bandwidth FIFOs
    (``r3_throughput_eps * dt`` events per directed tile pair per step,
    deterministic lowest-source-id-first overflow), and scattered into a
    delay-indexed activity buffer — cross-tile events arrive
    ``ceil(mesh_hops * latency_across_chip_s / dt)`` steps later.

    Two entry points:

    * :meth:`deliver` (the registry API) models one *isolated* timestep:
      link capacity and the hop/latency/energy accounting apply, but with no
      delay line to thread the buffer is collapsed — every surviving event
      is delivered in the same step ("zero-warp" statistical mode). With
      infinite link capacity this is bit-identical to ``reference``.
    * :meth:`deliver_fabric` takes and returns the in-flight buffer
      (``[..., max_delay, n_clusters, K]``) so ``EventEngine(fabric=...)``
      can carry it through the scan — events then really arrive late.
    * :meth:`deliver_fabric_ring` is the **fast path** (DESIGN.md §14): the
      carried buffer is a time-wheel ring ``[..., max_delay + 1, nc, K]``
      indexed by a carried write cursor, delivery runs over a static
      per-SRAM-entry table (kernels/fabric_deliver), and advancing the delay
      line is a pointer bump — no dense shift. Bit-identical arrival steps,
      drops and integer stats to the roll path (locked by the ring property
      suite); the default mode of ``EventEngine(fabric=...)``.

    ``ring=False`` keeps the roll-based carry (the parity reference).
    ``tile_of_cluster`` pins the placement (default: hierarchical linear);
    per-event constants are precomputed once per cluster count
    (routing.build_delivery_model) and uploaded as jnp constants.
    ``interpret``/``block_c`` configure the fabric_deliver kernel exactly
    like :class:`FusedBackend` (None = kernel on TPU, jnp fast path
    elsewhere; True = force interpret mode for CPU validation).
    """

    def __init__(
        self,
        fabric=None,
        tile_of_cluster=None,
        dt: float = 1e-3,
        vdd: float = 1.3,
        link_capacity: int | None = None,
        ring: bool = True,
        block_c: int = 16,
        interpret: bool | None = None,
        faults=None,  # faults.FaultSpec | None — injected topology faults (§15)
        per_link_stats: bool = False,  # keep drop/delivered attribution (§18)
    ):
        from repro.core.routing import Fabric

        self.fabric = fabric if fabric is not None else Fabric()
        self.tile_of_cluster = tile_of_cluster
        self.dt = float(dt)
        self.vdd = vdd
        self.link_capacity = link_capacity
        self.ring = bool(ring)
        self.block_c = block_c
        self.interpret = interpret
        self.faults = faults
        self.per_link_stats = bool(per_link_stats)
        if faults is not None:
            faults.validate(self.fabric)
        self._models: dict[int, tuple] = {}
        self._entry_alive_cache: dict[tuple, jax.Array | None] = {}

    def model_for(self, n_clusters: int):
        """(FabricDeliveryModel, jnp constant arrays) for a cluster count."""
        cached = self._models.get(n_clusters)
        if cached is None:
            from repro.core.routing import build_delivery_model

            model = build_delivery_model(
                self.fabric,
                n_clusters,
                self.dt,
                tile_of_cluster=self.tile_of_cluster,
                vdd=self.vdd,
                link_capacity=self.link_capacity,
                faults=self.faults,
            )
            arrays = {
                "cluster_tile": jnp.asarray(model.tile_of_cluster),
                "delay_steps": jnp.asarray(model.delay_steps),
                "mesh_hops": jnp.asarray(model.mesh_hops),
                "latency_s": jnp.asarray(model.latency_s),
                "energy_j": jnp.asarray(model.energy_j),
            }
            cached = (model, arrays)
            self._models[n_clusters] = cached
        return cached

    def init_inflight(
        self,
        n_clusters: int,
        k_tags: int,
        batch: int | tuple[int, ...] | None = None,
        dtype=jnp.float32,
    ) -> jax.Array:
        """Zero in-flight buffer ``[..., max_delay, n_clusters, K]``."""
        model, _ = self.model_for(n_clusters)
        lead = () if batch is None else (batch,) if isinstance(batch, int) else tuple(batch)
        return jnp.zeros((*lead, model.max_delay, n_clusters, k_tags), dtype)

    def init_ring(
        self,
        n_clusters: int,
        k_tags: int,
        batch: int | tuple[int, ...] | None = None,
        dtype=jnp.float32,
    ) -> tuple[jax.Array, jax.Array]:
        """Zero time-wheel ring ``[..., max_delay + 1, nc, K]`` + cursor 0.

        The cursor is a shared int32 scalar — every batch slot steps in
        lockstep, so one phase pointer serves the whole pool (DESIGN.md §14).
        """
        model, _ = self.model_for(n_clusters)
        lead = () if batch is None else (batch,) if isinstance(batch, int) else tuple(batch)
        ring = jnp.zeros((*lead, model.max_delay + 1, n_clusters, k_tags), dtype)
        return ring, jnp.zeros((), jnp.int32)

    def build_entries(self, src_tag, src_dest, cluster_size: int, k_tags: int):
        """Static per-SRAM-entry table for the ring fast path (host-side).

        Precomputed once per engine from the routing tables + the delivery
        model: destination address, arrival delay, link bin and Table II-IV
        figures per *occupied* SRAM entry, statically sorted in arbitration
        order. See kernels/fabric_deliver/ops.py.
        """
        from repro.kernels.fabric_deliver import ops as fabric_ops

        n_clusters = src_tag.shape[0] // cluster_size
        model, _ = self.model_for(n_clusters)
        return fabric_ops.build_fabric_entries(
            src_tag, src_dest, cluster_size, k_tags, model
        )

    def build_entries_slabs(
        self, per_model, cluster_size: int, k_tags: int
    ):
        """Multi-model entry table as slab-offset concatenation (§16).

        ``per_model`` is a sequence of per-resident ``(src_tag, src_dest)``
        pairs laid out back to back; the combined cluster count is derived
        from the total neuron count. Bit-identical to :meth:`build_entries`
        on the concatenated tables — see
        kernels/fabric_deliver/ops.build_fabric_entries_slabs.
        """
        from repro.kernels.fabric_deliver import ops as fabric_ops

        n_total = sum(np.asarray(st).shape[0] for st, _ in per_model)
        model, _ = self.model_for(n_total // cluster_size)
        return fabric_ops.build_fabric_entries_slabs(
            per_model, cluster_size, k_tags, model
        )

    def entry_alive_for(self, src_tag, src_dest, cluster_size: int):
        """Per-SRAM-entry survival mask ``[N, E]`` (bool) or ``None``.

        ``None`` when no faults sever any route — the roll path then skips
        the per-event gather entirely. Cached per table identity so repeat
        engine builds don't redraw the erasure Bernoulli.
        """
        if self.faults is None or not self.faults.routes_faulted:
            return None
        src_tag = np.asarray(src_tag)
        src_dest = np.asarray(src_dest)
        key = (id(src_tag), id(src_dest), cluster_size)
        if key not in self._entry_alive_cache:
            from repro.core.faults import entry_alive_mask

            n_clusters = src_tag.shape[0] // cluster_size
            model, _ = self.model_for(n_clusters)
            mask = entry_alive_mask(src_tag, src_dest, cluster_size, model)
            self._entry_alive_cache[key] = None if mask is None else jnp.asarray(mask)
        return self._entry_alive_cache[key]

    def deliver_fabric_ring(
        self,
        spikes,
        entries,  # FabricEntries from build_entries
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        ring,  # [..., max_delay + 1, nc, K]
        cursor,  # int32 scalar write cursor
        external_activity=None,
        queue_capacity=None,
        syn_onehot=None,
    ):
        """Ring fast-path fabric step: ``(drive, ring, cursor, DeliveryStats)``.

        Event-count-proportional delivery over the static entry table —
        no per-step SRAM gather, no argsort arbitration, no dense delay-line
        shift. Kernel-fused on TPU (kernels/fabric_deliver), jnp fast path
        elsewhere; ``interpret=True`` at construction forces the kernel in
        interpret mode for CPU validation.
        """
        from repro.kernels.fabric_deliver import ops as fabric_ops

        n_clusters = spikes.shape[-1] // cluster_size
        model, _ = self.model_for(n_clusters)
        return fabric_ops.fabric_deliver_ring(
            spikes,
            entries,
            cam_tag,
            cam_syn,
            cluster_size,
            k_tags,
            ring,
            cursor,
            max_delay=model.max_delay,
            link_capacity=model.link_capacity,
            queue_capacity=queue_capacity,
            external_activity=external_activity,
            syn_onehot=syn_onehot,
            block_c=self.block_c,
            interpret=self.interpret,
            per_link_stats=self.per_link_stats,
            n_tiles=model.n_tiles,
        )

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size, syn_onehot=None):
        return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size, syn_onehot)

    def deliver_fabric(
        self,
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        inflight=None,  # [..., max_delay, n_clusters, K] or None (collapse delays)
        external_activity=None,
        queue_capacity=None,
        syn_onehot=None,
        entry_alive=None,  # [N, E] bool fault-survival mask (None → auto from faults)
    ):
        """Full fabric step: ``(drive, new_inflight, DeliveryStats)``.

        ``new_inflight`` is ``None`` when ``inflight`` was ``None`` (the
        collapsed single-shot mode used by :meth:`deliver`).
        """
        n = spikes.shape[-1]
        n_clusters = n // cluster_size
        model, arrs = self.model_for(n_clusters)
        if entry_alive is None and self.faults is not None:
            entry_alive = self.entry_alive_for(src_tag, src_dest, cluster_size)
        capacity = n if queue_capacity is None else queue_capacity
        queue = compact_events(spikes, capacity)
        route = stage1_route_events_fabric(
            queue,
            src_tag,
            src_dest,
            n_clusters,
            k_tags,
            cluster_size,
            arrs["cluster_tile"],
            arrs["delay_steps"],
            model.n_tiles,
            model.max_delay,
            model.link_capacity,
            mesh_hops=arrs["mesh_hops"],
            latency_s=arrs["latency_s"],
            energy_j=arrs["energy_j"],
            entry_alive=entry_alive,
            per_link_stats=self.per_link_stats,
        )
        a, new_inflight = advance_inflight(route.buffer, inflight, model.max_delay)
        if external_activity is not None:
            a = a + external_activity
        drive = stage2_cam_match(a, cam_tag, cam_syn, cluster_size, syn_onehot)
        stats = DeliveryStats(
            dropped=queue.dropped,
            link_dropped=route.link_dropped,
            delivered=route.delivered,
            hops=route.hops,
            latency_s=route.latency_s,
            energy_j=route.energy_j,
        )
        return drive, new_inflight, stats

    def deliver(
        self,
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        external_activity=None,
        queue_capacity=None,
        syn_onehot=None,
        with_stats=False,
    ):
        drive, _, stats = self.deliver_fabric(
            spikes,
            src_tag,
            src_dest,
            cam_tag,
            cam_syn,
            cluster_size,
            k_tags,
            inflight=None,
            external_activity=external_activity,
            queue_capacity=queue_capacity,
            syn_onehot=syn_onehot,
        )
        if with_stats:
            return drive, stats
        return drive


def sharded_local_deliver(
    spikes: jax.Array,  # [..., N_local] this device's neuron slab
    src_tag: jax.Array,
    src_dest: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    n_clusters: int,  # GLOBAL cluster count (stage-1 targets any cluster)
    k_tags: int,
    cluster_axis: str,
    external_activity: jax.Array | None = None,  # [..., n_clusters/n_dev, K]
    queue_capacity: int | None = None,
    syn_onehot: jax.Array | None = None,
    with_stats: bool = False,
):
    """Per-device delivery body shared by ShardedBackend and
    ``EventEngine.make_sharded_step`` (runs INSIDE shard_map).

    Stage 1 scatters this device's sources into a partial activity matrix
    covering ALL clusters; the reduce-scatter over ``cluster_axis`` hands
    each owner its slab (the R2/R3 point-to-point hop); stage 2 is local.

    With ``queue_capacity`` each device compacts its own slab's spikes — the
    hardware picture of one output FIFO per core. ``with_stats=True`` returns
    ``(drive, dropped)`` where ``dropped`` is already summed over the cluster
    axis (total events lost fabric-wide, replicated per device).
    """
    a_partial, dropped = _stage1_activity(
        spikes, src_tag, src_dest, n_clusters, k_tags, queue_capacity
    )
    a_local = jax.lax.psum_scatter(
        a_partial, cluster_axis, scatter_dimension=a_partial.ndim - 2, tiled=True
    )
    if external_activity is not None:
        a_local = a_local + external_activity
    drive = stage2_cam_match(a_local, cam_tag, cam_syn, cluster_size, syn_onehot)
    if with_stats:
        return drive, jax.lax.psum(dropped, cluster_axis)
    return drive


@register_backend("sharded")
class ShardedBackend(DispatchBackend):
    """Full delivery under shard_map on a 2-D (batch, cluster) mesh.

    ``batch_axis`` shards event streams (data parallel — no communication),
    ``cluster_axis`` shards clusters/cores (model parallel — stage-1 partial
    activity is reduce-scattered to the slab owner, DESIGN.md §2). A 1x1
    default mesh makes the backend runnable — and testable — on one device.
    """

    def __init__(
        self,
        mesh: jax.sharding.Mesh | None = None,
        batch_axis: str = "data",
        cluster_axis: str = "model",
    ):
        if mesh is None:
            mesh = jax.make_mesh((1, 1), (batch_axis, cluster_axis))
        self.mesh = mesh
        self.batch_axis = batch_axis
        self.cluster_axis = cluster_axis

    def cam_match(self, activity, cam_tag, cam_syn, cluster_size, syn_onehot=None):
        # stage 2 alone is embarrassingly parallel; the interesting
        # communication lives in deliver(). Reference semantics here.
        return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size, syn_onehot)

    def deliver(
        self,
        spikes,
        src_tag,
        src_dest,
        cam_tag,
        cam_syn,
        cluster_size,
        k_tags,
        external_activity=None,
        queue_capacity=None,
        syn_onehot=None,
        with_stats=False,
    ):
        from jax.sharding import PartitionSpec as P

        from repro.core.shard_compat import SM_CHECK_KW, shard_map

        # normalize any leading batch shape (incl. none) to one flat B
        batch_shape = spikes.shape[:-1]
        n = spikes.shape[-1]
        spikes = spikes.reshape(-1, n)
        b = spikes.shape[0]
        n_clusters = n // cluster_size
        n_cl_dev = self.mesh.shape[self.cluster_axis]
        n_b_dev = self.mesh.shape[self.batch_axis]
        assert n_clusters % n_cl_dev == 0, (n_clusters, n_cl_dev)
        assert b % n_b_dev == 0, (b, n_b_dev)
        if external_activity is None:
            external_activity = jnp.zeros((b, n_clusters, k_tags), spikes.dtype)
        else:  # broadcast shared (unbatched) stimulus like the other backends
            external_activity = jnp.broadcast_to(
                external_activity, (*batch_shape, n_clusters, k_tags)
            ).reshape(b, n_clusters, k_tags)

        ba, ca = self.batch_axis, self.cluster_axis
        # per-device FIFO: each cluster shard compacts its slab of sources
        local_capacity = queue_capacity
        if local_capacity is not None:
            local_capacity = max(1, -(-local_capacity // n_cl_dev))

        def local(spk, s_tag, s_dest, c_tag, c_syn, s_1h, ext):
            return sharded_local_deliver(
                spk, s_tag, s_dest, c_tag, c_syn, cluster_size, n_clusters,
                k_tags, ca, external_activity=ext,
                queue_capacity=local_capacity, syn_onehot=s_1h, with_stats=True,
            )

        if syn_onehot is None:
            from repro.core.two_stage import precompute_syn_onehot

            syn_onehot = precompute_syn_onehot(cam_syn, dtype=spikes.dtype)

        f = shard_map(
            local,
            mesh=self.mesh,
            in_specs=(P(ba, ca), P(ca), P(ca), P(ca), P(ca), P(ca), P(ba, ca)),
            out_specs=(P(ba, ca), P(ba)),
            **SM_CHECK_KW,
        )
        drive, dropped = f(
            spikes, src_tag, src_dest, cam_tag, cam_syn, syn_onehot, external_activity
        )
        drive = drive.reshape(*batch_shape, n, N_SYN_TYPES)
        if with_stats:
            return drive, DeliveryStats(dropped=dropped.reshape(batch_shape))
        return drive


# ---------------------------------------------------------------------------
# dispatch autotuner — measured dense/queued/fused crossover (DESIGN.md §18)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AutotuneDecision:
    """Outcome of one :func:`autotune_backend` pass.

    ``winner`` is the measured-fastest candidate; ``backend`` / ``dense``
    are how the engine realizes it (registry backend name + whether the AER
    queue compaction is bypassed — the dense path still reports zero-drop
    stats, so the step's output contract is unchanged). ``measurements``
    records every candidate's best-of-``iters`` wall time in µs, in
    canonical candidate order, so the decision is auditable and the engine
    fingerprint can carry it.
    """

    winner: str
    backend: str
    dense: bool
    activity: float
    batch: int
    measurements: tuple[tuple[str, float], ...]

    def token(self) -> str:
        """Compact fingerprint component (decision, not timings)."""
        return f"autotune:{self.winner}:act{self.activity:g}:B{self.batch}"


# candidate -> (registry backend, bypass queue compaction)
_AUTOTUNE_IMPL = {
    "dense": ("reference", True),
    "queued": ("reference", False),
    "fused": ("fused", False),
    # fabric_ring is measurable only via an injected measurement (timing it
    # needs a ring carry); it maps onto the fabric backend's default mode
    "fabric_ring": ("fabric", False),
}


def autotune_candidates() -> tuple[str, ...]:
    return tuple(_AUTOTUNE_IMPL)


def autotune_backend(
    src_tag,
    src_dest,
    cam_tag,
    cam_syn,
    cluster_size: int,
    k_tags: int,
    *,
    activity: float = 0.1,
    batch: int = 8,
    queue_capacity: int | None = None,
    candidates: tuple[str, ...] = ("dense", "queued", "fused"),
    measure: dict[str, float] | None = None,
    iters: int = 3,
    seed: int = 0,
    tol: float = 0.05,
) -> AutotuneDecision:
    """Measure the dense/queued/fused crossover at one (activity, B) point.

    Times each candidate's jitted delivery on a deterministic synthetic
    spike batch (``batch`` streams at ``activity`` fraction active, drawn
    from ``seed``) and returns the winner as an :class:`AutotuneDecision`.
    ``measure`` injects known timings per candidate (µs) — injected
    candidates are not re-timed, so a fully-injected call is deterministic
    and timing-free (the conformance tests use this, and benchmarks use it
    to add a ``fabric_ring`` figure measured elsewhere). The winner is the
    *earliest* candidate within ``tol`` of the measured fastest, not the
    strict argmin: at a genuine crossover two candidates time equal and
    wall-clock jitter would flip the argmin between runs, whereas the
    noise band makes the decision stable (and exact ties break in
    ``candidates`` order either way).

    ``queue_capacity`` should be the engine's actual queue depth: the
    queued candidate is measured under exactly the compaction the engine
    would run. With ``None`` (or a capacity at/above the event count) the
    queued path degenerates to dense — the lossless-queue shortcut — so
    the tuner records dense's timing for it instead of racing two
    timings of the same program, and the dead heat resolves to ``dense``
    by construction.
    """
    import time as _time

    for cand in candidates:
        if cand not in _AUTOTUNE_IMPL:
            raise ValueError(
                f"unknown autotune candidate {cand!r}; known: {autotune_candidates()}"
            )
    measure = dict(measure or {})
    timed = [c for c in candidates if c not in measure]
    if timed:
        n = src_tag.shape[0]
        rng = np.random.default_rng(seed)
        spikes = jnp.asarray(
            (rng.random((int(batch), n)) < float(activity)).astype(np.float32)
        )
        st, sd = jnp.asarray(src_tag), jnp.asarray(src_dest)
        ct, cs = jnp.asarray(cam_tag), jnp.asarray(cam_syn)
        from repro.core.two_stage import precompute_syn_onehot

        onehot = precompute_syn_onehot(cs)
        # a lossless queue (capacity at/above the event count) makes the
        # queued path computationally identical to dense — don't race two
        # timings of the same program (a dead heat any load spike can flip):
        # record dense's figure for queued after the loop
        lossless = queue_capacity is None or int(queue_capacity) >= n
        alias_queued = (
            lossless and "queued" in timed
            and ("dense" in measure or "dense" in timed)
        )
        for cand in timed:
            if cand == "queued" and alias_queued:
                continue
            if cand == "fabric_ring":
                raise ValueError(
                    "fabric_ring can only be autotuned via an injected "
                    "measurement (measure={'fabric_ring': us})"
                )
            bname, dense = _AUTOTUNE_IMPL[cand]
            be = get_backend(bname)
            qc = None if dense else queue_capacity

            def fn(s, _be=be, _qc=qc):
                return backend_deliver(
                    _be, s, st, sd, ct, cs, cluster_size, k_tags,
                    queue_capacity=_qc, syn_onehot=onehot,
                )

            jfn = jax.jit(fn)
            jfn(spikes).block_until_ready()  # compile + warm outside timing
            best = float("inf")
            for _ in range(max(1, int(iters))):
                t0 = _time.perf_counter()
                jfn(spikes).block_until_ready()
                best = min(best, _time.perf_counter() - t0)
            measure[cand] = best * 1e6
        if alias_queued:
            measure["queued"] = measure["dense"]
    best = min(measure[c] for c in candidates)
    winner = next(c for c in candidates if measure[c] <= (1.0 + tol) * best)
    backend, dense = _AUTOTUNE_IMPL[winner]
    return AutotuneDecision(
        winner=winner,
        backend=backend,
        dense=dense,
        activity=float(activity),
        batch=int(batch),
        measurements=tuple((c, float(measure[c])) for c in candidates),
    )
