"""Event-driven SNN engine: two-stage routing + neuron dynamics, scan-able.

The engine is the executable model of the whole DYNAPs fabric:

  spikes[t] --stage1--> tag activity A[c, k] --stage2/CAM--> drive[N, 4]
           --AdExp/DPI--> spikes[t+1]

External stimulation (the chip's Input Interface) enters as tag activity
(events addressed to (cluster, tag)), exactly like the FPGA path in Fig. 7.

The whole path is batch-native (DESIGN.md §9): carry and inputs may bear a
leading batch dimension ``B`` — B independent event streams (users / DVS
sensors) stepped against one set of routing tables in a single dispatch.
``EventEngine.run`` scans over a ``[T, n_clusters, K]`` (or batched
``[T, B, n_clusters, K]``) input-event tensor. Delivery is delegated to a
pluggable dispatch backend (core/dispatch.py): ``reference`` (pure jnp),
``pallas`` (TPU kernel), or ``sharded`` (2-D-mesh shard_map), selected by
name — this replaces the old ``use_kernel`` bool.

``dense_reference_step`` is the oracle: the same network as one dense
[N, N, 4] connectivity tensor (used by tests to prove routing equivalence),
batched the same way.

For multi-device execution, ``make_sharded_step`` shards clusters (cores)
across a mesh axis with ``shard_map``: stage-1 scatter produces a partial
activity matrix per device which is reduce-scattered over the cluster axis
— the TPU analogue of point-to-point R2/R3 traffic (DESIGN.md §2). With
``batch_axis`` set it runs on a 2-D mesh, sharding event streams over the
data axis as well.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as neuron_mod
from repro.core.dispatch import DispatchBackend, get_backend
from repro.core.neuron import NeuronParams, NeuronState
from repro.core.shard_compat import SM_CHECK_KW, shard_map
from repro.core.tags import RoutingTables
from repro.core.two_stage import N_SYN_TYPES

__all__ = ["EventEngine", "dense_weights_from_tables", "dense_reference_step"]


@dataclasses.dataclass(frozen=True)
class _Tables:
    src_tag: jax.Array
    src_dest: jax.Array
    cam_tag: jax.Array
    cam_syn: jax.Array


jax.tree_util.register_dataclass(
    _Tables, data_fields=["src_tag", "src_dest", "cam_tag", "cam_syn"], meta_fields=[]
)


class EventEngine:
    """Executable DYNAPs fabric for a compiled network."""

    def __init__(
        self,
        tables: RoutingTables,
        params: NeuronParams | None = None,
        backend: str | DispatchBackend = "reference",
        backend_options: dict | None = None,
    ):
        self.params = params or NeuronParams()
        self.cluster_size = tables.cluster_size
        self.k_tags = tables.k_tags
        self.n_neurons = tables.n_neurons
        self.n_clusters = tables.n_clusters
        self.backend = get_backend(backend, **(backend_options or {}))
        self.tables = _Tables(
            src_tag=jnp.asarray(tables.src_tag),
            src_dest=jnp.asarray(tables.src_dest),
            cam_tag=jnp.asarray(tables.cam_tag),
            cam_syn=jnp.asarray(tables.cam_syn),
        )

    # ------------------------------------------------------------------
    def init_state(
        self, batch: int | tuple[int, ...] | None = None
    ) -> tuple[NeuronState, jax.Array]:
        """(neuron state, previous-step spikes); batched when ``batch`` set."""
        lead = () if batch is None else (batch,) if isinstance(batch, int) else tuple(batch)
        return (
            neuron_mod.init_state(self.n_neurons, self.params, batch=batch),
            jnp.zeros((*lead, self.n_neurons), jnp.float32),
        )

    @partial(jax.jit, static_argnums=0)
    def step(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_activity: jax.Array,  # [..., n_clusters, K] external events this step
        i_ext: jax.Array | None = None,
    ) -> tuple[tuple[NeuronState, jax.Array], jax.Array]:
        state, prev_spikes = carry
        drive = self.backend.deliver(
            prev_spikes,
            self.tables.src_tag,
            self.tables.src_dest,
            self.tables.cam_tag,
            self.tables.cam_syn,
            self.cluster_size,
            self.k_tags,
            external_activity=input_activity,
        )
        state, spikes = neuron_mod.neuron_step(state, drive, self.params, i_ext)
        return (state, spikes), spikes

    def run(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_events: jax.Array,  # [T, ..., n_clusters, K]
        i_ext: jax.Array | None = None,
    ) -> tuple[tuple[NeuronState, jax.Array], jax.Array]:
        """Scan T steps; returns (final carry, spikes [T, ..., N])."""

        def body(c, inp):
            return self.step(c, inp, i_ext)

        return jax.lax.scan(body, carry, input_events)

    # ------------------------------------------------------------------
    def make_sharded_step(
        self,
        mesh: jax.sharding.Mesh,
        axis: str = "data",
        batch_axis: str | None = None,
    ):
        """shard_map step with clusters sharded over ``axis``.

        Neurons, CAM tables and neuron state are sharded by cluster slab;
        stage-1 partial activity is reduce-scattered across devices (the
        R2/R3 point-to-point hop), stage-2 and dynamics are fully local.

        With ``batch_axis`` set the mesh is 2-D: event streams shard over
        ``batch_axis`` (pure data parallelism) while clusters shard over
        ``axis``; all carried arrays then bear a leading batch dim.
        """
        from jax.sharding import PartitionSpec as P

        n_dev = mesh.shape[axis]
        assert self.n_clusters % n_dev == 0, "clusters must divide device axis"
        params = self.params
        cluster_size, k_tags = self.cluster_size, self.k_tags
        n_clusters = self.n_clusters

        from repro.core.dispatch import sharded_local_deliver

        def local_step(tables, state, prev_spikes, input_activity, i_ext):
            # prev_spikes: local slab [..., N/n_dev]; tables rows local.
            drive = sharded_local_deliver(
                prev_spikes,
                tables.src_tag,
                tables.src_dest,
                tables.cam_tag,
                tables.cam_syn,
                cluster_size,
                n_clusters,
                k_tags,
                axis,
                external_activity=input_activity,
            )
            state, spikes = neuron_mod.neuron_step(state, drive, params, i_ext)
            return state, spikes

        spec_t = P(axis)  # tables: shard rows (neurons) over the cluster axis
        if batch_axis is None:
            spec_c = P(axis)  # unbatched carry: leading dim is neurons
        else:
            spec_c = P(batch_axis, axis)  # batched carry: [B, N_local, ...]
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                _Tables(spec_t, spec_t, spec_t, spec_t),
                NeuronState(spec_c, spec_c, spec_c, spec_c),
                spec_c,
                spec_c,
                spec_c,
            ),
            out_specs=(NeuronState(spec_c, spec_c, spec_c, spec_c), spec_c),
            **SM_CHECK_KW,
        )


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------
def dense_weights_from_tables(tables: RoutingTables) -> np.ndarray:
    """[N, N, 4] dense fan-in counts implied by the routing tables."""
    n = tables.n_neurons
    w = np.zeros((n, n, N_SYN_TYPES), dtype=np.float32)
    for src, dst, syn in tables.dense_equivalent():
        w[dst, src, syn] += 1.0
    return w


def dense_reference_step(
    dense_w: jax.Array,  # [N, N, 4]
    prev_spikes: jax.Array,  # [..., N]
    state: NeuronState,
    params: NeuronParams,
    external_drive: jax.Array | None = None,  # [..., N, 4]
    i_ext: jax.Array | None = None,
):
    """Oracle step: dense matmul delivery instead of two-stage routing."""
    drive = jnp.einsum("dst,...s->...dt", dense_w, prev_spikes)
    if external_drive is not None:
        drive = drive + external_drive
    return neuron_mod.neuron_step(state, drive, params, i_ext)
