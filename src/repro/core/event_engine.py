"""Event-driven SNN engine: two-stage routing + neuron dynamics, scan-able.

The engine is the executable model of the whole DYNAPs fabric:

  spikes[t] --stage1--> tag activity A[c, k] --stage2/CAM--> drive[N, 4]
           --AdExp/DPI--> spikes[t+1]

External stimulation (the chip's Input Interface) enters as tag activity
(events addressed to (cluster, tag)), exactly like the FPGA path in Fig. 7.

``EventEngine.run`` scans over a [T, n_clusters, K] input-event tensor.
``dense_reference_step`` is the oracle: the same network as one dense
[N, N, 4] connectivity tensor (used by tests to prove routing equivalence).

For multi-device execution, ``make_sharded_step`` shards clusters (cores)
across the mesh's device axis with ``shard_map``: stage-1 scatter produces a
partial activity matrix per device which is reduce-scattered over the cluster
axis — the TPU analogue of point-to-point R2/R3 traffic (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as neuron_mod
from repro.models.moe import _SM_CHECK_KW
from repro.core.neuron import NeuronParams, NeuronState
from repro.core.tags import RoutingTables
from repro.core.two_stage import (
    N_SYN_TYPES,
    stage1_route,
    stage2_cam_match,
    two_stage_deliver,
)

__all__ = ["EventEngine", "dense_weights_from_tables", "dense_reference_step"]


@dataclasses.dataclass(frozen=True)
class _Tables:
    src_tag: jax.Array
    src_dest: jax.Array
    cam_tag: jax.Array
    cam_syn: jax.Array


jax.tree_util.register_dataclass(
    _Tables, data_fields=["src_tag", "src_dest", "cam_tag", "cam_syn"], meta_fields=[]
)


class EventEngine:
    """Executable DYNAPs fabric for a compiled network."""

    def __init__(
        self,
        tables: RoutingTables,
        params: NeuronParams | None = None,
        use_kernel: bool = False,
    ):
        self.params = params or NeuronParams()
        self.cluster_size = tables.cluster_size
        self.k_tags = tables.k_tags
        self.n_neurons = tables.n_neurons
        self.n_clusters = tables.n_clusters
        self.use_kernel = use_kernel
        self.tables = _Tables(
            src_tag=jnp.asarray(tables.src_tag),
            src_dest=jnp.asarray(tables.src_dest),
            cam_tag=jnp.asarray(tables.cam_tag),
            cam_syn=jnp.asarray(tables.cam_syn),
        )

    # ------------------------------------------------------------------
    def init_state(self) -> tuple[NeuronState, jax.Array]:
        """(neuron state, previous-step spikes)."""
        return (
            neuron_mod.init_state(self.n_neurons, self.params),
            jnp.zeros((self.n_neurons,), jnp.float32),
        )

    @partial(jax.jit, static_argnums=0)
    def step(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_activity: jax.Array,  # [n_clusters, K] external events this step
        i_ext: jax.Array | None = None,
    ) -> tuple[tuple[NeuronState, jax.Array], jax.Array]:
        state, prev_spikes = carry
        drive = two_stage_deliver(
            prev_spikes,
            self.tables.src_tag,
            self.tables.src_dest,
            self.tables.cam_tag,
            self.tables.cam_syn,
            self.cluster_size,
            self.k_tags,
            external_activity=input_activity,
            use_kernel=self.use_kernel,
        )
        state, spikes = neuron_mod.neuron_step(state, drive, self.params, i_ext)
        return (state, spikes), spikes

    def run(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_events: jax.Array,  # [T, n_clusters, K]
        i_ext: jax.Array | None = None,
    ) -> tuple[tuple[NeuronState, jax.Array], jax.Array]:
        """Scan T steps; returns (final carry, spikes [T, N])."""

        def body(c, inp):
            return self.step(c, inp, i_ext)

        return jax.lax.scan(body, carry, input_events)

    # ------------------------------------------------------------------
    def make_sharded_step(self, mesh: jax.sharding.Mesh, axis: str = "data"):
        """shard_map step with clusters sharded over ``axis``.

        Neurons, CAM tables and neuron state are sharded by cluster slab;
        stage-1 partial activity is reduce-scattered across devices (the
        R2/R3 point-to-point hop), stage-2 and dynamics are fully local.
        """
        from jax.sharding import PartitionSpec as P

        try:
            from jax import shard_map
        except ImportError:  # older jax
            from jax.experimental.shard_map import shard_map

        n_dev = mesh.shape[axis]
        assert self.n_clusters % n_dev == 0, "clusters must divide device axis"
        params = self.params
        cluster_size, k_tags = self.cluster_size, self.k_tags
        n_clusters = self.n_clusters

        def local_step(tables, state, prev_spikes, input_activity, i_ext):
            # prev_spikes: local slab [N/n_dev]; tables rows local.
            a_partial = stage1_route(
                prev_spikes, tables.src_tag, tables.src_dest, n_clusters, k_tags
            )
            # point-to-point hop: every device contributes events for every
            # cluster; scatter-reduce so the owner core receives its slab.
            a_local = jax.lax.psum_scatter(
                a_partial, axis, scatter_dimension=0, tiled=True
            )
            a_local = a_local + input_activity
            drive = stage2_cam_match(a_local, tables.cam_tag, tables.cam_syn, cluster_size)
            state, spikes = neuron_mod.neuron_step(state, drive, params, i_ext)
            return state, spikes

        spec_n = P(axis)  # shard leading (neuron / cluster) dim
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                _Tables(spec_n, spec_n, spec_n, spec_n),
                NeuronState(spec_n, spec_n, spec_n, spec_n),
                spec_n,
                spec_n,
                spec_n,
            ),
            out_specs=(NeuronState(spec_n, spec_n, spec_n, spec_n), spec_n),
            **_SM_CHECK_KW,
        )


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------
def dense_weights_from_tables(tables: RoutingTables) -> np.ndarray:
    """[N, N, 4] dense fan-in counts implied by the routing tables."""
    n = tables.n_neurons
    w = np.zeros((n, n, N_SYN_TYPES), dtype=np.float32)
    for src, dst, syn in tables.dense_equivalent():
        w[dst, src, syn] += 1.0
    return w


def dense_reference_step(
    dense_w: jax.Array,  # [N, N, 4]
    prev_spikes: jax.Array,  # [N]
    state: NeuronState,
    params: NeuronParams,
    external_drive: jax.Array | None = None,  # [N, 4]
    i_ext: jax.Array | None = None,
):
    """Oracle step: dense matmul delivery instead of two-stage routing."""
    drive = jnp.einsum("dst,s->dt", dense_w, prev_spikes)
    if external_drive is not None:
        drive = drive + external_drive
    return neuron_mod.neuron_step(state, drive, params, i_ext)
