"""Event-driven SNN engine: two-stage routing + neuron dynamics, scan-able.

The engine is the executable model of the whole DYNAPs fabric:

  spikes[t] --AER queue--> stage1 --> tag activity A[c, k] --stage2/CAM-->
           drive[N, 4] --AdExp/DPI--> spikes[t+1]

External stimulation (the chip's Input Interface) enters as tag activity
(events addressed to (cluster, tag)), exactly like the FPGA path in Fig. 7.

The whole path is batch-native (DESIGN.md §9): carry and inputs may bear a
leading batch dimension ``B`` — B independent event streams (users / DVS
sensors) stepped against one set of routing tables in a single dispatch.
``EventEngine.run`` scans over a ``[T, n_clusters, K]`` (or batched
``[T, B, n_clusters, K]``) input-event tensor. Delivery is delegated to a
pluggable dispatch backend (core/dispatch.py): ``reference`` (pure jnp),
``pallas`` (TPU stage-2 kernel), ``fused`` (single-kernel stage-1+2), or
``sharded`` (2-D-mesh shard_map), selected by name.

**Event-sparse delivery** (DESIGN.md §10): construct the engine with
``queue_capacity=Q`` to compact each step's spikes into a fixed-capacity AER
queue before stage 1 — delivery cost then scales with event count, and
``step``/``run`` additionally emit a :class:`DeliveryStats` (per-stream
FIFO-overflow drop counts, stacked over time by the scan). With
``donate_carry=True`` the step carry is donated to the compiled step on
accelerators, so the neuron-state buffers are updated in place across a
long run — but a carry you already stepped can then no longer be read
(always thread the returned one).

``dense_reference_step`` is the oracle: the same network as one dense
[N, N, 4] connectivity tensor (used by tests to prove routing equivalence),
batched the same way.

For multi-device execution, ``make_sharded_step`` shards clusters (cores)
across a mesh axis with ``shard_map``: stage-1 scatter produces a partial
activity matrix per device which is reduce-scattered over the cluster axis
— the TPU analogue of point-to-point R2/R3 traffic (DESIGN.md §2). With
``batch_axis`` set it runs on a 2-D mesh, sharding event streams over the
data axis as well. With ``queue_capacity`` set, each device compacts its
own neuron slab (one output FIFO per core, like the chip).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as neuron_mod
from repro.core.dispatch import (
    DeliveryStats,
    DispatchBackend,
    backend_deliver,
    get_backend,
)
from repro.core.neuron import NeuronParams, NeuronState
from repro.core.shard_compat import SM_CHECK_KW, shard_map
from repro.core.tags import RoutingTables
from repro.core.two_stage import N_SYN_TYPES, precompute_syn_onehot

__all__ = [
    "EventEngine",
    "DeliveryStats",
    "dense_weights_from_tables",
    "dense_reference_step",
]


@dataclasses.dataclass(frozen=True)
class _Tables:
    src_tag: jax.Array
    src_dest: jax.Array
    cam_tag: jax.Array
    cam_syn: jax.Array
    # per-table constant [N, S, 4]: one-hot synapse types, precomputed once so
    # the expansion never runs in the per-step hot path (DESIGN.md §10)
    cam_syn_onehot: jax.Array


jax.tree_util.register_dataclass(
    _Tables,
    data_fields=["src_tag", "src_dest", "cam_tag", "cam_syn", "cam_syn_onehot"],
    meta_fields=[],
)

def _donate_carry_kwargs() -> dict:
    """Carry donation lets XLA reuse the neuron-state buffers across steps;
    the CPU backend does not implement donation and would warn on every
    compile. Resolved at first :class:`EventEngine` construction — not at
    import — so importing this module never initializes the JAX runtime."""
    return {} if jax.default_backend() == "cpu" else {"donate_argnums": (0,)}


class EventEngine:
    """Executable DYNAPs fabric for a compiled network."""

    def __init__(
        self,
        tables: RoutingTables,
        params: NeuronParams | None = None,
        backend: str | DispatchBackend = "reference",
        backend_options: dict | None = None,
        queue_capacity: int | None = None,
        donate_carry: bool = False,
    ):
        self.params = params or NeuronParams()
        self.cluster_size = tables.cluster_size
        self.k_tags = tables.k_tags
        self.n_neurons = tables.n_neurons
        self.n_clusters = tables.n_clusters
        self.backend = get_backend(backend, **(backend_options or {}))
        if queue_capacity is not None and queue_capacity <= 0:
            raise ValueError(f"queue_capacity must be positive, got {queue_capacity}")
        self.queue_capacity = queue_capacity
        cam_syn = jnp.asarray(tables.cam_syn)
        self.tables = _Tables(
            src_tag=jnp.asarray(tables.src_tag),
            src_dest=jnp.asarray(tables.src_dest),
            cam_tag=jnp.asarray(tables.cam_tag),
            cam_syn=cam_syn,
            cam_syn_onehot=precompute_syn_onehot(cam_syn),
        )
        # per-engine compiled step (self is closed over = static). Carry
        # donation is opt-in: with donate_carry=True on an accelerator the
        # neuron-state buffers are updated in place across a long run, but a
        # carry you already stepped can no longer be read (parity tests and
        # debuggers do exactly that — hence the conservative default).
        donate = _donate_carry_kwargs() if donate_carry else {}
        self._jit_step = jax.jit(self._step_impl, **donate)

    # ------------------------------------------------------------------
    def init_state(
        self, batch: int | tuple[int, ...] | None = None
    ) -> tuple[NeuronState, jax.Array]:
        """(neuron state, previous-step spikes); batched when ``batch`` set."""
        lead = () if batch is None else (batch,) if isinstance(batch, int) else tuple(batch)
        return (
            neuron_mod.init_state(self.n_neurons, self.params, batch=batch),
            jnp.zeros((*lead, self.n_neurons), jnp.float32),
        )

    def step(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_activity: jax.Array,  # [..., n_clusters, K] external events this step
        i_ext: jax.Array | None = None,
    ):
        """One fabric timestep (jit-compiled per engine; carry donated when
        the engine was built with ``donate_carry=True``).

        Returns ``(carry, spikes)`` — or ``(carry, (spikes, DeliveryStats))``
        when the engine was built with ``queue_capacity`` (drop counts are
        part of the observable output so ``run``'s scan stacks them over T).
        """
        return self._jit_step(carry, input_activity, i_ext)

    def _step_impl(self, carry, input_activity, i_ext=None):
        state, prev_spikes = carry
        drive, stats = backend_deliver(
            self.backend,
            prev_spikes,
            self.tables.src_tag,
            self.tables.src_dest,
            self.tables.cam_tag,
            self.tables.cam_syn,
            self.cluster_size,
            self.k_tags,
            external_activity=input_activity,
            queue_capacity=self.queue_capacity,
            syn_onehot=self.tables.cam_syn_onehot,
            with_stats=True,
        )
        state, spikes = neuron_mod.neuron_step(state, drive, self.params, i_ext)
        out = spikes if self.queue_capacity is None else (spikes, stats)
        return (state, spikes), out

    def run(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_events: jax.Array,  # [T, ..., n_clusters, K]
        i_ext: jax.Array | None = None,
    ):
        """Scan T steps; returns ``(final carry, spikes [T, ..., N])`` — with
        ``queue_capacity`` set, ``(final carry, (spikes [T, ..., N],
        DeliveryStats with dropped [T, ...]))``."""

        def body(c, inp):
            return self.step(c, inp, i_ext)

        return jax.lax.scan(body, carry, input_events)

    # ------------------------------------------------------------------
    def make_sharded_step(
        self,
        mesh: jax.sharding.Mesh,
        axis: str = "data",
        batch_axis: str | None = None,
    ):
        """shard_map step with clusters sharded over ``axis``.

        Neurons, CAM tables and neuron state are sharded by cluster slab;
        stage-1 partial activity is reduce-scattered across devices (the
        R2/R3 point-to-point hop), stage-2 and dynamics are fully local.

        With ``batch_axis`` set the mesh is 2-D: event streams shard over
        ``batch_axis`` (pure data parallelism) while clusters shard over
        ``axis``; all carried arrays then bear a leading batch dim.

        With the engine's ``queue_capacity`` set, each device compacts its
        local slab through its own AER FIFO and the step returns
        ``(state, spikes, dropped)`` — ``dropped`` already summed fabric-wide.
        """
        from jax.sharding import PartitionSpec as P

        n_dev = mesh.shape[axis]
        assert self.n_clusters % n_dev == 0, "clusters must divide device axis"
        params = self.params
        cluster_size, k_tags = self.cluster_size, self.k_tags
        n_clusters = self.n_clusters
        queue_capacity = self.queue_capacity
        if queue_capacity is not None:  # per-core FIFO: split capacity by slab
            queue_capacity = max(1, -(-queue_capacity // n_dev))

        from repro.core.dispatch import sharded_local_deliver

        def local_step(tables, state, prev_spikes, input_activity, i_ext):
            # prev_spikes: local slab [..., N/n_dev]; tables rows local.
            drive, dropped = sharded_local_deliver(
                prev_spikes,
                tables.src_tag,
                tables.src_dest,
                tables.cam_tag,
                tables.cam_syn,
                cluster_size,
                n_clusters,
                k_tags,
                axis,
                external_activity=input_activity,
                queue_capacity=queue_capacity,
                syn_onehot=tables.cam_syn_onehot,
                with_stats=True,
            )
            state, spikes = neuron_mod.neuron_step(state, drive, params, i_ext)
            if queue_capacity is None:
                return state, spikes
            return state, spikes, dropped

        spec_t = P(axis)  # tables: shard rows (neurons) over the cluster axis
        if batch_axis is None:
            spec_c = P(axis)  # unbatched carry: leading dim is neurons
            spec_d = P()  # drop counter: replicated (summed over ``axis``)
        else:
            spec_c = P(batch_axis, axis)  # batched carry: [B, N_local, ...]
            spec_d = P(batch_axis)
        state_spec = NeuronState(spec_c, spec_c, spec_c, spec_c)
        out_specs = (state_spec, spec_c)
        if queue_capacity is not None:
            out_specs = (state_spec, spec_c, spec_d)
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                _Tables(spec_t, spec_t, spec_t, spec_t, spec_t),
                state_spec,
                spec_c,
                spec_c,
                spec_c,
            ),
            out_specs=out_specs,
            **SM_CHECK_KW,
        )


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------
def dense_weights_from_tables(tables: RoutingTables) -> np.ndarray:
    """[N, N, 4] dense fan-in counts implied by the routing tables."""
    n = tables.n_neurons
    w = np.zeros((n, n, N_SYN_TYPES), dtype=np.float32)
    for src, dst, syn in tables.dense_equivalent():
        w[dst, src, syn] += 1.0
    return w


def dense_reference_step(
    dense_w: jax.Array,  # [N, N, 4]
    prev_spikes: jax.Array,  # [..., N]
    state: NeuronState,
    params: NeuronParams,
    external_drive: jax.Array | None = None,  # [..., N, 4]
    i_ext: jax.Array | None = None,
):
    """Oracle step: dense matmul delivery instead of two-stage routing."""
    drive = jnp.einsum("dst,...s->...dt", dense_w, prev_spikes)
    if external_drive is not None:
        drive = drive + external_drive
    return neuron_mod.neuron_step(state, drive, params, i_ext)
