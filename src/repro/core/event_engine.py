"""Event-driven SNN engine: two-stage routing + neuron dynamics, scan-able.

The engine is the executable model of the whole DYNAPs fabric:

  spikes[t] --AER queue--> stage1 --> tag activity A[c, k] --stage2/CAM-->
           drive[N, 4] --AdExp/DPI--> spikes[t+1]

External stimulation (the chip's Input Interface) enters as tag activity
(events addressed to (cluster, tag)), exactly like the FPGA path in Fig. 7.

The whole path is batch-native (DESIGN.md §9): carry and inputs may bear a
leading batch dimension ``B`` — B independent event streams (users / DVS
sensors) stepped against one set of routing tables in a single dispatch.
``EventEngine.run`` scans over a ``[T, n_clusters, K]`` (or batched
``[T, B, n_clusters, K]``) input-event tensor. Delivery is delegated to a
pluggable dispatch backend (core/dispatch.py): ``reference`` (pure jnp),
``pallas`` (TPU stage-2 kernel), ``fused`` (single-kernel stage-1+2), or
``sharded`` (2-D-mesh shard_map), selected by name.

**Event-sparse delivery** (DESIGN.md §10): construct the engine with
``queue_capacity=Q`` to compact each step's spikes into a fixed-capacity AER
queue before stage 1 — delivery cost then scales with event count, and
``step``/``run`` additionally emit a :class:`DeliveryStats` (per-stream
FIFO-overflow drop counts, stacked over time by the scan). With
``donate_carry=True`` the step carry is donated to the compiled step on
accelerators, so the neuron-state buffers are updated in place across a
long run — but a carry you already stepped can then no longer be read
(always thread the returned one).

**Fabric mode** (DESIGN.md §11): construct with ``fabric=routing.Fabric(...)``
(tables compiled with a placement via ``compile_network(spec, fabric=...)``)
to push delivery through the executable R1/R2/R3 model — cross-tile events
traverse per-hop delay lines (arriving ``ceil(hops * latency / dt)`` steps
late; the carry gains the in-flight buffer) and bandwidth-limited inter-tile
link FIFOs, with per-step hop/latency/energy accumulators and link-drop
counts in the :class:`DeliveryStats` output.

**Multi-tenant serving** (DESIGN.md §12): batch slots are tenants.
``EventEngine.reset_slots(carry, mask)`` surgically restores masked slots
to freshly-initialized state — neuron state, undelivered previous-step
spikes, and the fabric in-flight buffer — so a session pool (serve/aer.py)
can admit and evict independent users without recompiling or leaking state
between a slot's successive occupants.

``dense_reference_step`` is the oracle: the same network as one dense
[N, N, 4] connectivity tensor (used by tests to prove routing equivalence),
batched the same way.

For multi-device execution, ``make_sharded_step`` shards clusters (cores)
across a mesh axis with ``shard_map``: stage-1 scatter produces a partial
activity matrix per device which is reduce-scattered over the cluster axis
— the TPU analogue of point-to-point R2/R3 traffic (DESIGN.md §2). With
``batch_axis`` set it runs on a 2-D mesh, sharding event streams over the
data axis as well. With ``queue_capacity`` set, each device compacts its
own neuron slab (one output FIFO per core, like the chip).
"""

from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import neuron as neuron_mod
from repro.core.dispatch import (
    DeliveryStats,
    DispatchBackend,
    backend_deliver,
    get_backend,
)
from repro.core.neuron import NeuronParams, NeuronState
from repro.core.shard_compat import SM_CHECK_KW, shard_map
from repro.core.tags import RoutingTables
from repro.core.two_stage import N_SYN_TYPES, precompute_syn_onehot

__all__ = [
    "EventEngine",
    "ShardedEventEngine",
    "DeliveryStats",
    "SlotCarry",
    "ModelRegistry",
    "reset_slots",
    "slice_slot_carry",
    "embed_slot_carry",
    "dense_weights_from_tables",
    "dense_reference_step",
]


@dataclasses.dataclass
class SlotCarry:
    """Host-side serialization of a set of batch slots' full runtime state.

    Produced by :meth:`EventEngine.extract_slots`, consumed by
    :meth:`EventEngine.splice_slots` — the unit of session *migration*
    between engines (DESIGN.md §15). All leaves are numpy with leading dim
    ``S`` (the extracted slot count). ``inflight`` is the delay-line state
    in the *phase-normalized* roll layout — ``inflight[:, i]`` holds tag
    activity arriving ``i + 1`` steps after extraction — regardless of
    whether the source engine ran the ring fast path or the roll buffer, so
    a slot can be spliced across delivery modes and across engines whose
    ring cursors disagree. ``None`` when the source engine had no fabric.
    """

    state: NeuronState  # numpy leaves, each [S, ...]
    spikes: np.ndarray  # [S, N] previous-step spikes
    inflight: np.ndarray | None  # [S, max_delay, n_clusters, K] or None


@dataclasses.dataclass(frozen=True)
class _Tables:
    src_tag: jax.Array
    src_dest: jax.Array
    cam_tag: jax.Array
    cam_syn: jax.Array
    # per-table constant [N, S, 4]: one-hot synapse types, precomputed once so
    # the expansion never runs in the per-step hot path (DESIGN.md §10)
    cam_syn_onehot: jax.Array


jax.tree_util.register_dataclass(
    _Tables,
    data_fields=["src_tag", "src_dest", "cam_tag", "cam_syn", "cam_syn_onehot"],
    meta_fields=[],
)

def _donate_carry_kwargs() -> dict:
    """Carry donation lets XLA reuse the neuron-state buffers across steps;
    the CPU backend does not implement donation and would warn on every
    compile. Resolved at first :class:`EventEngine` construction — not at
    import — so importing this module never initializes the JAX runtime."""
    return {} if jax.default_backend() == "cpu" else {"donate_argnums": (0,)}


class EventEngine:
    """Executable DYNAPs fabric for a compiled network."""

    def __init__(
        self,
        tables: RoutingTables,
        params: NeuronParams | None = None,
        backend: str | DispatchBackend = "reference",
        backend_options: dict | None = None,
        autotune: dict | None = None,  # backend="auto" kwargs / {"decision": ...}
        queue_capacity: int | None = None,
        donate_carry: bool = False,
        fabric=None,  # routing.Fabric | dispatch.FabricBackend | None
        fabric_options: dict | None = None,
        entry_slabs=None,  # multi-model ring fast path: [(src_tag_m, src_dest_m)]
    ):
        # a compiler-v2 CompileResult (core/compiler.py) carries the tables
        # plus a CompileReport; unwrap it so optimized placements flow
        # end-to-end without the caller re-plumbing
        if not isinstance(tables, RoutingTables) and hasattr(tables, "tables"):
            tables = tables.tables
        self.params = params or NeuronParams()
        self.cluster_size = tables.cluster_size
        self.k_tags = tables.k_tags
        self.n_neurons = tables.n_neurons
        self.n_clusters = tables.n_clusters
        if queue_capacity is not None and queue_capacity <= 0:
            raise ValueError(f"queue_capacity must be positive, got {queue_capacity}")
        self.queue_capacity = queue_capacity
        # dispatch autotuner (DESIGN.md §18): backend="auto" measures the
        # dense/queued/fused crossover at this engine's (activity, B) point —
        # or honors an injected AutotuneDecision — and builds the winner.
        # ``dense`` winners bypass queue compaction in the step while keeping
        # the (spikes, stats) output contract (stats read zero drops).
        self.autotune_decision = None
        self._autotune_dense = False
        if backend == "auto":
            if fabric is not None:
                raise ValueError(
                    "backend='auto' tunes the dense/queued/fused dispatch "
                    "path; fabric engines deliver through the fabric model — "
                    "pass an explicit backend"
                )
            from repro.core.dispatch import autotune_backend

            opts = dict(autotune or {})
            decision = opts.pop("decision", None)
            if decision is None:
                opts.setdefault("queue_capacity", queue_capacity)
                decision = autotune_backend(
                    tables.src_tag,
                    tables.src_dest,
                    tables.cam_tag,
                    tables.cam_syn,
                    self.cluster_size,
                    self.k_tags,
                    **opts,
                )
            elif opts:
                raise ValueError(
                    "autotune={'decision': ...} is exclusive with tuning "
                    f"options {sorted(opts)}"
                )
            self.autotune_decision = decision
            backend = decision.backend
            self._autotune_dense = bool(decision.dense)
        elif autotune:
            raise ValueError("autotune options require backend='auto'")
        self.backend = get_backend(backend, **(backend_options or {}))
        # fabric mode (DESIGN.md §11): delivery runs on a FabricBackend and
        # the step carry gains the in-flight delay-line buffer; cross-tile
        # events arrive late and link FIFOs can drop. Takes precedence over
        # ``backend`` for delivery (stage 2 runs the jnp reference there).
        self.fabric_backend = None
        if fabric is not None:
            from repro.core.dispatch import FabricBackend

            if isinstance(fabric, FabricBackend):
                if fabric_options:
                    raise ValueError(
                        "fabric_options ignored: fabric was passed as a "
                        "FabricBackend instance — configure it at construction"
                    )
                self.fabric_backend = fabric
            else:
                opts = dict(fabric_options or {})
                opts.setdefault("tile_of_cluster", tables.tile_of_cluster)
                opts.setdefault("dt", self.params.dt)
                self.fabric_backend = FabricBackend(fabric=fabric, **opts)
            # the backend must agree with this engine however it was built:
            # a dt or placement mismatch silently warps arrival times / hops
            if self.fabric_backend.dt != self.params.dt:
                raise ValueError(
                    f"fabric dt={self.fabric_backend.dt} != NeuronParams.dt="
                    f"{self.params.dt}: delays and link capacity would be "
                    "derived at a timestep the neurons do not integrate with"
                )
            if tables.tile_of_cluster is not None:
                from repro.core.routing import default_tile_of_cluster

                backend_tiles = self.fabric_backend.tile_of_cluster
                if backend_tiles is None:
                    backend_tiles = default_tile_of_cluster(
                        self.n_clusters, self.fabric_backend.fabric
                    )
                if not np.array_equal(
                    np.asarray(backend_tiles), tables.tile_of_cluster
                ):
                    raise ValueError(
                        "fabric placement differs from the compiled tables' "
                        "tile_of_cluster — pass tile_of_cluster="
                        "tables.tile_of_cluster when constructing the backend"
                    )
            # build the delivery model eagerly: placement errors surface at
            # engine construction, and max_delay is needed by init_state
            self.fabric_model, _ = self.fabric_backend.model_for(self.n_clusters)
        # fault injection (DESIGN.md §15): the per-SRAM-entry survival mask is
        # drawn once, host-side, so both delivery paths consume the identical
        # erasure pattern — the ring path bakes it into FabricEntries.alive,
        # the roll path gathers it per queued event through this constant
        self._fault_entry_alive = None
        if self.fabric_backend is not None:
            self._fault_entry_alive = self.fabric_backend.entry_alive_for(
                tables.src_tag, tables.src_dest, self.cluster_size
            )
        cam_syn = jnp.asarray(tables.cam_syn)
        self.tables = _Tables(
            src_tag=jnp.asarray(tables.src_tag),
            src_dest=jnp.asarray(tables.src_dest),
            cam_tag=jnp.asarray(tables.cam_tag),
            cam_syn=cam_syn,
            cam_syn_onehot=precompute_syn_onehot(cam_syn),
        )
        # ring fast path (DESIGN.md §14): the carry gains a time-wheel ring +
        # write cursor instead of the shifted in-flight tail, and delivery
        # runs over a static per-SRAM-entry table precomputed here, once
        self.fabric_ring = (
            self.fabric_backend is not None and self.fabric_backend.ring
        )
        self._fabric_entries = None
        if self.fabric_ring:
            if entry_slabs is not None:
                # multi-model residency (DESIGN.md §16): the static entry
                # table is assembled slab-by-slab with slab-offset
                # addressing — bit-identical to building from the
                # concatenated table (tests/test_multimodel.py locks it)
                n_total = sum(np.asarray(st).shape[0] for st, _ in entry_slabs)
                if n_total != self.n_neurons:
                    raise ValueError(
                        f"entry_slabs span {n_total} neurons, tables have "
                        f"{self.n_neurons}"
                    )
                self._fabric_entries = self.fabric_backend.build_entries_slabs(
                    entry_slabs, self.cluster_size, self.k_tags
                )
            else:
                self._fabric_entries = self.fabric_backend.build_entries(
                    tables.src_tag, tables.src_dest, self.cluster_size, self.k_tags
                )
        elif entry_slabs is not None:
            raise ValueError(
                "entry_slabs only applies to the fabric ring fast path"
            )
        # per-engine compiled step (self is closed over = static). Carry
        # donation is opt-in: with donate_carry=True on an accelerator the
        # neuron-state buffers are updated in place across a long run, but a
        # carry you already stepped can no longer be read (parity tests and
        # debuggers do exactly that — hence the conservative default).
        donate = _donate_carry_kwargs() if donate_carry else {}
        self._jit_step = jax.jit(self._step_impl, **donate)
        self._jit_reset = jax.jit(self._reset_impl)

    # ------------------------------------------------------------------
    def init_state(
        self, batch: int | tuple[int, ...] | None = None
    ) -> tuple:
        """(neuron state, previous-step spikes); batched when ``batch`` set.

        In fabric mode the carry gains the delay-line state: with the ring
        fast path (the default) elements 3 and 4 are the time-wheel ring
        ``[..., max_delay + 1, n_clusters, K]`` and its shared int32 scalar
        write cursor; with ``fabric_options={"ring": False}`` element 3 is
        the roll-carried in-flight buffer ``[..., max_delay, nc, K]``.
        """
        lead = () if batch is None else (batch,) if isinstance(batch, int) else tuple(batch)
        carry = (
            neuron_mod.init_state(self.n_neurons, self.params, batch=batch),
            jnp.zeros((*lead, self.n_neurons), jnp.float32),
        )
        if self.fabric_backend is None:
            return carry
        if self.fabric_ring:
            ring, cursor = self.fabric_backend.init_ring(
                self.n_clusters, self.k_tags, batch=batch
            )
            return (*carry, ring, cursor)
        inflight = self.fabric_backend.init_inflight(
            self.n_clusters, self.k_tags, batch=batch
        )
        return (*carry, inflight)

    def step(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_activity: jax.Array,  # [..., n_clusters, K] external events this step
        i_ext: jax.Array | None = None,
    ):
        """One fabric timestep (jit-compiled per engine; carry donated when
        the engine was built with ``donate_carry=True``).

        Returns ``(carry, spikes)`` — or ``(carry, (spikes, DeliveryStats))``
        when the engine was built with ``queue_capacity`` or in fabric mode
        (stats are part of the observable output so ``run``'s scan stacks
        them over T; fabric mode always emits them — drops, hops, latency
        and energy are the point of running the fabric model). In fabric
        mode the carry is the tuple from :meth:`init_state`, including the
        delay-line state (ring + cursor by default, the in-flight buffer
        with ``fabric_options={"ring": False}``).
        """
        return self._jit_step(carry, input_activity, i_ext)

    def _step_impl(self, carry, input_activity, i_ext=None):
        # inputs adopt the carry dtype: under x64, default-f64 stimulus
        # arrays would otherwise promote the neuron state mid-scan and trip
        # lax.scan's carry-type check
        dtype = carry[1].dtype
        input_activity = jnp.asarray(input_activity, dtype)
        if i_ext is not None:
            i_ext = jnp.asarray(i_ext, dtype)
        if self.fabric_backend is not None and self.fabric_ring:
            state, prev_spikes, ring, cursor = carry
            drive, ring, cursor, stats = self.fabric_backend.deliver_fabric_ring(
                prev_spikes,
                self._fabric_entries,
                self.tables.cam_tag,
                self.tables.cam_syn,
                self.cluster_size,
                self.k_tags,
                ring,
                cursor,
                external_activity=input_activity,
                queue_capacity=self.queue_capacity,
                syn_onehot=self.tables.cam_syn_onehot,
            )
            state, spikes = neuron_mod.neuron_step(state, drive, self.params, i_ext)
            return (state, spikes, ring, cursor), (spikes, stats)
        if self.fabric_backend is not None:
            state, prev_spikes, inflight = carry
            drive, inflight, stats = self.fabric_backend.deliver_fabric(
                prev_spikes,
                self.tables.src_tag,
                self.tables.src_dest,
                self.tables.cam_tag,
                self.tables.cam_syn,
                self.cluster_size,
                self.k_tags,
                inflight=inflight,
                external_activity=input_activity,
                queue_capacity=self.queue_capacity,
                syn_onehot=self.tables.cam_syn_onehot,
                entry_alive=self._fault_entry_alive,
            )
            state, spikes = neuron_mod.neuron_step(state, drive, self.params, i_ext)
            # fabric mode always reports stats: drops/hops/latency/energy are
            # the point of running the fabric model
            return (state, spikes, inflight), (spikes, stats)
        state, prev_spikes = carry
        drive, stats = backend_deliver(
            self.backend,
            prev_spikes,
            self.tables.src_tag,
            self.tables.src_dest,
            self.tables.cam_tag,
            self.tables.cam_syn,
            self.cluster_size,
            self.k_tags,
            external_activity=input_activity,
            # an autotuned "dense" winner bypasses compaction; the output
            # contract still follows queue_capacity (stats read zero drops)
            queue_capacity=None if self._autotune_dense else self.queue_capacity,
            syn_onehot=self.tables.cam_syn_onehot,
            with_stats=True,
        )
        state, spikes = neuron_mod.neuron_step(state, drive, self.params, i_ext)
        out = spikes if self.queue_capacity is None else (spikes, stats)
        return (state, spikes), out

    def reset_slots(self, carry, mask):
        """Per-slot state surgery for multi-tenant serving (DESIGN.md §12).

        ``mask`` is a boolean array over the carry's leading batch dims
        (``True`` = wipe that slot). Masked slots are restored to the
        freshly-initialized state of :meth:`init_state`: neuron state back
        to rest, previous-step spikes cleared, and — in fabric mode — that
        slot's in-flight delay-line buffer zeroed, so a departing tenant's
        still-in-transit cross-tile events can never leak into the slot's
        next occupant. Unmasked slots are untouched (bit-identical), which
        is what lets a session pool admit/evict tenants independently while
        the others keep running.
        """
        return self._jit_reset(carry, jnp.asarray(mask))

    def _reset_impl(self, carry, mask):
        if mask.ndim < 1:
            raise ValueError("reset_slots needs a batched carry (mask per slot)")
        lead = tuple(carry[1].shape[: mask.ndim])
        if tuple(mask.shape) != lead:
            raise ValueError(
                f"reset mask shape {tuple(mask.shape)} does not match the "
                f"carry's slot dims {lead} — a mis-sized mask must raise, "
                "not broadcast (it would wipe the wrong tenants)"
            )
        fresh = self.init_state(batch=mask.shape)
        return reset_slots(carry, mask, fresh)

    # ------------------------------------------------------------------
    # Slot migration (DESIGN.md §15): extract_slots / splice_slots generalize
    # reset_slots — instead of wiping a slot, serialize its complete runtime
    # state (including the fabric delay-line contents) so surviving sessions
    # can move onto a repaired engine or come back from a checkpoint.
    def _check_slot_index(self, slots, batch: int) -> np.ndarray:
        idx = np.atleast_1d(np.asarray(slots, dtype=np.int64))
        if idx.ndim != 1 or idx.size == 0:
            raise ValueError("slots must be a non-empty 1-D index sequence")
        if np.unique(idx).size != idx.size:
            raise ValueError(f"slots must be unique, got {idx.tolist()}")
        if np.any(idx < 0) or np.any(idx >= batch):
            raise ValueError(
                f"slots {idx.tolist()} out of range for batch size {batch}"
            )
        return idx

    def extract_slots(self, carry, slots) -> SlotCarry:
        """Serialize ``slots``' full per-slot runtime state (host-side).

        The carry must bear exactly one leading batch dim (a session pool).
        Ring-mode delay state is phase-normalized on the way out: wheel slot
        ``(cursor + i) % (max_delay + 1)`` holds the events arriving in
        ``i + 1`` steps, so the returned ``inflight[:, i]`` has the roll
        layout and the wheel phase does not travel with the snapshot.
        """
        spikes = np.asarray(carry[1])
        if spikes.ndim != 2:
            raise ValueError(
                "extract_slots needs a carry with one leading batch dim, got "
                f"spikes shape {spikes.shape}"
            )
        idx = self._check_slot_index(slots, spikes.shape[0])
        state = jax.tree_util.tree_map(lambda x: np.asarray(x)[idx], carry[0])
        inflight = None
        if self.fabric_backend is not None:
            if self.fabric_ring:
                ring = np.asarray(carry[2])  # [B, max_delay + 1, nc, K]
                cur = int(np.asarray(carry[3]))
                d1 = ring.shape[-3]
                order = (cur + np.arange(d1 - 1)) % d1
                inflight = ring[idx][:, order]
            else:
                inflight = np.asarray(carry[2])[idx]
        return SlotCarry(state=state, spikes=spikes[idx], inflight=inflight)

    def splice_slots(self, carry, slots, sc: SlotCarry):
        """Write ``sc``'s serialized slots into ``carry`` at ``slots``.

        The inverse of :meth:`extract_slots`, on *this* engine's carry —
        the source engine may differ (that is the point: migration onto a
        repaired placement, or restore into a fresh pool). Neuron count,
        cluster count and K must match. Delay-line contents are re-bucketed
        when the two engines' ``max_delay`` differ: shorter horizons gain
        zero tail slots; longer horizons fold the excess tail into the last
        slot (events arrive *earlier* than on the source fabric — best
        effort; the exchange is bit-exact when the horizons agree).
        Unlisted slots are untouched bit-identically.
        """
        spikes_t = carry[1]
        if spikes_t.ndim != 2:
            raise ValueError(
                "splice_slots needs a carry with one leading batch dim, got "
                f"spikes shape {spikes_t.shape}"
            )
        idx = self._check_slot_index(slots, spikes_t.shape[0])
        sp = np.asarray(sc.spikes)
        if sp.shape[0] != idx.size:
            raise ValueError(
                f"{idx.size} slots but SlotCarry holds {sp.shape[0]}"
            )
        if sp.shape[-1] != self.n_neurons:
            raise ValueError(
                f"SlotCarry has {sp.shape[-1]} neurons, engine has "
                f"{self.n_neurons}"
            )
        def _checked_set(cur, new):
            new = jnp.asarray(new, cur.dtype)
            want = (idx.size, *cur.shape[1:])
            if tuple(new.shape) != want:
                raise ValueError(
                    f"SlotCarry state leaf shape {tuple(new.shape)} != "
                    f"expected {want} — a mismatched leaf must raise, not "
                    "broadcast into the pool"
                )
            return cur.at[jidx].set(new)

        jidx = jnp.asarray(idx)
        state = jax.tree_util.tree_map(_checked_set, carry[0], sc.state)
        spikes = spikes_t.at[jidx].set(jnp.asarray(sp, spikes_t.dtype))
        if self.fabric_backend is None:
            if sc.inflight is not None and np.any(np.asarray(sc.inflight)):
                raise ValueError(
                    "SlotCarry holds in-flight fabric events but the target "
                    "engine has no fabric delay line to receive them"
                )
            return (state, spikes)
        d_t = self.fabric_model.max_delay
        if sc.inflight is None:
            inflight = np.zeros(
                (idx.size, d_t, self.n_clusters, self.k_tags), np.float32
            )
        else:
            inflight = np.asarray(sc.inflight)
            if inflight.shape[-2:] != (self.n_clusters, self.k_tags):
                raise ValueError(
                    f"SlotCarry in-flight grid {inflight.shape[-2:]} != "
                    f"engine ({self.n_clusters}, {self.k_tags})"
                )
            d_s = inflight.shape[1]
            if d_s > d_t:  # fold the excess tail into the last live slot
                if d_t == 0:
                    if np.any(inflight):
                        raise ValueError(
                            "target engine has no delay line (max_delay=0) "
                            "but the SlotCarry holds in-flight events"
                        )
                    inflight = inflight[:, :0]
                else:
                    inflight = np.concatenate(
                        [
                            inflight[:, : d_t - 1],
                            inflight[:, d_t - 1 :].sum(axis=1, keepdims=True),
                        ],
                        axis=1,
                    )
            elif d_s < d_t:
                pad = np.zeros(
                    (idx.size, d_t - d_s, *inflight.shape[2:]), inflight.dtype
                )
                inflight = np.concatenate([inflight, pad], axis=1)
        if self.fabric_ring:
            ring, cursor = carry[2], carry[3]
            cur = int(np.asarray(cursor))
            d1 = d_t + 1
            rows = np.zeros((idx.size, d1, *inflight.shape[2:]), inflight.dtype)
            rows[:, (cur + np.arange(d_t)) % d1] = inflight
            ring = ring.at[jidx].set(jnp.asarray(rows, ring.dtype))
            return (state, spikes, ring, cursor)
        infl = carry[2].at[jidx].set(jnp.asarray(inflight, carry[2].dtype))
        return (state, spikes, infl)

    def run(
        self,
        carry: tuple[NeuronState, jax.Array],
        input_events: jax.Array,  # [T, ..., n_clusters, K]
        i_ext: jax.Array | None = None,
    ):
        """Scan T steps; returns ``(final carry, spikes [T, ..., N])`` — with
        ``queue_capacity`` (or fabric mode) set, ``(final carry, (spikes
        [T, ..., N], DeliveryStats stacked over T))``.

        ``i_ext`` may be time-varying: a ``[T, ..., N]`` current (one more
        leading axis than the spike state, first axis of length ``T``) is
        scanned alongside ``input_events`` — step ``t`` sees ``i_ext[t]``.
        Anything of the spike state's rank or below is broadcast as a
        per-step constant, so ``[N]`` with ``N == T`` or batched ``[B, N]``
        with ``B == T`` are never misread as time series.
        """
        t = input_events.shape[0]
        i_shape = () if i_ext is None else np.shape(i_ext)
        time_varying = (
            len(i_shape) == np.ndim(carry[1]) + 1 and i_shape[0] == t
        )
        if time_varying:

            def body_t(c, xs):
                inp, ie = xs
                return self.step(c, inp, ie)

            return jax.lax.scan(body_t, carry, (input_events, jnp.asarray(i_ext)))

        def body(c, inp):
            return self.step(c, inp, i_ext)

        return jax.lax.scan(body, carry, input_events)

    # ------------------------------------------------------------------
    def make_sharded_step(
        self,
        mesh: jax.sharding.Mesh,
        axis: str = "data",
        batch_axis: str | None = None,
    ):
        """shard_map step with clusters sharded over ``axis``.

        Neurons, CAM tables and neuron state are sharded by cluster slab;
        stage-1 partial activity is reduce-scattered across devices (the
        R2/R3 point-to-point hop), stage-2 and dynamics are fully local.

        With ``batch_axis`` set the mesh is 2-D: event streams shard over
        ``batch_axis`` (pure data parallelism) while clusters shard over
        ``axis``; all carried arrays then bear a leading batch dim.

        With the engine's ``queue_capacity`` set, each device compacts its
        local slab through its own AER FIFO and the step returns
        ``(state, spikes, dropped)`` — ``dropped`` already summed fabric-wide.

        In fabric mode (``EventEngine(fabric=...)``) the device mesh mirrors
        the chip mesh: each device owns a contiguous slab of whole *tiles*
        (the placement must not split a tile across devices), per-link FIFO
        arbitration runs where the events originate — exact, since a
        directed link's traffic all comes from one device — and the step
        signature becomes ``(tables, state, prev_spikes, inflight,
        input_activity, i_ext) -> (state, spikes, inflight, DeliveryStats)``
        with the in-flight buffer sharded over the cluster axis and stats
        psum-reduced fabric-wide. With the ring fast path (the default) the
        delay-line carry is instead the time-wheel pair: ``(tables, state,
        prev_spikes, ring, cursor, input_activity, i_ext) -> (state, spikes,
        ring, cursor, DeliveryStats)`` — the ring sharded like the in-flight
        buffer, the scalar cursor replicated (``P()``).
        """
        from jax.sharding import PartitionSpec as P

        n_dev = mesh.shape[axis]
        assert self.n_clusters % n_dev == 0, "clusters must divide device axis"
        params = self.params
        cluster_size, k_tags = self.cluster_size, self.k_tags
        n_clusters = self.n_clusters
        queue_capacity = self.queue_capacity
        if queue_capacity is not None:  # per-core FIFO: split capacity by slab
            queue_capacity = max(1, -(-queue_capacity // n_dev))

        if self.fabric_backend is not None:
            if self.fabric_backend.faults is not None:
                raise NotImplementedError(
                    "fault injection is not supported by the sharded fabric "
                    "step — run faulted scenarios single-device (DESIGN.md §15)"
                )
            return self._make_sharded_fabric_step(
                mesh, axis, batch_axis, n_dev, queue_capacity
            )

        from repro.core.dispatch import sharded_local_deliver

        def local_step(tables, state, prev_spikes, input_activity, i_ext):
            # prev_spikes: local slab [..., N/n_dev]; tables rows local.
            drive, dropped = sharded_local_deliver(
                prev_spikes,
                tables.src_tag,
                tables.src_dest,
                tables.cam_tag,
                tables.cam_syn,
                cluster_size,
                n_clusters,
                k_tags,
                axis,
                external_activity=input_activity,
                queue_capacity=queue_capacity,
                syn_onehot=tables.cam_syn_onehot,
                with_stats=True,
            )
            state, spikes = neuron_mod.neuron_step(state, drive, params, i_ext)
            if queue_capacity is None:
                return state, spikes
            return state, spikes, dropped

        spec_t = P(axis)  # tables: shard rows (neurons) over the cluster axis
        if batch_axis is None:
            spec_c = P(axis)  # unbatched carry: leading dim is neurons
            spec_d = P()  # drop counter: replicated (summed over ``axis``)
        else:
            spec_c = P(batch_axis, axis)  # batched carry: [B, N_local, ...]
            spec_d = P(batch_axis)
        state_spec = NeuronState(spec_c, spec_c, spec_c, spec_c)
        out_specs = (state_spec, spec_c)
        if queue_capacity is not None:
            out_specs = (state_spec, spec_c, spec_d)
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=(
                _Tables(spec_t, spec_t, spec_t, spec_t, spec_t),
                state_spec,
                spec_c,
                spec_c,
                spec_c,
            ),
            out_specs=out_specs,
            **SM_CHECK_KW,
        )

    def _make_sharded_fabric_step(self, mesh, axis, batch_axis, n_dev, queue_capacity):
        """Fabric-mode shard_map step: tiles -> devices (see make_sharded_step)."""
        from jax.sharding import PartitionSpec as P

        from repro.core.dispatch import DeliveryStats, advance_inflight
        from repro.core.two_stage import (
            compact_events,
            stage1_route_events_fabric,
            stage2_cam_match,
        )

        params = self.params
        cluster_size, k_tags = self.cluster_size, self.k_tags
        n_clusters = self.n_clusters
        nc_local = n_clusters // n_dev
        model, arrs = self.fabric_backend.model_for(n_clusters)
        # the device mesh mirrors the chip mesh only if no tile straddles a
        # device boundary — every link's traffic then originates on exactly
        # one device and per-device FIFO arbitration is globally exact
        slab_of_cluster = np.arange(n_clusters) // nc_local
        for t in np.unique(model.tile_of_cluster):
            devs = np.unique(slab_of_cluster[model.tile_of_cluster == t])
            if devs.size > 1:
                raise ValueError(
                    f"tile {t} is split across devices {devs.tolist()}: fabric-"
                    "sharded execution needs each tile's clusters on one device "
                    "(use the hierarchical linear placement or re-shard)"
                )

        def _route_local(tables, prev_spikes, cursor=None):
            """Shared stage-1 body: compact the slab, route through the fabric."""
            n_local = prev_spikes.shape[-1]
            capacity = n_local if queue_capacity is None else queue_capacity
            offset = jax.lax.axis_index(axis) * nc_local
            queue = compact_events(prev_spikes, capacity)
            route = stage1_route_events_fabric(
                queue,
                tables.src_tag,
                tables.src_dest,
                n_clusters,
                k_tags,
                cluster_size,
                arrs["cluster_tile"],
                arrs["delay_steps"],
                model.n_tiles,
                model.max_delay,
                model.link_capacity,
                mesh_hops=arrs["mesh_hops"],
                latency_s=arrs["latency_s"],
                energy_j=arrs["energy_j"],
                src_cluster_offset=offset,
                cursor=cursor,
                per_link_stats=self.fabric_backend.per_link_stats,
            )
            # hand every (delay, cluster) slab to its owner — the R3 hop
            buf = jax.lax.psum_scatter(
                route.buffer, axis, scatter_dimension=route.buffer.ndim - 2, tiled=True
            )  # [..., max_delay + 1, nc_local, K]
            # per_link_stats widens link_dropped/delivered with a trailing
            # bin axis; the elementwise psum and the batch-only PartitionSpec
            # (trailing dims replicated) treat both shapes uniformly — each
            # device contributes its own sources' bins, summed fabric-wide
            stats = DeliveryStats(
                dropped=jax.lax.psum(queue.dropped, axis),
                link_dropped=jax.lax.psum(route.link_dropped, axis),
                delivered=jax.lax.psum(route.delivered, axis),
                hops=jax.lax.psum(route.hops, axis),
                latency_s=jax.lax.psum(route.latency_s, axis),
                energy_j=jax.lax.psum(route.energy_j, axis),
            )
            return buf, stats

        def _finish_local(tables, state, a, input_activity, i_ext):
            a = a + input_activity
            drive = stage2_cam_match(
                a, tables.cam_tag, tables.cam_syn, cluster_size, tables.cam_syn_onehot
            )
            return neuron_mod.neuron_step(state, drive, params, i_ext)

        def local_step(tables, state, prev_spikes, inflight, input_activity, i_ext):
            buf, stats = _route_local(tables, prev_spikes)
            a, new_inflight = advance_inflight(buf, inflight, model.max_delay)
            state, spikes = _finish_local(tables, state, a, input_activity, i_ext)
            return state, spikes, new_inflight, stats

        def local_step_ring(
            tables, state, prev_spikes, ring, cursor, input_activity, i_ext
        ):
            # wheel semantics of the single-device ring step, with the routed
            # scatter already cursor-rotated by stage 1: accumulate this
            # step's arrivals, pop + clear the cursor slot, bump the pointer
            buf, stats = _route_local(tables, prev_spikes, cursor=cursor)
            ring = ring + buf
            slot_ax = ring.ndim - 3
            a = jnp.take(ring, cursor, axis=slot_ax)
            ring = jax.lax.dynamic_update_index_in_dim(
                ring, jnp.zeros_like(a), cursor, slot_ax
            )
            state, spikes = _finish_local(tables, state, a, input_activity, i_ext)
            return state, spikes, ring, (cursor + 1) % (model.max_delay + 1), stats

        spec_t = P(axis)
        if batch_axis is None:
            spec_c = P(axis)
            spec_f = P(None, axis)  # delay-line carry [D, nc, K]: shard clusters
            spec_d = P()
        else:
            spec_c = P(batch_axis, axis)
            spec_f = P(batch_axis, None, axis)  # [B, D, nc, K]
            spec_d = P(batch_axis)
        state_spec = NeuronState(spec_c, spec_c, spec_c, spec_c)
        stats_spec = DeliveryStats(spec_d, spec_d, spec_d, spec_d, spec_d, spec_d)
        in_specs = (
            _Tables(spec_t, spec_t, spec_t, spec_t, spec_t),
            state_spec,
            spec_c,
            spec_f,
            spec_c,
            spec_c,
        )
        if self.fabric_ring:
            # ring sharded like the in-flight buffer; scalar cursor replicated
            return shard_map(
                local_step_ring,
                mesh=mesh,
                in_specs=(*in_specs[:4], P(), *in_specs[4:]),
                out_specs=(state_spec, spec_c, spec_f, P(), stats_spec),
                **SM_CHECK_KW,
            )
        return shard_map(
            local_step,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(state_spec, spec_c, spec_f, stats_spec),
            **SM_CHECK_KW,
        )


class ShardedEventEngine(EventEngine):
    """:class:`EventEngine` whose jitted step runs multi-device via shard_map.

    The engine owns a 2-D device mesh named ``("data", "model")``: batch
    slots (tenants) shard over ``data`` and clusters (tiles) over ``model``
    — one serving shard of a ``ShardedSessionPool`` (serve/sharded.py,
    DESIGN.md §17). The public step contract is unchanged
    (``step(carry, input_activity, i_ext) -> (carry, (spikes, stats))``),
    so session pools, slot surgery (``reset_slots`` / ``extract_slots`` /
    ``splice_slots``) and checkpointing work on it untouched; only the step
    dispatch is resharded through :meth:`EventEngine.make_sharded_step`.
    Queued engines always report a :class:`DeliveryStats` (drops summed
    fabric-wide by the sharded step), matching the ``queue_capacity``
    contract of the local engine.

    Constraints inherited from the sharded step: the carry must be batched
    and the batch must divide ``batch_devices``; ``n_clusters`` must divide
    ``cluster_devices``; in fabric mode the compiled placement must keep
    every tile's clusters inside one device slab
    (:func:`repro.core.compiler.device_slab_placement` builds such
    placements) and fault injection is rejected. A ``(1, 1)`` mesh is valid
    — serving code paths are then identical with or without real devices.
    """

    def __init__(
        self,
        tables,
        params: NeuronParams | None = None,
        *,
        devices=None,
        cluster_devices: int = 1,
        batch_devices: int = 1,
        **engine_kw,
    ):
        donate = bool(engine_kw.get("donate_carry", False))
        super().__init__(tables, params, **engine_kw)
        if cluster_devices <= 0 or batch_devices <= 0:
            raise ValueError(
                f"mesh extents must be positive, got {batch_devices} x "
                f"{cluster_devices}"
            )
        need = batch_devices * cluster_devices
        if devices is None:
            avail = jax.devices()
            if need > len(avail):
                raise ValueError(
                    f"mesh needs {need} devices, only {len(avail)} visible "
                    "(set --xla_force_host_platform_device_count on CPU)"
                )
            devices = avail[:need]
        devices = np.asarray(devices, dtype=object)
        if devices.size != need:
            raise ValueError(
                f"got {devices.size} devices for a {batch_devices} x "
                f"{cluster_devices} mesh"
            )
        if self.n_clusters % cluster_devices:
            raise ValueError(
                f"{self.n_clusters} clusters do not divide over "
                f"{cluster_devices} cluster devices"
            )
        self.mesh = jax.sharding.Mesh(
            devices.reshape(batch_devices, cluster_devices), ("data", "model")
        )
        self.cluster_devices = cluster_devices
        self.batch_devices = batch_devices
        # the sharded step's flat signature, re-adapted to step()'s contract;
        # placement/tile-split errors surface here, at construction
        sharded = self.make_sharded_step(self.mesh, "model", batch_axis="data")
        fabric = self.fabric_backend is not None
        ring = self.fabric_ring
        qc = self.queue_capacity

        def _wrapped(carry, input_activity, i_ext=None):
            dtype = carry[1].dtype
            inp = jnp.asarray(input_activity, dtype)
            # shard_map in_specs cannot carry a None leaf: vacant external
            # drive becomes explicit zeros (free under XLA's simplifier)
            ie = (
                jnp.zeros_like(carry[1])
                if i_ext is None
                else jnp.asarray(i_ext, dtype)
            )
            if fabric and ring:
                state, prev, rg, cur = carry
                state, spikes, rg, cur, stats = sharded(
                    self.tables, state, prev, rg, cur, inp, ie
                )
                return (state, spikes, rg, cur), (spikes, stats)
            if fabric:
                state, prev, infl = carry
                state, spikes, infl, stats = sharded(
                    self.tables, state, prev, infl, inp, ie
                )
                return (state, spikes, infl), (spikes, stats)
            state, prev = carry
            out = sharded(self.tables, state, prev, inp, ie)
            if qc is None:
                state, spikes = out
                return (state, spikes), spikes
            state, spikes, dropped = out
            return (state, spikes), (spikes, DeliveryStats(dropped=dropped))

        self._jit_step = jax.jit(
            _wrapped, **(_donate_carry_kwargs() if donate else {})
        )

    def carry_pspecs(self):
        """PartitionSpec tree for a batched carry under this engine's mesh.

        Matches :meth:`EventEngine.make_sharded_step`'s layout: neuron-state
        leaves and spikes shard ``[B, N]`` over ``(data, model)``, fabric
        delay-line carries shard clusters (``[B, D, nc, K]`` over
        ``(data, None, model)``), and the ring's shared write cursor is
        replicated. Feed through ``distributed.sharding.named`` into
        ``jax.device_put`` / ``Checkpointer.restore(shardings=...)`` to land
        a carry on the mesh — the elastic-restart path
        (distributed/elastic.py, DESIGN.md §17).
        """
        from jax.sharding import PartitionSpec as P

        spec_c = P("data", "model")
        state = NeuronState(spec_c, spec_c, spec_c, spec_c)
        if self.fabric_backend is None:
            return (state, spec_c)
        spec_f = P("data", None, "model")
        if self.fabric_ring:
            return (state, spec_c, spec_f, P())
        return (state, spec_c, spec_f)

    def place_carry(self, carry):
        """device_put ``carry`` onto this engine's mesh per :meth:`carry_pspecs`.

        Splice/restore surgery produces host-backed or default-placed
        arrays; pinning them back onto the shard's own mesh keeps a
        multi-shard fleet's carries resident on their devices instead of
        bouncing through the step's implicit resharding.
        """
        from repro.distributed.sharding import named

        shardings = named(self.mesh, self.carry_pspecs())
        return jax.tree.map(jax.device_put, carry, shardings)


# ---------------------------------------------------------------------------
# Per-slot state surgery
# ---------------------------------------------------------------------------
def reset_slots(carry, mask: jax.Array, fresh):
    """Replace masked slots of ``carry`` with the matching slots of ``fresh``.

    ``carry`` and ``fresh`` are any pytrees of identically-shaped arrays
    whose leading dims start with ``mask``'s shape (the slot axes); every
    leaf is selected slot-wise. This is the functional core of
    :meth:`EventEngine.reset_slots` — kept standalone so custom serving
    loops can splice arbitrary per-slot state (e.g. a checkpointed tenant)
    instead of the engine's fresh init.

    Leaves with fewer dims than ``mask`` are slot-*shared* (the ring-mode
    write cursor: every slot steps in lockstep, so one phase pointer serves
    the whole pool) and pass through unchanged — zeroing a masked slot's
    whole ring is phase-independent, so the evicted tenant leaks nothing at
    any cursor position.
    """
    def sel(cur, new):
        if cur.ndim < mask.ndim:
            return cur
        if tuple(cur.shape[: mask.ndim]) != tuple(mask.shape):
            raise ValueError(
                f"mask shape {tuple(mask.shape)} does not match carry leaf "
                f"slot dims {tuple(cur.shape[: mask.ndim])} — refusing to "
                "broadcast a mis-sized mask across slots"
            )
        m = mask.reshape(mask.shape + (1,) * (cur.ndim - mask.ndim))
        return jnp.where(m, jnp.asarray(new, cur.dtype), cur)

    return jax.tree_util.tree_map(sel, carry, fresh)


# ---------------------------------------------------------------------------
# Multi-model residency (DESIGN.md §16)
# ---------------------------------------------------------------------------
def slice_slot_carry(sc: SlotCarry, slab) -> SlotCarry:
    """Restrict a :class:`SlotCarry` to one resident model's table slab.

    ``slab`` is a :class:`repro.core.tags.TableSlab`. Neuron-state leaves
    carry the neuron axis at position 1 (``[S, N]`` / ``[S, N, 4]``), so one
    slice serves all of them; the in-flight buffer is cut on the cluster
    axis and narrowed to the slab's own ``k_tags`` — the combined engine may
    pad K up to the widest resident model, and tag activity a model never
    compiled is structurally zero in its slab.
    """
    n0, n1 = slab.neuron_lo, slab.neuron_hi
    state = jax.tree_util.tree_map(lambda x: np.asarray(x)[:, n0:n1], sc.state)
    spikes = np.asarray(sc.spikes)[:, n0:n1]
    inflight = None
    if sc.inflight is not None:
        inflight = np.asarray(sc.inflight)[
            :, :, slab.cluster_lo : slab.cluster_hi, : slab.k_tags
        ]
    return SlotCarry(state=state, spikes=spikes, inflight=inflight)


def embed_slot_carry(sc_slab: SlotCarry, engine: "EventEngine", slab) -> SlotCarry:
    """Embed a slab-restricted :class:`SlotCarry` into ``engine``'s geometry.

    The inverse of :func:`slice_slot_carry` for migration onto a pool whose
    slab layout moved (hot-swap of a co-resident model). The base is the
    engine's *fresh* init — not zeros: a zeroed membrane (``v = 0``) sits at
    the firing threshold and every neuron outside the slab would spike on
    the first step. The returned in-flight buffer keeps the source horizon
    ``D_src``; :meth:`EventEngine.splice_slots` re-buckets it to the target
    engine's ``max_delay`` and re-rotates the ring phase.
    """
    part = np.asarray(sc_slab.spikes)
    s = part.shape[0]
    if part.shape[-1] != slab.n_neurons:
        raise ValueError(
            f"SlotCarry holds {part.shape[-1]} neurons but the slab spans "
            f"{slab.n_neurons}"
        )
    base = engine.extract_slots(engine.init_state(batch=s), np.arange(s))
    n0, n1 = slab.neuron_lo, slab.neuron_hi

    def put(full, p):
        full = np.array(full)
        full[:, n0:n1] = p
        return full

    state = jax.tree_util.tree_map(put, base.state, sc_slab.state)
    spikes = put(base.spikes, part)
    inflight = None
    if engine.fabric_backend is not None:
        if sc_slab.inflight is None:
            inflight = base.inflight
        else:
            src = np.asarray(sc_slab.inflight)
            if src.shape[-2:] != (slab.n_clusters, slab.k_tags):
                raise ValueError(
                    f"SlotCarry in-flight grid {src.shape[-2:]} != slab "
                    f"({slab.n_clusters}, {slab.k_tags})"
                )
            if slab.k_tags > engine.k_tags:
                raise ValueError(
                    f"slab k_tags {slab.k_tags} exceeds engine K {engine.k_tags}"
                )
            inflight = np.zeros(
                (s, src.shape[1], engine.n_clusters, engine.k_tags), np.float32
            )
            inflight[
                :, :, slab.cluster_lo : slab.cluster_hi, : slab.k_tags
            ] = src
    elif sc_slab.inflight is not None and np.any(sc_slab.inflight):
        raise ValueError(
            "SlotCarry holds in-flight fabric events but the target engine "
            "has no fabric delay line to receive them"
        )
    return SlotCarry(state=state, spikes=spikes, inflight=inflight)


class ModelRegistry:
    """Ordered set of resident compiled networks sharing ONE engine (§16).

    Each model keeps its own :class:`RoutingTables`; :meth:`combined`
    concatenates them into disjoint neuron/cluster slabs (tag values need no
    rebasing — ``(cluster, tag)`` is the routed address and clusters are
    rebased by :func:`repro.core.tags.concat_tables`). The slab layout is
    insertion-ordered, so *which models are resident, in which order* is the
    whole identity of the combined engine — :meth:`fingerprint` hashes
    exactly that, and checkpoint restore compares it.
    """

    def __init__(self, models=None):
        self._models: dict[str, RoutingTables] = {}
        if models:
            for name, tables in models.items():
                self.load(name, tables)

    @staticmethod
    def _unwrap(tables) -> RoutingTables:
        # accept CompileResult / CompiledArtifact / CompiledCnn wrappers
        while hasattr(tables, "tables"):
            tables = tables.tables
        return tables

    def load(self, name: str, tables) -> None:
        if name in self._models:
            raise ValueError(f"model {name!r} already resident")
        tables = self._unwrap(tables)
        for other_name, other in self._models.items():
            if other.cluster_size != tables.cluster_size:
                raise ValueError(
                    f"model {name!r} cluster_size {tables.cluster_size} != "
                    f"resident {other_name!r} cluster_size {other.cluster_size}"
                )
        self._models[name] = tables

    def unload(self, name: str) -> None:
        if name not in self._models:
            raise KeyError(f"model {name!r} is not resident")
        del self._models[name]

    def __contains__(self, name: str) -> bool:
        return name in self._models

    def __len__(self) -> int:
        return len(self._models)

    @property
    def names(self) -> list[str]:
        return list(self._models)

    def tables_of(self, name: str) -> RoutingTables:
        return self._models[name]

    def slabs(self) -> dict:
        """Slab layout by model name, insertion-ordered (no concat needed)."""
        from repro.core.tags import TableSlab

        out, n0, c0 = {}, 0, 0
        for name, t in self._models.items():
            out[name] = TableSlab(
                neuron_lo=n0,
                neuron_hi=n0 + t.n_neurons,
                cluster_lo=c0,
                cluster_hi=c0 + t.n_clusters,
                k_tags=t.k_tags,
            )
            n0 += t.n_neurons
            c0 += t.n_clusters
        return out

    def combined(self) -> tuple[RoutingTables, dict]:
        """(combined tables, slab layout by name). Single resident model
        returns its tables untouched, so a registry-of-one is free."""
        from repro.core.tags import concat_tables

        if not self._models:
            raise ValueError("registry holds no resident models")
        names = list(self._models)
        if len(names) == 1:
            return self._models[names[0]], self.slabs()
        tables, slab_list = concat_tables(list(self._models.values()))
        return tables, dict(zip(names, slab_list))

    def fingerprint(self) -> str:
        """sha256 over (name, table fingerprint) pairs in slab order."""
        h = hashlib.sha256()
        for name, t in self._models.items():
            h.update(name.encode())
            h.update(b"\x00")
            h.update(t.fingerprint().encode())
            h.update(b"\x01")
        return h.hexdigest()


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------
def dense_weights_from_tables(tables: RoutingTables) -> np.ndarray:
    """[N, N, 4] dense fan-in counts implied by the routing tables."""
    n = tables.n_neurons
    w = np.zeros((n, n, N_SYN_TYPES), dtype=np.float32)
    for src, dst, syn in tables.dense_equivalent():
        w[dst, src, syn] += 1.0
    return w


def dense_reference_step(
    dense_w: jax.Array,  # [N, N, 4]
    prev_spikes: jax.Array,  # [..., N]
    state: NeuronState,
    params: NeuronParams,
    external_drive: jax.Array | None = None,  # [..., N, 4]
    i_ext: jax.Array | None = None,
):
    """Oracle step: dense matmul delivery instead of two-stage routing."""
    drive = jnp.einsum("dst,...s->...dt", dense_w, prev_spikes)
    if external_drive is not None:
        drive = drive + external_drive
    return neuron_mod.neuron_step(state, drive, params, i_ext)
