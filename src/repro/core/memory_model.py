"""Memory-optimized two-stage routing theory (paper §II + Appendix A).

All equations follow the paper's notation:

  N : total number of neurons in the network
  F : fan-out per neuron
  C : cluster (core) size
  K : number of distinct tags per cluster (K = alpha * C)
  M : second-stage (broadcast) fan-out; stage-1 point-to-point fan-out is F/M

Source memory  MEM_S = (F/M) * (log2(K) + log2(N/C))       [eq. MEM_S, bits/neuron]
Target memory  MEM_T = (K*M/C) * log2(K)                   [bits/neuron]
Total          MEM   = (F/M) * log2(K*N/C) + (K*M/C)*log2(K)      (eq. 2)
With K = alpha*C:
               MEM   = (F/M) * log2(alpha*N) + alpha*M*log2(alpha*C)  (eq. 3)
Optimal        M*    = sqrt( F*log2(alpha*N) / (alpha*log2(alpha*C)) ) (eq. 5)
At M*:         MEM   = 2*sqrt(alpha*F*log2(alpha*C)*log2(alpha*N))     (eq. 6 general)
For alpha=1:   MEM   = 2*sqrt(F*log2(C)*log2(N))                       (eq. 6)

Conventional (source/destination-addressed) routing: F*log2(N) bits/neuron.

These are pure functions of python/numpy scalars: they are used by the network
compiler to size tables, by benchmarks to reproduce Fig. 13, and by tests
(hypothesis) to verify optimality and the Appendix-A feasibility constraints.
"""

from __future__ import annotations

import dataclasses
import math

__all__ = [
    "RoutingParams",
    "mem_source_bits",
    "mem_target_bits",
    "mem_total_bits",
    "mem_total_bits_alpha",
    "optimal_m",
    "optimal_m_integer",
    "mem_at_optimal_m",
    "conventional_bits",
    "feasible",
    "constraint_c_lower_bound",
    "paper_prototype_params",
]


@dataclasses.dataclass(frozen=True)
class RoutingParams:
    """A concrete design point of the two-stage routing scheme."""

    n: int  # total neurons N
    f: int  # fan-out F
    c: int  # cluster size C
    m: int  # second-stage fan-out M
    alpha: float = 1.0  # K / C

    @property
    def k(self) -> int:
        """Tags per cluster."""
        return max(1, int(round(self.alpha * self.c)))

    @property
    def n_clusters(self) -> int:
        """Clusters needed to host N neurons — ceil, so a ragged tail cluster
        (n % c != 0) is counted instead of silently dropping its neurons
        from feasibility/traffic numbers."""
        return max(1, math.ceil(self.n / self.c))

    @property
    def stage1_fanout(self) -> int:
        """Entries in the source (SRAM) table per neuron: F/M point-to-point copies."""
        return max(1, math.ceil(self.f / self.m))

    @property
    def cam_words_per_neuron(self) -> int:
        """Target (CAM) entries per neuron: K*M/C assuming uniform tag use."""
        return max(1, math.ceil(self.k * self.m / self.c))


def mem_source_bits(n: float, f: float, c: float, m: float, k: float) -> float:
    """MEM_S = (F/M) * (log2 K + log2 (N/C)) bits per neuron."""
    return (f / m) * (math.log2(k) + math.log2(n / c))


def mem_target_bits(c: float, m: float, k: float) -> float:
    """MEM_T = (K*M/C) * log2 K bits per neuron."""
    return (k * m / c) * math.log2(k)


def mem_total_bits(n: float, f: float, c: float, m: float, k: float) -> float:
    """Eq. (2): total bits/neuron for a given design point."""
    return mem_source_bits(n, f, c, m, k) + mem_target_bits(c, m, k)


def mem_total_bits_alpha(n: float, f: float, c: float, m: float, alpha: float = 1.0) -> float:
    """Eq. (3): total bits/neuron with K = alpha*C substituted."""
    return (f / m) * math.log2(alpha * n) + alpha * m * math.log2(alpha * c)


def optimal_m(n: float, f: float, c: float, alpha: float = 1.0) -> float:
    """Eq. (5): M* = sqrt(F log2(alpha N) / (alpha log2(alpha C)))."""
    return math.sqrt(f * math.log2(alpha * n) / (alpha * math.log2(alpha * c)))


def optimal_m_integer(n: float, f: float, c: float, alpha: float = 1.0) -> int:
    """Integer argmin of eq.(3) over the feasible M in [1, min(F, C)].

    Eq.(5)'s M* is real-valued; hardware picks an integer second-stage
    fan-out. Eq.(3) is strictly convex in M (a/M + b*M with a, b > 0), so
    the integer optimum is one of floor(M*)/ceil(M*) clamped into range —
    checked explicitly so the property test can compare against brute force.
    """
    hi = max(1, int(min(f, c)))
    m_star = optimal_m(n, f, c, alpha)
    candidates = {1, hi}
    for m in (math.floor(m_star), math.ceil(m_star)):
        if 1 <= m <= hi:
            candidates.add(int(m))
    return min(candidates, key=lambda m: (mem_total_bits_alpha(n, f, c, m, alpha), m))


def mem_at_optimal_m(n: float, f: float, c: float, alpha: float = 1.0) -> float:
    """Eq. (6) generalized: 2*sqrt(alpha F log2(alpha C) log2(alpha N))."""
    return 2.0 * math.sqrt(alpha * f * math.log2(alpha * c) * math.log2(alpha * n))


def conventional_bits(n: float, f: float) -> float:
    """Flat source/destination-addressed routing: F*log2(N) bits/neuron."""
    return f * math.log2(n)


def feasible(n: float, f: float, c: float, alpha: float = 1.0) -> bool:
    """Appendix-A feasibility of the optimal design point: M* <= F and M* <= C."""
    m_star = optimal_m(n, f, c, alpha)
    return m_star <= f and m_star <= c


def constraint_c_lower_bound(n: float, f: float) -> float:
    """Appendix A (alpha=1): smallest C with C*sqrt(log2 C) >= sqrt(F log2 N).

    Solved numerically by bisection (the LHS is monotone for C >= 2).
    """
    target = math.sqrt(f * math.log2(n))

    def lhs(c: float) -> float:
        return c * math.sqrt(math.log2(c))

    lo, hi = 2.0, 2.0
    while lhs(hi) < target:
        hi *= 2.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        if lhs(mid) < target:
            lo = mid
        else:
            hi = mid
    return hi


def paper_prototype_params() -> RoutingParams:
    """The fabricated prototype's design point (§III-B / §IV).

    256 neurons/core, 4 cores/chip, fan-out 4k, 64 CAM words per neuron
    (K*M/C = 64 as used for Fig. 13), K = C = 256 (alpha = 1), M = 64.
    """
    return RoutingParams(n=1024, f=4096, c=256, m=64, alpha=1.0)
