"""Version-portable ``shard_map`` plumbing shared by core and models.

JAX has moved ``shard_map`` (experimental -> top-level) and renamed its
replication-check kwarg (``check_rep`` -> ``check_vma``) across releases.
Every ``shard_map`` call site in this repo resolves the function and the
kwarg through this module so the dance lives in exactly one place
(previously it was duplicated in models/moe.py and core/event_engine.py,
with core importing from models — a layering inversion).
"""

from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.6
    from jax import shard_map
except ImportError:  # older jax
    from jax.experimental.shard_map import shard_map

try:
    _params = inspect.signature(shard_map).parameters
    if "check_vma" in _params:
        SM_CHECK_KW = {"check_vma": False}
    elif "check_rep" in _params:
        SM_CHECK_KW = {"check_rep": False}
    else:  # pragma: no cover
        SM_CHECK_KW = {}
except Exception:  # pragma: no cover
    SM_CHECK_KW = {}


def axis_size(axis) -> int:
    """Static size of a named mesh axis (or tuple of axes) inside shard_map.

    ``jax.lax.axis_size`` only exists in newer jax; ``psum(1, axis)``
    constant-folds to the same Python int everywhere.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


__all__ = ["shard_map", "SM_CHECK_KW", "axis_size"]
