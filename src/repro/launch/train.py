"""Fault-tolerant training driver (checkpoint/restart supervisor).

Runs the end-to-end loop at any scale the local device set allows:

  - deterministic data source keyed by step (restart-safe),
  - jitted train_step with sharding constraints from the resolved specs,
  - async checkpointing every ``ckpt_every`` steps,
  - a SUPERVISOR loop: any exception inside the step loop (device loss,
    preemption signal file, numerical panic) triggers restore-from-latest
    and resume; ``--max-failures`` bounds restart storms,
  - preemption hook: touching ``<ckpt_dir>/PREEMPT`` makes the loop
    checkpoint + exit(42) at the next step boundary (the scheduler restarts
    the job elsewhere — standard TPU-pod preemption choreography).

Example (CPU, smoke config):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_source
from repro.models.model import build_model
from repro.train.loop import init_train_state, make_train_step
from repro.train.optimizer import OptConfig


def run(args) -> int:
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=min(20, args.steps // 10 + 1))
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    data = make_source(
        DataConfig(vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq, seed=args.seed)
    )

    step_fn = jax.jit(make_train_step(model, opt_cfg, microbatches=args.microbatches))

    failures = 0
    while True:
        try:
            # ---- (re)initialize or restore -------------------------------
            start = ckpt.latest_step()
            state = init_train_state(model, jax.random.PRNGKey(args.seed), opt_cfg)
            if start is not None:
                state = ckpt.restore(start, state)
                print(f"[supervisor] resumed from step {start}")
            step0 = (start or 0)

            t_last = time.time()
            for step in range(step0, args.steps):
                if os.path.exists(os.path.join(args.ckpt_dir, "PREEMPT")):
                    print("[supervisor] preemption requested; checkpointing")
                    ckpt.save(step, state, blocking=True)
                    return 42
                batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
                if args.fail_at is not None and step == args.fail_at and failures == 0:
                    raise RuntimeError("injected failure (test)")
                state, metrics = step_fn(state, batch)
                if jnp.isnan(metrics["loss"]):
                    raise FloatingPointError(f"loss NaN at step {step}")
                if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
                    ckpt.save(step + 1, state)
                if (step + 1) % args.log_every == 0:
                    dt = time.time() - t_last
                    t_last = time.time()
                    print(
                        f"step {step + 1}: loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.3f} "
                        f"lr={float(metrics['lr']):.2e} ({dt / args.log_every:.2f}s/step)"
                    )
            ckpt.wait()
            print("[supervisor] training complete")
            return 0
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:  # noqa: BLE001 — the supervisor's whole job
            failures += 1
            print(f"[supervisor] failure #{failures}: {type(e).__name__}: {e}")
            if failures > args.max_failures:
                print("[supervisor] failure budget exhausted")
                raise
            time.sleep(args.restart_delay)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-failures", type=int, default=3)
    ap.add_argument("--restart-delay", type=float, default=0.5)
    ap.add_argument("--fail-at", type=int, default=None, help="inject a failure (testing)")
    raise SystemExit(run(ap.parse_args()))


if __name__ == "__main__":
    main()
