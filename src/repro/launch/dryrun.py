import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the FULL architecture config and the production mesh,
  2. resolves parameter/optimizer/cache/input shardings (logical axes ->
     PartitionSpec via distributed/sharding.py),
  3. ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` — no allocation,
  4. records memory_analysis(), cost_analysis(), and per-device collective
     bytes parsed from the compiled HLO,
into ``experiments/dryrun/<arch>__<shape>__<mesh>.json`` — the §Roofline
inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import LONG_OK, SHAPES, Shape, get_config
from repro.launch.costs import hlo_collective_bytes, jaxpr_cost
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import layers as L
from repro.models.model import build_model
from repro.train.loop import make_train_step
from repro.train.optimizer import OptConfig, init_opt_state

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"),
)

import contextlib


@contextlib.contextmanager
def _null_ctx():
    yield


def pad_heads(cfg, multiple: int):
    """Pad attention q-heads up to a multiple of the TP degree (zero-weight
    heads — exact numerics, vLLM-style). Enables clean head sharding for
    head counts like yi-34b's 56 on a 16-way axis (§Perf iteration E)."""
    import math as _math

    h = _math.ceil(cfg.n_heads / multiple) * multiple
    if h == cfg.n_heads or cfg.n_heads < multiple:
        return cfg
    if cfg.n_kv_heads and h % cfg.n_kv_heads != 0:
        return cfg  # would break GQA grouping
    return dataclasses.replace(cfg, n_heads=h)


# v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9
LINK_BW = 50e9


# ---------------------------------------------------------------------------
# sharding resolution for the full state
# ---------------------------------------------------------------------------
def model_param_pspecs(model, params_shapes, mesh):
    spec_tree = model.param_specs()
    out = {}
    for k, sub in spec_tree.items():
        if isinstance(sub, dict) and "periods" in sub:  # stack-like (decoder/encoder)
            sub_out = {}
            for name, blk in sub.items():
                pn = 1 if name == "periods" else 0
                sub_out[name] = shd.tree_pspecs(blk, params_shapes[k][name], mesh, prefix_none=pn)
            out[k] = sub_out
        else:
            out[k] = shd.tree_pspecs(sub, params_shapes[k], mesh)
    return out


def opt_pspecs(param_pspec_tree, params_shapes, mesh, opt_cfg: OptConfig, zero1: bool = True):
    """Moments follow params; ZeRO-1 adds spare axes on the first divisible
    unsharded dim. q8 moments shard the block dim."""
    spare = [a for a in ("pod",) if a in mesh.shape]

    def moment_spec(pspec, shape):
        if opt_cfg.state_dtype == "q8":
            # q/scale add trailing (blocks, block) dims; leading dims (and
            # their shardings) match the parameter exactly
            lead = list(pspec)[: max(0, len(shape) - 1)]
            lead += [None] * (max(0, len(shape) - 1) - len(lead))
            return {"q": P(*lead, None, None), "scale": P(*lead, None, None)}
        if not zero1 or not spare:
            return pspec
        used = set()
        for e in pspec:
            if e is None:
                continue
            used.update(e if isinstance(e, tuple) else (e,))
        size = int(np.prod([mesh.shape[a] for a in spare]))
        new = list(pspec) + [None] * (len(shape) - len(pspec))
        for i, d in enumerate(shape):
            if new[i] is None and d % size == 0:
                new[i] = tuple(spare) if len(spare) > 1 else spare[0]
                break
        return P(*new)

    def walk(pspec_node, shape_node):
        return jax.tree.map(
            lambda ps, sh: moment_spec(ps, sh.shape),
            pspec_node,
            shape_node,
            is_leaf=lambda x: isinstance(x, P),
        )

    return walk(param_pspec_tree, params_shapes)


def cache_pspecs(cache_shapes, mesh, batch: int):
    """Resolve cache tree shardings by leaf name + shape."""

    def resolve(path, leaf):
        name = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
        shape = leaf.shape
        in_periods = any(getattr(p, "key", None) == "periods" for p in path)
        off = 1 if in_periods else 0  # leading stacked-period dim
        spec = [None] * len(shape)
        used: set[str] = set()

        def assign(i, axes_pref):
            for axes in axes_pref:
                axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
                if not all(a in mesh.shape for a in axes_t) or (set(axes_t) & used):
                    continue
                size = int(np.prod([mesh.shape[a] for a in axes_t]))
                if size > 1 and shape[i] % size == 0:
                    spec[i] = axes_t if len(axes_t) > 1 else axes_t[0]
                    used.update(axes_t)
                    return

        if name in ("k", "v"):  # [.., B, L, KV, HD]
            assign(off + 2, ["model"])
            assign(off + 0, [("pod", "data"), "data", "pod"])
            assign(off + 1, ["data"])
        elif name in ("c_kv", "k_rope"):  # [.., B, L, R]
            assign(off + 0, [("pod", "data"), "data", "pod"])
            assign(off + 1, ["data"])
        elif name == "pos":  # [.., B, L]
            assign(off + 0, [("pod", "data"), "data", "pod"])
            assign(off + 1, ["data"])
        elif name == "conv":  # [.., B, K-1, C]
            assign(off + 2, ["model"])
            assign(off + 0, [("pod", "data"), "data", "pod"])
        elif name == "ssm":  # [.., B, H, P, N]
            assign(off + 1, ["model"])
            assign(off + 0, [("pod", "data"), "data", "pod"])
        elif name == "wkv":  # [.., B, H, P, P]
            assign(off + 1, ["model"])
            assign(off + 0, [("pod", "data"), "data", "pod"])
        elif name == "x_prev":  # [.., B, D]
            assign(off + 0, [("pod", "data"), "data", "pod"])
        elif name == "enc_out":  # [B, S, D]
            assign(0, [("pod", "data"), "data", "pod"])
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    specs = [resolve(path, leaf) for path, leaf in flat]
    return jax.tree.unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg, shape: Shape, mesh):
    """Training/prefill/decode inputs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    tok_spec = shd.token_pspec(b, s, mesh)
    batch_axes = tok_spec[0]
    out = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        out["pos"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    if cfg.frontend == "vision_stub" and shape.kind in ("train", "prefill"):
        out["prefix_embeddings"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_embeddings, cfg.d_model), jnp.bfloat16
        )
    if cfg.frontend == "audio_stub" and shape.kind in ("train", "prefill"):
        out["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    shardings = {}
    for k, v in out.items():
        if k in ("tokens", "labels"):
            shardings[k] = NamedSharding(mesh, tok_spec if shape.kind == "train" else P(batch_axes, None))
        elif k == "pos":
            shardings[k] = NamedSharding(mesh, P(batch_axes, None))
        else:
            shardings[k] = NamedSharding(mesh, P(batch_axes, None, None))
    return out, shardings


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape: Shape, multi_pod: bool, opt_cfg: OptConfig | None = None,
             save: bool = True, mesh=None, cfg=None) -> dict:
    t0 = time.time()
    cfg = cfg if cfg is not None else get_config(arch)
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    moe_impl = "sharded" if cfg.n_experts else "local"
    loss_chunk = int(os.environ.get("REPRO_LOSS_CHUNK", "0"))
    if int(os.environ.get("REPRO_PAD_HEADS", "0")):
        cfg = pad_heads(cfg, int(os.environ["REPRO_PAD_HEADS"]))
    model = build_model(cfg, moe_impl=moe_impl, mesh=mesh, loss_chunk=loss_chunk)
    opt_cfg = opt_cfg or OptConfig(state_dtype="q8" if cfg.param_count()[0] > 1e11 else "float32")

    opt_level = int(os.environ.get("REPRO_OPT_LEVEL", "1"))  # 0 = baseline
    act_ctx = shd.activation_mesh(mesh) if opt_level >= 1 else _null_ctx()
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_pspecs = model_param_pspecs(model, params_shapes, mesh)
    p_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), p_pspecs,
                               is_leaf=lambda x: isinstance(x, P))

    inputs, in_shardings = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        o_shapes = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_shapes)
        o_pspecs = opt_pspecs(p_pspecs, params_shapes, mesh, opt_cfg)
        o_shardings = {
            "m": jax.tree.map(lambda s: NamedSharding(mesh, s), o_pspecs, is_leaf=lambda x: isinstance(x, P)),
            "v": jax.tree.map(lambda s: NamedSharding(mesh, s), o_pspecs, is_leaf=lambda x: isinstance(x, P)),
            "step": NamedSharding(mesh, P()),
        }
        state_shapes = {"params": params_shapes, "opt": o_shapes}
        state_shardings = {"params": p_shardings, "opt": o_shardings}
        step_fn = make_train_step(model, opt_cfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(state_shardings, in_shardings),
            donate_argnums=0,
        )
        with mesh, act_ctx:
            lowered = jitted.lower(state_shapes, {k: v for k, v in inputs.items()})
            traced_jaxpr = jax.make_jaxpr(step_fn)(state_shapes, inputs)
    else:
        cache_shapes = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len + 8)
        )
        c_pspecs = cache_pspecs(cache_shapes, mesh, shape.global_batch)
        c_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), c_pspecs,
                                   is_leaf=lambda x: isinstance(x, P))
        if shape.kind == "prefill":
            extras = {k: v for k, v in inputs.items() if k not in ("tokens",)}
            extras_sh = {k: in_shardings[k] for k in extras} or None

            def prefill_fn(params, tokens, caches, batch):
                return model.prefill(params, tokens, caches, batch)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(p_shardings, in_shardings["tokens"], c_shardings, extras_sh),
                donate_argnums=2,
            )
            with mesh, act_ctx:
                lowered = jitted.lower(
                    params_shapes, inputs["tokens"], cache_shapes,
                    {k: extras[k] for k in extras} if extras else None,
                )
                traced_jaxpr = jax.make_jaxpr(prefill_fn)(
                    params_shapes, inputs["tokens"], cache_shapes,
                    {k: extras[k] for k in extras} if extras else None,
                )
        else:  # decode
            def decode_fn(params, tokens, pos, caches):
                return model.decode_step(params, tokens, pos, caches)

            jitted = jax.jit(
                decode_fn,
                in_shardings=(p_shardings, in_shardings["tokens"], in_shardings["pos"], c_shardings),
                donate_argnums=3,
            )
            with mesh, act_ctx:
                lowered = jitted.lower(params_shapes, inputs["tokens"], inputs["pos"], cache_shapes)
                traced_jaxpr = jax.make_jaxpr(decode_fn)(
                    params_shapes, inputs["tokens"], inputs["pos"], cache_shapes
                )

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    coll_hlo = hlo_collective_bytes(hlo)
    # analytic (scan-aware) cost from the traced jaxpr
    analytic = jaxpr_cost(traced_jaxpr)
    coll = dict(coll_hlo)
    coll["analytic_total"] = analytic["collective"]["total"]
    coll["total"] = max(coll_hlo.get("total", 0.0), analytic["collective"]["total"])

    n_chips = mesh.devices.size
    flops = analytic["flops"] / n_chips  # global -> per-chip
    bytes_acc = analytic["bytes"] / n_chips
    hlo_flops_raw = float(cost.get("flops", 0.0)) if cost else 0.0
    total_p, active_p = cfg.param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        model_flops = 6 * active_p * tokens
    elif shape.kind == "prefill":
        model_flops = 2 * active_p * tokens
    else:
        model_flops = 2 * active_p * tokens

    result = {
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "n_chips": int(n_chips),
        "seconds_to_compile": round(time.time() - t0, 1),
        "memory": {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        },
        "cost": {
            "flops_per_device": flops,
            "bytes_per_device": bytes_acc,
            "xla_cost_analysis_flops_raw": hlo_flops_raw,  # body-once; see costs.py
        },
        "collective_bytes_per_device": coll,
        "params": {"total": total_p, "active": active_p},
        "model_flops_global": model_flops,
        "roofline": {},
    }
    # roofline terms (seconds), per §Roofline
    comp_t = flops / PEAK_FLOPS
    mem_t = bytes_acc / HBM_BW
    coll_t = coll.get("total", 0) / LINK_BW
    dom = max(("compute", comp_t), ("memory", mem_t), ("collective", coll_t), key=lambda kv: kv[1])
    result["roofline"] = {
        "compute_s": comp_t,
        "memory_s": mem_t,
        "collective_s": coll_t,
        "dominant": dom[0],
        "model_flops_ratio": (model_flops / (flops * n_chips)) if flops else None,
        "mfu_upper_bound": (model_flops / (PEAK_FLOPS * n_chips)) / max(comp_t, mem_t, coll_t)
        if max(comp_t, mem_t, coll_t) > 0
        else None,
    }
    if save:
        os.makedirs(ART_DIR, exist_ok=True)
        fn = os.path.join(ART_DIR, f"{arch}__{shape.name}__{mesh_name}.json")
        with open(fn, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    from repro.configs import ARCHS, cells

    todo = []
    for arch, shape, runnable, skip in cells():
        if not args.all:
            if args.arch and arch != args.arch:
                continue
            if args.shape and shape.name != args.shape:
                continue
        if not runnable:
            print(f"SKIP {arch} x {shape.name}: {skip}")
            continue
        for mp in ([False, True] if args.mesh == "both" else [args.mesh == "multi"]):
            todo.append((arch, shape, mp))

    failures = 0
    for arch, shape, mp in todo:
        tag = f"{arch} x {shape.name} x {'multi' if mp else 'single'}"
        try:
            r = run_cell(arch, shape, mp)
            rf = r["roofline"]
            print(
                f"OK   {tag}: compile={r['seconds_to_compile']}s "
                f"compute={rf['compute_s']:.3e}s memory={rf['memory_s']:.3e}s "
                f"collective={rf['collective_s']:.3e}s dominant={rf['dominant']}"
            )
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            failures += 1
            print(f"FAIL {tag}: {e}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
