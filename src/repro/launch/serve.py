"""Serving driver: load (or init) a model and serve batched generations.

Example (CPU, smoke config):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --smoke \
      --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        step = ckpt.latest_step()
        if step is not None:
            state_like = {"params": params}
            params = ckpt.restore(step, state_like)["params"]
            print(f"loaded checkpoint step {step}")

    engine = Engine(model, params, ServeConfig(max_len=args.max_len, temperature=args.temperature))
    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab, dtype=jnp.int32)
    extras = None
    if cfg.frontend == "audio_stub":
        extras = {"frames": jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model), jnp.float32)}
    t0 = time.time()
    out = engine.generate(prompts, args.max_new, extras)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.max_new / dt:.1f} tok/s)")
    print(out[:2])


if __name__ == "__main__":
    main()
