"""Roofline cost extraction: analytic jaxpr walker + trip-count-corrected HLO.

Why two mechanisms (both reported in EXPERIMENTS.md §Roofline):

* ``compiled.cost_analysis()`` counts a ``while`` body ONCE — a 58-period
  scan under-reports FLOPs ~58x (verified in-repo; see EXPERIMENTS.md
  §Dry-run "measurement notes"). So compute/memory terms come from
  ``jaxpr_cost``: an exact walker over the lowered jaxpr that multiplies
  scan bodies by their trip count, recurses into pjit/remat/shard_map, and
  counts dot_general/conv FLOPs from shapes. Remat recompute is visible in
  the grad jaxpr, so the "wasted recompute" ratio MODEL_FLOPS/HLO_FLOPS is
  preserved.
* Memory bytes: a fusion-aware *estimate* — operand+result bytes of major
  ops only (dot/conv/gather/scatter/collectives + jaxpr inputs), assuming
  elementwise ops fuse. This is the roofline-relevant minimum HBM traffic.
* Collective bytes: parsed from the post-SPMD HLO (the only place GSPMD's
  auto-inserted all-gathers/reduce-scatters exist), with while-loop trip
  counts recovered from loop-condition constants and multiplied through.

Conventions: jaxpr shapes are GLOBAL; shard_map bodies are PER-DEVICE (their
costs are multiplied by the mapped mesh size to stay global). Final report
divides by n_chips -> per-chip seconds.
"""

from __future__ import annotations

import re
from collections import defaultdict

import jax
import numpy as np

# ---------------------------------------------------------------------------
# analytic jaxpr walker
# ---------------------------------------------------------------------------
_COLL_PRIMS = {
    "psum": "all-reduce",
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "psum_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
}

_MAJOR_BYTES_PRIMS = {
    "dot_general",
    "conv_general_dilated",
    "gather",
    "scatter",
    "scatter-add",
    "dynamic_slice",
    "dynamic_update_slice",
    "sort",
}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # scalars / abstract tokens
        return 0


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    m = int(np.prod([a.shape[i] for i in range(len(a.shape)) if i not in set(lc) | set(lb)]))
    k = int(np.prod([a.shape[i] for i in lc]))
    batch = int(np.prod([a.shape[i] for i in lb])) if lb else 1
    n = int(np.prod([b.shape[i] for i in range(len(b.shape)) if i not in set(rc) | set(rb)]))
    return 2.0 * batch * m * n * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # flops = 2 * out_elems * (kernel spatial * in_channels)
    kernel = int(np.prod(rhs.shape[:-1]))  # rough: all but out-feature dim
    return 2.0 * int(np.prod(out.shape)) * kernel


def jaxpr_cost(jaxpr, mult: float = 1.0, axis_sizes: dict | None = None) -> dict:
    """Walk a (closed) jaxpr; returns global flops, major-op bytes, and
    per-device collective bytes by type."""
    axis_sizes = axis_sizes or {}
    acc = {"flops": 0.0, "bytes": 0.0, "collective": defaultdict(float)}
    _walk(getattr(jaxpr, "jaxpr", jaxpr), mult, axis_sizes, acc)
    acc["collective"] = dict(acc["collective"])
    acc["collective"]["total"] = sum(acc["collective"].values())
    return acc


def _sub_jaxprs(eqn):
    """(sub_jaxpr, extra_multiplier, extra_axis_sizes) triples for one eqn."""
    p = eqn.params
    name = eqn.primitive.name
    out = []
    if name == "scan":
        out.append((p["jaxpr"], float(p["length"]), {}))
    elif name == "while":
        # we only emit bounded loops via scan; treat raw while as 1 trip
        out.append((p["body_jaxpr"], 1.0, {}))
        out.append((p["cond_jaxpr"], 1.0, {}))
    elif name == "cond":
        for br in p["branches"]:
            out.append((br, 1.0, {}))  # upper bound: count all branches? no —
        out = out[:1] if out else []  # count first branch only (symmetric in our code)
    elif "jaxpr" in p:
        out.append((p["jaxpr"], 1.0, {}))
    elif "call_jaxpr" in p:
        out.append((p["call_jaxpr"], 1.0, {}))
    elif name == "shard_map":
        sizes = dict(p["mesh"].shape)
        out.append((p["jaxpr"], float(np.prod(list(sizes.values()))), sizes))
    elif name == "custom_vjp_call" or name == "custom_jvp_call":
        key = "fun_jaxpr" if "fun_jaxpr" in p else "call_jaxpr"
        if key in p:
            out.append((p[key], 1.0, {}))
    return out


def _axis_size(axis, sizes) -> int:
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axis, 1)


def _walk(jaxpr, mult, axis_sizes, acc):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            f = _dot_flops(eqn)
            acc["flops"] += mult * f
            acc["bytes"] += mult * (
                sum(_nbytes(v.aval) for v in eqn.invars) + sum(_nbytes(v.aval) for v in eqn.outvars)
            )
        elif name == "conv_general_dilated":
            acc["flops"] += mult * _conv_flops(eqn)
            acc["bytes"] += mult * (
                sum(_nbytes(v.aval) for v in eqn.invars) + sum(_nbytes(v.aval) for v in eqn.outvars)
            )
        elif name in _COLL_PRIMS:
            # per-device payload bytes; inside shard_map shapes are local.
            # mult includes mesh-size factors from enclosing shard_map — undo
            # them for the per-device metric, keep loop factors.
            n_dev = float(np.prod(list(axis_sizes.values()))) if axis_sizes else 1.0
            payload = sum(_nbytes(v.aval) for v in eqn.invars)
            kind = _COLL_PRIMS[name]
            if name == "psum":  # ring: 2x payload on the wire
                wire = 2.0 * payload
            elif name in ("all_gather",):
                wire = payload * max(_axis_size(eqn.params.get("axis_name"), axis_sizes) - 1, 1)
            else:
                wire = payload
            acc["collective"][kind] += (mult / max(n_dev, 1.0)) * wire
        elif name in _MAJOR_BYTES_PRIMS:
            acc["bytes"] += mult * (
                sum(_nbytes(v.aval) for v in eqn.invars) + sum(_nbytes(v.aval) for v in eqn.outvars)
            )
        for sub, extra, sizes in _sub_jaxprs(eqn):
            merged = dict(axis_sizes)
            merged.update(sizes)
            _walk(getattr(sub, "jaxpr", sub), mult * extra, merged, acc)
    # count reads of the jaxpr's own inputs once (params/caches streamed in)
    if mult == 1.0 and not axis_sizes:
        acc["bytes"] += sum(_nbytes(v.aval) for v in jaxpr.invars)


# ---------------------------------------------------------------------------
# HLO collective parsing with while trip-count correction
# ---------------------------------------------------------------------------
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s32|u32|s8|u8|s16|u16|pred|s64|u64)\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
          "pred": 1, "s16": 2, "u16": 2, "s64": 8, "u64": 8}
_COMP_RE = re.compile(r"^(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=([%\w.\-]+),\s*body=([%\w.\-]+)")
_COLL_LINE_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[\w\[\],{}\/#: ]*?))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _BYTES[dtype]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """HLO computations start at column 0 ending with '{' and close with a
    column-0 '}'. (Headers contain nested parens, so split by indentation.)"""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if cur is None:
            if line and not line[0].isspace() and line.rstrip().endswith("{"):
                if line.startswith("ENTRY"):
                    cur = "ENTRY"
                else:
                    cur = line.split()[0].lstrip("%")
                comps[cur] = []
        else:
            if line.strip() == "}" and not line[:1].isspace():
                cur = None
            elif line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line.strip())
    return comps


def hlo_collective_bytes(hlo: str) -> dict:
    """Per-device collective payload bytes, scaled by while trip counts."""
    comps = _split_computations(hlo)
    if "ENTRY" not in comps:
        # fall back: find the last computation as entry
        entry = list(comps)[-1] if comps else None
    else:
        entry = "ENTRY"

    # direct collective bytes + while children per computation
    direct: dict[str, dict[str, float]] = {}
    children: dict[str, list[tuple[str, str]]] = {}
    for name, lines in comps.items():
        d: dict[str, float] = defaultdict(float)
        ch = []
        for ln in lines:
            if "-done(" in ln:
                continue
            m = _COLL_LINE_RE.search(ln)
            if m:
                d[m.group(2)] += _shape_bytes(m.group(1))
            w = _WHILE_RE.search(ln)
            if w:
                ch.append((w.group(1).lstrip("%"), w.group(2).lstrip("%")))
        direct[name] = dict(d)
        children[name] = ch

    def trip_count(cond_name: str) -> float:
        consts = []
        for ln in comps.get(cond_name, []):
            consts += [int(c) for c in _CONST_RE.findall(ln)]
        return float(max(consts)) if consts else 1.0

    total: dict[str, float] = defaultdict(float)
    seen: set[tuple[str, float]] = set()

    def visit(name: str, mult: float):
        if name not in comps:
            return
        for k, v in direct.get(name, {}).items():
            total[k] += mult * v
        for cond, body in children.get(name, []):
            visit(body, mult * trip_count(cond))

    if entry:
        visit(entry, 1.0)
    out = dict(total)
    out["total"] = sum(total.values())
    return out
