"""Deterministic, resumable, sharded data pipeline.

Token sources behind one interface:

* ``SyntheticSource``: counter-based PRNG token stream (threefry on
  (seed, step, shard)) — fully deterministic, O(1) state, used by smoke
  tests, examples and the dry-run's input_specs sanity path.
* ``FileSource``: memory-mapped flat token file (uint16/uint32), strided by
  (host, step) — restart-safe because the cursor is derived from the step
  counter, never from consumed state.

Event sources for the AER serving path (DESIGN.md §12):

* ``DvsStreamSource``: per-session synthetic poker-DVS symbol stream —
  ``events(step)`` is a pure function of (seed, session_id, step), so a
  serving slot evicted and re-admitted (or a restarted server) replays the
  identical event sequence from any step counter.

Determinism + statelessness is the fault-tolerance story: a restarted (or
re-elasticized) job continues from ``step`` with byte-identical batches; no
shuffle buffers to rebuild.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    path: str | None = None  # file-backed when set
    token_dtype: str = "uint16"


class SyntheticSource:
    """Stateless synthetic LM data: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        assert cfg.global_batch % n_hosts == 0
        self.local_batch = cfg.global_batch // n_hosts

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), self.host_id
        )
        toks = jax.random.randint(
            key, (self.local_batch, cfg.seq_len + 1), 0, cfg.vocab, dtype=np.int32
        )
        toks = np.asarray(toks)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }


class FileSource:
    """Flat-token-file source; cursor = f(step), never mutable state."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self.tokens = np.memmap(cfg.path, dtype=np.dtype(cfg.token_dtype), mode="r")
        self.n_tokens = len(self.tokens)
        self.samples = self.n_tokens // (cfg.seq_len + 1)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        sl = cfg.seq_len + 1
        base = step * cfg.global_batch + self.host_id * self.local_batch
        idx = (base + np.arange(self.local_batch)) % self.samples
        rows = np.stack([self.tokens[i * sl : (i + 1) * sl] for i in idx]).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_source(cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
    if cfg.path:
        return FileSource(cfg, host_id, n_hosts)
    return SyntheticSource(cfg, host_id, n_hosts)


# ---------------------------------------------------------------------------
# DVS event streams (paper §V poker symbols, serving input path)
# ---------------------------------------------------------------------------
def symbol_dvs_events(
    symbol: int, n_events: int, rng, input_hw: int = 32, jitter: float = 1.0
) -> np.ndarray:
    """Synthetic DVS event cloud for one poker-suit flash: ``[n_events, 2]``
    (y, x) rows on a ``input_hw x input_hw`` sensor.

    Suit geometry matches the paper's §V edge features: 0 = vertical bar
    (diamond edge), 1 = horizontal bar (club), 2 = upward vertex (spade),
    3 = downward vertex (heart). Shared by the batch example and the
    serving stream source so both present identical stimuli.
    """
    if not 0 <= symbol < 4:
        raise ValueError(f"symbol must be in [0, 4), got {symbol}")
    s = input_hw / 32.0  # geometry scales with sensor resolution
    if symbol == 0:
        ys = rng.integers(int(6 * s), int(26 * s), n_events)
        xs = 15 * s + rng.normal(0, jitter, n_events)
    elif symbol == 1:
        xs = rng.integers(int(6 * s), int(26 * s), n_events)
        ys = 15 * s + rng.normal(0, jitter, n_events)
    elif symbol == 2:
        t = rng.uniform(-1, 1, n_events)
        xs = 16 * s + t * 10 * s + rng.normal(0, jitter, n_events)
        ys = 8 * s + np.abs(t) * 14 * s
    else:
        t = rng.uniform(-1, 1, n_events)
        xs = 16 * s + t * 10 * s + rng.normal(0, jitter, n_events)
        ys = 24 * s - np.abs(t) * 14 * s
    hi = input_hw - 1
    return np.stack(
        [np.clip(ys, 0, hi).astype(np.int64), np.clip(xs, 0, hi).astype(np.int64)], 1
    )


@dataclasses.dataclass(frozen=True)
class DvsStreamConfig:
    """One tenant's synthetic DVS stream (a user holding a card to a sensor)."""

    symbol: int  # poker suit in [0, 4)
    events_per_step: int = 16  # sensor events per engine timestep
    input_hw: int = 32
    jitter: float = 1.0
    seed: int = 0


class DvsStreamSource:
    """Stateless per-session DVS stream: ``events(step)`` is a pure function.

    Like :class:`SyntheticSource`, the cursor is the step counter — never
    consumed state — so a serving slot can be evicted, its session resumed
    elsewhere, and the replayed stream is byte-identical. Distinct
    ``session_id``s give statistically independent streams of the same
    symbol (the PRNG is seeded on (seed, session_id, step)).
    """

    def __init__(self, cfg: DvsStreamConfig, session_id: int = 0):
        self.cfg = cfg
        self.session_id = int(session_id)

    def events(self, step: int) -> np.ndarray:
        """DVS events ``[events_per_step, 2]`` emitted during ``step``."""
        cfg = self.cfg
        rng = np.random.default_rng([cfg.seed, self.session_id, int(step)])
        return symbol_dvs_events(
            cfg.symbol, cfg.events_per_step, rng, cfg.input_hw, cfg.jitter
        )
