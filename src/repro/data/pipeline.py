"""Deterministic, resumable, sharded token pipeline.

Two sources behind one interface:

* ``SyntheticSource``: counter-based PRNG token stream (threefry on
  (seed, step, shard)) — fully deterministic, O(1) state, used by smoke
  tests, examples and the dry-run's input_specs sanity path.
* ``FileSource``: memory-mapped flat token file (uint16/uint32), strided by
  (host, step) — restart-safe because the cursor is derived from the step
  counter, never from consumed state.

Determinism + statelessness is the fault-tolerance story: a restarted (or
re-elasticized) job continues from ``step`` with byte-identical batches; no
shuffle buffers to rebuild.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    path: str | None = None  # file-backed when set
    token_dtype: str = "uint16"


class SyntheticSource:
    """Stateless synthetic LM data: batch(step) is a pure function."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        assert cfg.global_batch % n_hosts == 0
        self.local_batch = cfg.global_batch // n_hosts

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), self.host_id
        )
        toks = jax.random.randint(
            key, (self.local_batch, cfg.seq_len + 1), 0, cfg.vocab, dtype=np.int32
        )
        toks = np.asarray(toks)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }


class FileSource:
    """Flat-token-file source; cursor = f(step), never mutable state."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts
        self.tokens = np.memmap(cfg.path, dtype=np.dtype(cfg.token_dtype), mode="r")
        self.n_tokens = len(self.tokens)
        self.samples = self.n_tokens // (cfg.seq_len + 1)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        sl = cfg.seq_len + 1
        base = step * cfg.global_batch + self.host_id * self.local_batch
        idx = (base + np.arange(self.local_batch)) % self.samples
        rows = np.stack([self.tokens[i * sl : (i + 1) * sl] for i in idx]).astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_source(cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
    if cfg.path:
        return FileSource(cfg, host_id, n_hosts)
    return SyntheticSource(cfg, host_id, n_hosts)
