"""RWKV6 "Finch" — data-dependent decay linear attention (arXiv:2404.05892).

Per head (vectors r, k in R^P, v in R^P, decay w_t in (0,1)^P, bonus u):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T                 S: [P, P]
    y_t = (r_t)^T (S_{t-1} + diag(u) k_t v_t^T)

Token-shift mixing is data-dependent through a low-rank "ddlerp" (the Finch
novelty): mix_x = x + (x_prev - x) * (mu + lora(x + (x_prev - x) * mu0)).

Train/prefill runs a chunked form whose decay factors are all <= 1 (products
of w along the chunk), so no max-subtraction is needed; ``rwkv6_sequential``
is the oracle. A Pallas kernel (kernels/rwkv6) implements the chunk step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_rmsnorm, rmsnorm


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_rwkv6(key, cfg, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    ks = jax.random.split(key, 12)
    s = d**-0.5
    return {
        # token-shift data-dependent mixing (5 channels: r, k, v, w, g)
        "mu": jax.random.normal(ks[0], (5, d), jnp.float32) * 0.1,
        "mu0": jax.random.normal(ks[1], (d,), jnp.float32) * 0.1,
        "mix_a": jax.random.normal(ks[2], (d, 5 * cfg.rwkv_lora_mix), dtype) * s,
        "mix_b": jax.random.normal(ks[3], (5, cfg.rwkv_lora_mix, d), dtype)
        * cfg.rwkv_lora_mix**-0.5,
        # projections
        "wr": jax.random.normal(ks[4], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[5], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[6], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[7], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[8], (d, d), dtype) * s,
        # data-dependent decay lora
        "w_base": jnp.full((d,), -6.0, jnp.float32),
        "w_a": jax.random.normal(ks[9], (d, cfg.rwkv_lora_w), dtype) * s,
        "w_b": jax.random.normal(ks[10], (cfg.rwkv_lora_w, d), dtype)
        * cfg.rwkv_lora_w**-0.5,
        "u_bonus": jax.random.normal(ks[11], (h, p), jnp.float32) * 0.1,
        "ln_out": init_rmsnorm(d),
    }


def rwkv6_spec(cfg) -> dict:
    return {
        "mu": (None, "embed"),
        "mu0": ("embed",),
        "mix_a": ("embed", None),
        "mix_b": (None, None, "embed"),
        "wr": ("embed", "heads_flat"),
        "wk": ("embed", "heads_flat"),
        "wv": ("embed", "heads_flat"),
        "wg": ("embed", "heads_flat"),
        "wo": ("heads_flat", "embed"),
        "w_base": ("heads_flat",),
        "w_a": ("embed", None),
        "w_b": (None, "heads_flat"),
        "u_bonus": ("heads", None),
        "ln_out": {"scale": ("embed",)},
    }


def init_rwkv6_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    return {
        "x_prev": jnp.zeros((batch, d), dtype),  # token-shift memory
        "wkv": jnp.zeros((batch, h, p, p), dtype),  # per-head state matrix
    }


# ---------------------------------------------------------------------------
# projections with data-dependent token shift
# ---------------------------------------------------------------------------
def _ddlerp(params, x, x_shift):
    """Finch data-dependent mixing -> (r_in, k_in, v_in, w_in, g_in)."""
    dx = x_shift - x  # [B,S,D]
    base = x + dx * params["mu0"][None, None]
    lora = jnp.tanh(jnp.einsum("bsd,dr->bsr", base, params["mix_a"]))
    lora = lora.reshape(*lora.shape[:2], 5, -1)
    mixes = params["mu"][None, None] + jnp.einsum(
        "bscr,crd->bscd", lora.astype(params["mix_b"].dtype), params["mix_b"]
    ).astype(jnp.float32)
    out = x[:, :, None, :] + dx[:, :, None, :] * mixes  # [B,S,5,D]
    return tuple(out[:, :, i] for i in range(5))


def _project(params, x, x_shift, cfg):
    h = cfg.n_heads
    p = cfg.d_model // h
    xr, xk, xv, xw, xg = _ddlerp(params, x.astype(jnp.float32), x_shift.astype(jnp.float32))
    cd = params["wr"].dtype
    r = jnp.einsum("bsd,de->bse", xr.astype(cd), params["wr"])
    k = jnp.einsum("bsd,de->bse", xk.astype(cd), params["wk"])
    v = jnp.einsum("bsd,de->bse", xv.astype(cd), params["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg.astype(cd), params["wg"]))
    # decay: w in (0,1): exp(-exp(base + lora))
    wl = jnp.einsum("bsd,dr->bsr", jnp.tanh(xw.astype(cd)), params["w_a"])
    logw = params["w_base"][None, None] + jnp.einsum(
        "bsr,rd->bsd", wl, params["w_b"]
    ).astype(jnp.float32)
    log_decay = -jnp.exp(jnp.clip(logw, -20.0, 1.0))  # log w_t  (< 0)
    shp = (*x.shape[:2], h, p)
    return (
        r.reshape(shp).astype(jnp.float32),
        k.reshape(shp).astype(jnp.float32),
        v.reshape(shp).astype(jnp.float32),
        log_decay.reshape(shp),
        g,
    )


# ---------------------------------------------------------------------------
# cores
# ---------------------------------------------------------------------------
def rwkv6_sequential_core(r, k, v, log_w, u, s0=None):
    """r/k/v/log_w: [B,S,H,P]; u: [H,P]. Returns (y [B,S,H,P], s_f [B,H,P,P])."""
    b, s, h, p = r.shape
    state = jnp.zeros((b, h, p, p), jnp.float32) if s0 is None else s0

    def step(st, t_in):
        r_t, k_t, v_t, lw_t = t_in  # [B,H,P]
        kv = jnp.einsum("bhp,bhq->bhpq", k_t, v_t)
        y_t = jnp.einsum("bhp,bhpq->bhq", r_t, st + u[None, :, :, None] * kv)
        st_new = st * jnp.exp(lw_t)[..., None] + kv
        return st_new, y_t

    s_f, ys = jax.lax.scan(
        step,
        state,
        tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, log_w)),
    )
    return jnp.moveaxis(ys, 0, 1), s_f


def rwkv6_chunked_core(r, k, v, log_w, u, chunk: int, s0=None, use_kernel: bool = False):
    b, s, h, p = r.shape
    pad = (-s) % chunk
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (s + pad) // chunk
    rc, kc, vc, wc = (
        t.reshape(b, nc, chunk, h, p) for t in (r, k, v, log_w)
    )
    state = jnp.zeros((b, h, p, p), jnp.float32) if s0 is None else s0

    if use_kernel:
        from repro.kernels.rwkv6 import ops as rwkv_ops

        chunk_fn = rwkv_ops.rwkv6_chunk
    else:
        chunk_fn = rwkv6_chunk_ref

    def chunk_step(st, c_in):
        rr, kk, vv, ww = c_in  # [B,T,H,P]
        y, st_new = chunk_fn(rr, kk, vv, ww, u, st)
        return st_new, y

    s_f, ys = jax.lax.scan(
        chunk_step,
        state,
        tuple(jnp.moveaxis(t, 1, 0) for t in (rc, kc, vc, wc)),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s + pad, h, p)[:, :s]
    return y, s_f


def rwkv6_chunk_ref(r, k, v, log_w, u, s0):
    """One chunk, closed form. r/k/v/log_w: [B,T,H,P]; s0: [B,H,P,P].

    cum[t] = sum_{j<=t} log_w[j]  (inclusive). Contribution of i<t to y_t:
        (r_t * exp(cum[t-1]-cum[i])) . k_i  outer  v_i
    i == t uses the bonus u instead of decay. All exponents <= 0.
    """
    b, t, h, p = r.shape
    cum = jnp.cumsum(log_w, axis=1)  # [B,T,H,P]
    cum_prev = cum - log_w  # cum[t-1] (exclusive)
    # pairwise decay exp(cum_prev[t] - cum[i]) for i < t  -> [B,T,T,H,P]
    diff = cum_prev[:, :, None] - cum[:, None, :, :]
    idx = jnp.arange(t)
    strict = idx[:, None] > idx[None, :]
    decay = jnp.where(strict[None, :, :, None, None], jnp.exp(diff), 0.0)
    return _chunk_finish(r, k, v, u, s0, cum, cum_prev, decay)


def _chunk_finish(r, k, v, u, s0, cum, cum_prev, decay):
    # intra (i < t): per-head attention-like matrix [B,T,T,H]
    a_mat = jnp.einsum("bthp,btihp,bihp->btih", r, decay, k)
    y = jnp.einsum("btih,bihq->bthq", a_mat, v)
    # diagonal bonus term (i == t)
    diag = jnp.einsum("bthp,hp,bthp->bth", r, u, k)
    y = y + diag[..., None] * v
    # carry-in state, read with decay exp(cum_prev[t])
    y = y + jnp.einsum("bthp,bthp,bhpq->bthq", r, jnp.exp(cum_prev), s0)
    # state update: S' = diag(exp(cum[T-1])) S + sum_i exp(cum[T-1]-cum[i]) k_i v_i^T
    tail = jnp.exp(cum[:, -1:] - cum)  # [B,T,H,P]
    s_new = s0 * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
        "bihp,bihp,bihq->bhpq", tail, k, v
    )
    return y, s_new


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------
def rwkv6_layer(params, x, cfg, state: dict | None = None, sequential: bool = False,
                use_kernel: bool = False):
    """Time-mix block. x: [B,S,D] -> (y, new_state)."""
    b, s, d = x.shape
    h = cfg.n_heads
    p = d // h
    if state is not None:
        prev = state["x_prev"][:, None]  # [B,1,D]
    else:
        prev = jnp.zeros((b, 1, d), x.dtype)
    x_shift = jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)

    r, k, v, log_w, g = _project(params, x, x_shift, cfg)
    u = params["u_bonus"]
    s0 = state["wkv"] if state is not None else None
    if sequential or s == 1:
        y, s_f = rwkv6_sequential_core(r, k, v, log_w, u, s0)
    else:
        y, s_f = rwkv6_chunked_core(r, k, v, log_w, u, cfg.ssm_chunk, s0, use_kernel)

    y = y.reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(params["ln_out"], y, cfg.norm_eps) * g.astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, params["wo"])
    new_state = None
    if state is not None:
        new_state = {"x_prev": x[:, -1].astype(state["x_prev"].dtype), "wkv": s_f}
    return out, new_state
