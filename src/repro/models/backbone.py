"""Block-pattern backbone: scan-over-periods composition of heterogeneous stacks.

A model is ``prefix_layers`` (unrolled) + ``n_periods`` repetitions of
``period`` (one ``lax.scan`` over stacked params) + ``remainder`` (unrolled).
Every block inside a period may be a different kind (attention with its own
window, MLA, Mamba2, RWKV6) and carries its own FFN (dense/MoE/none), so
local:global patterns (gemma2/3) and hybrid patterns (zamba2) compile as a
single scanned body — one layer's HLO regardless of depth.

``shared`` blocks (zamba2) use one parameter set stored OUTSIDE the scan and
closed over by the body; their per-application KV caches are stacked and
scanned like everything else.

Caches: pytree mirroring the block structure. A block with no cache uses an
empty dict (scan-compatible placeholder).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import init_mlp, init_rmsnorm, mlp, mlp_spec, rmsnorm, rmsnorm_spec

# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def init_block(key, spec: BlockSpec, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"pre_norm": init_rmsnorm(cfg.d_model)}
    if spec.kind == "attn":
        p["inner"] = attn_mod.init_attention(ks[0], cfg, dtype)
    elif spec.kind == "mla":
        p["inner"] = mla_mod.init_mla(ks[0], cfg, dtype)
    elif spec.kind == "mamba2":
        p["inner"] = ssm_mod.init_mamba2(ks[0], cfg, dtype)
    elif spec.kind == "rwkv6":
        p["inner"] = rwkv_mod.init_rwkv6(ks[0], cfg, dtype)
    if cross:
        p["cross_norm"] = init_rmsnorm(cfg.d_model)
        p["cross"] = attn_mod.init_attention(ks[1], cfg, dtype)
    if cfg.post_block_norm:
        p["post_norm"] = init_rmsnorm(cfg.d_model)
    if spec.ffn != "none":
        p["ffn_norm"] = init_rmsnorm(cfg.d_model)
        if spec.ffn == "dense":
            p["ffn"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["ffn"] = moe_mod.init_moe(ks[2], cfg, dtype)
            if cfg.n_shared_experts:
                p["ffn_shared"] = init_mlp(
                    ks[3], cfg.d_model, cfg.n_shared_experts * cfg.moe_d_ff, dtype
                )
        if cfg.post_block_norm:
            p["ffn_post_norm"] = init_rmsnorm(cfg.d_model)
    return p


def block_spec_tree(spec: BlockSpec, cfg: ModelConfig, cross: bool = False) -> dict:
    p: dict[str, Any] = {"pre_norm": rmsnorm_spec()}
    if spec.kind == "attn":
        p["inner"] = attn_mod.attention_spec(cfg)
    elif spec.kind == "mla":
        p["inner"] = mla_mod.mla_spec(cfg)
    elif spec.kind == "mamba2":
        p["inner"] = ssm_mod.mamba2_spec(cfg)
    elif spec.kind == "rwkv6":
        p["inner"] = rwkv_mod.rwkv6_spec(cfg)
    if cross:
        p["cross_norm"] = rmsnorm_spec()
        p["cross"] = attn_mod.attention_spec(cfg)
    if cfg.post_block_norm:
        p["post_norm"] = rmsnorm_spec()
    if spec.ffn != "none":
        p["ffn_norm"] = rmsnorm_spec()
        p["ffn"] = mlp_spec() if spec.ffn == "dense" else moe_mod.moe_spec(cfg)
        if spec.ffn == "moe" and cfg.n_shared_experts:
            p["ffn_shared"] = mlp_spec()
        if cfg.post_block_norm:
            p["ffn_post_norm"] = rmsnorm_spec()
    return p


def init_block_cache(
    spec: BlockSpec, cfg: ModelConfig, batch: int, max_len: int, dtype
) -> dict:
    if spec.kind in ("attn",):
        return attn_mod.init_kv_cache(
            batch, max_len, cfg.n_kv_heads, cfg.head_dim, spec.window, dtype
        )
    if spec.kind == "mla":
        return mla_mod.init_mla_cache(batch, max_len, cfg, dtype)
    if spec.kind == "mamba2":
        return ssm_mod.init_mamba2_state(batch, cfg)
    if spec.kind == "rwkv6":
        return rwkv_mod.init_rwkv6_state(batch, cfg)
    return {}


def apply_block(
    params: dict,
    spec: BlockSpec,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: dict | None,
    enc_out: jax.Array | None = None,
    moe_impl: str = "local",
    mesh=None,
) -> tuple[jax.Array, dict | None, dict]:
    aux: dict[str, Any] = {}
    h = rmsnorm(params["pre_norm"], x, cfg.norm_eps)
    new_cache = cache
    if spec.kind == "attn":
        out, new_cache = attn_mod.attention_layer(
            params["inner"], h, positions, cfg, window=spec.window, cache=cache or None
        )
    elif spec.kind == "mla":
        out, new_cache = mla_mod.mla_layer(params["inner"], h, positions, cfg, cache or None)
    elif spec.kind == "mamba2":
        out, new_cache = ssm_mod.mamba2_layer(params["inner"], h, cfg, cache or None)
    elif spec.kind == "rwkv6":
        out, new_cache = rwkv_mod.rwkv6_layer(params["inner"], h, cfg, cache or None)
    else:
        raise ValueError(spec.kind)
    if cfg.post_block_norm:
        out = rmsnorm(params["post_norm"], out, cfg.norm_eps)
    x = x + out
    if new_cache is None:
        new_cache = {}

    if "cross" in params and enc_out is not None:
        hc = rmsnorm(params["cross_norm"], x, cfg.norm_eps)
        ck = jnp.einsum("bsd,dhk->bshk", enc_out, params["cross"]["wk"])
        cv = jnp.einsum("bsd,dhk->bshk", enc_out, params["cross"]["wv"])
        out, _ = attn_mod.attention_layer(
            params["cross"], hc, positions, cfg, window=None, cross_kv=(ck, cv)
        )
        x = x + out

    if spec.ffn != "none":
        h2 = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        if spec.ffn == "dense":
            out2 = mlp(params["ffn"], h2)
        else:
            b, s, d = h2.shape
            flat = h2.reshape(b * s, d)
            if moe_impl == "sharded":
                y, moe_aux = moe_mod.moe_block_sharded(params["ffn"], h2, cfg, mesh)
                out2 = y
            else:
                y, moe_aux = moe_mod.moe_local(params["ffn"], flat, cfg)
                out2 = y.reshape(b, s, d)
            aux["moe_load"] = moe_aux["load"]
            if cfg.n_shared_experts:
                out2 = out2 + mlp(params["ffn_shared"], h2)
        if cfg.post_block_norm:
            out2 = rmsnorm(params["ffn_post_norm"], out2, cfg.norm_eps)
        x = x + out2
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Stack:
    """Static description of the full layer stack for one model."""

    cfg: ModelConfig
    cross: bool = False  # decoder blocks carry cross-attention (whisper)

    @property
    def period(self) -> tuple[BlockSpec, ...]:
        return self.cfg.period

    def init(self, key, dtype) -> dict:
        cfg = self.cfg
        n_p = len(cfg.period)
        keys = jax.random.split(key, cfg.n_periods)

        def init_period(k):
            kk = jax.random.split(k, n_p)
            return {
                f"b{i}": init_block(kk[i], cfg.period[i], cfg, dtype, self.cross)
                for i in range(n_p)
                if not cfg.period[i].shared
            }

        params: dict[str, Any] = {"periods": jax.vmap(init_period)(keys)}
        shared_specs = [b for b in cfg.period if b.shared]
        if shared_specs:
            params["shared_block"] = init_block(
                jax.random.fold_in(key, 17), shared_specs[0], cfg, dtype, self.cross
            )
        for name, blocks in (("prefix", cfg.prefix_layers), ("remainder", cfg.remainder)):
            for i, b in enumerate(blocks):
                params[f"{name}{i}"] = init_block(
                    jax.random.fold_in(key, 100 + i + (0 if name == "prefix" else 50)),
                    b,
                    cfg,
                    dtype,
                    self.cross,
                )
        return params

    def spec(self) -> dict:
        cfg = self.cfg
        tree: dict[str, Any] = {
            "periods": {
                f"b{i}": block_spec_tree(cfg.period[i], cfg, self.cross)
                for i in range(len(cfg.period))
                if not cfg.period[i].shared
            }
        }
        if any(b.shared for b in cfg.period):
            shared = [b for b in cfg.period if b.shared][0]
            tree["shared_block"] = block_spec_tree(shared, cfg, self.cross)
        for name, blocks in (("prefix", cfg.prefix_layers), ("remainder", cfg.remainder)):
            for i, b in enumerate(blocks):
                tree[f"{name}{i}"] = block_spec_tree(b, cfg, self.cross)
        return tree

    def init_caches(self, batch: int, max_len: int, dtype) -> dict:
        cfg = self.cfg

        def period_caches():
            return {
                f"b{i}": init_block_cache(cfg.period[i], cfg, batch, max_len, dtype)
                for i in range(len(cfg.period))
            }

        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs), *[period_caches() for _ in range(cfg.n_periods)]
        )
        caches: dict[str, Any] = {"periods": stacked}
        for name, blocks in (("prefix", cfg.prefix_layers), ("remainder", cfg.remainder)):
            for i, b in enumerate(blocks):
                caches[f"{name}{i}"] = init_block_cache(b, cfg, batch, max_len, dtype)
        return caches

    # ------------------------------------------------------------------
    def apply(
        self,
        params: dict,
        x: jax.Array,
        positions: jax.Array,
        caches: dict | None = None,
        enc_out: jax.Array | None = None,
        moe_impl: str = "local",
        mesh=None,
    ) -> tuple[jax.Array, dict | None, dict]:
        cfg = self.cfg
        aux_acc: dict[str, Any] = {}
        new_caches: dict[str, Any] = {} if caches is not None else None

        def _merge_aux(aux):
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0.0) + v

        for i, b in enumerate(cfg.prefix_layers):
            c = caches[f"prefix{i}"] if caches is not None else None
            x, nc, aux = apply_block(
                params[f"prefix{i}"], b, cfg, x, positions, c, enc_out, moe_impl, mesh
            )
            if caches is not None:
                new_caches[f"prefix{i}"] = nc
            _merge_aux(aux)

        # scanned periods
        shared_params = params.get("shared_block")
        period_specs = cfg.period
        has_cache = caches is not None

        def body(carry, scanned):
            x_c = carry
            p_params, p_caches = scanned
            aux_out = {}
            ncs = {}
            for i, b in enumerate(period_specs):
                bp = shared_params if b.shared else p_params[f"b{i}"]
                c = p_caches[f"b{i}"] if has_cache else None
                x_c, nc, aux = apply_block(
                    bp, b, cfg, x_c, positions, c, enc_out, moe_impl, mesh
                )
                ncs[f"b{i}"] = nc if has_cache else {}
                for k, v in aux.items():
                    aux_out[k] = aux_out.get(k, 0.0) + v
            if not aux_out:
                aux_out = {"_": jnp.zeros(())}
            return x_c, (ncs, aux_out)

        if cfg.remat != "none" and not has_cache:
            policy = (
                jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                if cfg.remat == "dots"
                else jax.checkpoint_policies.nothing_saveable
            )
            body = jax.checkpoint(body, policy=policy)

        scanned_caches = (
            caches["periods"]
            if has_cache
            else jax.tree.map(lambda _: 0, {f"b{i}": {} for i in range(len(period_specs))})
        )
        x, (nc_periods, aux_stack) = jax.lax.scan(
            body, x, (params["periods"], scanned_caches)
        )
        if has_cache:
            new_caches["periods"] = nc_periods
        for k, v in aux_stack.items():
            if k != "_":
                aux_acc[k] = aux_acc.get(k, 0.0) + v.sum(0)
                if k == "moe_load":
                    aux_acc["moe_load_periods"] = v  # [n_periods, E]

        for i, b in enumerate(cfg.remainder):
            c = caches[f"remainder{i}"] if caches is not None else None
            x, nc, aux = apply_block(
                params[f"remainder{i}"], b, cfg, x, positions, c, enc_out, moe_impl, mesh
            )
            if caches is not None:
                new_caches[f"remainder{i}"] = nc
            _merge_aux(aux)

        return x, new_caches, aux_acc
