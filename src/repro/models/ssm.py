"""Mamba2 (SSD) block — chunked parallel train path + recurrent decode path.

State-space recurrence per head (A scalar per head, Mamba-2 simplification):

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t        h: [P, N]
    y_t = C_t . h_t + D * x_t

Train/prefill uses the chunked (SSD) algorithm: within-chunk contributions via
a causal decay matrix L[t, i] = exp(cum[t] - cum[i]) (always <= 1, numerically
safe — no max-subtraction needed), across-chunk via a scanned state carry.
``mamba2_sequential`` is the oracle; tests assert chunked == sequential and
prefill+decode == full.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_mamba2(key, cfg, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.n_ssm_heads
    g = 1  # n_groups
    conv_ch = di + 2 * g * n
    d_in = 2 * di + 2 * g * n + h
    ks = jax.random.split(key, 4)
    return {
        "in_proj": jax.random.normal(ks[0], (d, d_in), dtype) * d**-0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), dtype) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),  # A = -exp(a_log) in [-1, ...)
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": {"scale": jnp.zeros((di,), jnp.float32)},
        "out_proj": jax.random.normal(ks[2], (di, d), dtype) * di**-0.5,
    }


def mamba2_spec(cfg) -> dict:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": {"scale": ("inner",)},
        "out_proj": ("inner", "embed"),
    }


def init_mamba2_state(batch: int, cfg, dtype=jnp.float32) -> dict:
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    conv_ch = di + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, p, n), dtype),
    }


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------
def _split_proj(params, u, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = jnp.einsum("bsd,de->bse", u, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt  # [B,S,di], [B,S,di+2N], [B,S,H]


def _causal_conv(xbc, conv_w, conv_b, prev: jax.Array | None):
    """Depthwise causal conv along seq; prev = [B, K-1, C] history (decode)."""
    k = conv_w.shape[0]
    if prev is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [B, S+K-1, C]
    out = sum(xp[:, i : i + xbc.shape[1]] * conv_w[i][None, None] for i in range(k))
    out = jax.nn.silu(out + conv_b[None, None])
    new_prev = xp[:, xp.shape[1] - (k - 1) :]
    return out, new_prev


def _heads(x, b_mat, c_mat, dt, params, cfg):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    p = di // h
    bsz, s = x.shape[:2]
    xh = x.reshape(bsz, s, h, p).astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"][None, None])
    a = -jnp.exp(params["a_log"])  # [H]
    return xh, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32), dt, a


# ---------------------------------------------------------------------------
# sequential oracle
# ---------------------------------------------------------------------------
def mamba2_sequential_core(xh, b_mat, c_mat, dt, a, d_skip, h0=None):
    """xh: [B,S,H,P]; b/c: [B,S,N]; dt: [B,S,H]; returns (y [B,S,H,P], h_f)."""
    bsz, s, h, p = xh.shape
    n = b_mat.shape[-1]
    h_state = jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0

    def step(h_prev, t_in):
        x_t, b_t, c_t, dt_t = t_in
        decay = jnp.exp(dt_t * a[None, :])  # [B,H]
        upd = jnp.einsum("bhp,bn->bhpn", x_t * dt_t[..., None], b_t)
        h_new = h_prev * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", h_new, c_t)
        return h_new, y_t

    h_f, ys = jax.lax.scan(
        step,
        h_state,
        (
            jnp.moveaxis(xh, 1, 0),
            jnp.moveaxis(b_mat, 1, 0),
            jnp.moveaxis(c_mat, 1, 0),
            jnp.moveaxis(dt, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1) + xh * d_skip[None, None, :, None]
    return y, h_f


# ---------------------------------------------------------------------------
# chunked (SSD) core
# ---------------------------------------------------------------------------
def mamba2_chunked_core(xh, b_mat, c_mat, dt, a, d_skip, chunk: int, h0=None):
    bsz, s, h, p = xh.shape
    n = b_mat.shape[-1]
    pad = (-s) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // chunk
    xc = xh.reshape(bsz, nc, chunk, h, p)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)
    dtc = dt.reshape(bsz, nc, chunk, h)

    h_init = jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0

    def chunk_step(h_prev, c_in):
        x, b, c, dtt = c_in  # [B,T,H,P], [B,T,N], [B,T,N], [B,T,H]
        la = dtt * a[None, None]  # log decay per step, <= 0
        cum = jnp.cumsum(la, axis=1)  # [B,T,H] inclusive
        # intra-chunk: L[t,i] = exp(cum[t]-cum[i]) for i<=t  (<=1, safe)
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # [B,T,T,H]
        t_idx = jnp.arange(x.shape[1])
        causal = t_idx[:, None] >= t_idx[None, :]
        l_mat = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("btn,bin->bti", c, b)  # [B,T,T]
        w = cb[:, :, :, None] * l_mat  # [B,T,T,H]
        y = jnp.einsum("btih,bihp->bthp", w, x * dtt[..., None])
        # inter-chunk: carry-in state read by C with decay exp(cum[t])
        read = jnp.exp(cum)  # [B,T,H]
        y = y + jnp.einsum("btn,bhpn,bth->bthp", c, h_prev, read)
        # state update: h_new = exp(cum[-1]) h_prev + sum_i exp(cum[-1]-cum[i]) dt_i B_i x_i
        tail = jnp.exp(cum[:, -1:, :] - cum)  # [B,T,H]
        upd = jnp.einsum("bihp,bin,bih->bhpn", x * dtt[..., None], b, tail)
        h_new = h_prev * jnp.exp(cum[:, -1])[:, :, None, None] + upd
        return h_new, y

    h_f, ys = jax.lax.scan(
        chunk_step,
        h_init,
        (
            jnp.moveaxis(xc, 1, 0),
            jnp.moveaxis(bc, 1, 0),
            jnp.moveaxis(cc, 1, 0),
            jnp.moveaxis(dtc, 1, 0),
        ),
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s + pad, h, p)[:, :s]
    y = y + xh[:, :s] * d_skip[None, None, :, None]
    return y, h_f


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------
def mamba2_layer(params, u, cfg, state: dict | None = None, sequential: bool = False):
    """u: [B, S, D] -> (y [B, S, D], new_state). state enables decode."""
    di, n = cfg.d_inner, cfg.ssm_state
    z, xbc, dt = _split_proj(params, u, cfg)
    prev = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], prev)
    x, b_mat, c_mat = jnp.split(xbc, [di, di + n], axis=-1)
    xh, b_mat, c_mat, dt, a = _heads(x, b_mat, c_mat, dt, params, cfg)

    h0 = state["ssm"] if state is not None else None
    if sequential or u.shape[1] == 1:
        y, h_f = mamba2_sequential_core(xh, b_mat, c_mat, dt, a, params["d_skip"], h0)
    else:
        y, h_f = mamba2_chunked_core(xh, b_mat, c_mat, dt, a, params["d_skip"], cfg.ssm_chunk, h0)

    y = y.reshape(u.shape[0], u.shape[1], di).astype(u.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"])
    new_state = None
    if state is not None:
        new_state = {"conv": new_conv.astype(state["conv"].dtype), "ssm": h_f}
    return out, new_state
