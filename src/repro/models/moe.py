"""Mixture-of-Experts with DYNAPs two-stage tag dispatch (DESIGN.md §3).

The mapping from the paper's routing scheme (core/two_stage.py) to MoE:

  spiking neuron        -> token with a routing decision
  tag                   -> expert id *within its expert shard* (k = E_local)
  cluster               -> expert shard (one device slab of the `model` axis)
  stage 1 point-to-point-> all_to_all of token payloads to destination shards
  stage 2 CAM broadcast -> on-shard scatter of received events into expert
                           buffers by tag (every expert "subscribed" to its
                           own tag picks its events out of the broadcast)

Routing state per token is (tag, dest-cluster) — log2(E_local)+log2(tp) bits,
exactly the paper's MEM_S entry — instead of a T x E dispatch matrix; this is
what keeps dispatch memory linear in tokens (Fig. 13's argument applied to
expert routing).

Three implementations, numerically interchangeable (tests assert so):
  * ``moe_reference``      — loop over experts, dense masks (oracle, tiny dims)
  * ``moe_local``          — sort-based two-stage dispatch on one device
  * ``moe_sharded``        — shard_map EP: stage-1 all_to_all over the model
                             axis, stage-2 local dispatch (production path)

Routers: softmax top-k (deepseek-moe-16b) and sigmoid+bias aux-free
(deepseek-v3; the bias is updated outside the gradient, train/loop.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# version-portable shard_map kwargs live in core (shared with event_engine)
from repro.core.shard_compat import SM_CHECK_KW as _SM_CHECK_KW
from repro.core.shard_compat import axis_size as _axis_size

# fixed-capacity slot assignment shared with the AER event path: the expert
# buffer IS the event queue of DESIGN.md §10 (bins = experts/shards,
# cap = expert capacity, overflow = token drop).
from repro.core.two_stage import dispatch_slots as _dispatch_indices

# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_moe(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    s_in, s_out = d**-0.5, f**-0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "router_bias": jnp.zeros((e,), jnp.float32),
        "wi_gate": jax.random.normal(ks[1], (e, d, f), dtype) * s_in,
        "wi_up": jax.random.normal(ks[2], (e, d, f), dtype) * s_in,
        "wo": jax.random.normal(ks[3], (e, f, d), dtype) * s_out,
    }
    return p


def moe_spec(cfg) -> dict:
    p = {
        "router": ("embed", None),
        "router_bias": (None,),
        "wi_gate": ("experts", "embed", "mlp"),
        "wi_up": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
    }
    return p


# ---------------------------------------------------------------------------
# routing decisions (stage-0: which tag/cluster does each token emit?)
# ---------------------------------------------------------------------------
def route(params: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: [T, D] -> (top_idx [T,k], top_w [T,k], load [E]).

    deepseek-v3 aux-free: routing by sigmoid(score)+bias, weights from the
    *unbiased* sigmoid scores renormalized over the chosen experts.
    """
    scores = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    if cfg.router_aux_free:
        affinity = jax.nn.sigmoid(scores)
        _, top_idx = jax.lax.top_k(affinity + params["router_bias"][None, :], cfg.top_k)
        top_w = jnp.take_along_axis(affinity, top_idx, axis=1)
        top_w = top_w / (top_w.sum(-1, keepdims=True) + 1e-20)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
        top_w, top_idx = jax.lax.top_k(probs, cfg.top_k)
        top_w = top_w / (top_w.sum(-1, keepdims=True) + 1e-20)
    load = jnp.zeros((cfg.n_experts,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    return top_idx, top_w, load


def aux_loss(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Switch-style load-balancing loss (used when not aux-free)."""
    scores = jnp.einsum("td,de->te", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(scores, axis=-1)
    _, top_idx = jax.lax.top_k(probs, cfg.top_k)
    t = x.shape[0]
    frac = jnp.zeros((cfg.n_experts,)).at[top_idx.reshape(-1)].add(1.0) / (t * cfg.top_k)
    imp = probs.mean(0)
    return cfg.n_experts * jnp.sum(frac * imp)


# ---------------------------------------------------------------------------
# expert compute (stage-2 "core": the subscribed synapse integrates)
# ---------------------------------------------------------------------------
def _experts_ffn(params: dict, buf: jax.Array, e_slice=None) -> jax.Array:
    """buf: [E(_local), cap, D] -> same shape through gated FFN."""
    wi_g, wi_u, wo = params["wi_gate"], params["wi_up"], params["wo"]
    gate = jnp.einsum("ecd,edf->ecf", buf, wi_g)
    up = jnp.einsum("ecd,edf->ecf", buf, wi_u)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up, wo)


# ---------------------------------------------------------------------------
# sort-based two-stage dispatch (single device / per-shard stage 2);
# slot assignment lives in core.two_stage.dispatch_slots (shared with AER)
# ---------------------------------------------------------------------------
def moe_local(params: dict, x: jax.Array, cfg, capacity: int | None = None):
    """Two-stage dispatch on one device. x: [T, D] -> ([T, D], aux)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = capacity or max(8, int(t * k / e * cfg.capacity_factor))
    top_idx, top_w, load = route(params, x, cfg)

    flat_e = top_idx.reshape(-1)  # [T*k] — the emitted (tag) stream
    slot, keep = _dispatch_indices(flat_e, e, cap)
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, e * cap)].add(
        x[token_of] * keep[:, None].astype(x.dtype), mode="drop"
    )
    out_buf = _experts_ffn(params, buf.reshape(e, cap, d)).reshape(e * cap, d)
    gathered = out_buf[jnp.clip(slot, 0)] * keep[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype)
    y = y.at[token_of].add(gathered * top_w.reshape(-1)[:, None].astype(x.dtype))
    return y, {"load": load}


def moe_reference(params: dict, x: jax.Array, cfg):
    """Oracle: every expert computed densely for every token (tiny dims only)."""
    t, d = x.shape
    top_idx, top_w, load = route(params, x, cfg)
    combine = (
        jnp.zeros((t, cfg.n_experts), jnp.float32)
        .at[jnp.arange(t)[:, None], top_idx]
        .add(top_w)
    )
    all_out = _experts_ffn(params, jnp.broadcast_to(x[None], (cfg.n_experts, t, d)))
    y = jnp.einsum("te,etd->td", combine, all_out.astype(jnp.float32)).astype(x.dtype)
    return y, {"load": load}


# ---------------------------------------------------------------------------
# sharded EP: stage-1 all_to_all (point-to-point to the destination cluster)
# ---------------------------------------------------------------------------
def _axes_tuple(axes):
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _axes_size(axes) -> int:
    n = 1
    for a in _axes_tuple(axes):
        n *= _axis_size(a)
    return n


def _axes_linear_index(axes) -> jax.Array:
    """Linearized rank over a tuple of mesh axes (row-major, like P(axes))."""
    idx = jnp.zeros((), jnp.int32)
    for a in _axes_tuple(axes):
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx


def moe_sharded(params: dict, x: jax.Array, cfg, axis="model",
                capacity: int | None = None, owned: jax.Array | None = None):
    """Runs INSIDE shard_map. x: [t_local, D]; experts sharded over ``axis``
    (a mesh-axis name or tuple — e.g. ("data","model") = in-pod EP256).

    Stage 1 = all_to_all of token payloads to their destination expert shard
    ("cluster"), stage 2 = local sort-based dispatch by tag (expert id within
    the shard). ``owned`` masks out tokens this rank must NOT dispatch (used
    when activations are replicated over part of the EP mesh at decode).
    """
    tp = _axes_size(axis)
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_local = e // tp
    cap_send = capacity or max(8, int(t * k / tp * cfg.capacity_factor))
    cap_recv = max(8, int(t * k / e_local * cfg.capacity_factor))

    top_idx, top_w, _ = route(params, x, cfg)
    flat_e = top_idx.reshape(-1)  # [T*k] — the emitted (tag) stream
    if owned is not None:
        flat_e = jnp.where(jnp.repeat(owned, k), flat_e, -1)
    # load counts only assignments this rank actually emits (exact after psum)
    load = jnp.zeros((e,), jnp.float32).at[jnp.where(flat_e >= 0, flat_e, e)].add(
        1.0, mode="drop"
    )
    dest = jnp.where(flat_e >= 0, flat_e // e_local, -1)  # cluster id
    tag = flat_e % e_local  # tag within cluster

    # pack per-destination send buffers (stage-1 SRAM entries -> fabric)
    slot, keep = _dispatch_indices(jnp.where(dest >= 0, dest, tp), tp, cap_send)
    keep = keep & (dest >= 0)
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    drop = tp * cap_send
    idx = jnp.where(keep, slot, drop)
    payload = jnp.zeros((drop + 1, d), x.dtype).at[idx].add(
        x[token_of] * keep[:, None].astype(x.dtype)
    )[:-1]
    tags_buf = jnp.full((drop + 1,), -1, jnp.int32).at[idx].max(jnp.where(keep, tag, -1))[:-1]

    payload = payload.reshape(tp, cap_send, d)
    tags_buf = tags_buf.reshape(tp, cap_send)

    # stage 1: point-to-point exchange over the EP mesh (R2 hop; in-pod only)
    recv_x = jax.lax.all_to_all(payload, axis, split_axis=0, concat_axis=0, tiled=False)
    recv_tag = jax.lax.all_to_all(tags_buf, axis, split_axis=0, concat_axis=0, tiled=False)

    # stage 2: local dispatch of received events by tag (CAM match)
    ev_x = recv_x.reshape(tp * cap_send, d)
    ev_tag = recv_tag.reshape(tp * cap_send)
    slot2, keep2 = _dispatch_indices(jnp.where(ev_tag >= 0, ev_tag, e_local), e_local, cap_recv)
    keep2 = keep2 & (ev_tag >= 0)
    drop2 = e_local * cap_recv
    idx2 = jnp.where(keep2, slot2, drop2)
    buf = jnp.zeros((drop2 + 1, d), x.dtype).at[idx2].add(
        ev_x * keep2[:, None].astype(x.dtype)
    )[:-1]

    out_buf = _experts_ffn(params, buf.reshape(e_local, cap_recv, d)).reshape(drop2, d)

    # inverse path: events pick up their results, a2a back, weighted combine
    ev_out = out_buf[jnp.clip(slot2, 0)] * keep2[:, None].astype(x.dtype)
    back = jax.lax.all_to_all(
        ev_out.reshape(tp, cap_send, d), axis, split_axis=0, concat_axis=0, tiled=False
    ).reshape(tp * cap_send, d)
    gathered = back[jnp.clip(slot, 0)] * keep[:, None].astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype)
    y = y.at[token_of].add(gathered * top_w.reshape(-1)[:, None].astype(x.dtype))

    return y, {"load": load}


# ---------------------------------------------------------------------------
# jit-level wrapper: shard_map the two-stage dispatch over the mesh
# ---------------------------------------------------------------------------
def ep_axes_for(cfg, mesh, model_axis: str = "model"):
    """EP mesh axes: same resolution rule as the expert weight sharding
    (distributed/sharding.py RULES['experts']) so dispatch matches storage."""
    import numpy as np

    for cand in (("data", model_axis), (model_axis,), ("data",)):
        if all(a in mesh.shape for a in cand):
            size = int(np.prod([mesh.shape[a] for a in cand]))
            if size > 1 and cfg.n_experts % size == 0:
                return cand
    return ()


def moe_block_sharded(params: dict, x3: jax.Array, cfg, mesh, model_axis: str = "model"):
    """x3: [B, S, D] (global). Activation layout adapts to the cell:

    * tokens split over (batch axes) x (seq over model) when S divides the
      model axis (train / prefill) — every device dispatches a distinct slab;
    * otherwise (decode, S == 1) tokens shard over whatever batch axes divide
      B and are REPLICATED over the remaining EP axes; each replica rank
      dispatches only its strided slice of tokens (owned mask) and outputs
      are psum-recombined — correctness without duplicate expert work.

    The EP exchange never crosses the pod axis: expert clusters live inside a
    pod and pods replicate experts (the paper's "local traffic stays local").
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.core.shard_compat import shard_map as _shard_map

    ep = ep_axes_for(cfg, mesh, model_axis)
    if not ep:  # tiny config / 1-device mesh: local dispatch
        b, s, d = x3.shape
        y, aux = moe_local(params, x3.reshape(b * s, d), cfg)
        return y.reshape(b, s, d), aux

    b, s, d = x3.shape
    pspec = {
        "router": P(),
        "router_bias": P(),
        "wi_gate": P(ep),
        "wi_up": P(ep),
        "wo": P(ep),
    }

    def axes_size(axes):
        return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    s_shardable = s % mesh.shape[model_axis] == 0 and s > 1

    # batch sharding: as many of (pod, data) as divide B
    b_axes = [a for a in ("pod", "data") if a in mesh.shape]
    while b_axes and b % axes_size(b_axes) != 0:
        b_axes.pop(0)

    if s_shardable:
        act_used = set(b_axes) | {model_axis}
        in_x = P(tuple(b_axes) if b_axes else None, model_axis, None)
    else:
        act_used = set(b_axes)
        in_x = P(tuple(b_axes) if b_axes else None, None, None)
    rep_axes = tuple(a for a in ep if a not in act_used)
    # non-EP axes over which tokens are replicated run independent identical
    # dispatches (DP replicas, e.g. pod when B doesn't divide it): divide
    # their multiplicity out of the load accounting.
    dup = 1
    for a in mesh.axis_names:
        if a not in ep and a not in act_used:
            dup *= mesh.shape[a]

    def local_fn(p, xx):
        bl, sl, dl = xx.shape
        t = bl * sl
        flat = xx.reshape(t, dl)
        owned = None
        if rep_axes:
            rank = _axes_linear_index(rep_axes)
            n_rep = _axes_size(rep_axes)
            owned = (jnp.arange(t, dtype=jnp.int32) % n_rep) == rank
        y, aux = moe_sharded(p, flat, cfg, axis=ep, owned=owned)
        if rep_axes:
            y = jax.lax.psum(y, rep_axes)
        # exact global load: sum emitted counts over every rank, de-duped
        load = jax.lax.psum(aux["load"], tuple(mesh.axis_names)) / dup
        return y.reshape(bl, sl, dl), {"load": load}

    f = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(pspec, in_x),
        out_specs=(in_x, {"load": P()}),
        **_SM_CHECK_KW,
    )
    return f(params, x3)
