"""Attention: MHA/GQA/MQA with sliding windows, softcap, RoPE, KV caches.

Three execution paths share one masked online-softmax core:

* ``attend`` (dense): materializes [B, nkv, G, Sq, Sk] scores — short seqs.
* ``attend_chunked``: double-blocked (q-block x kv-block) online softmax via
  ``lax.scan`` — bounded memory for 32k+ prefill. Numerically identical to
  dense (fp32 accumulation both ways).
* decode: one-token query against a ring-buffer cache.

Sliding-window layers allocate ``min(window, max_len)`` cache slots and write
with ``pos % len`` (ring); a per-slot absolute-position array drives both the
causal/window mask and RoPE (keys are rotated at write time), so prefill,
decode, and window eviction all fall out of one mask rule:

    valid(k_pos, q_pos) = 0 <= k_pos <= q_pos and q_pos - k_pos < window
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import active_axis_size, constrain
from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm, rmsnorm_spec

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attention(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = d**-0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kv, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kv, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h, hd, d), dtype) * (h * hd) ** -0.5,
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def attention_spec(cfg) -> dict:
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_spec()
        p["k_norm"] = rmsnorm_spec()
    return p


# ---------------------------------------------------------------------------
# masked softmax core
# ---------------------------------------------------------------------------
def _mask(q_pos, k_pos, window, causal):
    """q_pos: [..., Sq], k_pos: [..., Sk] -> bool [..., Sq, Sk]."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    m = kp >= 0  # invalid (unwritten) cache slots carry pos = -1
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= qp - kp < window
    return m


def _scores(qg, k, scale, softcap):
    s = jnp.einsum("bqngd,bknd->bngqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def attend_dense(q, k, v, q_pos, k_pos, *, causal=True, window=None, scale, softcap=None):
    b, sq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    dv = v.shape[-1]
    qg = q.reshape(b, sq, kv, g, d)
    s = _scores(qg, k, scale, softcap)  # [B, KV, G, Sq, Sk]
    m = _mask(q_pos, k_pos, window, causal)[:, None, None]  # [B,1,1,Sq,Sk]
    s = jnp.where(m, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (can happen for padded cache) -> zero output
    p = jnp.where(m.any(-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bngqk,bknd->bqngd", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, dv)


def attend_chunked(
    q, k, v, q_pos, k_pos, *, causal=True, window=None, scale, softcap=None,
    block_q: int = 1024, block_k: int = 1024,
):
    """Online-softmax attention, blocked over q and kv (flash-style dataflow)."""
    b, sq, h, d = q.shape
    kv_h = k.shape[2]
    g = h // kv_h
    dv = v.shape[-1]
    sk = k.shape[1]
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    pad_q = (-sq) % bq
    pad_k = (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=0)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad_k)), constant_values=-1)
    nq, nk = (sq + pad_q) // bq, (sk + pad_k) // bk

    # pin block layouts: GSPMD otherwise loses head sharding through the
    # reshape->moveaxis->scan chain and all-gathers K/V blocks (§Perf A1).
    # Only pin when the model axis divides kv-heads or q-head-groups —
    # otherwise pinning would FORCE head replication and regress GQA shapes
    # like internvl2 (kv=8, g=8 on a 16-way axis); leave GSPMD free there.
    m_size = active_axis_size("model")
    pin = m_size > 1 and (kv_h % m_size == 0 or g % m_size == 0)

    def _pin(t, dims):
        return constrain(t, dims) if pin else t

    # double "model" entry: lands on kv_h when divisible, else on g
    qg = _pin(q.reshape(b, nq, bq, kv_h, g, d),
              ("batch", None, None, "model", "model", None))
    qpos_b = q_pos.reshape(b, nq, bq)
    kb = _pin(k.reshape(b, nk, bk, kv_h, d), ("batch", None, None, "model", None))
    vb = _pin(v.reshape(b, nk, bk, kv_h, dv), ("batch", None, None, "model", None))
    kpos_b = k_pos.reshape(b, nk, bk)

    def q_block(args):
        qblk, qp = args  # [B, bq, KV, G, D], [B, bq]

        def kv_step(carry, kv_args):
            m_run, l_run, acc = carry
            kblk, vblk, kp = kv_args  # [B, bk, KV, D], [B, bk]
            s = _scores(qblk, kblk, scale, softcap)  # [B, KV, G, bq, bk]
            msk = _mask(qp, kp, window, causal)[:, None, None]
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m_run, s.max(-1))
            # guard: all-masked rows keep m=-inf; exp(NEG_INF - NEG_INF) avoided
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - m_safe), 0.0)
            l_new = l_run * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bknd->bngqd", p, vblk.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = _pin(jnp.full((b, kv_h, g, bq), NEG_INF, jnp.float32),
                  ("batch", "model", "model", None))
        l0 = _pin(jnp.zeros((b, kv_h, g, bq), jnp.float32),
                  ("batch", "model", "model", None))
        a0 = _pin(jnp.zeros((b, kv_h, g, bq, dv), jnp.float32),
                  ("batch", "model", "model", None, None))
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, a0),
            (
                jnp.moveaxis(kb, 1, 0),
                jnp.moveaxis(vb, 1, 0),
                jnp.moveaxis(kpos_b, 1, 0),
            ),
        )
        out = acc / jnp.maximum(l_f[..., None], 1e-37)
        return jnp.moveaxis(out, 3, 1)  # [B, bq, KV, G, D]

    outs = jax.lax.map(q_block, (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qpos_b, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * bq, h, dv)
    return out[:, :sq].astype(q.dtype)


def attention_core(q, k, v, q_pos, k_pos, *, causal=True, window=None, scale,
                   softcap=None, chunk_threshold: int = 4096):
    """Dispatch dense vs chunked on total score size."""
    if q.shape[1] * k.shape[1] > chunk_threshold * chunk_threshold // 4 and q.shape[1] > 1:
        return attend_chunked(
            q, k, v, q_pos, k_pos, causal=causal, window=window, scale=scale, softcap=softcap
        )
    return attend_dense(
        q, k, v, q_pos, k_pos, causal=causal, window=window, scale=scale, softcap=softcap
    )


# ---------------------------------------------------------------------------
# full layer: projections + rope + cache handling
# ---------------------------------------------------------------------------
def init_kv_cache(batch: int, max_len: int, n_kv: int, head_dim: int, window: int | None,
                  dtype=jnp.bfloat16) -> dict:
    length = max_len if window is None else min(window, max_len)
    return {
        "k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def attention_layer(
    params: dict,
    x: jax.Array,  # [B, S, E]
    positions: jax.Array,  # [B, S]
    cfg,
    *,
    window: int | None,
    cache: dict | None = None,
    cross_kv: tuple[jax.Array, jax.Array] | None = None,  # encoder K/V (pre-projected)
) -> tuple[jax.Array, dict | None]:
    """Self- (or cross-) attention layer. Returns (output, updated cache)."""
    h, kv_h, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    scale = cfg.attn_scale if cfg.attn_scale is not None else hd**-0.5

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
    if cross_kv is not None:
        k, v = cross_kv
        q = q  # no rope on cross-attention queries (whisper-style)
        k_pos = jnp.broadcast_to(
            jnp.arange(k.shape[1], dtype=jnp.int32)[None], (k.shape[0], k.shape[1])
        )
        out = attention_core(
            q, k, v, positions, k_pos, causal=False, window=None, scale=scale,
            softcap=cfg.attn_softcap,
        )
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"], preferred_element_type=x.dtype), cache

    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = attention_core(
            q, k, v, positions, positions, causal=True, window=window, scale=scale,
            softcap=cfg.attn_softcap,
        )
        return jnp.einsum("bshk,hkd->bsd", out, params["wo"], preferred_element_type=x.dtype), None

    # cache path: only the last `length` tokens can live in the ring buffer,
    # so keep the tail (ring slots are then collision-free within one write).
    s = x.shape[0], x.shape[1]
    length = cache["k"].shape[1]
    tail = max(0, x.shape[1] - length)
    k_t, v_t, pos_t = k[:, tail:], v[:, tail:], positions[:, tail:]
    slots = pos_t % length
    b_idx = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
    new_cache = {
        "k": cache["k"].at[b_idx, slots].set(k_t.astype(cache["k"].dtype)),
        "v": cache["v"].at[b_idx, slots].set(v_t.astype(cache["v"].dtype)),
        "pos": cache["pos"].at[b_idx, slots].set(pos_t),
    }
    if x.shape[1] > 1:
        # prefill: the ring may be smaller than S — attend over full fresh K/V.
        out = attention_core(
            q, k, v, positions, positions, causal=True, window=window, scale=scale,
            softcap=cfg.attn_softcap,
        )
    else:
        # decode: attend against the (just-updated) ring buffer.
        out = attention_core(
            q, new_cache["k"], new_cache["v"], positions, new_cache["pos"],
            causal=True, window=window, scale=scale, softcap=cfg.attn_softcap,
        )
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"], preferred_element_type=x.dtype), new_cache
