"""Multi-head Latent Attention (DeepSeek-V2/V3) — train, prefill, absorbed decode.

Two numerically-equivalent execution paths:

* train/prefill: decompress the latent ``c_kv`` into per-head K/V and run the
  shared chunked attention core (bounded memory at 32k prefill).
* decode ("absorbed"): the cache stores only ``(c_kv[B,L,512], k_rope[B,L,64])``
  — 4.7x smaller than GQA-128 K/V — and the up-projections are absorbed into
  the query / output sides:

      q_eff[b,h,c]   = sum_d q_nope[b,h,d] * w_uk[c,h,d]
      score          = (q_eff . c_kv + q_rope . k_rope) * scale
      ctx[b,h,c]     = sum_l softmax(score)[l] * c_kv[l,c]
      out_head[b,h,d]= sum_c ctx[b,h,c] * w_uv[c,h,d]

Equivalence decode==prefill is asserted in tests/test_models.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.attention import attention_core
from repro.models.layers import apply_rope, init_rmsnorm, rmsnorm, rmsnorm_spec


def init_mla(key, cfg, dtype=jnp.bfloat16) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    s = d**-0.5
    p = {}
    if r_q:
        p["w_dq"] = jax.random.normal(ks[0], (d, r_q), dtype) * s
        p["q_norm"] = init_rmsnorm(r_q)
        p["w_uq"] = jax.random.normal(ks[1], (r_q, h, dn + dr), dtype) * r_q**-0.5
    else:
        p["w_uq"] = jax.random.normal(ks[1], (d, h, dn + dr), dtype) * s
    p["w_dkv"] = jax.random.normal(ks[2], (d, r_kv), dtype) * s
    p["kv_norm"] = init_rmsnorm(r_kv)
    p["w_kr"] = jax.random.normal(ks[3], (d, dr), dtype) * s
    p["w_uk"] = jax.random.normal(ks[4], (r_kv, h, dn), dtype) * r_kv**-0.5
    p["w_uv"] = jax.random.normal(ks[5], (r_kv, h, dv), dtype) * r_kv**-0.5
    p["wo"] = jax.random.normal(ks[6], (h, dv, d), dtype) * (h * dv) ** -0.5
    return p


def mla_spec(cfg) -> dict:
    p = {
        "w_dkv": ("embed", "kv_lora"),
        "kv_norm": rmsnorm_spec(),
        "w_kr": ("embed", "head_dim"),
        "w_uk": ("kv_lora", "heads", "head_dim"),
        "w_uv": ("kv_lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = ("embed", "q_lora")
        p["q_norm"] = rmsnorm_spec()
        p["w_uq"] = ("q_lora", "heads", "head_dim")
    else:
        p["w_uq"] = ("embed", "heads", "head_dim")
    return p


def init_mla_cache(batch: int, max_len: int, cfg, dtype=jnp.bfloat16) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
    }


def _project_q(params, x, cfg):
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
        cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["w_uq"])
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]


def mla_layer(
    params: dict,
    x: jax.Array,  # [B, S, E]
    positions: jax.Array,  # [B, S]
    cfg,
    cache: dict | None = None,
) -> tuple[jax.Array, dict | None]:
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    q_nope, q_rope = _project_q(params, x, cfg)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]

    new_cache = None
    if cache is not None:
        b_idx = jnp.arange(x.shape[0], dtype=jnp.int32)[:, None]
        slots = positions % cache["c_kv"].shape[1]
        new_cache = {
            "c_kv": cache["c_kv"].at[b_idx, slots].set(c_kv.astype(cache["c_kv"].dtype)),
            "k_rope": cache["k_rope"].at[b_idx, slots].set(k_rope.astype(cache["k_rope"].dtype)),
            "pos": cache["pos"].at[b_idx, slots].set(positions),
        }

    if x.shape[1] > 1 or cache is None:
        # -- train / prefill: decompress and use the shared attention core --
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
        v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], k_nope.shape[:3] + (cfg.qk_rope_dim,))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        q = constrain(q, ("batch", None, "model", None))
        k = constrain(k, ("batch", None, "model", None))
        v = constrain(v, ("batch", None, "model", None))
        out = attention_core(
            q, k, v, positions, positions, causal=True, window=None, scale=scale, softcap=None
        )
    else:
        # -- absorbed decode against the latent cache -----------------------
        q_eff = jnp.einsum("bshd,rhd->bshr", q_nope, params["w_uk"])  # [B,1,H,r_kv]
        ck, kr, kpos = new_cache["c_kv"], new_cache["k_rope"], new_cache["pos"]
        s_lat = jnp.einsum(
            "bshr,blr->bhsl", q_eff.astype(jnp.float32), ck.astype(jnp.float32)
        )
        s_rope = jnp.einsum(
            "bshr,blr->bhsl", q_rope.astype(jnp.float32), kr.astype(jnp.float32)
        )
        scores = (s_lat + s_rope) * scale
        mask = (kpos[:, None, None, :] >= 0) & (kpos[:, None, None, :] <= positions[:, None, :, None])
        scores = jnp.where(mask, scores, -2.0e38)
        p_attn = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhsl,blr->bshr", p_attn.astype(ck.dtype), ck)
        out = jnp.einsum("bshr,rhd->bshd", ctx, params["w_uv"])

    return jnp.einsum("bshk,hkd->bsd", out, params["wo"], preferred_element_type=x.dtype), new_cache
