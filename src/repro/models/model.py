"""Top-level model: embeddings + backbone + head, with train/serve entry points.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions fit
for jit/lowering:

  init(key)                               -> params
  param_specs()                           -> logical-axis pytree (sharding.py)
  loss(params, batch)                     -> (scalar, aux)        [train_4k]
  prefill(params, tokens, ...)            -> (logits, caches)     [prefill_32k]
  decode_step(params, tokens, pos, caches)-> (logits, caches)     [decode/long]
  init_caches(batch, max_len)             -> cache pytree

Modality frontends are stubs per the assignment: audio (whisper) consumes
precomputed frame embeddings [B, enc_seq, D]; vlm consumes precomputed patch
embeddings [B, n_prefix, D] which overwrite the first ``n_prefix`` token
embeddings. MTP (deepseek-v3) adds one extra scanned-style block applied to
(h_t, emb(t+1)) predicting token t+2, averaged into the loss.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.distributed.sharding import constrain
from repro.models import backbone as bb
from repro.models import layers as L

Batch = dict[str, jax.Array]


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None):
    """logits: [B,S,V] fp32; labels: [B,S] int32. Mean NLL over valid tokens."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    moe_impl: str = "local"  # "local" | "sharded"
    mesh: Any = None
    loss_chunk: int = 0  # >0: blockwise CE over seq chunks (never materialize
    #                      full [B,S,V] logits — §Perf memory iteration B2)

    # -- construction -------------------------------------------------------
    def _stack(self) -> bb.Stack:
        return bb.Stack(self.cfg, cross=self.cfg.n_enc_layers > 0)

    def _enc_stack(self) -> bb.Stack | None:
        if not self.cfg.n_enc_layers:
            return None
        enc_cfg = dataclasses.replace(
            self.cfg,
            period=(BlockSpec(kind="attn", ffn="dense"),),
            n_periods=self.cfg.n_enc_layers,
            prefix_layers=(),
            remainder=(),
        )
        return bb.Stack(enc_cfg, cross=False)

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = L.dt(cfg.param_dtype)
        k_emb, k_stack, k_enc, k_mtp = jax.random.split(key, 4)
        params: dict[str, Any] = {
            "embedding": L.init_embedding(k_emb, cfg.vocab, cfg.d_model, dtype),
            "stack": self._stack().init(k_stack, dtype),
            "final_norm": L.init_rmsnorm(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = L.init_embedding(
                jax.random.fold_in(k_emb, 1), cfg.vocab, cfg.d_model, dtype
            )
        enc = self._enc_stack()
        if enc is not None:
            params["encoder"] = enc.init(k_enc, dtype)
            params["enc_norm"] = L.init_rmsnorm(cfg.d_model)
        if cfg.mtp_depth:
            params["mtp"] = {
                "proj": jax.random.normal(k_mtp, (2 * cfg.d_model, cfg.d_model), dtype)
                * (2 * cfg.d_model) ** -0.5,
                "block": bb.init_block(
                    jax.random.fold_in(k_mtp, 1), BlockSpec(kind="attn"), cfg, dtype
                ),
                "norm_h": L.init_rmsnorm(cfg.d_model),
                "norm_e": L.init_rmsnorm(cfg.d_model),
            }
        return params

    def param_specs(self) -> dict:
        cfg = self.cfg
        tree: dict[str, Any] = {
            # untied input tables shard embed (gather-local); tied tables keep
            # vocab sharding for the dominant unembed matmul
            "embedding": L.embedding_spec(for_input=not cfg.tie_embeddings),
            "stack": self._stack().spec(),
            "final_norm": L.rmsnorm_spec(),
        }
        if not cfg.tie_embeddings:
            tree["unembed"] = L.embedding_spec()
        enc = self._enc_stack()
        if enc is not None:
            tree["encoder"] = enc.spec()
            tree["enc_norm"] = L.rmsnorm_spec()
        if cfg.mtp_depth:
            tree["mtp"] = {
                "proj": ("embed", "embed_out"),
                "block": bb.block_spec_tree(BlockSpec(kind="attn"), cfg),
                "norm_h": L.rmsnorm_spec(),
                "norm_e": L.rmsnorm_spec(),
            }
        return tree

    # -- pieces --------------------------------------------------------------
    def _embed(self, params, tokens, batch: Batch | None = None) -> jax.Array:
        cfg = self.cfg
        x = L.embed(params["embedding"], tokens, cfg.scale_embeddings, cfg.d_model)
        if cfg.frontend == "vision_stub" and batch is not None and "prefix_embeddings" in batch:
            n = cfg.n_prefix_embeddings
            pre = batch["prefix_embeddings"].astype(x.dtype)
            x = jnp.concatenate([pre, x[:, n:]], axis=1)
        return x

    def _encode(self, params, frames: jax.Array) -> jax.Array:
        """Audio stub frontend: frames are precomputed embeddings [B, T, D]."""
        enc = self._enc_stack()
        pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1], dtype=jnp.int32)[None], frames.shape[:2]
        )
        h, _, _ = enc.apply(params["encoder"], frames.astype(L.dt(self.cfg.compute_dtype)), pos)
        return L.rmsnorm(params["enc_norm"], h, self.cfg.norm_eps)

    def _unembed(self, params, h) -> jax.Array:
        table = params["embedding"] if self.cfg.tie_embeddings else params["unembed"]
        logits = L.unembed(table, h, self.cfg.final_softcap)
        return constrain(logits, ("batch", None, "model"))

    def forward(
        self,
        params: dict,
        tokens: jax.Array,
        positions: jax.Array,
        caches: dict | None = None,
        batch: Batch | None = None,
    ):
        cfg = self.cfg
        x = self._embed(params, tokens, batch).astype(L.dt(cfg.compute_dtype))
        x = constrain(x, ("batch", None, None))
        enc_out = None
        if cfg.n_enc_layers and batch is not None and "frames" in batch:
            enc_out = self._encode(params, batch["frames"])
        elif caches is not None and caches.get("enc_out") is not None:
            enc_out = caches["enc_out"]
        stack_caches = caches["stack"] if caches is not None else None
        h, new_stack_caches, aux = self._stack().apply(
            params["stack"], x, positions, stack_caches, enc_out,
            moe_impl=self.moe_impl, mesh=self.mesh,
        )
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        new_caches = None
        if caches is not None:
            new_caches = dict(caches)
            new_caches["stack"] = new_stack_caches
            if enc_out is not None:
                new_caches["enc_out"] = enc_out
        return h, new_caches, aux

    # -- entry points -----------------------------------------------------------
    def loss(self, params: dict, batch: Batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        pos = jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
        )
        h, _, aux = self.forward(params, tokens, pos, None, batch)
        mask = batch.get("mask")
        if self.loss_chunk and tokens.shape[1] % self.loss_chunk == 0:
            total = self._chunked_ce(params, h, labels, mask)
        else:
            logits = self._unembed(params, h)
            total = cross_entropy(logits, labels, mask)
        if cfg.mtp_depth:
            total = total + 0.3 * self._mtp_loss(params, h, tokens, labels, pos)
        if cfg.n_experts and not cfg.router_aux_free:
            # switch-style aux loss on the mean load imbalance
            load = aux.get("moe_load")
            if load is not None:
                frac = load / jnp.maximum(load.sum(), 1.0)
                total = total + 1e-2 * cfg.n_experts * jnp.sum(frac * frac)
        aux["loss"] = total
        return total, aux

    def _chunked_ce(self, params, h, labels, mask):
        """CE via scan over sequence chunks: peak logits memory drops from
        [B, S, V] to [B, chunk, V] (backward recomputes per chunk)."""
        b, s, d = h.shape
        c = self.loss_chunk
        nc = s // c
        h_c = jnp.moveaxis(h.reshape(b, nc, c, d), 1, 0)
        y_c = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
        m_c = (
            jnp.moveaxis(mask.reshape(b, nc, c), 1, 0)
            if mask is not None
            else jnp.ones((nc, b, c), jnp.float32)
        )

        def body(acc, xs):
            hh, yy, mm = xs
            logits = self._unembed(params, hh)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, yy[..., None], axis=-1)[..., 0]
            nll = (lse - gold) * mm.astype(lse.dtype)
            return (acc[0] + nll.sum(), acc[1] + mm.sum()), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (h_c, y_c, m_c))
        return tot / jnp.maximum(cnt, 1.0)

    def _mtp_loss(self, params, h, tokens, labels, pos):
        """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1})."""
        cfg = self.cfg
        emb_next = self._embed(params, jnp.roll(tokens, -1, axis=1)).astype(h.dtype)
        merged = jnp.concatenate(
            [
                L.rmsnorm(params["mtp"]["norm_h"], h, cfg.norm_eps),
                L.rmsnorm(params["mtp"]["norm_e"], emb_next, cfg.norm_eps),
            ],
            axis=-1,
        )
        hm = jnp.einsum("bsd,de->bse", merged, params["mtp"]["proj"])
        hm, _, _ = bb.apply_block(
            params["mtp"]["block"], BlockSpec(kind="attn"), cfg, hm, pos, None
        )
        logits = self._unembed(params, hm)
        mtp_labels = jnp.roll(labels, -1, axis=1)
        mask = jnp.ones_like(labels, jnp.float32).at[:, -2:].set(0.0)
        return cross_entropy(logits, mtp_labels, mask)

    def init_caches(self, batch: int, max_len: int, dtype=None) -> dict:
        dtype = dtype or L.dt(self.cfg.param_dtype)
        caches: dict[str, Any] = {"stack": self._stack().init_caches(batch, max_len, dtype)}
        if self.cfg.n_enc_layers:
            caches["enc_out"] = jnp.zeros(
                (batch, self.cfg.enc_seq, self.cfg.d_model), dtype
            )
        return caches

    def prefill(self, params: dict, tokens: jax.Array, caches: dict, batch: Batch | None = None):
        pos = jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32)[None], tokens.shape
        )
        h, caches, _ = self.forward(params, tokens, pos, caches, batch)
        logits = self._unembed(params, h[:, -1:])
        return logits, caches

    def decode_step(self, params: dict, tokens: jax.Array, pos: jax.Array, caches: dict):
        """tokens: [B, 1]; pos: [B, 1] absolute positions."""
        h, caches, _ = self.forward(params, tokens, pos, caches)
        logits = self._unembed(params, h)
        return logits, caches


def build_model(cfg: ModelConfig, moe_impl: str = "local", mesh=None,
                loss_chunk: int = 0) -> Model:
    return Model(cfg=cfg, moe_impl=moe_impl, mesh=mesh, loss_chunk=loss_chunk)
