"""Shared primitive layers: norms, RoPE, embeddings, gated MLP.

Module convention (whole models/ package): every layer is a pair of pure
functions —

    init_<layer>(key, cfg, ...) -> params        (pytree of jnp arrays)
    <layer>(params, x, ...)     -> y

plus ``<layer>_spec(cfg) -> pytree`` of *logical axis* tuples mirroring the
params tree (consumed by distributed/sharding.py). No flax — params are plain
dicts so checkpointing, resharding, and dry-run eval_shape stay trivial.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------
DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def dt(name: str):
    return DTYPES[name]


# ---------------------------------------------------------------------------
# RMSNorm (LLaMA-style; gemma variant adds 1.0 to the scale)
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm_spec() -> dict:
    return {"scale": ("embed",)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(orig)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] int32. Rotates pairs (split-half)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embedding_spec(for_input: bool = False) -> dict:
    # input tables shard the EMBED dim: token gathers then stay local per
    # shard; vocab-sharded tables would be all-gathered for every lookup.
    # Output (unembed) tables shard VOCAB for the logits matmul.
    return {"table": ("vocab_in", "embed") if for_input else ("vocab", "embed")}


def embed(params: dict, tokens: jax.Array, scale: bool, d_model: int) -> jax.Array:
    x = jnp.take(params["table"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(d_model**0.5, x.dtype)
    return x


def unembed(params: dict, x: jax.Array, softcap: float | None = None) -> jax.Array:
    logits = jnp.einsum("bsd,vd->bsv", x, params["table"]).astype(jnp.float32)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = d**-0.5, d_ff**-0.5
    return {
        "wi_gate": jax.random.normal(k1, (d, d_ff), dtype) * s_in,
        "wi_up": jax.random.normal(k2, (d, d_ff), dtype) * s_in,
        "wo": jax.random.normal(k3, (d_ff, d), dtype) * s_out,
    }


def mlp_spec() -> dict:
    return {
        "wi_gate": ("embed", "mlp"),
        "wi_up": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
    }


def mlp(params: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"])
    up = jnp.einsum("bsd,df->bsf", x, params["wi_up"])
    gate = jax.nn.gelu(gate) if act == "gelu" else jax.nn.silu(gate)
    # keep the row-parallel partial sums in the input dtype: GSPMD otherwise
    # promotes the cross-shard reduction to f32 (2x collective bytes, §Perf C2)
    return jnp.einsum("bsf,fd->bsd", gate * up, params["wo"],
                      preferred_element_type=x.dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
