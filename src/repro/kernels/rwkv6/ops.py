"""jit'd wrapper for the RWKV6 chunk kernel (platform dispatch)."""

from __future__ import annotations

import jax

from repro.kernels.rwkv6.ref import rwkv6_chunk_ref
from repro.kernels.rwkv6.rwkv6 import rwkv6_chunk_pallas


def rwkv6_chunk(r, k, v, log_w, u, s0, force_kernel: bool = False):
    platform = jax.default_backend()
    if platform == "tpu":
        return rwkv6_chunk_pallas(r, k, v, log_w, u, s0, interpret=False)
    if force_kernel:
        return rwkv6_chunk_pallas(r, k, v, log_w, u, s0, interpret=True)
    return rwkv6_chunk_ref(r, k, v, log_w, u, s0)
