"""Pallas TPU kernel: one RWKV6 chunk step (chunked WKV linear attention).

Grid over (batch, head); the whole chunk for one head lives in VMEM:

  r/k/v/log_w tiles [T, P], state [P, P], pairwise decay plane [T, T, P].

T = P = 64 default -> the decay plane is 1 MB fp32, the three matmuls
( a_mat = (r*decay) @ k^T contracted per-p, y = a_mat @ v, state update
(k*tail)^T @ v ) are MXU-shaped. All decay exponents are <= 0 by
construction (cumulated log w < 0), so no max-subtraction pass is needed —
this is the TPU-friendly property the chunking was chosen for (DESIGN.md §8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rwkv6_chunk_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s1_ref):
    # blocks: r/k/v/w [1, T, 1, P]; u [1, P]; s0 [1, 1, P, P]
    r = r_ref[0, :, 0, :].astype(jnp.float32)  # [T, P]
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    lw = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)  # [P]
    s0 = s0_ref[0, 0].astype(jnp.float32)  # [P, P]
    t, p = r.shape

    cum = jnp.cumsum(lw, axis=0)  # [T, P]
    cum_prev = cum - lw
    # pairwise decay exp(cum_prev[t] - cum[i]) masked to i < t  (<= 1)
    diff = cum_prev[:, None, :] - cum[None, :, :]  # [T, T, P]
    ti = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    strict = ti > tj
    decay = jnp.where(strict[:, :, None], jnp.exp(diff), 0.0)

    # a_mat[t, i] = sum_p r[t,p] * decay[t,i,p] * k[i,p]
    rk = r[:, None, :] * decay * k[None, :, :]  # [T, T, P]
    a_mat = jnp.sum(rk, axis=2)  # [T, T]
    y = jnp.dot(a_mat, v, preferred_element_type=jnp.float32)  # [T, P]
    # diagonal bonus
    diag = jnp.sum(r * u[None, :] * k, axis=1)  # [T]
    y = y + diag[:, None] * v
    # carry-in read
    y = y + jnp.dot(r * jnp.exp(cum_prev), s0, preferred_element_type=jnp.float32)
    # state update
    tail = jnp.exp(cum[-1:, :] - cum)  # [T, P]
    s1 = s0 * jnp.exp(cum[-1])[:, None] + jnp.dot(
        (k * tail).T, v, preferred_element_type=jnp.float32
    )

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    s1_ref[0, 0] = s1.astype(s1_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_chunk_pallas(r, k, v, log_w, u, s0, interpret: bool = True):
    """r/k/v/log_w: [B, T, H, P]; u: [H, P]; s0: [B, H, P, P]."""
    b, t, h, p = r.shape
    grid = (b, h)
    tile = pl.BlockSpec((1, t, 1, p), lambda i, j: (i, 0, j, 0))
    y, s1 = pl.pallas_call(
        _rwkv6_chunk_kernel,
        grid=grid,
        in_specs=[
            tile,
            tile,
            tile,
            tile,
            pl.BlockSpec((1, p), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1, p, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            tile,
            pl.BlockSpec((1, 1, p, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, p, p), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, log_w, u, s0)
    return y, s1
