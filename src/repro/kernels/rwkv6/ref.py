"""Pure-jnp oracle for one RWKV6 chunk step (re-export of the model's ref).

Kept as a separate module so the kernel test sweep depends only on
kernels/rwkv6, mirroring the cam_match layout.
"""

from repro.models.rwkv import rwkv6_chunk_ref

__all__ = ["rwkv6_chunk_ref"]
