"""Fused time-wheel fabric delivery (DESIGN.md §14).

The fabric backend's fast path: static per-SRAM-entry routing tables, a
carried ring buffer indexed by a write cursor instead of the dense
``advance_inflight`` shift, and a Pallas kernel fusing the ring update with
the stage-2 CAM match for slot-0 arrivals. ``ops.fabric_deliver_ring`` is
the entry point; ``ref.fabric_deliver_ring_ref`` is the roll-equivalent
oracle built from the production two_stage functions.
"""
