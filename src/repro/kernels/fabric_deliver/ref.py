"""Reference ring-step oracle built from the production roll-path primitives.

``fabric_deliver_ring_ref`` runs the *same* pipeline as the roll-based
``FabricBackend.deliver_fabric`` — ``compact_events`` →
``stage1_route_events_fabric`` → stage-2 CAM match — but addresses the
scatter as a time-wheel (``cursor`` passed through to stage 1) and carries
the full ``[max_delay + 1]``-slot ring instead of the shifted tail. It is
the bridge the property suite uses to prove the fast path
(kernels/fabric_deliver/ops.py) equivalent to the roll path: the ref shares
its *semantics* with the roll (identical arbitration/drop/stats code) and
its *carry contract* with the fast path (ring + cursor), so

    roll == ref  locks the wheel addressing,
    ref == ops   locks the static entry table + prefix-count arbitration.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dispatch import DeliveryStats
from repro.core.two_stage import (
    compact_events,
    stage1_route_events_fabric,
    stage2_cam_match,
)

__all__ = ["fabric_deliver_ring_ref"]


def fabric_deliver_ring_ref(
    spikes: jax.Array,  # [..., N]
    src_tag: jax.Array,  # [N, E]
    src_dest: jax.Array,  # [N, E]
    cam_tag: jax.Array,  # [N, S]
    cam_syn: jax.Array,  # [N, S]
    cluster_size: int,
    k_tags: int,
    ring: jax.Array,  # [..., max_delay + 1, nc, K]
    cursor: jax.Array,  # int32 scalar
    *,
    cluster_tile: jax.Array,  # [nc]
    delay_steps: jax.Array,  # [nc, nc]
    n_tiles: int,
    max_delay: int,
    link_capacity: int | None,
    queue_capacity: int | None = None,
    external_activity: jax.Array | None = None,
    syn_onehot: jax.Array | None = None,
    mesh_hops: jax.Array | None = None,
    latency_s: jax.Array | None = None,
    energy_j: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, DeliveryStats]:
    """One ring-carried fabric step: ``(drive, ring, cursor, DeliveryStats)``."""
    n = spikes.shape[-1]
    n_clusters = n // cluster_size
    d1 = max_delay + 1
    cursor = jnp.asarray(cursor, jnp.int32)
    capacity = n if queue_capacity is None else queue_capacity
    queue = compact_events(spikes, capacity)
    route = stage1_route_events_fabric(
        queue,
        src_tag,
        src_dest,
        n_clusters,
        k_tags,
        cluster_size,
        cluster_tile,
        delay_steps,
        n_tiles,
        max_delay,
        link_capacity,
        mesh_hops=mesh_hops,
        latency_s=latency_s,
        energy_j=energy_j,
        cursor=cursor,
    )
    ring = ring + route.buffer
    ax = ring.ndim - 3
    a = jnp.take(ring, cursor, axis=ax)
    ring = jax.lax.dynamic_update_index_in_dim(ring, jnp.zeros_like(a), cursor, ax)
    if external_activity is not None:
        a = a + external_activity
    drive = stage2_cam_match(a, cam_tag, cam_syn, cluster_size, syn_onehot)
    stats = DeliveryStats(
        dropped=queue.dropped,
        link_dropped=route.link_dropped,
        delivered=route.delivered,
        hops=route.hops,
        latency_s=route.latency_s,
        energy_j=route.energy_j,
    )
    return drive, ring, (cursor + 1) % d1, stats
