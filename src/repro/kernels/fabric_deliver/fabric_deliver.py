"""Pallas TPU kernel: fused time-wheel fabric delivery (DESIGN.md §14).

The jnp fabric path updates the carried ring buffer in HBM, reads the
arrival slot back, and hands it to the stage-2 CAM match — the arrival
activity row makes an HBM round-trip between the ring update and the match.
This kernel fuses the three per (batch, cluster) grid step:

  1. the ring *column* ``ring[b, :, c, :]`` ([max_delay + 1, K]) is pulled
     into VMEM and the step's surviving events are scatter-added into it via
     the one-hot compare-plane matmul idiom of kernels/fused_deliver — one
     plane per delay slot, events pre-addressed as flat ring targets
     ``slot * (nc * K) + dst * K + tag`` (slot already cursor-rotated);
  2. the cursor row (slot-0 arrivals) is captured — carried events + this
     step's zero-delay events + external input — into a VMEM scratch row
     that never round-trips HBM, and zeroed in the outgoing ring column
     (read-then-clear, the time-wheel pop);
  3. the neuron tiles of cluster ``c`` CAM-match the VMEM-resident row
     (identical to kernels/fused_deliver stage 2).

Arbitration (per-directed-link FIFOs) and queue admission happen *outside*
in O(events) masked prefix sums (kernels/fabric_deliver/ops.py) — they are
cheap, shared with the jnp fast path, and produce the masked event weights
this kernel consumes (weight 0 = not delivered).

Grid ``(B, n_clusters, neuron-tile)``; TPU grids execute sequentially with
the last dimension minor, so the scratch row built at tile ``j == 0``
persists for the (batch, cluster) pair's remaining neuron tiles, and the
ring column written once at ``j == 0`` is flushed when the block changes.

VMEM sizing: the compare plane is chunked to ``ev_chunk * K`` floats under
``_PLANE_BUDGET_ELEMS`` (one plane per delay slot is built at a time); the
resident ring column adds ``(max_delay + 1) * K`` floats and the scratch
row ``K`` — small next to the plane budget for any realistic ``max_delay``.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_SYN_TYPES = 4

# compare-plane budget: ev_chunk * K floats kept under ~2 MB of VMEM
_PLANE_BUDGET_ELEMS = 512 * 1024


def _fabric_deliver_kernel(
    cur_ref,  # SMEM [1, 1] int32 — the time-wheel write cursor
    ev_flat_ref,  # [1, Mp] int32 — flat ring target per entry (-1 = pad)
    ev_w_ref,  # [1, Mp] — masked event weight (0 = dropped/silent/pad)
    ext_ref,  # [1, 1, K] — external input activity for this (batch, cluster)
    ring_ref,  # [1, D1, 1, K] — carried ring column of this (batch, cluster)
    tag_ref,  # [1, Cb, S] — CAM tags of the neuron tile (batch-shared)
    syn_ref,  # [1, Cb, S] — synapse types of the neuron tile
    out_ref,  # [1, 1, Cb, 4] — per-type synaptic drive
    ring_out_ref,  # [1, D1, 1, K] — updated ring column (cursor row zeroed)
    act_ref,  # VMEM scratch [1, K] — this (batch, cluster)'s arrival row
    *,
    k_tags: int,
    n_clusters: int,
    d1: int,  # max_delay + 1 ring slots
    ev_chunk: int,
):
    c = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _update_ring_column():
        cur = cur_ref[0, 0]
        mp = ev_flat_ref.shape[1]

        def chunk_body(i, col):
            f = ev_flat_ref[0, pl.ds(i * ev_chunk, ev_chunk)]  # [ev_chunk]
            w = ev_w_ref[0, pl.ds(i * ev_chunk, ev_chunk)]
            rows = []
            for d in range(d1):  # static, small: one compare plane per slot
                base = (d * n_clusters + c) * k_tags
                kk = (
                    jax.lax.broadcasted_iota(jnp.int32, (ev_chunk, k_tags), 1)
                    + base
                )
                match = (f[:, None] == kk).astype(jnp.float32)
                rows.append(
                    jax.lax.dot_general(
                        w.reshape(1, ev_chunk).astype(jnp.float32),
                        match,
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32,
                    )
                )  # [1, K]
            return col + jnp.concatenate(rows, axis=0)  # [D1, K]

        col = jax.lax.fori_loop(
            0, mp // ev_chunk, chunk_body, ring_ref[0, :, 0, :].astype(jnp.float32)
        )
        # pop the cursor slot: arrivals = carried + zero-delay + external,
        # then clear the row so the wheel can reuse it next revolution
        sel = jax.lax.broadcasted_iota(jnp.int32, (d1, k_tags), 0) == cur
        arrivals = jnp.sum(jnp.where(sel, col, 0.0), axis=0)  # [K]
        act_ref[0, :] = (arrivals + ext_ref[0, 0, :].astype(jnp.float32)).astype(
            act_ref.dtype
        )
        ring_out_ref[0, :, 0, :] = jnp.where(sel, 0.0, col).astype(
            ring_out_ref.dtype
        )

    # stage 2: CAM match of the VMEM-resident arrival row (kernels/fused_deliver)
    a = act_ref[0, :]  # [K]
    tags = tag_ref[0]  # [Cb, S] int32
    syn = syn_ref[0]  # [Cb, S] int32
    cb, s = tags.shape

    valid = tags >= 0
    kk = jax.lax.broadcasted_iota(jnp.int32, (cb, s, k_tags), 2)
    match = (tags[:, :, None] == kk).astype(a.dtype)
    vals = jax.lax.dot_general(
        match.reshape(cb * s, k_tags),
        a.reshape(k_tags, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(cb, s)
    vals = jnp.where(valid, vals, 0.0)
    tt = jax.lax.broadcasted_iota(jnp.int32, (cb, s, N_SYN_TYPES), 2)
    syn1h = (syn[:, :, None] == tt).astype(vals.dtype)
    drive = jax.lax.dot_general(
        vals.reshape(cb, 1, s),
        syn1h,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(cb, N_SYN_TYPES)
    out_ref[0, 0] = drive.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("cluster_size", "k_tags", "max_delay", "block_c", "interpret"),
)
def fabric_deliver_ring_pallas(
    ev_flat: jax.Array,  # [M] int32 flat ring targets (cursor-rotated), -1 pad
    ev_w: jax.Array,  # [..., M] masked event weights (0 = not delivered)
    ring: jax.Array,  # [..., max_delay + 1, n_clusters, K] carried ring
    cursor: jax.Array,  # int32 scalar write cursor
    external_activity: jax.Array,  # [..., n_clusters, K]
    cam_tag: jax.Array,  # [N, S]
    cam_syn: jax.Array,  # [N, S]
    cluster_size: int,
    k_tags: int,
    max_delay: int,
    block_c: int = 16,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:  # (drive [..., N, 4], new ring)
    n, s = cam_tag.shape
    n_clusters = n // cluster_size
    k = k_tags
    d1 = max_delay + 1
    batch_shape = ev_w.shape[:-1]
    b = math.prod(batch_shape)
    block_c = min(block_c, cluster_size)
    assert cluster_size % block_c == 0, (cluster_size, block_c)
    dtype = ev_w.dtype

    ev_w2 = ev_w.reshape(b, -1)
    m = ev_w2.shape[1]
    # chunk the compare plane to a fixed VMEM budget; pad M up so the chunks
    # tile it exactly (padding entries are -1/0 = no-ops)
    ev_chunk = max(1, min(m, _PLANE_BUDGET_ELEMS // max(1, k)))
    m_pad = -(-m // ev_chunk) * ev_chunk
    ev_flat2 = ev_flat.reshape(1, m)
    if m_pad != m:
        ev_flat2 = jnp.pad(ev_flat2, ((0, 0), (0, m_pad - m)), constant_values=-1)
        ev_w2 = jnp.pad(ev_w2, ((0, 0), (0, m_pad - m)))

    ring2 = ring.reshape(b, d1, n_clusters, k)
    ext3 = jnp.broadcast_to(
        external_activity, (*batch_shape, n_clusters, k)
    ).reshape(b, n_clusters, k).astype(dtype)
    tags3 = cam_tag.reshape(n_clusters, cluster_size, s)
    syn3 = cam_syn.reshape(n_clusters, cluster_size, s)
    cur2 = jnp.asarray(cursor, jnp.int32).reshape(1, 1)
    grid = (b, n_clusters, cluster_size // block_c)

    drive, new_ring = pl.pallas_call(
        functools.partial(
            _fabric_deliver_kernel,
            k_tags=k,
            n_clusters=n_clusters,
            d1=d1,
            ev_chunk=ev_chunk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, i, j: (0, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, m_pad), lambda bi, i, j: (0, 0)),
            pl.BlockSpec((1, m_pad), lambda bi, i, j: (bi, 0)),
            pl.BlockSpec((1, 1, k), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, d1, 1, k), lambda bi, i, j: (bi, 0, i, 0)),
            pl.BlockSpec((1, block_c, s), lambda bi, i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, s), lambda bi, i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_c, N_SYN_TYPES), lambda bi, i, j: (bi, i, j, 0)),
            pl.BlockSpec((1, d1, 1, k), lambda bi, i, j: (bi, 0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_clusters, cluster_size, N_SYN_TYPES), dtype),
            jax.ShapeDtypeStruct((b, d1, n_clusters, k), ring.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, k), dtype)],
        interpret=interpret,
    )(cur2, ev_flat2, ev_w2, ext3, ring2, tags3, syn3)
    return (
        drive.reshape(*batch_shape, n, N_SYN_TYPES),
        new_ring.reshape(*batch_shape, d1, n_clusters, k)
        if batch_shape
        else new_ring.reshape(d1, n_clusters, k),
    )
