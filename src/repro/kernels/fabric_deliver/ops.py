"""Time-wheel fabric delivery: static entry tables + the ring fast path.

The roll-based fabric step (core/dispatch.py ``deliver_fabric``) re-derives
every event's route *per step*: gather the queued sources' SRAM rows, bin by
tile pair, argsort-arbitrate the link FIFOs, gather four ``[nc, nc]`` stats
matrices, then concat-shift the whole delay-line buffer. All of that is a
function of the *routing tables*, which never change at run time.

:func:`build_fabric_entries` hoists it to engine construction: one host-side
pass enumerates the ``M`` occupied SRAM entries and precomputes, per entry,
the flat destination address, arrival delay, directed-link bin and the
Table II-IV per-event figures — statically sorted in **arbitration order**
``(link, src, entry)``, which is exactly the order the per-step
``dispatch_slots`` argsort would produce (queue slots ascend by source id,
entries by index). Per step, delivery is then event-count-proportional:

  * queue admission  = one masked prefix count over the spike vector
    (bit-identical to ``compact_events`` truncation: first ``capacity``
    active sources, lowest id first);
  * link arbitration = one masked prefix count over the entry axis — the
    in-link FIFO position of an active cross-tile entry is the number of
    active cross-tile entries before it in its statically-sorted link
    group, no sort at run time (bit-identical keep set);
  * delay scatter    = one scatter-add of masked weights at
    ``(cursor + delay) % (max_delay + 1)`` into the carried ring — the
    time-wheel replacing the dense ``advance_inflight`` shift;
  * stats            = masked sums of the static per-entry columns
    (integer stats bit-identical; float latency/energy sums may associate
    differently than the roll path's gather — same addends).

:func:`fabric_deliver_ring` follows the kernels platform policy: the fused
Pallas kernel (fabric_deliver.py) on TPU, the jnp ring update + stage-2
reference elsewhere; ``interpret=True`` forces the kernel in interpret mode
for CPU validation.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import DeliveryStats
from repro.core.two_stage import _accumulate_into, _scatter_count, stage2_cam_match
from repro.kernels.fabric_deliver.fabric_deliver import fabric_deliver_ring_pallas

__all__ = [
    "FabricEntries",
    "build_fabric_entries",
    "build_fabric_entries_slabs",
    "fabric_deliver_ring",
]


@dataclasses.dataclass(frozen=True)
class FabricEntries:
    """Static per-SRAM-entry routing table, sorted in arbitration order.

    One row per *occupied* SRAM entry (``src_tag >= 0``), statically
    lexsorted by ``(link, src, entry)`` — intra-tile entries (``link = -1``)
    first, then each directed link's group in the arbiter's scan order.
    ``link_start[m]`` is the index of row ``m``'s link-group start, so an
    active entry's FIFO position is a prefix-count difference. ``valid`` is
    ``False`` only on the single pad row of an entry-less table.
    """

    src: jax.Array  # [M] int32 source neuron id
    dstk: jax.Array  # [M] int32 flat dst_cluster * K + tag
    delay: jax.Array  # [M] int32 arrival delay in steps
    cross: jax.Array  # [M] bool inter-tile (link-arbitrated)
    link_start: jax.Array  # [M] int32 index of this entry's link-group start
    # flat directed tile pair src_tile * n_tiles + dst_tile for per-link
    # stats attribution (DESIGN.md §18); intra-tile entries carry the tile's
    # self-link diagonal. NOT the sort key — ordering still groups intra
    # entries first (see _entries_from_raw), so carries stay bit-identical.
    link: jax.Array  # [M] int32
    hops: jax.Array  # [M] int32 mesh hops (Table IV)
    latency_s: jax.Array  # [M] float32 per-event latency (Table II)
    energy_j: jax.Array  # [M] float32 per-event energy (Table III/IV)
    valid: jax.Array  # [M] bool
    # fault injection (DESIGN.md §15): a False entry is statically severed
    # (dead tile/link or Bernoulli route erasure) — its events always drop,
    # are counted in link_dropped, and never consume link-FIFO capacity
    alive: jax.Array  # [M] bool


jax.tree_util.register_dataclass(
    FabricEntries,
    data_fields=[
        "src", "dstk", "delay", "cross", "link_start", "link", "hops",
        "latency_s", "energy_j", "valid", "alive",
    ],
    meta_fields=[],
)


def build_fabric_entries(
    src_tag,  # [N, E] int32, -1 = empty (numpy or jax)
    src_dest,  # [N, E] int32 destination cluster ids
    cluster_size: int,
    k_tags: int,
    model,  # routing.FabricDeliveryModel
    entry_alive=None,  # [N, E] bool fault mask (faults.entry_alive_mask)
) -> FabricEntries:
    """Host-side precompute of the static entry table (numpy, once per engine).

    ``entry_alive`` (from :func:`repro.core.faults.entry_alive_mask`, or
    derived here from the model's fault matrices when omitted) statically
    severs faulted entries: they keep their table row — so the fault is
    *observable* as a per-step ``link_dropped`` count — but never deliver
    and never occupy link-FIFO capacity (a dead link has no FIFO).
    """
    src_tag = np.asarray(src_tag)
    src_dest = np.asarray(src_dest)
    tiles = np.asarray(model.tile_of_cluster)
    n_clusters = tiles.shape[0]
    if entry_alive is None and getattr(model, "pair_alive", None) is not None:
        from repro.core.faults import entry_alive_mask

        entry_alive = entry_alive_mask(src_tag, src_dest, cluster_size, model)
    src_ids, e_ids = np.nonzero(src_tag >= 0)
    if src_ids.size == 0:  # entry-less table: one inert pad row
        return _pad_entries()
    tag = src_tag[src_ids, e_ids].astype(np.int64)
    dst = np.clip(src_dest[src_ids, e_ids], 0, n_clusters - 1).astype(np.int64)
    alive = (
        None if entry_alive is None else np.asarray(entry_alive)[src_ids, e_ids]
    )
    return _entries_from_raw(
        src_ids, e_ids, tag, dst, cluster_size, k_tags, model, alive
    )


def _pad_entries() -> FabricEntries:
    """One inert pad row for an entry-less table."""
    z = np.zeros(1, np.int32)
    return FabricEntries(
        src=jnp.asarray(z), dstk=jnp.asarray(z), delay=jnp.asarray(z),
        cross=jnp.asarray(np.zeros(1, bool)), link_start=jnp.asarray(z),
        link=jnp.asarray(z),
        hops=jnp.asarray(z), latency_s=jnp.zeros(1, jnp.float32),
        energy_j=jnp.zeros(1, jnp.float32),
        valid=jnp.asarray(np.zeros(1, bool)),
        alive=jnp.asarray(np.ones(1, bool)),
    )


def _entries_from_raw(
    src_ids, e_ids, tag, dst, cluster_size, k_tags, model, alive
) -> FabricEntries:
    """Arbitration-order sort + static per-entry figures from raw entry rows.

    ``src_ids``/``e_ids`` must arrive in row-major table order (src asc,
    entry asc) — both the dense ``np.nonzero`` path and the slab
    concatenation produce exactly that, so the stable lexsort yields one
    canonical arbitration order regardless of how the rows were enumerated.
    """
    tiles = np.asarray(model.tile_of_cluster)
    src_cl = src_ids // cluster_size
    s_tile = tiles[src_cl]
    d_tile = tiles[dst]
    cross = s_tile != d_tile
    link = np.where(cross, s_tile * model.n_tiles + d_tile, -1)
    # stats attribution column: intra-tile entries map to the tile's
    # self-link diagonal (the sort key keeps -1 so ordering is unchanged)
    stat_link = np.where(cross, s_tile * model.n_tiles + d_tile,
                         s_tile * model.n_tiles + s_tile)
    # arbitration order: link groups, each scanned (src asc, entry asc) —
    # identical to dispatch_slots' stable argsort of queue-major event order
    order = np.lexsort((e_ids, src_ids, link))
    src_s, dst_s, tag_s = src_ids[order], dst[order], tag[order]
    cl_s, link_s, cross_s = src_cl[order], link[order], cross[order]
    stat_link_s = stat_link[order]
    alive_s = np.ones(src_s.size, bool) if alive is None else alive[order]
    m = src_s.size
    is_start = np.ones(m, bool)
    is_start[1:] = link_s[1:] != link_s[:-1]
    link_start = np.maximum.accumulate(np.where(is_start, np.arange(m), 0))
    return FabricEntries(
        src=jnp.asarray(src_s.astype(np.int32)),
        dstk=jnp.asarray((dst_s * k_tags + tag_s).astype(np.int32)),
        delay=jnp.asarray(np.asarray(model.delay_steps)[cl_s, dst_s].astype(np.int32)),
        cross=jnp.asarray(cross_s),
        link_start=jnp.asarray(link_start.astype(np.int32)),
        link=jnp.asarray(stat_link_s.astype(np.int32)),
        hops=jnp.asarray(np.asarray(model.mesh_hops)[cl_s, dst_s].astype(np.int32)),
        latency_s=jnp.asarray(
            np.asarray(model.latency_s)[cl_s, dst_s].astype(np.float32)
        ),
        energy_j=jnp.asarray(
            np.asarray(model.energy_j)[cl_s, dst_s].astype(np.float32)
        ),
        valid=jnp.asarray(np.ones(m, bool)),
        alive=jnp.asarray(alive_s),
    )


def build_fabric_entries_slabs(
    per_model,  # sequence of (src_tag_m [N_m, E_m], src_dest_m [N_m, E_m])
    cluster_size: int,
    k_tags: int,  # the COMBINED table's K (flat dstk addressing)
    model,  # routing.FabricDeliveryModel over the combined cluster count
) -> FabricEntries:
    """Entry table for N resident models as slab-offset concatenation.

    Builds the multi-model ring fast path's static table directly from the
    per-model slabs: each model's raw entry rows are rebased by its slab's
    neuron/cluster offsets (slabs are laid out back to back, in order), then
    a single global arbitration sort merges them — models share the physical
    link FIFOs, so each directed link's group interleaves every model's
    entries in source-id order. Bit-identical to :func:`build_fabric_entries`
    on the concatenated table (``tags.concat_tables``): slab enumeration
    yields the same row-major entry sequence, and the stable lexsort is
    order-canonical (the conformance test in tests/test_multimodel.py locks
    this).

    Fault masks are drawn over the full table grid, so a faulted ``model``
    must go through the concatenated-table path instead.
    """
    if getattr(model, "pair_alive", None) is not None:
        raise ValueError(
            "build_fabric_entries_slabs does not support fault injection — "
            "build from the concatenated tables (build_fabric_entries) so "
            "the route-erasure draw sees the full table grid"
        )
    srcs, ents, tags, dsts = [], [], [], []
    n0 = 0
    nc = np.asarray(model.tile_of_cluster).shape[0]
    for src_tag_m, src_dest_m in per_model:
        src_tag_m = np.asarray(src_tag_m)
        src_dest_m = np.asarray(src_dest_m)
        c0 = n0 // cluster_size
        s_m, e_m = np.nonzero(src_tag_m >= 0)
        srcs.append(s_m + n0)
        ents.append(e_m)
        tags.append(src_tag_m[s_m, e_m].astype(np.int64))
        dsts.append(
            np.clip(src_dest_m[s_m, e_m] + c0, 0, nc - 1).astype(np.int64)
        )
        n0 += src_tag_m.shape[0]
    src_ids = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    if src_ids.size == 0:
        return _pad_entries()
    return _entries_from_raw(
        src_ids,
        np.concatenate(ents),
        np.concatenate(tags),
        np.concatenate(dsts),
        cluster_size,
        k_tags,
        model,
        None,
    )


def _count_bins(mask, bins, size):
    """Per-bin counts of a ``[..., M]`` entry mask at static ``[M]`` bins."""
    return _scatter_count(
        mask[..., None], jnp.broadcast_to(bins[:, None], mask.shape + (1,)), size
    )


def _ring_update_jnp(
    ring, flat, w, cursor, external_activity, cam_tag, cam_syn, cluster_size,
    k_tags, d1, syn_onehot,
):
    """jnp fast path: scatter into the carried ring, pop the cursor slot."""
    batch_shape = w.shape[:-1]
    n_clusters = cam_tag.shape[0] // cluster_size
    size = d1 * n_clusters * k_tags
    b = math.prod(batch_shape) if batch_shape else 1
    buf = _accumulate_into(ring.reshape(b, size), flat, w.reshape(b, -1))
    ring = buf.reshape(*batch_shape, d1, n_clusters, k_tags)
    ax = ring.ndim - 3
    a = jnp.take(ring, cursor, axis=ax)
    ring = jax.lax.dynamic_update_index_in_dim(ring, jnp.zeros_like(a), cursor, ax)
    if external_activity is not None:
        a = a + external_activity
    drive = stage2_cam_match(a, cam_tag, cam_syn, cluster_size, syn_onehot)
    return drive, ring


def fabric_deliver_ring(
    spikes: jax.Array,  # [..., N]
    entries: FabricEntries,
    cam_tag: jax.Array,  # [N, S]
    cam_syn: jax.Array,  # [N, S]
    cluster_size: int,
    k_tags: int,
    ring: jax.Array,  # [..., max_delay + 1, n_clusters, K]
    cursor: jax.Array,  # int32 scalar
    *,
    max_delay: int,
    link_capacity: int | None,
    queue_capacity: int | None = None,
    external_activity: jax.Array | None = None,
    syn_onehot: jax.Array | None = None,
    block_c: int = 16,
    interpret: bool | None = None,
    per_link_stats: bool = False,
    n_tiles: int | None = None,  # required when per_link_stats
) -> tuple[jax.Array, jax.Array, jax.Array, DeliveryStats]:
    """One time-wheel fabric step: ``(drive, ring, cursor, DeliveryStats)``.

    Bit-identical arrival steps, drop counts and integer stats to the
    roll-based ``compact_events`` + ``stage1_route_events_fabric`` +
    ``advance_inflight`` pipeline (the ring property suite locks this);
    float latency/energy sums agree to reduction-order tolerance.

    ``per_link_stats`` widens ``link_dropped`` to per directed tile pair
    (``[..., n_tiles**2]``, fault drops of intra-tile entries on the
    diagonal) and ``delivered`` to per (src, dst) cluster pair
    (``[..., n_clusters**2]``) — same convention as the roll path, summing
    to exactly the scalar counters. The delivery itself (and hence the ring
    carry) is untouched: stats live outside the kernel.
    """
    n = spikes.shape[-1]
    n_clusters = n // cluster_size
    d1 = max_delay + 1
    cursor = jnp.asarray(cursor, jnp.int32)
    batch_shape = spikes.shape[:-1]

    # queue admission — compact_events truncation in mask form: the first
    # ``capacity`` active sources (ascending id = arbiter scan order) win
    active = spikes != 0
    cap = n if queue_capacity is None else min(int(queue_capacity), n)
    if cap >= n:
        in_q = active
        dropped = jnp.zeros(batch_shape, jnp.int32)
    else:
        pos = jnp.cumsum(active, axis=-1, dtype=jnp.int32)
        in_q = active & (pos <= cap)
        dropped = jnp.maximum(pos[..., -1] - cap, 0)

    act_all = jnp.take(in_q, entries.src, axis=-1) & entries.valid  # [..., M]
    # fault-severed entries (DESIGN.md §15) always drop — counted with the
    # link drops (a dead link is a zero-capacity link) — and never contend
    # for a live link's FIFO slots
    act_e = act_all & entries.alive
    fault_mask = act_all & ~entries.alive

    # per-directed-link FIFO arbitration without a sort: entries are already
    # in the arbiter's scan order, so an active cross-tile entry's FIFO
    # position is the count of active cross-tile entries since its link start
    if link_capacity is None:
        kept = act_e
        drop_mask = fault_mask
    else:
        cnt = (act_e & entries.cross).astype(jnp.int32)
        excl = jnp.cumsum(cnt, axis=-1) - cnt
        pos_in_link = excl - jnp.take(excl, entries.link_start, axis=-1)
        keep_cross = pos_in_link < link_capacity
        kept = act_e & (~entries.cross | keep_cross)
        # disjoint masks (alive vs severed), so the union's per-bin counts
        # sum to exactly the scalar fault + overflow totals
        drop_mask = fault_mask | (act_e & entries.cross & ~keep_cross)

    if per_link_stats:
        if n_tiles is None:
            raise ValueError("per_link_stats=True requires n_tiles")
        link_dropped = _count_bins(drop_mask, entries.link, n_tiles * n_tiles)
        pair = (entries.src // cluster_size) * n_clusters + entries.dstk // k_tags
        delivered = _count_bins(kept, pair, n_clusters * n_clusters)
    else:
        link_dropped = drop_mask.sum(-1, dtype=jnp.int32)
        delivered = kept.sum(-1, dtype=jnp.int32)

    stats = DeliveryStats(
        dropped=dropped,
        link_dropped=link_dropped,
        delivered=delivered,
        hops=jnp.where(kept, entries.hops, 0).sum(-1, dtype=jnp.int32),
        latency_s=jnp.where(kept, entries.latency_s, 0.0).sum(-1, dtype=jnp.float32),
        energy_j=jnp.where(kept, entries.energy_j, 0.0).sum(-1, dtype=jnp.float32),
    )

    # delay-indexed scatter targets on the wheel; dropped/silent entries
    # carry weight exactly 0 (their flat target stays in range — adding 0.0
    # is the no-op, so no sentinel slot is needed)
    w = jnp.take(spikes, entries.src, axis=-1) * kept.astype(spikes.dtype)
    slot = (cursor + entries.delay) % d1
    flat = slot * (n_clusters * k_tags) + entries.dstk  # [M], batch-shared

    if interpret is None and jax.default_backend() != "tpu":
        drive, ring = _ring_update_jnp(
            ring, flat, w, cursor, external_activity, cam_tag, cam_syn,
            cluster_size, k_tags, d1, syn_onehot,
        )
    else:
        if external_activity is None:
            external_activity = jnp.zeros(
                (*batch_shape, n_clusters, k_tags), w.dtype
            )
        drive, ring = fabric_deliver_ring_pallas(
            flat, w, ring, cursor, external_activity, cam_tag, cam_syn,
            cluster_size, k_tags, max_delay, block_c=block_c,
            interpret=bool(interpret),
        )
    return drive, ring, (cursor + 1) % d1, stats
