"""Fused stage-1 + stage-2 event delivery (kernel + ops + reference).

See fused_deliver.py for the kernel design and DESIGN.md §10 for the memory
layout. Most callers should go through the ``fused`` dispatch backend
(repro.core.dispatch) instead of importing from here directly.
"""

from repro.kernels.fused_deliver.fused_deliver import fused_deliver_pallas  # noqa: F401
from repro.kernels.fused_deliver.ops import fused_deliver  # noqa: F401
from repro.kernels.fused_deliver.ref import fused_deliver_ref  # noqa: F401
