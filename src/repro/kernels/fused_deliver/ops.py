"""Public jit'd wrapper for fused event-sparse delivery.

Chooses kernel vs reference by platform, mirroring kernels/cam_match/ops:
the fused Pallas kernel targets TPU; on CPU we default to the jnp
event-sparse oracle (queue-compacted stage 1 + indexed stage 2) and can
validate the kernel in interpret mode via ``interpret=True`` (slow).

Consumes an :class:`~repro.core.two_stage.EventQueue` — the SRAM gather for
queued events happens here (outside the kernel, where XLA fuses it with the
queue build) and the kernel receives pre-flattened ``(dest * K + tag)``
entries. Most callers should go through the ``fused`` dispatch backend
(repro.core.dispatch) instead of calling this directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.two_stage import EventQueue, gather_event_entries
from repro.kernels.fused_deliver.fused_deliver import fused_deliver_pallas
from repro.kernels.fused_deliver.ref import fused_deliver_ref


def _event_entries_flat(
    queue: EventQueue, src_tag: jax.Array, src_dest: jax.Array, k_tags: int
) -> tuple[jax.Array, jax.Array]:
    """Queue -> kernel inputs: flat ``dest*K + tag`` [..., Q*E] + weights."""
    ev_tag, ev_dest = gather_event_entries(queue, src_tag, src_dest)
    valid = ev_tag >= 0
    ev_flat = jnp.where(valid, ev_dest * k_tags + ev_tag, -1)
    ev_w = queue.weight[..., None] * valid.astype(queue.weight.dtype)
    batch_shape = queue.src.shape[:-1]
    return ev_flat.reshape(*batch_shape, -1), ev_w.reshape(*batch_shape, -1)


def fused_deliver(
    queue: EventQueue,
    src_tag: jax.Array,
    src_dest: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    k_tags: int,
    external_activity: jax.Array | None = None,
    syn_onehot: jax.Array | None = None,
    block_c: int = 16,
    interpret: bool | None = None,
) -> jax.Array:
    # same policy as PallasBackend: None = platform default (compiled kernel
    # on TPU, jnp reference elsewhere); True/False = force the kernel in
    # interpret/compiled mode regardless of platform.
    if interpret is None:
        if jax.default_backend() != "tpu":
            return fused_deliver_ref(
                queue, src_tag, src_dest, cam_tag, cam_syn, cluster_size, k_tags,
                external_activity=external_activity, syn_onehot=syn_onehot,
            )
        interpret = False
    ev_flat, ev_w = _event_entries_flat(queue, src_tag, src_dest, k_tags)
    n_clusters = src_tag.shape[0] // cluster_size
    if external_activity is None:
        external_activity = jnp.zeros(
            (*queue.src.shape[:-1], n_clusters, k_tags), ev_w.dtype
        )
    return fused_deliver_pallas(
        ev_flat, ev_w, cam_tag, cam_syn, external_activity, cluster_size, k_tags,
        block_c=block_c, interpret=interpret,
    )
