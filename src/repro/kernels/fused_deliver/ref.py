"""Pure-jnp oracle for fused event-sparse delivery (no Pallas).

Semantics: exactly stage-1-from-queue followed by stage-2 CAM match —

    A[c, k]     = sum_{queued events (src, w)} sum_e w * [src_dest[src,e]==c]
                                                       * [src_tag[src,e]==k]
    drive[n, t] = sum_s A[cluster_of(n), cam_tag[n, s]] * [cam_syn[n, s]==t]

The implementation IS ``core.two_stage.stage1_route_events`` +
``stage2_cam_match`` — one algorithm, composed here so kernel tests name
their oracle without caring where the production jnp path lives (and so the
two can never drift apart). This is also the CPU compute path of the
``fused`` dispatch backend (the Pallas kernel targets TPU).
"""

from __future__ import annotations

import jax

from repro.core.two_stage import (  # noqa: F401
    EventQueue,
    N_SYN_TYPES,
    stage1_route_events,
    stage2_cam_match,
)


def fused_deliver_ref(
    queue: EventQueue,  # src/weight [..., Q]
    src_tag: jax.Array,  # [N, E] int32, -1 empty
    src_dest: jax.Array,  # [N, E] int32
    cam_tag: jax.Array,  # [N, S] int32, -1 empty
    cam_syn: jax.Array,  # [N, S] int32 in [0, 4)
    cluster_size: int,
    k_tags: int,
    external_activity: jax.Array | None = None,  # [..., n_clusters, K]
    syn_onehot: jax.Array | None = None,  # [N, S, 4] per-table constant
) -> jax.Array:  # [..., N, 4]
    n = src_tag.shape[0]
    a = stage1_route_events(queue, src_tag, src_dest, n // cluster_size, k_tags)
    if external_activity is not None:
        a = a + external_activity
    return stage2_cam_match(a, cam_tag, cam_syn, cluster_size, syn_onehot)
