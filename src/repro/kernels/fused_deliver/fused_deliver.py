"""Pallas TPU kernel: fused stage-1 scatter + stage-2 CAM match.

The separate-stage pipeline writes the tag-activity matrix ``A[B, nc, K]``
to HBM after stage 1 and reads it straight back for stage 2. This kernel
fuses the two: for each (batch, cluster) grid step the activity *row* is
built in a VMEM scratch buffer directly from the queued events and consumed
by the CAM match before the grid moves on — ``A`` never exists in HBM.
That is the TPU transcription of the chip's datapath, where the R1 router
feeds the core's broadcast driver directly (no DRAM between fabric and CAM).

Inputs are the AER queue's SRAM entries, pre-gathered and flattened to
``ev_flat[B, QE]`` (``dest * K + tag`` per queued (event, SRAM-entry) pair,
``-1`` = empty) with matching weights ``ev_w[B, QE]`` — event count, not
network size, so QE = Q*E stays small at real sparsity levels.

Grid ``(B, n_clusters, neuron-tile)``; TPU grids execute sequentially with
the last dimension minor, so the row scratch built at tile ``j == 0`` of a
(batch, cluster) pair persists for that pair's remaining neuron tiles.

Stage 1 in-kernel uses the same MXU idiom as the CAM compare: a one-hot
compare plane ``(ev_flat == c*K + iota(K))`` contracted against the weights
— a scatter-free scatter-add. The plane is built over event chunks of
``ev_chunk`` so VMEM holds at most ``ev_chunk * K`` floats at once.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

N_SYN_TYPES = 4

# stage-1 compare-plane budget: ev_chunk * K floats kept under ~2 MB of VMEM
_PLANE_BUDGET_ELEMS = 512 * 1024


def _fused_deliver_kernel(
    ev_flat_ref,  # [1, QE] int32 — flat (dest*K + tag) per queued entry, -1 empty
    ev_w_ref,  # [1, QE] — event weight per entry (0 for empty)
    ext_ref,  # [1, 1, K] — external input activity for this (batch, cluster)
    tag_ref,  # [1, Cb, S] — CAM tags of the neuron tile (batch-shared)
    syn_ref,  # [1, Cb, S] — synapse types of the neuron tile
    out_ref,  # [1, 1, Cb, 4] — per-type synaptic drive
    act_ref,  # VMEM scratch [1, K] — this (batch, cluster)'s activity row
    *,
    k_tags: int,
    ev_chunk: int,
):
    c = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _build_activity_row():
        # stage 1 for (b, c): accumulate this cluster's K-row from the queue.
        base = c * k_tags
        qe = ev_flat_ref.shape[1]

        def chunk_body(i, acc):
            f = ev_flat_ref[0, pl.ds(i * ev_chunk, ev_chunk)]  # [ev_chunk]
            w = ev_w_ref[0, pl.ds(i * ev_chunk, ev_chunk)]
            kk = jax.lax.broadcasted_iota(jnp.int32, (ev_chunk, k_tags), 1) + base
            match = (f[:, None] == kk).astype(acc.dtype)  # [ev_chunk, K]
            return acc + jax.lax.dot_general(
                w.reshape(1, ev_chunk),
                match,
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )

        row = jax.lax.fori_loop(
            0, qe // ev_chunk, chunk_body, ext_ref[0].astype(jnp.float32)
        )
        act_ref[...] = row.astype(act_ref.dtype)

    # stage 2: CAM match of the VMEM-resident row against this neuron tile.
    a = act_ref[0, :]  # [K]
    tags = tag_ref[0]  # [Cb, S] int32
    syn = syn_ref[0]  # [Cb, S] int32
    cb, s = tags.shape

    valid = tags >= 0
    kk = jax.lax.broadcasted_iota(jnp.int32, (cb, s, k_tags), 2)
    match = (tags[:, :, None] == kk).astype(a.dtype)
    vals = jax.lax.dot_general(
        match.reshape(cb * s, k_tags),
        a.reshape(k_tags, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(cb, s)
    vals = jnp.where(valid, vals, 0.0)
    tt = jax.lax.broadcasted_iota(jnp.int32, (cb, s, N_SYN_TYPES), 2)
    syn1h = (syn[:, :, None] == tt).astype(vals.dtype)
    drive = jax.lax.dot_general(
        vals.reshape(cb, 1, s),
        syn1h,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(cb, N_SYN_TYPES)
    out_ref[0, 0] = drive.astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("cluster_size", "k_tags", "block_c", "interpret")
)
def fused_deliver_pallas(
    ev_flat: jax.Array,  # [..., QE] int32, -1 = empty entry
    ev_w: jax.Array,  # [..., QE] event weights (0 for empty)
    cam_tag: jax.Array,  # [N, S]
    cam_syn: jax.Array,  # [N, S]
    external_activity: jax.Array,  # [..., n_clusters, K]
    cluster_size: int,
    k_tags: int,
    block_c: int = 16,
    interpret: bool = True,
) -> jax.Array:  # [..., N, N_SYN_TYPES]
    n, s = cam_tag.shape
    n_clusters = n // cluster_size
    k = k_tags
    batch_shape = ev_flat.shape[:-1]
    b = math.prod(batch_shape)
    block_c = min(block_c, cluster_size)
    assert cluster_size % block_c == 0, (cluster_size, block_c)
    dtype = ev_w.dtype

    ev_flat2 = ev_flat.reshape(b, -1)
    ev_w2 = ev_w.reshape(b, -1)
    qe = ev_flat2.shape[1]
    # chunk the stage-1 compare plane to a fixed VMEM budget; pad QE up so
    # the chunks tile it exactly (padding entries are -1/0 = no-ops).
    ev_chunk = max(1, min(qe, _PLANE_BUDGET_ELEMS // max(1, k)))
    qe_pad = -(-qe // ev_chunk) * ev_chunk
    if qe_pad != qe:
        pad = ((0, 0), (0, qe_pad - qe))
        ev_flat2 = jnp.pad(ev_flat2, pad, constant_values=-1)
        ev_w2 = jnp.pad(ev_w2, pad)

    ext3 = jnp.broadcast_to(
        external_activity, (*batch_shape, n_clusters, k)
    ).reshape(b, n_clusters, k).astype(dtype)
    tags3 = cam_tag.reshape(n_clusters, cluster_size, s)
    syn3 = cam_syn.reshape(n_clusters, cluster_size, s)
    grid = (b, n_clusters, cluster_size // block_c)

    out = pl.pallas_call(
        functools.partial(_fused_deliver_kernel, k_tags=k, ev_chunk=ev_chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qe_pad), lambda bi, i, j: (bi, 0)),
            pl.BlockSpec((1, qe_pad), lambda bi, i, j: (bi, 0)),
            pl.BlockSpec((1, 1, k), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, block_c, s), lambda bi, i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, s), lambda bi, i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_c, N_SYN_TYPES), lambda bi, i, j: (bi, i, j, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_clusters, cluster_size, N_SYN_TYPES), dtype
        ),
        scratch_shapes=[pltpu.VMEM((1, k), dtype)],
        interpret=interpret,
    )(ev_flat2, ev_w2, ext3, tags3, syn3)
    return out.reshape(*batch_shape, n, N_SYN_TYPES)
