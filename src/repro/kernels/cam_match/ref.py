"""Pure-jnp oracle for the stage-2 CAM match (no Pallas).

Semantics (paper §III-B / §IV-B): for every neuron ``n`` in cluster ``c`` and
every CAM word ``s``:

    drive[n, t] = sum_s  activity[c, cam_tag[n, s]] * [cam_syn[n, s] == t]

with empty CAM words (``cam_tag < 0``) contributing nothing. This is the
"broadcast the event to all nodes of the core; every matching CAM word fires
its pulse generator" operation, summed over one timestep's worth of events
(``activity[c, k]`` = number/weight of events with tag ``k`` delivered to
cluster ``c``). Batch-native: ``activity`` may carry leading batch dims,
resolved against the same (batch-shared) CAM tables.

The implementation IS ``core.two_stage.stage2_cam_match`` — one algorithm,
re-exported here so kernel tests name their oracle without caring where the
production jnp path lives (and so the two can never drift apart).
"""

from __future__ import annotations

import jax

from repro.core.two_stage import N_SYN_TYPES, stage2_cam_match  # noqa: F401


def cam_match_ref(
    activity: jax.Array,  # [..., n_clusters, K] float
    cam_tag: jax.Array,  # [N, S] int32, -1 empty
    cam_syn: jax.Array,  # [N, S] int32 in [0, 4)
    cluster_size: int,
) -> jax.Array:  # [..., N, 4] same dtype as activity
    return stage2_cam_match(activity, cam_tag, cam_syn, cluster_size)
