"""Pure-jnp oracle for the stage-2 CAM match (no Pallas).

Semantics (paper §III-B / §IV-B): for every neuron ``n`` in cluster ``c`` and
every CAM word ``s``:

    drive[n, t] = sum_s  activity[c, cam_tag[n, s]] * [cam_syn[n, s] == t]

with empty CAM words (``cam_tag < 0``) contributing nothing. This is the
"broadcast the event to all nodes of the core; every matching CAM word fires
its pulse generator" operation, summed over one timestep's worth of events
(``activity[c, k]`` = number/weight of events with tag ``k`` delivered to
cluster ``c``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

N_SYN_TYPES = 4


def cam_match_ref(
    activity: jax.Array,  # [n_clusters, K] float
    cam_tag: jax.Array,  # [N, S] int32, -1 empty
    cam_syn: jax.Array,  # [N, S] int32 in [0, 4)
    cluster_size: int,
) -> jax.Array:  # [N, 4] same dtype as activity
    n, s = cam_tag.shape
    n_clusters, k = activity.shape
    assert n == n_clusters * cluster_size
    tags = cam_tag.reshape(n_clusters, cluster_size, s)
    valid = tags >= 0
    rows = activity[:, None, :]  # [n_clusters, 1, K]
    vals = jnp.take_along_axis(
        jnp.broadcast_to(rows, (n_clusters, cluster_size, k)),
        jnp.clip(tags, 0, k - 1),
        axis=2,
    )
    vals = jnp.where(valid, vals, jnp.zeros((), activity.dtype))
    syn = cam_syn.reshape(n_clusters, cluster_size, s)
    onehot = jax.nn.one_hot(syn, N_SYN_TYPES, dtype=activity.dtype)
    return jnp.einsum("ncs,ncst->nct", vals, onehot).reshape(n, N_SYN_TYPES)
