"""Pallas TPU kernel for the stage-2 CAM match.

TPU-native rethink of the chip's CAM core (DESIGN.md §8): the hardware
performs a *parallel compare* of an incoming 10-bit tag against all 64 CAM
words of all 256 neurons in the core simultaneously (pre-charged match
lines). The TPU analogue of "compare one word against everything at once" is
a one-hot compare matrix contracted on the MXU:

    match[c, s, k] = (cam_tag[c, s] == k)            # the CAM compare plane
    vals[c, s]     = sum_k match[c, s, k] * A[k]     # match-line AND activity
    drive[c, t]    = sum_s vals[c, s] * (cam_syn[c, s] == t)

The kernel is batch-native: the grid is ``(B, cluster, neuron-tile)``. One
(batch, cluster) pair's activity row is pinned in VMEM per grid step (the
"broadcast within the core"), while neurons tile within the cluster so the
compare plane (block_c * S * K floats) stays within VMEM. The CAM tables are
shared across the batch — the same neuron tile is revisited for every batch
element with only the [1, K] activity row changing, so B tiles the MXU
without growing the VMEM-resident CAM state. All events of a timestep that
target one core are therefore resolved against VMEM-resident state, exactly
the paper's "CAM cells of different cores operate in parallel" argument.

Block shapes: K and S should be multiples of 128 on real hardware for MXU
alignment; interpret mode (CPU validation) accepts any shape.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_SYN_TYPES = 4


def _cam_match_kernel(activity_ref, tag_ref, syn_ref, out_ref, *, k_tags: int):
    # activity_ref: [1, 1, K]     — this (batch, cluster)'s broadcast activity
    # tag_ref:      [1, Cb, S]    — CAM tags of the neuron tile (batch-shared)
    # syn_ref:      [1, Cb, S]    — synapse types of the neuron tile
    # out_ref:      [1, 1, Cb, 4] — per-type synaptic drive
    a = activity_ref[0, 0, :]  # [K]
    tags = tag_ref[0]  # [Cb, S] int32
    syn = syn_ref[0]  # [Cb, S] int32
    cb, s = tags.shape

    valid = tags >= 0
    # CAM compare plane: [Cb, S, K] one-hot (the parallel match-line search).
    kk = jax.lax.broadcasted_iota(jnp.int32, (cb, s, k_tags), 2)
    match = (tags[:, :, None] == kk).astype(a.dtype)
    # match-line x activity: contract K on the MXU.
    vals = jax.lax.dot_general(
        match.reshape(cb * s, k_tags),
        a.reshape(k_tags, 1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(cb, s)
    vals = jnp.where(valid, vals, 0.0)
    # accumulate into the 4 synapse-type lines (pulse-decoder DECs).
    tt = jax.lax.broadcasted_iota(jnp.int32, (cb, s, N_SYN_TYPES), 2)
    syn1h = (syn[:, :, None] == tt).astype(vals.dtype)
    drive = jax.lax.dot_general(
        vals.reshape(cb, 1, s),
        syn1h,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).reshape(cb, N_SYN_TYPES)
    out_ref[0, 0] = drive.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("cluster_size", "block_c", "interpret"))
def cam_match_pallas(
    activity: jax.Array,  # [..., n_clusters, K]
    cam_tag: jax.Array,  # [N, S]
    cam_syn: jax.Array,  # [N, S]
    cluster_size: int,
    block_c: int = 16,
    interpret: bool = True,
) -> jax.Array:  # [..., N, N_SYN_TYPES]
    n, s = cam_tag.shape
    n_clusters, k = activity.shape[-2:]
    batch_shape = activity.shape[:-2]
    b = math.prod(batch_shape)
    assert n == n_clusters * cluster_size
    block_c = min(block_c, cluster_size)
    assert cluster_size % block_c == 0, (cluster_size, block_c)

    act3 = activity.reshape(b, n_clusters, k)
    tags3 = cam_tag.reshape(n_clusters, cluster_size, s)
    syn3 = cam_syn.reshape(n_clusters, cluster_size, s)
    grid = (b, n_clusters, cluster_size // block_c)

    out = pl.pallas_call(
        functools.partial(_cam_match_kernel, k_tags=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, k), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, block_c, s), lambda bi, i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, s), lambda bi, i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_c, N_SYN_TYPES), lambda bi, i, j: (bi, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (b, n_clusters, cluster_size, N_SYN_TYPES), activity.dtype
        ),
        interpret=interpret,
    )(act3, tags3, syn3)
    return out.reshape(*batch_shape, n, N_SYN_TYPES)
