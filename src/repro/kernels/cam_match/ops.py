"""Public jit'd wrapper for the CAM-match kernel.

Chooses kernel vs reference by platform: the Pallas kernel targets TPU; on
CPU we validate it in interpret mode (slow) and default to the jnp oracle
for actual compute unless ``force_kernel`` is set. Batch-native: accepts
``activity [..., n_clusters, K]`` and returns ``[..., N, 4]``.

Most callers should go through the dispatch-backend registry
(repro.core.dispatch) instead of calling this directly.
"""

from __future__ import annotations

import jax

from repro.kernels.cam_match.cam_match import cam_match_pallas
from repro.kernels.cam_match.ref import cam_match_ref


def cam_match(
    activity: jax.Array,
    cam_tag: jax.Array,
    cam_syn: jax.Array,
    cluster_size: int,
    force_kernel: bool = False,
    block_c: int = 16,
) -> jax.Array:
    platform = jax.default_backend()
    if platform == "tpu":
        return cam_match_pallas(
            activity, cam_tag, cam_syn, cluster_size, block_c=block_c, interpret=False
        )
    if force_kernel:  # CPU validation path (interpret mode)
        return cam_match_pallas(
            activity, cam_tag, cam_syn, cluster_size, block_c=block_c, interpret=True
        )
    return cam_match_ref(activity, cam_tag, cam_syn, cluster_size)
