"""Multi-host elastic serving: sharded session pools over device meshes.

This is the fleet layer over serve/aer.py (DESIGN.md §17) — the ROADMAP's
"road to millions of sessions" item. A :class:`ShardedSessionPool`
partitions serving capacity into ``n_shards`` shards; each shard is one
:class:`~repro.serve.aer.AerSessionPool` over its own
:class:`~repro.core.event_engine.ShardedEventEngine` — a
``(batch_devices, cluster_devices)`` device mesh driving the sharded
fabric-ring (or queued) step, with the compiled network's ``device_slabs``
placement mapping whole tiles onto the cluster axis. Cross-shard mesh
traffic inside a shard flows through the existing sharded link arbitration
(``make_sharded_step``); across shards, tenants are independent — the
fleet's cross-shard operations are control-plane moves (admission,
migration, recovery), never data-plane hops.

Four layers (the §17 ladder):

  1. **sharded pool** — fixed per-shard slot pools; one fleet ``step()``
     dispatches every shard's jitted step before collecting any, so the
     shards' device work overlaps under JAX async dispatch. Per-shard
     :class:`DeliveryStats` (already psum-reduced across each shard's mesh)
     are summed host-side into fleet metrics (:meth:`fleet_stats`).
  2. **admission control** — :meth:`submit` routes a session to the
     least-loaded shard by the compiler's traffic model
     (:func:`~repro.core.compiler.session_rate` of the session's model,
     summed over each shard's resident + queued sessions), with a bounded
     waiting queue per shard and a typed :class:`AdmissionError` when every
     queue is full — one hot shard cannot starve the fleet, and backpressure
     is explicit rather than an unbounded queue.
  3. **live migration** — :meth:`migrate` moves a mid-flight tenant between
     shards (different meshes included) via
     ``AerSessionPool.extract_session`` / ``inject_session``: neuron state,
     undelivered spikes and the phase-normalized time-wheel slab splice at
     the destination engine's cursor phase, bit-exact when the shards share
     tables and delay horizon. :meth:`drain_shard` empties a host for
     maintenance.
  4. **elastic restart** — :meth:`checkpoint` writes one atomic fleet tree
     (per-shard engine carries + session/queue meta); :meth:`restore`
     rebuilds a fleet onto a *different* shard count (lost shards' sessions
     redistribute into surviving free slots, bit-exact because sessions are
     pure in their own step counter), and :meth:`recover_shard` rolls a
     killed shard's sessions back to the latest checkpoint and splices them
     into the survivors while their current state keeps serving untouched.
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque

import jax
import numpy as np

from repro.core.cnn import CompiledCnn, poker_neuron_params
from repro.core.compiler import device_slab_placement, session_rate
from repro.core.dispatch import DeliveryStats
from repro.core.event_engine import ModelRegistry, ShardedEventEngine
from repro.core.tags import RoutingTables
from repro.serve.aer import (
    AerServeConfig,
    AerSessionPool,
    CheckpointMismatchError,
    DvsSession,
    SessionResult,
    session_from_meta,
)

__all__ = [
    "ShardConfig",
    "AdmissionError",
    "ShardedSessionPool",
    "build_poker_shard_engine",
    "retile_for_slabs",
]


class AdmissionError(RuntimeError):
    """The fleet cannot accept a session: every admissible shard's bounded
    waiting queue is full (or no shard is alive). Backpressure is the
    caller's to handle — retry later or scale out; the fleet never grows an
    unbounded queue."""


@dataclasses.dataclass(frozen=True)
class ShardConfig:
    """Fleet topology: how many shards, their meshes, and queue bounds.

    Per-shard slot count and decision policy live in the shard pools'
    :class:`~repro.serve.aer.AerServeConfig` (``pool_size`` is per shard —
    fleet capacity is ``n_shards * pool_size``). ``queue_depth`` bounds each
    shard's waiting queue; ``cluster_devices`` x ``batch_devices`` is one
    shard's device mesh (clusters over ``model``, batch slots over
    ``data``). When the process holds at least ``n_shards`` such meshes'
    worth of devices, shards get disjoint device sets (the multi-host
    layout); otherwise they share the first mesh's devices (oversubscribed —
    semantics identical, used by single-device tests).
    """

    n_shards: int = 2
    queue_depth: int = 8
    cluster_devices: int = 1
    batch_devices: int = 1
    backend: str = "reference"  # dispatch backend name, or "fabric"
    # fabric mode only: per-link drop / per-pair delivery attribution — the
    # shards' pools then grow TrafficProfiles and the fleet's admission
    # scoring upgrades to measured rates (DESIGN.md §18)
    per_link_stats: bool = False


def retile_for_slabs(cc: CompiledCnn, n_slabs: int, fabric=None, seed: int = 0):
    """``cc`` with its placement re-annealed under the ``n_slabs`` device-slab
    constraint (:func:`~repro.core.compiler.device_slab_placement`) —
    required before fabric-mode shards can split clusters over
    ``cluster_devices > 1`` (every tile's clusters must live on one device).
    """
    from repro.core.routing import Fabric

    fab = fabric or Fabric()
    placement, _ = device_slab_placement(cc.tables, fab, n_slabs, seed=seed)
    return dataclasses.replace(
        cc, tables=dataclasses.replace(cc.tables, tile_of_cluster=placement)
    )


def build_poker_shard_engine(
    tables,
    backend: str = "reference",
    *,
    cluster_devices: int = 1,
    batch_devices: int = 1,
    devices=None,
    donate_carry: bool = True,
    entry_slabs=None,
    per_link_stats: bool = False,
) -> ShardedEventEngine:
    """One serving shard's engine at the §V poker operating point.

    The multi-device sibling of :func:`~repro.serve.aer.build_poker_engine`:
    same neuron parameters and lossless AER queue capacity, but the step is
    a :class:`ShardedEventEngine` over a ``(batch_devices,
    cluster_devices)`` mesh. Fabric mode with ``cluster_devices > 1`` needs
    tables whose placement satisfies the device-slab invariant
    (:func:`retile_for_slabs`) — a violating placement raises at
    construction, not mid-serve.
    """
    params = poker_neuron_params()
    if not isinstance(tables, RoutingTables) and hasattr(tables, "tables"):
        tables = tables.tables
    mesh_kw = dict(
        devices=devices,
        cluster_devices=cluster_devices,
        batch_devices=batch_devices,
        donate_carry=donate_carry,
        queue_capacity=tables.n_neurons,
    )
    if backend == "fabric":
        from repro.core.routing import Fabric

        fabric_options = (
            {"per_link_stats": True} if per_link_stats else None
        )
        return ShardedEventEngine(
            tables,
            params,
            fabric=Fabric(),
            entry_slabs=entry_slabs,
            fabric_options=fabric_options,
            **mesh_kw,
        )
    if entry_slabs is not None:
        raise ValueError("entry_slabs only applies to the fabric backend")
    if per_link_stats:
        raise ValueError("per_link_stats only applies to the fabric backend")
    return ShardedEventEngine(tables, params, backend=backend, **mesh_kw)


class ShardedSessionPool:
    """A fleet of session-pool shards with admission, migration, recovery.

    ``cfg`` is the per-shard :class:`AerServeConfig` (``pool_size`` slots
    per shard); ``shards`` the :class:`ShardConfig` topology. Every shard
    serves the same resident model set — shards are interchangeable
    capacity, which is what makes migration and elastic restart free of
    geometry negotiation. ``engine_factory(shard_id, devices) -> engine``
    overrides shard engine construction (tests use it to build
    heterogeneous meshes); the default builds
    :func:`build_poker_shard_engine` on the shard's device set.
    """

    def __init__(
        self,
        cc: CompiledCnn,
        cfg: AerServeConfig,
        shards: ShardConfig,
        *,
        models: dict[str, CompiledCnn] | None = None,
        devices=None,
        engine_factory=None,
    ):
        if shards.n_shards <= 0:
            raise ValueError(f"n_shards must be positive, got {shards.n_shards}")
        if shards.queue_depth < 0:
            raise ValueError(
                f"queue_depth must be non-negative, got {shards.queue_depth}"
            )
        self.cfg = cfg
        self.shards = shards
        if (
            shards.backend == "fabric"
            and shards.cluster_devices > 1
            and engine_factory is None
        ):
            if models is not None and len(models) > 1:
                raise NotImplementedError(
                    "multi-model residency with cluster-sharded fabric shards "
                    "needs a caller-built engine_factory (the combined slabs "
                    "must be retiled jointly)"
                )
            cc = retile_for_slabs(cc, shards.cluster_devices)
        self.models: dict[str, CompiledCnn] = (
            dict(models) if models else {"default": cc}
        )
        self._shard_devices = self._assign_devices(devices)
        entry_slabs = None
        if len(self.models) == 1:
            eng_tables = next(iter(self.models.values())).tables
        else:
            # multi-model: one engine over the concatenated slabs (fabric
            # multi-model over cluster shards is rejected above); in fabric
            # mode the entry table is assembled slab-by-slab, mirroring
            # AerSessionPool._engine_for
            registry = ModelRegistry(
                {n: m.tables for n, m in self.models.items()}
            )
            eng_tables, _ = registry.combined()
            if shards.backend == "fabric":
                entry_slabs = [
                    (t.src_tag, t.src_dest)
                    for t in (registry.tables_of(n) for n in registry.names)
                ]
        self.pools: list[AerSessionPool | None] = []
        for i in range(shards.n_shards):
            if engine_factory is not None:
                engine = engine_factory(i, self._shard_devices[i])
            else:
                engine = build_poker_shard_engine(
                    eng_tables,
                    shards.backend,
                    cluster_devices=shards.cluster_devices,
                    batch_devices=shards.batch_devices,
                    devices=self._shard_devices[i],
                    entry_slabs=entry_slabs,
                    per_link_stats=shards.per_link_stats,
                )
            pool = AerSessionPool(cc, engine, cfg, models=self.models)
            if isinstance(engine, ShardedEventEngine):
                pool.carry = engine.place_carry(pool.carry)
            self.pools.append(pool)
        self.queues: list[deque[DvsSession]] = [
            deque() for _ in range(shards.n_shards)
        ]
        self.dead: set[int] = set()  # killed shards keep their index
        self.n_steps = 0
        # admission scoring: predicted per-session fabric traffic by model
        # (the compiler's traffic model — DESIGN.md §13 driving §17)
        self._rates = {
            name: session_rate(m.tables) for name, m in self.models.items()
        }
        # observed per-model rates (§18): shards built with per-link stats
        # feed their traffic profiles back here; once a model has enough
        # observed session-steps the measured delivered/session-step rate
        # replaces the static compiler prediction in admission scoring
        self.observed_min_session_steps = 8
        self._obs_delivered: dict[str, float] = {n: 0.0 for n in self.models}
        self._obs_session_steps: dict[str, int] = {n: 0 for n in self.models}

    def _assign_devices(self, devices) -> list[list]:
        per = self.shards.cluster_devices * self.shards.batch_devices
        avail = list(devices) if devices is not None else jax.devices()
        n = self.shards.n_shards
        if len(avail) >= n * per:
            return [avail[i * per : (i + 1) * per] for i in range(n)]
        if len(avail) >= per:
            return [avail[:per] for _ in range(n)]
        raise ValueError(
            f"fleet needs at least {per} devices per shard, have {len(avail)}"
        )

    # -- introspection -----------------------------------------------------
    def live_shards(self) -> list[int]:
        return [i for i in range(self.shards.n_shards) if i not in self.dead]

    @property
    def busy(self) -> bool:
        return any(
            self.queues[i] or self.pools[i].occupied for i in self.live_shards()
        )

    def occupancy(self) -> dict[int, tuple[int, int]]:
        """Per live shard: (occupied slots, queued sessions)."""
        return {
            i: (len(self.pools[i].occupied), len(self.queues[i]))
            for i in self.live_shards()
        }

    def fleet_stats(self) -> DeliveryStats | None:
        """Fleet-level delivery metrics for the most recent step.

        Each shard's stats are already psum-reduced across its own device
        mesh by the sharded step; the fleet total is their host-side sum
        (drops, link drops, delivered, hops, latency, energy — ``None``
        fields, e.g. outside fabric mode, stay ``None``).
        """
        per = [
            self.pools[i].last_stats
            for i in self.live_shards()
            if self.pools[i].last_stats is not None
        ]
        if not per:
            return None

        def tot(field):
            vals = [getattr(s, field) for s in per]
            if any(v is None for v in vals):
                return None
            return np.asarray([np.asarray(v).sum() for v in vals]).sum()

        return DeliveryStats(
            dropped=tot("dropped"),
            link_dropped=tot("link_dropped"),
            delivered=tot("delivered"),
            hops=tot("hops"),
            latency_s=tot("latency_s"),
            energy_j=tot("energy_j"),
        )

    def _rate_of(self, sess: DvsSession) -> float:
        """Admission cost of one session: observed rate when measured,
        else the static compiler prediction.

        The observed rate (delivered events per session-step, from the
        shards' traffic profiles) and the static :func:`session_rate`
        (expected events under uniform firing) are different units — both
        only ever rank sessions against each other inside one admission
        decision, and the ``observed_min_session_steps`` floor keeps the
        mixed-unit transition window short.
        """
        name = sess.model
        if name is None:
            if len(self.models) != 1:
                raise ValueError(
                    "session must name its model when several are resident "
                    f"(have {list(self.models)})"
                )
            name = next(iter(self.models))
        if name not in self._rates:
            raise KeyError(
                f"model {name!r} is not resident (have {list(self.models)})"
            )
        n = self._obs_session_steps.get(name, 0)
        if n >= self.observed_min_session_steps:
            return self._obs_delivered[name] / n
        return self._rates[name]

    def observed_rates(self) -> dict[str, float | None]:
        """Measured per-model delivered/session-step rates (``None`` below
        the ``observed_min_session_steps`` floor or without per-link stats)."""
        out: dict[str, float | None] = {}
        for name in self.models:
            n = self._obs_session_steps.get(name, 0)
            out[name] = (
                self._obs_delivered[name] / n
                if n >= self.observed_min_session_steps
                else None
            )
        return out

    def _observe_rates(self, live: list[int]) -> None:
        """Fold the shards' last-step traffic profiles into the per-model
        observed-rate accumulators (slab-sliced: slabs are disjoint and
        arbitration is per batch slot, so a slab's delivered counts belong
        entirely to its model's sessions)."""
        for i in live:
            pool = self.pools[i]
            prof = getattr(pool, "profile", None)
            if prof is None or prof.last is None:
                continue
            by_model: dict[str, int] = {}
            for s in pool.slots:
                if s is not None and s.model is not None:
                    by_model[s.model] = by_model.get(s.model, 0) + 1
            for name, count in by_model.items():
                slab = pool.slabs[name]
                sub = prof.last[
                    slab.cluster_lo : slab.cluster_hi,
                    slab.cluster_lo : slab.cluster_hi,
                ]
                self._obs_delivered[name] = (
                    self._obs_delivered.get(name, 0.0) + float(sub.sum())
                )
                self._obs_session_steps[name] = (
                    self._obs_session_steps.get(name, 0) + count
                )

    def _score(self, i: int) -> float:
        """Predicted traffic load of shard ``i``: summed per-session rates of
        its resident + queued sessions (the admission objective)."""
        pool = self.pools[i]
        live = [s for s in pool.slots if s is not None]
        return sum(self._rate_of(s) for s in live) + sum(
            self._rate_of(s) for s in self.queues[i]
        )

    # -- admission (DESIGN.md §17 layer 2) ---------------------------------
    def submit(self, session: DvsSession) -> int:
        """Route ``session`` to the least-loaded admissible shard.

        Scoring is the compiler traffic model: each shard's predicted event
        rate over resident + queued sessions; the session lands on the
        cheapest shard with a free slot, else the cheapest with queue room
        (admitted at the next step's backfill). Raises
        :class:`AdmissionError` when every live shard's bounded queue is
        full. Returns the chosen shard id.
        """
        rate = self._rate_of(session)  # validates the model name early
        del rate
        live = self.live_shards()
        if not live:
            raise AdmissionError("no live shards remain in the fleet")
        # a queued session bound for a free slot does not consume queue
        # room: queue_depth bounds only the overflow beyond free slots
        with_slot = [
            i
            for i in live
            if len(self.pools[i].free_slots) > len(self.queues[i])
        ]
        cands = with_slot or [
            i
            for i in live
            if len(self.queues[i])
            < len(self.pools[i].free_slots) + self.shards.queue_depth
        ]
        if not cands:
            raise AdmissionError(
                f"fleet at capacity: every live shard's waiting queue is at "
                f"queue_depth={self.shards.queue_depth}"
            )
        best = min(cands, key=lambda i: (self._score(i), i))
        self.queues[best].append(session)
        return best

    def _backfill(self) -> None:
        for i in self.live_shards():
            while self.pools[i].admit_next(self.queues[i]) is not None:
                pass

    # -- stepping (DESIGN.md §17 layer 1) ----------------------------------
    def step(self) -> None:
        """One fleet timestep: backfill, then step every live shard.

        All shards' engine steps are dispatched before any result is read
        back — JAX async dispatch then overlaps the shards' device work, so
        a fleet step costs max(shard step), not sum (the multi-host analogy
        at single-process scale).
        """
        self._backfill()
        live = self.live_shards()
        outs = [self.pools[i].begin_step() for i in live]
        for i, out in zip(live, outs):
            self.pools[i].finish_step(out)
        self._observe_rates(live)
        self.n_steps += 1

    def evict_finished(self) -> list[SessionResult]:
        results: list[SessionResult] = []
        for i in self.live_shards():
            fin = self.pools[i].finished_slots()
            if fin:
                results.extend(self.pools[i].evict_many(fin))
        return results

    def serve(self, sessions) -> list[SessionResult]:
        """Drain ``sessions`` through the fleet with continuous batching.

        Pending sessions submit as queue room frees (admission backpressure
        never surfaces to the caller here — the fleet-level pending list
        absorbs it); results return in completion order.
        """
        pending = deque(sessions)
        results: list[SessionResult] = []
        while pending or self.busy:
            while pending:
                try:
                    self.submit(pending[0])
                except AdmissionError:
                    break
                pending.popleft()
            self.step()
            results.extend(self.evict_finished())
        return results

    # -- live migration (DESIGN.md §17 layer 3) ----------------------------
    def locate(self, session_id: int) -> tuple[int, int]:
        """(shard, slot) of a resident session; raises ``KeyError`` if the
        session is not resident (queued sessions have no slot yet)."""
        for i in self.live_shards():
            for slot, s in enumerate(self.pools[i].slots):
                if s is not None and s.session_id == session_id:
                    return i, slot
        raise KeyError(f"session {session_id} is not resident in the fleet")

    def migrate(self, session_id: int, dst_shard: int) -> int:
        """Move a mid-flight session onto ``dst_shard``; returns its new slot.

        The cross-host transfer: the source shard serializes the slot
        (neuron state, undelivered previous-step spikes, phase-normalized
        time-wheel in-flight slab), the destination — possibly a different
        device mesh — splices it at its own engine's cursor phase
        (``extract_session`` / ``inject_session``). Bit-exact when the
        shards share tables and delay horizon, which fleet shards do by
        construction.
        """
        if dst_shard in self.dead or not 0 <= dst_shard < len(self.pools):
            raise ValueError(f"destination shard {dst_shard} is not live")
        src_shard, slot = self.locate(session_id)
        if src_shard == dst_shard:
            return slot
        sess, sc = self.pools[src_shard].extract_session(slot)
        dst_pool = self.pools[dst_shard]
        new_slot = dst_pool.inject_session(sess, sc)
        if isinstance(dst_pool.engine, ShardedEventEngine):
            dst_pool.carry = dst_pool.engine.place_carry(dst_pool.carry)
        return new_slot

    def drain_shard(self, shard_id: int) -> int:
        """Empty ``shard_id`` for maintenance: migrate every resident session
        to the least-loaded other shard with a free slot and re-route its
        queue. Returns the number of sessions moved; raises
        :class:`AdmissionError` (before moving anything) when the rest of
        the fleet lacks slots for them."""
        if shard_id in self.dead:
            raise ValueError(f"shard {shard_id} is already dead")
        pool = self.pools[shard_id]
        others = [i for i in self.live_shards() if i != shard_id]
        free_elsewhere = sum(len(self.pools[i].free_slots) for i in others)
        if len(pool.occupied) > free_elsewhere:
            raise AdmissionError(
                f"cannot drain shard {shard_id}: {len(pool.occupied)} resident "
                f"sessions but only {free_elsewhere} free slots elsewhere"
            )
        moved = 0
        for slot in list(pool.occupied):
            sess = pool.slots[slot]
            dst = min(
                (i for i in others if self.pools[i].free_slots),
                key=lambda i: (self._score(i), i),
            )
            self.migrate(sess.session_id, dst)
            moved += 1
        queued, self.queues[shard_id] = list(self.queues[shard_id]), deque()
        for sess in queued:
            self.submit(sess)
            moved += 1
        return moved

    def kill_shard(self, shard_id: int) -> None:
        """Simulate losing ``shard_id``'s host: its pool, carry and queue are
        gone. Sessions it held are recoverable only through
        :meth:`recover_shard` (from the last checkpoint)."""
        if shard_id in self.dead:
            raise ValueError(f"shard {shard_id} is already dead")
        self.dead.add(shard_id)
        self.pools[shard_id] = None
        self.queues[shard_id] = deque()

    # -- checkpoint / elastic restart (DESIGN.md §17 layer 4) --------------
    def _fleet_meta(self) -> dict:
        return {
            "n_shards": self.shards.n_shards,
            "n_steps": self.n_steps,
            "pool_size": self.cfg.pool_size,
            "queue_depth": self.shards.queue_depth,
            "dead": sorted(self.dead),
            "queues": [
                None
                if i in self.dead
                else [self.pools[i]._session_meta(s) for s in self.queues[i]]
                for i in range(self.shards.n_shards)
            ],
        }

    def snapshot_tree(self) -> dict:
        """One atomic fleet tree: per-shard pool snapshots + fleet meta."""
        blob = np.frombuffer(
            json.dumps(self._fleet_meta()).encode(), dtype=np.uint8
        ).copy()
        return {
            "fleet_meta": blob,
            "shards": {
                f"s{i}": self.pools[i].snapshot_tree()
                for i in self.live_shards()
            },
        }

    def checkpoint(self, ckptr, step: int | None = None, blocking: bool = False):
        """Write the whole fleet atomically (checkpoint/checkpointer.py).

        Dead shards are omitted (their state died with the host — the
        snapshot of record for their sessions is the previous checkpoint).
        ``step`` defaults to the fleet step counter.
        """
        ckptr.save(
            self.n_steps if step is None else step,
            self.snapshot_tree(),
            blocking=blocking,
        )

    @staticmethod
    def _restore_fleet_meta(ckptr, step: int) -> dict:
        tree = ckptr.restore(step, {"fleet_meta": np.zeros(0, np.uint8)})
        return json.loads(
            np.asarray(tree["fleet_meta"]).astype(np.uint8).tobytes().decode()
        )

    def _shard_like(self) -> dict:
        proto = self.pools[self.live_shards()[0]]
        carry = jax.tree.map(np.zeros_like, jax.device_get(proto.carry))
        return {"carry": carry, "session_meta": np.zeros(0, np.uint8)}

    def _redistribute_shard_tree(
        self, shard_tree: dict, queue_meta, source_factory=None
    ) -> int:
        """Splice one saved shard's sessions into the live fleet.

        Resident sessions need free slots (mid-flight state cannot wait in a
        queue); queued ones re-route through :meth:`submit`. Raises
        :class:`CheckpointMismatchError` — before any state lands — when the
        surviving fleet lacks capacity, the typed "reshard impossible" path.
        """
        meta = json.loads(
            np.asarray(shard_tree["session_meta"])
            .astype(np.uint8)
            .tobytes()
            .decode()
        )
        slots = [
            (i, sm) for i, sm in enumerate(meta["slots"]) if sm is not None
        ]
        free_total = sum(
            len(self.pools[i].free_slots) for i in self.live_shards()
        )
        queue_room = sum(
            self.shards.queue_depth - len(self.queues[i])
            for i in self.live_shards()
        )
        n_queued = len(queue_meta or [])
        if len(slots) > free_total or n_queued > queue_room:
            raise CheckpointMismatchError(
                f"cannot redistribute a lost shard's {len(slots)} resident + "
                f"{n_queued} queued sessions: the surviving fleet has "
                f"{free_total} free slots and {queue_room} queue slots"
            )
        moved = 0
        if slots:
            # one extraction for all of the shard's occupied slots; any live
            # engine serves — extraction is geometry, not placement
            any_pool = self.pools[self.live_shards()[0]]
            sc_all = any_pool.engine.extract_slots(
                shard_tree["carry"], [i for i, _ in slots]
            )
            for j, (_, sm) in enumerate(slots):
                sess = session_from_meta(
                    sm, self.models, source_factory=source_factory
                )
                row = type(sc_all)(
                    state=jax.tree.map(lambda x: x[j : j + 1], sc_all.state),
                    spikes=sc_all.spikes[j : j + 1],
                    inflight=None
                    if sc_all.inflight is None
                    else sc_all.inflight[j : j + 1],
                )
                dst = min(
                    (
                        i
                        for i in self.live_shards()
                        if self.pools[i].free_slots
                    ),
                    key=lambda i: (self._score(i), i),
                )
                dst_pool = self.pools[dst]
                dst_pool.inject_session(sess, row)
                if isinstance(dst_pool.engine, ShardedEventEngine):
                    dst_pool.carry = dst_pool.engine.place_carry(dst_pool.carry)
                moved += 1
        for sm in queue_meta or []:
            self.submit(
                session_from_meta(sm, self.models, source_factory=source_factory)
            )
            moved += 1
        return moved

    @classmethod
    def restore(
        cls,
        cc: CompiledCnn,
        cfg: AerServeConfig,
        shards: ShardConfig,
        ckptr,
        step: int | None = None,
        *,
        models: dict[str, CompiledCnn] | None = None,
        devices=None,
        engine_factory=None,
        source_factory=None,
    ) -> "ShardedSessionPool":
        """Rebuild a fleet from a checkpoint, elastically.

        ``shards.n_shards`` may differ from the saved fleet's: shards
        ``j < min(saved, new)`` restore in place bit-exactly (their whole
        carry lands back on shard ``j``'s mesh — mesh *shape* may differ
        too, the carry arrays are global values); saved shards beyond the
        new count redistribute their sessions into surviving free slots via
        the migration path. Sessions are pure in their own step counter, so
        a redistributed session's future decisions are bit-exact regardless
        of which shard (or slot) it lands in. Raises
        :class:`CheckpointMismatchError` when the new fleet cannot hold the
        snapshot's live sessions — the typed "reshard impossible" path.
        """
        if step is None:
            step = ckptr.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no complete checkpoint under {ckptr.dir}"
                )
        fleet = cls(
            cc,
            cfg,
            shards,
            models=models,
            devices=devices,
            engine_factory=engine_factory,
        )
        meta = cls._restore_fleet_meta(ckptr, step)
        if int(meta["pool_size"]) != cfg.pool_size:
            raise CheckpointMismatchError(
                f"fleet checkpoint was taken at pool_size={meta['pool_size']} "
                f"per shard, restoring at pool_size={cfg.pool_size}"
            )
        saved_live = [
            j
            for j in range(int(meta["n_shards"]))
            if j not in set(meta.get("dead", []))
        ]
        shard_like = fleet._shard_like()
        like = {
            "fleet_meta": np.zeros(0, np.uint8),
            "shards": {f"s{j}": shard_like for j in saved_live},
        }
        try:
            tree = ckptr.restore(step, like)
        except CheckpointMismatchError:
            raise
        except ValueError as e:
            raise CheckpointMismatchError(
                f"fleet checkpoint at step {step} does not fit the restoring "
                f"shards' carry: {e}"
            ) from e
        fleet.n_steps = int(meta["n_steps"])
        queues_meta = meta.get("queues") or [None] * int(meta["n_shards"])
        direct = [j for j in saved_live if j < shards.n_shards]
        lost = [j for j in saved_live if j >= shards.n_shards]
        for j in direct:
            pool = fleet.pools[j]
            pool.load_snapshot_tree(
                tree["shards"][f"s{j}"], source_factory=source_factory
            )
            if isinstance(pool.engine, ShardedEventEngine):
                pool.carry = pool.engine.place_carry(pool.carry)
            for sm in queues_meta[j] or []:
                fleet.queues[j].append(
                    session_from_meta(
                        sm, fleet.models, source_factory=source_factory
                    )
                )
        for j in lost:
            fleet._redistribute_shard_tree(
                tree["shards"][f"s{j}"],
                queues_meta[j],
                source_factory=source_factory,
            )
        return fleet

    def recover_shard(
        self, ckptr, shard_id: int, step: int | None = None, source_factory=None
    ) -> int:
        """Recover a killed shard's sessions onto the surviving shards.

        The live half of elastic restart: the fleet keeps serving on its
        survivors (their *current* state, untouched); the dead shard's
        sessions roll back to the latest checkpoint and splice into
        surviving free slots. Deterministic stream replay (sources pure in
        the session step counter) makes the recovered sessions' results
        bit-exact vs an undisturbed run — they just finish later. Returns
        the number of sessions recovered. Call :meth:`kill_shard` (or lose
        the host) first.
        """
        if shard_id not in self.dead:
            raise ValueError(
                f"shard {shard_id} is live — recover_shard is for lost shards"
            )
        if not self.live_shards():
            raise AdmissionError("no live shards remain to recover onto")
        if step is None:
            step = ckptr.latest_step()
            if step is None:
                raise FileNotFoundError(
                    f"no complete checkpoint under {ckptr.dir}"
                )
        meta = self._restore_fleet_meta(ckptr, step)
        if shard_id in set(meta.get("dead", [])) or shard_id >= int(
            meta["n_shards"]
        ):
            raise CheckpointMismatchError(
                f"checkpoint at step {step} holds no state for shard "
                f"{shard_id}"
            )
        like = {
            "fleet_meta": np.zeros(0, np.uint8),
            "shards": {f"s{shard_id}": self._shard_like()},
        }
        try:
            tree = ckptr.restore(step, like)
        except ValueError as e:
            raise CheckpointMismatchError(
                f"checkpoint at step {step} does not fit the fleet's shard "
                f"carry: {e}"
            ) from e
        queues_meta = meta.get("queues") or [None] * int(meta["n_shards"])
        return self._redistribute_shard_tree(
            tree["shards"][f"s{shard_id}"],
            queues_meta[shard_id],
            source_factory=source_factory,
        )
