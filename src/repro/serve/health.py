"""Serving health: watchdog, typed fault events, and the resilient drain loop.

Degraded-mode serving (DESIGN.md §15) layers three escalation stages over
the session pool, strictly from cheapest to most disruptive:

  1. **per-session retry** — a faulted tenant (input fault, or silent past
     the watchdog threshold) is evicted and re-enqueued through the normal
     admission queue with bounded exponential backoff *in engine steps*;
     the stream source is pure in its step counter, so a retry replays the
     session from scratch deterministically.
  2. **slot quarantine** — a slot whose successive tenants keep faulting is
     a lane-correlated symptom (e.g. a corrupted table row the blast-radius
     oracle maps to those neurons); the slot is withdrawn from admission so
     the pool keeps serving on the remaining lanes.
  3. **pool-level degraded mode** — a sustained fabric-wide link-drop rate
     above threshold means the topology itself is sick. The loop emits a
     ``pool-degraded`` event; the ``on_degraded`` callback may hand back a
     replacement pool (typically :func:`migrate_pool` onto an engine built
     around ``compiler.repair_placement``) and serving continues there,
     with surviving tenants' full fabric state spliced across.

The watchdog reads only what the pool already exposes per step
(:class:`~repro.core.dispatch.DeliveryStats` via ``pool.last_stats`` and
the per-session readout accumulators) — observing never perturbs the
tenants it watches.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serve.aer import AerSessionPool, DvsSession, SessionResult

__all__ = [
    "WatchdogConfig",
    "FaultEvent",
    "Watchdog",
    "FleetWatchdog",
    "serve_resilient",
    "migrate_pool",
    "ReplacementConfig",
    "ReplacementController",
]


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Thresholds for the per-step health scan (DESIGN.md §15)."""

    silence_steps: int = 12  # steps without output-spike progress -> faulted
    link_drop_threshold: float = 0.25  # windowed drop fraction -> degraded
    window: int = 8  # steps in the link-drop moving window
    max_retries: int = 2  # per-session re-admissions before giving up
    backoff_base: int = 4  # retry n waits base * 2**(n-1) engine steps
    quarantine_after: int = 2  # consecutive faulted tenants -> quarantine slot


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One typed watchdog observation.

    ``kind`` is one of ``"session-error"`` (the pool faulted a tenant on a
    malformed packet), ``"session-silent"`` (no readout progress for
    ``silence_steps``), ``"slot-quarantined"`` (escalation stage 2) and
    ``"pool-degraded"`` (stage 3). ``value`` carries the triggering
    measurement — silent steps, or the windowed link-drop fraction.
    """

    kind: str
    step: int  # pool.n_steps when observed
    slot: int | None = None
    session_id: int | None = None
    value: float | None = None
    message: str = ""


class Watchdog:
    """Per-step scan of a pool's health signals into :class:`FaultEvent` s."""

    def __init__(self, cfg: WatchdogConfig | None = None):
        self.cfg = cfg or WatchdogConfig()
        # (slot, session_id) -> (last counts sum, session step at last progress)
        self._progress: dict[tuple[int, int], tuple[float, int]] = {}
        self._silent_flagged: set[tuple[int, int]] = set()
        self._error_flagged: set[tuple[int, int]] = set()
        self._drop_window: deque[float] = deque(maxlen=self.cfg.window)
        self._degraded_flagged = False

    def link_drop_rate(self) -> float:
        """Current windowed fraction of fabric events lost on links."""
        if not self._drop_window:
            return 0.0
        return float(np.mean(self._drop_window))

    def observe(self, pool: AerSessionPool) -> list[FaultEvent]:
        """Scan ``pool`` after a step; emit newly-detected fault events.

        Each condition fires once per episode: a silent session is flagged
        once until it makes progress again, and ``pool-degraded`` re-arms
        only after the windowed drop rate falls below half the threshold
        (hysteresis, so a rate hovering at the threshold does not flap).
        """
        cfg = self.cfg
        events: list[FaultEvent] = []

        # -- per-session: input faults and readout silence ---------------
        live_keys = set()
        for slot, sess in enumerate(pool.slots):
            if sess is None:
                continue
            key = (slot, sess.session_id)
            live_keys.add(key)
            if sess.error is not None and key not in self._error_flagged:
                self._error_flagged.add(key)
                events.append(
                    FaultEvent(
                        kind="session-error",
                        step=pool.n_steps,
                        slot=slot,
                        session_id=sess.session_id,
                        message=sess.error,
                    )
                )
            total = float(sess.counts.sum()) if sess.counts is not None else 0.0
            last_total, last_step = self._progress.get(key, (-1.0, 0))
            if total > last_total:
                self._progress[key] = (total, sess.step)
                self._silent_flagged.discard(key)
            elif (
                sess.error is None
                and sess.step - last_step >= cfg.silence_steps
                and key not in self._silent_flagged
            ):
                self._silent_flagged.add(key)
                events.append(
                    FaultEvent(
                        kind="session-silent",
                        step=pool.n_steps,
                        slot=slot,
                        session_id=sess.session_id,
                        value=float(sess.step - last_step),
                        message=(
                            f"no readout progress for {sess.step - last_step} "
                            f"steps (threshold {cfg.silence_steps})"
                        ),
                    )
                )
        # evicted tenants free their trackers so a slot's next occupant
        # starts with a clean progress history
        for key in set(self._progress) - live_keys:
            self._progress.pop(key, None)
            self._silent_flagged.discard(key)
            self._error_flagged.discard(key)

        # -- pool-level: windowed fabric link-drop rate -------------------
        stats = pool.last_stats
        if stats is not None and stats.link_dropped is not None:
            lost = float(np.asarray(stats.link_dropped).sum())
            sent = lost + (
                float(np.asarray(stats.delivered).sum())
                if stats.delivered is not None
                else 0.0
            )
            self._drop_window.append(lost / sent if sent > 0 else 0.0)
        rate = self.link_drop_rate()
        if (
            len(self._drop_window) == cfg.window
            and rate >= cfg.link_drop_threshold
            and not self._degraded_flagged
        ):
            self._degraded_flagged = True
            events.append(
                FaultEvent(
                    kind="pool-degraded",
                    step=pool.n_steps,
                    value=rate,
                    message=(
                        f"windowed link-drop rate {rate:.3f} >= "
                        f"{cfg.link_drop_threshold} over {cfg.window} steps"
                    ),
                )
            )
        elif rate < cfg.link_drop_threshold / 2:
            self._degraded_flagged = False
        return events


class FleetWatchdog:
    """Health scan over a :class:`~repro.serve.sharded.ShardedSessionPool`.

    One independent :class:`Watchdog` per shard — progress trackers and
    drop windows must not mix across shards, whose pools step different
    tenants on different meshes. :meth:`observe` scans every live shard and
    returns ``(shard_id, event)`` pairs; a shard that dies between steps
    simply drops out of the scan (its watchdog state is kept in case the
    shard index is later recovered onto a replacement pool).
    """

    def __init__(self, cfg: WatchdogConfig | None = None):
        self.cfg = cfg or WatchdogConfig()
        self._per_shard: dict[int, Watchdog] = {}

    def shard_watchdog(self, shard_id: int) -> Watchdog:
        if shard_id not in self._per_shard:
            self._per_shard[shard_id] = Watchdog(self.cfg)
        return self._per_shard[shard_id]

    def observe(self, fleet) -> list[tuple[int, FaultEvent]]:
        events: list[tuple[int, FaultEvent]] = []
        for i in fleet.live_shards():
            wd = self.shard_watchdog(i)
            events.extend((i, ev) for ev in wd.observe(fleet.pools[i]))
        return events

    def link_drop_rate(self) -> float:
        """Worst windowed link-drop rate across shards (the fleet's health
        is gated by its sickest shard, not the average)."""
        rates = [w.link_drop_rate() for w in self._per_shard.values()]
        return max(rates) if rates else 0.0


def _failed_result(sess: DvsSession, error: str) -> SessionResult:
    counts = (
        sess.counts
        if sess.counts is not None
        else np.zeros(1, dtype=np.float64)
    )
    return SessionResult(
        session_id=sess.session_id,
        label=sess.label,
        prediction=int(np.argmax(counts)),
        decided=False,
        latency_steps=sess.step,
        counts=np.asarray(counts, dtype=np.float64).copy(),
        dropped=sess.dropped,
        link_dropped=sess.link_dropped,
        error=error,
    )


def serve_resilient(
    pool: AerSessionPool,
    sessions,
    watchdog: Watchdog | None = None,
    on_degraded=None,
) -> tuple[list[SessionResult], list[FaultEvent]]:
    """Drain ``sessions`` through ``pool`` with the §15 escalation ladder.

    Like ``pool.serve`` but fault-aware: faulted tenants retry through the
    admission queue with exponential backoff (``backoff_base * 2**(n-1)``
    engine steps before attempt ``n``, bounded by ``max_retries`` — the
    intermediate failed results are discarded; the last failure's result is
    kept), slots whose tenants fault ``quarantine_after`` times in a row
    are withdrawn, and a ``pool-degraded`` event is offered to
    ``on_degraded(pool, event)`` which may return a replacement pool
    (serving transparently continues on it — see :func:`migrate_pool`).

    Returns ``(results, events)`` in completion order. When every slot ends
    up quarantined with work still queued, the remainder is failed
    explicitly rather than spinning forever.
    """
    wd = watchdog or Watchdog()
    cfg = wd.cfg
    pending: deque[DvsSession] = deque(sessions)
    waiting: list[tuple[int, DvsSession]] = []  # (admissible at n_steps, sess)
    attempts: dict[int, int] = {}
    slot_faults: dict[int, int] = {}
    results: list[SessionResult] = []
    events: list[FaultEvent] = []

    while pending or waiting or pool.occupied:
        # backoff expiry: move due retries into the admission queue
        due = [s for t, s in waiting if t <= pool.n_steps]
        if due:
            waiting = [(t, s) for t, s in waiting if t > pool.n_steps]
            pending.extend(due)
        while pending and pool.free_slots:
            pool.admit(pending.popleft())
        if not pool.occupied and (pending or waiting):
            if not pool.free_slots:
                # every lane quarantined: fail the remainder rather than spin
                for sess in list(pending) + [s for _, s in waiting]:
                    results.append(
                        _failed_result(
                            sess, "pool exhausted: all slots quarantined"
                        )
                    )
                break
            # nothing admissible yet (all retries still backing off): the
            # empty step below advances n_steps toward their due time

        pool.step()
        evs = wd.observe(pool)
        events.extend(evs)
        for ev in evs:
            if ev.kind == "pool-degraded" and on_degraded is not None:
                replacement = on_degraded(pool, ev)
                if replacement is not None:
                    pool = replacement
            elif ev.kind == "session-silent":
                sess = pool.slots[ev.slot] if ev.slot is not None else None
                if sess is not None and sess.session_id == ev.session_id:
                    sess.error = ev.message  # finishes at the next sweep

        finished = pool.finished_slots()
        if not finished:
            continue
        finished_sessions = [pool.slots[i] for i in finished]
        for slot, sess, res in zip(
            finished, finished_sessions, pool.evict_many(finished)
        ):
            if res.error is None:
                slot_faults[slot] = 0
                results.append(res)
                continue
            slot_faults[slot] = slot_faults.get(slot, 0) + 1
            n = attempts.get(sess.session_id, 0)
            if n < cfg.max_retries:
                attempts[sess.session_id] = n + 1
                waiting.append(
                    (pool.n_steps + cfg.backoff_base * 2**n, sess)
                )
            else:
                results.append(res)  # final failure: keep the error result
            if (
                slot_faults[slot] >= cfg.quarantine_after
                and pool.slots[slot] is None
                and slot not in pool.quarantined
            ):
                pool.quarantine_slot(slot)
                events.append(
                    FaultEvent(
                        kind="slot-quarantined",
                        step=pool.n_steps,
                        slot=slot,
                        value=float(slot_faults[slot]),
                        message=(
                            f"{slot_faults[slot]} consecutive faulted "
                            "tenants"
                        ),
                    )
                )
    return results, events


def migrate_pool(
    pool: AerSessionPool, new_engine, cfg=None
) -> AerSessionPool:
    """Move a pool's live sessions onto ``new_engine`` mid-flight.

    The degraded-mode recovery step: build a fresh pool on the repaired
    engine (typically compiled with the placement from
    ``compiler.repair_placement``), then carry every surviving tenant's
    complete runtime state across — neuron state, previous-step spikes and
    phase-normalized in-flight fabric events via
    ``EventEngine.extract_slots`` / ``splice_slots``, plus the session's
    readout accumulators untouched (``admit_restored``). Bit-exact when the
    two engines share geometry and ``max_delay``; best-effort re-bucketing
    otherwise (DESIGN.md §15). Quarantined-slot state is deliberately NOT
    copied: the new engine's lanes start with a clean record. Multi-model
    pools keep their full resident set — the mechanics live in
    :meth:`AerSessionPool.clone_onto` (DESIGN.md §16).
    """
    return pool.clone_onto(new_engine, cfg)


# ---------------------------------------------------------------------------
# Profile-guided live re-placement (DESIGN.md §18)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplacementConfig:
    """Thresholds and hysteresis for profile-guided re-placement.

    ``drift_threshold`` is a total-variation distance between the observed
    (cluster, cluster) traffic matrix and the compile-time assumption, in
    ``[0, 1]`` — 0.25 means a quarter of the probability mass moved to
    different source->destination pairs than the placement was optimized
    for. ``min_steps`` gates how much observation must accumulate before a
    judgement (a two-step window is all noise); ``cooldown_steps`` spaces
    consecutive recompiles so a workload oscillating around the threshold
    cannot thrash the placement (the observation window also restarts at
    every swap, so the cooldown compounds with ``min_steps``).
    """

    drift_threshold: float = 0.25  # TV distance observed vs assumed -> swap
    min_steps: int = 16  # observed pool steps before drift is judged
    cooldown_steps: int = 32  # pool steps between consecutive swaps
    anneal_steps: int | None = None  # optimize_placement budget (None = auto)
    seed: int = 0  # annealer seed (swap is deterministic given the profile)


class ReplacementController:
    """Closes the loop: observed traffic -> new placement -> live swap.

    Watches a pool's :class:`~repro.core.compiler.TrafficProfile` (the pool
    must be built with ``fabric_options={"per_link_stats": True}``) and,
    when the observed (cluster, cluster) delivery matrix drifts past
    ``drift_threshold`` from the uniform compile-time assumption, re-runs
    ``optimize_placement`` on the *measured* matrix and swaps the
    recompiled tables under the live sessions.

    The swap is the **bit-exact rung** of the §15/§16 ladder: the new
    placement is registered as a fresh model *version* (``name@r1``,
    ``name@r2``, ...) via :meth:`AerSessionPool.load_model`, constrained to
    tiles no resident model occupies. Mid-flight tenants keep serving on
    the old version — arbitration is per batch slot and a slot's spikes
    live entirely in its model's slab, so adding the new version's entries
    perturbs no in-flight numerics (the multi-model byte-equality tests of
    §16 are exactly this property). New admissions route to
    :attr:`current`; once the old version drains, :meth:`drain_retired`
    unloads it and frees its tiles. When no spare tiles exist the bit-exact
    rung is infeasible and the controller raises — the caller can fall back
    to :func:`migrate_pool` onto a re-placed engine (best-effort rung,
    bit-exact only when geometry and ``max_delay`` agree).
    """

    def __init__(
        self,
        pool: AerSessionPool,
        model: str | None = None,
        cfg: ReplacementConfig | None = None,
    ):
        self.pool = pool
        self.cfg = cfg or ReplacementConfig()
        if pool.profile is None:
            raise ValueError(
                "pool has no traffic profile — build the engine with "
                'fabric_options={"per_link_stats": True}'
            )
        if model is None:
            if len(pool.models) != 1:
                raise ValueError(
                    f"multi-model pool: pass model= explicitly "
                    f"(have {list(pool.models)})"
                )
            model = next(iter(pool.models))
        elif model not in pool.models:
            raise ValueError(
                f"model {model!r} is not resident (have {list(pool.models)})"
            )
        self.base = model  # versions are named f"{base}@r{n}"
        self.current = model  # where new admissions should go
        self.version = 0
        self.retired: list[str] = []  # old versions awaiting drain
        self.history: list[dict] = []  # one record per swap
        self._last_swap_step = -(10**9)
        self._stamp_effective_placements()

    # -- placement bookkeeping -------------------------------------------

    def _fabric(self):
        return self.pool.engine.fabric_backend.fabric

    def _stamp_effective_placements(self) -> None:
        """Back-fill explicit ``tile_of_cluster`` on every resident model.

        ``concat_tables`` composes placements all-or-none, so the versioned
        swap needs every resident stamped. A model compiled without one is
        effectively on its slice of the combined engine's default
        hierarchical-linear placement — stamping that exact slice changes
        no routing (the recompiled combined placement is identical), it
        only makes the implicit explicit so a re-placed version can join.
        """
        from repro.core.routing import default_tile_of_cluster

        if all(
            m.tables.tile_of_cluster is not None
            for m in self.pool.models.values()
        ):
            return
        engine = self.pool.engine
        backend_tiles = engine.fabric_backend.tile_of_cluster
        if backend_tiles is None:
            backend_tiles = default_tile_of_cluster(
                engine.n_clusters, self._fabric()
            )
        backend_tiles = np.asarray(backend_tiles)
        for name, cc in self.pool.models.items():
            if cc.tables.tile_of_cluster is not None:
                continue
            slab = self.pool.slabs[name]
            tiles = backend_tiles[slab.cluster_lo : slab.cluster_hi].copy()
            self.pool.models[name] = dataclasses.replace(
                cc,
                tables=dataclasses.replace(cc.tables, tile_of_cluster=tiles),
            )

    def _occupied_tiles(self) -> np.ndarray:
        """Per-tile core occupancy over every resident model."""
        fabric = self._fabric()
        count = np.zeros(fabric.n_tiles, dtype=np.int64)
        for cc in self.pool.models.values():
            toc = cc.tables.tile_of_cluster
            if toc is not None:
                count += np.bincount(
                    np.asarray(toc), minlength=fabric.n_tiles
                )
        return count

    # -- observation ------------------------------------------------------

    def observed_matrix(self) -> np.ndarray:
        """Measured per-step (src, dst) cluster matrix for :attr:`current`,
        sliced to the model's slab of the combined profile."""
        prof = self.pool.profile
        slab = self.pool.slabs[self.current]
        m = prof.matrix()
        return m[
            slab.cluster_lo : slab.cluster_hi,
            slab.cluster_lo : slab.cluster_hi,
        ]

    def drift(self) -> float:
        """TV distance of the observed slab matrix from the compile-time
        uniform assumption, in ``[0, 1]`` (0.0 until traffic is observed)."""
        from repro.core.compiler import traffic_matrix

        prof = self.pool.profile
        if prof is None or prof.steps == 0:
            return 0.0
        obs = self.observed_matrix()
        so = float(obs.sum())
        if so <= 0.0:
            return 0.0
        assumed = traffic_matrix(self.pool.models[self.current].tables)
        sa = float(assumed.sum())
        if sa <= 0.0:
            return 0.0
        return 0.5 * float(np.abs(obs / so - assumed / sa).sum())

    # -- the swap ---------------------------------------------------------

    def maybe_replace(self, force: bool = False) -> dict | None:
        """Judge drift and, past threshold, perform the versioned swap.

        Returns a report dict (also appended to :attr:`history`) when a
        swap happened, else ``None``. ``force=True`` skips the drift and
        cooldown gates but still requires an observed matrix to optimize
        on — a watchdog ``pool-degraded`` event is the typical forcer.
        """
        from repro.core.compiler import optimize_placement, placement_cost
        from repro.core.routing import tile_hop_matrix

        cfg = self.cfg
        prof = self.pool.profile
        pool = self.pool
        if prof is None or prof.steps == 0:
            return None
        if not force:
            if prof.steps < cfg.min_steps:
                return None
            if pool.n_steps - self._last_swap_step < cfg.cooldown_steps:
                return None
        drift = self.drift()
        if not force and drift < cfg.drift_threshold:
            return None
        obs = self.observed_matrix()
        if float(obs.sum()) <= 0.0:
            return None

        fabric = self._fabric()
        cc = pool.models[self.current]
        nc = obs.shape[0]
        occupied = self._occupied_tiles()
        free = np.flatnonzero(occupied == 0)
        if free.size * fabric.cores_per_tile < nc:
            raise RuntimeError(
                f"bit-exact re-placement needs {nc} free cores on unoccupied "
                f"tiles but only {free.size} tiles "
                f"({free.size * fabric.cores_per_tile} cores) are free — "
                "drain retired versions first, or fall back to migrate_pool "
                "(best-effort rung)"
            )
        # seed: pack the free tiles in order, cores_per_tile clusters each
        init = free[np.arange(nc) // fabric.cores_per_tile]
        allowed = np.zeros(fabric.n_tiles, dtype=bool)
        allowed[free] = True
        placement, info = optimize_placement(
            obs,
            fabric,
            init=init,
            seed=cfg.seed,
            anneal_steps=cfg.anneal_steps,
            allowed_tiles=allowed,
        )
        # what the swap buys, measured on the same observed matrix
        h = tile_hop_matrix(fabric).astype(np.float64)
        old_toc = np.asarray(cc.tables.tile_of_cluster)
        cost_old = placement_cost(obs, h, old_toc)

        new_name = f"{self.base}@r{self.version + 1}"
        cc_new = dataclasses.replace(
            cc,
            tables=dataclasses.replace(cc.tables, tile_of_cluster=placement),
        )
        pool.load_model(new_name, cc_new)  # resets the observation window
        self.retired.append(self.current)
        self.current = new_name
        self.version += 1
        self._last_swap_step = pool.n_steps
        report = {
            "name": new_name,
            "step": pool.n_steps,
            "drift": drift,
            "placement": np.asarray(placement),
            "cost_observed_old": float(cost_old),
            "cost_observed_new": float(info["cost_final"]),
            "mean_hops_old": float(cost_old / obs.sum()),
            "mean_hops_new": float(info["mean_hops_final"]),
        }
        self.history.append(report)
        return report

    def retarget(self, sess: DvsSession) -> DvsSession:
        """Point a not-yet-admitted session at the newest version."""
        sess.model = self.current
        return sess

    def drain_retired(self) -> list[str]:
        """Unload retired versions with no live sessions; returns names."""
        pool = self.pool
        unloaded = []
        for name in list(self.retired):
            if any(s is not None and s.model == name for s in pool.slots):
                continue
            pool.unload_model(name)
            self.retired.remove(name)
            unloaded.append(name)
        return unloaded
