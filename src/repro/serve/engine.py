"""Batched serving engine: prefill + decode with ring-buffer KV caches.

Minimal production shape: a jitted prefill and a jitted single-token decode
step over a fixed batch slot layout; greedy or temperature sampling;
per-slot stop handling. Continuous batching at fleet scale would swap slots
between requests — the cache layout (batch-major ring buffers, positions
array) is already slot-addressable for that; `serve/aer.py` implements
exactly that slot-pool lifecycle for the event engine (DESIGN.md §12).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def generate(self, tokens, max_new: int, batch_extras: dict | None = None):
        """tokens: [B, S_prompt] int32 (right-aligned, no padding support in
        this minimal engine). Returns [B, max_new]."""
        b, s = tokens.shape
        if max_new <= 0:
            return jnp.zeros((b, 0), jnp.int32)
        if s + max_new > self.cfg.max_len:
            # decode positions past max_len would wrap the ring-buffer KV
            # cache and silently clobber the oldest entries
            raise ValueError(
                f"prompt ({s}) + max_new ({max_new}) exceeds max_len "
                f"({self.cfg.max_len}): decode would run off the KV cache"
            )
        caches = self.model.init_caches(b, self.cfg.max_len)
        logits, caches = self._prefill(self.params, tokens, caches, batch_extras)
        key = jax.random.PRNGKey(self.cfg.seed)
        cur = self._sample(logits[:, -1], key)
        out = [cur]
        # max_new - 1 decode steps: the last output token needs no forward pass
        for t in range(max_new - 1):
            pos = jnp.full((b, 1), s + t, jnp.int32)
            logits, caches = self._decode(self.params, cur[:, None], pos, caches)
            key = jax.random.fold_in(key, t)
            cur = self._sample(logits[:, 0], key)
            out.append(cur)
        return jnp.stack(out, axis=1)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.cfg.temperature).astype(jnp.int32)
